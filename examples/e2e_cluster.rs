//! **End-to-end validation driver** (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Boots the full sparklite stack — driver, central scheduler, executor
//! threads, binary task serialization — and pushes *real work* through
//! it: word-count jobs over a synthetic corpus plus matrix-multiply
//! jobs, under single-queue fork-join arrivals. Sweeps the task
//! granularity k and reports p50/p99 sojourn and throughput per point,
//! then compares the measured curve against the overhead-aware analytic
//! approximation evaluated through the AOT artifact engine (the paper's
//! headline Fig.-8 methodology, on real computation instead of
//! controlled busy-spins).
//!
//! Run: `cargo run --release --example e2e_cluster`

use tiny_tasks::config::{EmulatorConfig, ModelKind, OverheadConfig};
use tiny_tasks::emulator::{Cluster, JobOutcome, Payload};
use tiny_tasks::runtime::{BoundQuery, BoundsEngine};

/// Cheap deterministic hash → uniform f64 in (0, 1].
fn unit(job: u64, task: u32, salt: u64) -> f64 {
    let mut s = job
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((task as u64) << 17)
        .wrapping_add(salt) | 1;
    s ^= s >> 12;
    s ^= s << 25;
    s ^= s >> 27;
    ((s.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64 / (1u64 << 53) as f64)
        .max(1e-12)
}

/// Deterministic synthetic corpus: zipf-ish word frequencies.
fn corpus_shard(job: u64, task: u32, words: usize) -> String {
    const VOCAB: [&str; 24] = [
        "tiny", "tasks", "granularity", "overhead", "spark", "queue", "fork", "join",
        "split", "merge", "worker", "task", "job", "latency", "bound", "quantile",
        "stability", "scheduler", "executor", "driver", "serialize", "network", "batch",
        "stream",
    ];
    let mut state = job.wrapping_mul(0x9E37_79B9).wrapping_add(task as u64) | 1;
    let mut out = String::with_capacity(words * 7);
    for _ in 0..words {
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        let r = (state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 33) as usize;
        // Zipf-ish skew: quadratic map favours low indices.
        let idx = ((r % 576) * (r % 576)) / 13824 % VOCAB.len();
        out.push_str(VOCAB[idx]);
        out.push(' ');
    }
    out
}

fn main() -> anyhow::Result<()> {
    let l = 8usize;
    let jobs = 300usize;
    let warmup = 30usize;
    let lambda = 0.5; // jobs per emulated second
    let workload = 8.0; // E[L] ≈ 8 s emulated per job
    let eps = 0.01;
    // Tasks are real compute (word count / matmul) padded to an
    // exponentially distributed duration — I/O-bound map tasks. Word
    // volume per emulated second of task time:
    let words_rate = 5.0e3;
    // Per-k wall scale: cap the wall task rate (~2000/s) so the whole
    // cluster fits the testbed's core budget (DESIGN.md §2).
    let scale_for = |k: usize| (k as f64 * 2.5e-4).max(0.02);

    println!("=== tiny-tasks end-to-end driver ===");
    println!("sparklite: {l} executors, {jobs} jobs/point, SQ-FJ arrivals exp({lambda})");
    println!("workload: padded word-count shards + 64x64 matmuls, E[L] ≈ {workload} s\n");

    let engine = BoundsEngine::auto();
    println!("analytic engine: {:?}", engine.kind());

    let ks = [8usize, 24, 80, 240, 960];
    let mut measured: Vec<(usize, f64, f64, f64)> = Vec::new();

    for &k in &ks {
        let time_scale = scale_for(k);
        // Mean task duration: E[L]/k emulated seconds. Durations are
        // exponentially skewed (inverse-CDF on a per-task hash) — the
        // data-skew stragglers that motivate tiny tasks in real
        // map-reduce deployments. Word volume tracks duration so the
        // compute content is proportional to the shard "size".
        let mean_task_emu = workload / k as f64;
        let cfg = EmulatorConfig {
            executors: l,
            tasks_per_job: k,
            mode: ModelKind::ForkJoinSingleQueue,
            interarrival: format!("exp:{lambda}"),
            execution: "det:1".into(), // unused by run_with
            time_scale,
            jobs,
            warmup,
            seed: 42,
            inject_overhead: Some(OverheadConfig::paper()),
            workers: None,
        };
        let mut res = Cluster::run_with(&cfg, move |job, task| {
            // Exp-distributed task duration (capped at 20x mean).
            let skew = (-unit(job, task, 7).ln()).min(20.0);
            let dur_emu = mean_task_emu * skew;
            let inner = if job % 5 == 4 && task % 7 == 3 {
                Payload::MatMul { n: 64, seed: job ^ task as u64 }
            } else {
                let words = ((dur_emu * words_rate) as usize).max(16);
                Payload::WordCount { text: corpus_shard(job, task, words), top: 10 }
            };
            Payload::Padded { inner: Box::new(inner), seconds: dur_emu * time_scale }
        })
        .map_err(anyhow::Error::msg)?;

        let p50 = res.sojourn_quantile(0.5);
        let p99 = res.sojourn_quantile(1.0 - eps);
        let thr = res.throughput();
        measured.push((k, p50, p99, thr));
        // Show a real merge result to prove real data flowed end-to-end.
        if let Some((_, JobOutcome::MergedCounts(counts))) = res
            .outcomes
            .iter()
            .find(|(_, o)| matches!(o, JobOutcome::MergedCounts(_)))
        {
            let top: Vec<String> =
                counts.iter().take(3).map(|(w, c)| format!("{w}:{c}")).collect();
            println!(
                "k={k:>4}: p50={p50:>7.2}s p99={p99:>7.2}s thr={thr:>5.3} jobs/s \
                 (top words: {}) [{:.1}s wall]",
                top.join(" "),
                res.wall_seconds
            );
        } else {
            println!("k={k:>4}: p50={p50:>7.2}s p99={p99:>7.2}s thr={thr:>5.3} jobs/s");
        }
    }

    // Analytic approximation with overhead for the same sweep. The real
    // workload is not exponential, so this is a shape comparison — the
    // paper's point is the U-shaped trade-off, not exact values.
    println!("\nanalytic approximation (Sec. 6, exp-task model, same E[L]):");
    let queries: Vec<BoundQuery> = ks
        .iter()
        .map(|&k| BoundQuery {
            k,
            l,
            lambda,
            mu: k as f64 / workload,
            epsilon: eps,
            overhead: Some(OverheadConfig::paper()),
        })
        .collect();
    let rows = engine.bounds(&queries)?;
    println!("{:>6} {:>14} {:>14}", "k", "measured p99", "approx tau_eps");
    let mut best_measured = (0usize, f64::INFINITY);
    let mut best_analytic = (0usize, f64::INFINITY);
    for ((k, _p50, p99, _), row) in measured.iter().zip(&rows) {
        let tau = row.fork_join.unwrap_or(f64::NAN);
        println!("{k:>6} {p99:>14.2} {tau:>14.2}");
        if *p99 < best_measured.1 {
            best_measured = (*k, *p99);
        }
        if tau.is_finite() && tau < best_analytic.1 {
            best_analytic = (*k, tau);
        }
    }
    println!(
        "\nbest measured k = {} | analytic recommendation k = {} — the \
         trade-off optimum (tinyfication helps, overhead caps it).",
        best_measured.0, best_analytic.0
    );
    Ok(())
}
