//! Quickstart: the tiny-tasks effect in 60 seconds.
//!
//! Simulates a 50-worker cluster at utilization 0.5 under both
//! split-merge and single-queue fork-join scheduling, sweeping the task
//! granularity k, and compares the simulated 0.99 sojourn quantiles with
//! the paper's analytic bounds (Lemma 1 / Theorem 2 via the AOT artifact
//! engine when available).
//!
//! Run: `cargo run --release --example quickstart`

use tiny_tasks::config::{ArrivalConfig, ModelKind, ServiceConfig, SimulationConfig};
use tiny_tasks::runtime::{BoundQuery, BoundsEngine};
use tiny_tasks::sim::{self, RunOptions};

fn main() -> anyhow::Result<()> {
    let l = 50usize;
    let lambda = 0.5;
    let eps = 0.01;
    let ks = [50usize, 100, 200, 400, 800, 1600];
    let engine = BoundsEngine::auto();
    println!("bounds engine: {:?}\n", engine.kind());

    println!(
        "{:>6} {:>8} | {:>12} {:>12} | {:>12} {:>12}",
        "k", "kappa", "sim SM p99", "bound SM", "sim FJ p99", "bound FJ"
    );
    let queries: Vec<BoundQuery> = ks
        .iter()
        .map(|&k| BoundQuery {
            k,
            l,
            lambda,
            mu: k as f64 / l as f64,
            epsilon: eps,
            overhead: None,
        })
        .collect();
    let bound_rows = engine.bounds(&queries)?;

    for (i, &k) in ks.iter().enumerate() {
        let mu = k as f64 / l as f64;
        let simulate = |model: ModelKind| -> anyhow::Result<Option<f64>> {
            // Skip unstable split-merge points (κ too small at ρ = 0.5).
            if model == ModelKind::SplitMerge
                && tiny_tasks::analysis::stability::sm_tiny_tasks(l, k) < 0.5
            {
                return Ok(None);
            }
            let cfg = SimulationConfig {
                model,
                servers: l,
                tasks_per_job: k,
                arrival: ArrivalConfig { interarrival: format!("exp:{lambda}") },
                service: ServiceConfig { execution: format!("exp:{mu}") },
                jobs: 20_000,
                warmup: 2_000,
                seed: 7,
                overhead: None,
                workers: None,
                redundancy: None,
                faults: None,
                policy: None,
            };
            let mut res = sim::run(&cfg, RunOptions::default()).map_err(anyhow::Error::msg)?;
            Ok(Some(res.sojourn_quantile(1.0 - eps)))
        };
        let sm = simulate(ModelKind::SplitMerge)?;
        let fj = simulate(ModelKind::ForkJoinSingleQueue)?;
        let fmt = |x: Option<f64>| match x {
            Some(v) => format!("{v:12.2}"),
            None => format!("{:>12}", "unstable"),
        };
        println!(
            "{:>6} {:>8.1} | {} {} | {} {}",
            k,
            k as f64 / l as f64,
            fmt(sm),
            fmt(bound_rows[i].split_merge),
            fmt(fj),
            fmt(bound_rows[i].fork_join),
        );
    }
    println!(
        "\nTiny tasks stabilize split-merge and shrink fork-join tails; the\n\
         analytic bounds track the simulated quantiles (p99 estimates from\n\
         20k jobs carry ~10% noise near the split-merge stability edge)."
    );
    Ok(())
}
