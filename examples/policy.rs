//! Dispatch policies: opening the scheduling-policy axis.
//!
//! The paper's models dispatch FCFS to the earliest-free server. This
//! example sweeps task granularity k at constant mean job workload under
//! four disciplines — FCFS, size-interval task assignment (SITA) with a
//! boundary at the mean task size, two-class priority with a 2:1 server
//! partition, and round-robin work stealing — and prints how the sojourn
//! law responds. Priority runs also report per-class mean sojourns: the
//! weighted partition buys the favoured class its latency at the other
//! class's expense, at every granularity.
//!
//! Run: `cargo run --release --example policy`

use tiny_tasks::config::{
    ArrivalConfig, ModelKind, OverheadConfig, PolicyConfig, PolicyKind, ServiceConfig,
    SimulationConfig,
};
use tiny_tasks::sim::{self, RunOptions};

fn main() -> anyhow::Result<()> {
    let l = 10usize;
    let lambda = 0.4;
    let workload = l as f64; // E[L] = 10 s per job, utilization 0.4

    println!(
        "{:>6} {:>10} | {:>10} {:>10} | {:>10} {:>10}",
        "k", "policy", "mean", "p99", "class0", "class1"
    );
    for &k in &[20usize, 80, 320] {
        let mean_task = workload / k as f64;
        let policies: [(&str, Option<PolicyConfig>); 4] = [
            ("fcfs", None),
            (
                "sita",
                Some(PolicyConfig {
                    kind: PolicyKind::Sita,
                    sita_boundaries: vec![mean_task],
                    ..Default::default()
                }),
            ),
            (
                "priority",
                Some(PolicyConfig {
                    kind: PolicyKind::Priority,
                    classes: 2,
                    weights: vec![2.0, 1.0],
                    ..Default::default()
                }),
            ),
            (
                "worksteal",
                Some(PolicyConfig {
                    kind: PolicyKind::WorkSteal,
                    steal_threshold: mean_task,
                    ..Default::default()
                }),
            ),
        ];
        for (label, policy) in policies {
            let cfg = SimulationConfig {
                model: ModelKind::ForkJoinSingleQueue,
                servers: l,
                tasks_per_job: k,
                arrival: ArrivalConfig { interarrival: format!("exp:{lambda}") },
                service: ServiceConfig {
                    execution: format!("exp:{}", k as f64 / workload),
                },
                jobs: 8_000,
                warmup: 800,
                seed: 7,
                overhead: Some(OverheadConfig::paper()),
                workers: None,
                redundancy: None,
                faults: None,
                policy,
            };
            let mut res =
                sim::run(&cfg, RunOptions::default()).map_err(anyhow::Error::msg)?;
            let class = |c: usize| -> String {
                res.class_sojourn
                    .get(c)
                    .map(|s| format!("{:.2}", s.mean()))
                    .unwrap_or_else(|| "-".into())
            };
            println!(
                "{:>6} {:>10} | {:>10.2} {:>10.2} | {:>10} {:>10}",
                k,
                label,
                res.sojourn_summary.mean(),
                res.sojourn_quantile(0.99),
                class(0),
                class(1),
            );
        }
    }
    Ok(())
}
