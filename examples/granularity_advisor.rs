//! Granularity advisor — the paper's concluding application (Sec. 7):
//! use the overhead-aware analytic approximation to pick the number of
//! tasks per job for a concrete cluster.
//!
//! Run: `cargo run --release --example granularity_advisor -- [l] [lambda] [workload]`

use tiny_tasks::config::{ModelKind, OverheadConfig};
use tiny_tasks::coordinator::advisor;
use tiny_tasks::runtime::BoundsEngine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let l: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(50);
    let lambda: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let workload: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(l as f64);

    let engine = BoundsEngine::auto();
    println!("engine: {:?}", engine.kind());
    println!(
        "cluster: {l} workers, λ = {lambda}/s, E[workload] = {workload}s \
         (utilization {:.2})\n",
        lambda * workload / l as f64
    );

    for (name, model) in [
        ("single-queue fork-join", ModelKind::ForkJoinSingleQueue),
        ("split-merge", ModelKind::SplitMerge),
    ] {
        let rec = advisor::recommend(
            &engine,
            model,
            l,
            lambda,
            workload,
            0.01,
            OverheadConfig::paper(),
        )?;
        println!("== {name} ==");
        match rec.best {
            Some((k, tau)) => println!(
                "  recommended k = {k} (κ = {:.1}); predicted p99 sojourn {tau:.2}s",
                k as f64 / l as f64
            ),
            None => println!("  no stable k at this load"),
        }
        // Show the U-shape: first/best/last feasible points.
        let feasible: Vec<(usize, f64)> =
            rec.curve.iter().filter_map(|&(k, t)| t.map(|t| (k, t))).collect();
        if let (Some(first), Some(last)) = (feasible.first(), feasible.last()) {
            println!(
                "  curve: k={} -> {:.2}s ... k={} -> {:.2}s ({} feasible points)\n",
                first.0,
                first.1,
                last.0,
                last.1,
                feasible.len()
            );
        } else {
            println!();
        }
    }
    println!("The interior optimum is the tiny-tasks granularity trade-off.");
    Ok(())
}
