//! Fault injection: tiny tasks as a fault-tolerance mechanism.
//!
//! Sweeps task granularity k at constant mean job workload on a cluster
//! with Markov worker crashes (MTBF 50 s, MTTR 1 s) and a 2% per-attempt
//! task failure probability, and prints what each failure event costs.
//! The tiny-tasks argument extends beyond stragglers: a crash or failed
//! attempt wastes at most one task's worth of service, so the work lost
//! per failure shrinks as ~1/k while the total overhead bill (Sec. 2.6)
//! grows — the same trade-off, now with recovery in the balance.
//!
//! Run: `cargo run --release --example faults`

use tiny_tasks::config::{
    ArrivalConfig, FaultsConfig, ModelKind, OverheadConfig, ServiceConfig, SimulationConfig,
};
use tiny_tasks::sim::{self, RunOptions};

fn main() -> anyhow::Result<()> {
    let l = 10usize;
    let lambda = 0.4;
    let workload = l as f64; // E[L] = 10 s per job, utilization 0.4
    let eps = 0.01;
    let faults = FaultsConfig {
        mtbf: 50.0,
        mttr: 1.0,
        task_fail_p: 0.02,
        max_retries: 3,
        backoff_base: 0.01,
        ..FaultsConfig::default()
    };

    println!(
        "{:>6} | {:>12} {:>12} | {:>12} {:>12} {:>14}",
        "k", "p99 clean", "p99 faulty", "lost/job", "retries/job", "lost/failure"
    );
    for &k in &[10usize, 20, 40, 80, 160] {
        let base = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: l,
            tasks_per_job: k,
            arrival: ArrivalConfig { interarrival: format!("exp:{lambda}") },
            service: ServiceConfig { execution: format!("exp:{}", k as f64 / workload) },
            jobs: 8_000,
            warmup: 800,
            seed: 7,
            overhead: Some(OverheadConfig::paper()),
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let mut clean = sim::run(&base, RunOptions::default()).map_err(anyhow::Error::msg)?;
        let faulty_cfg = SimulationConfig { faults: Some(faults), ..base };
        let mut faulty =
            sim::run(&faulty_cfg, RunOptions::default()).map_err(anyhow::Error::msg)?;
        let lost = faulty.lost_summary.mean();
        let retries = faulty.retry_summary.mean();
        let per_failure = if retries > 0.0 { lost / retries } else { f64::NAN };
        println!(
            "{:>6} | {:>12.2} {:>12.2} | {:>12.3} {:>12.3} {:>14.4}",
            k,
            clean.sojourn_quantile(1.0 - eps),
            faulty.sojourn_quantile(1.0 - eps),
            lost,
            retries,
            per_failure,
        );
    }
    println!(
        "\nFiner granularity bounds the blast radius of a failure: the work\n\
         lost per failure event falls as ~1/k (one task, however small),\n\
         while crashes and retries only nudge the sojourn tail once tasks\n\
         are tiny. See `tiny-tasks figure faults` for the CSV pipeline and\n\
         configs/faults.toml for the config-file form of this scenario."
    );
    Ok(())
}
