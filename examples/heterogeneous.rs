//! Heterogeneous workers & redundant tasks: where does the tiny-tasks
//! sweet spot land when the cluster is skewed?
//!
//! Sweeps worker-speed skew σ × tasks-per-job k on a 10-worker
//! single-queue fork-join cluster at constant aggregate capacity and
//! paper overhead, then asks the simulated granularity advisor for the
//! best k at each skew, with and without r = 2 first-finish-wins
//! replication.
//!
//! Run: `cargo run --release --example heterogeneous`

use tiny_tasks::config::{
    ArrivalConfig, ModelKind, OverheadConfig, RedundancyConfig, ServiceConfig, SimulationConfig,
    WorkersConfig,
};
use tiny_tasks::coordinator::advisor;
use tiny_tasks::coordinator::figures::two_class_speeds;
use tiny_tasks::util::threadpool::ThreadPool;

fn main() -> anyhow::Result<()> {
    let l = 10usize;
    let lambda = 0.4;
    let epsilon = 0.05;
    let mean_workload = l as f64; // E[L] = 10 s, so ρ = λ·E[L]/l = 0.4
    let pool = ThreadPool::with_default_size();
    let ks = advisor::k_grid(l, 32.0);

    println!("l = {l}, lambda = {lambda}/s, E[workload] = {mean_workload} s, eps = {epsilon}");
    println!("speeds: fast half 1+sigma, slow half 1-sigma (capacity fixed)\n");
    println!(
        "{:>6} {:>4} {:>10} {:>12} {:>14}",
        "sigma", "r", "best k", "tau_eps(s)", "vs sigma=0"
    );

    let mut baseline: Option<f64> = None;
    for &skew in &[0.0, 0.25, 0.5, 0.75] {
        for replicas in [1usize, 2] {
            let base = SimulationConfig {
                model: ModelKind::ForkJoinSingleQueue,
                servers: l,
                tasks_per_job: l, // overridden per sweep point
                arrival: ArrivalConfig { interarrival: format!("exp:{lambda}") },
                service: ServiceConfig { execution: "exp:1.0".into() },
                jobs: 6_000,
                warmup: 600,
                seed: 42,
                overhead: Some(OverheadConfig::paper()),
                workers: if skew > 0.0 {
                    Some(WorkersConfig::Speeds(two_class_speeds(l, skew)))
                } else {
                    None
                },
                redundancy: if replicas > 1 {
                    Some(RedundancyConfig::new(replicas))
                } else {
                    None
                },
                faults: None,
                policy: None,
            };
            let rec = advisor::recommend_simulated(&pool, &base, mean_workload, epsilon, &ks)
                .map_err(anyhow::Error::msg)?;
            match rec.best {
                Some((k, tau)) => {
                    if skew == 0.0 && replicas == 1 {
                        baseline = Some(tau);
                    }
                    let vs = baseline
                        .map(|b| format!("{:+.1}%", (tau / b - 1.0) * 100.0))
                        .unwrap_or_else(|| "-".into());
                    println!("{skew:>6.2} {replicas:>4} {k:>10} {tau:>12.3} {vs:>14}");
                }
                None => println!("{skew:>6.2} {replicas:>4} {:>10} {:>12}", "-", "unstable"),
            }
        }
    }
    println!("\n(Columns: skew, replicas, advisor's k, simulated eps-quantile, vs homogeneous.)");
    Ok(())
}
