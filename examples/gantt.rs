//! Executor activity traces (Figs. 1–2): render an ASCII Gantt strip of a
//! split-merge run at coarse vs. fine task granularity and write the
//! full traces as CSV.
//!
//! Run: `cargo run --release --example gantt`

use tiny_tasks::config::{ArrivalConfig, ModelKind, ServiceConfig, SimulationConfig};
use tiny_tasks::sim::{self, RunOptions};

fn main() -> anyhow::Result<()> {
    for (label, k) in [("COARSE (k=400, Fig. 1)", 400usize), ("FINE (k=1500, Fig. 2)", 1500)] {
        let cfg = SimulationConfig {
            model: ModelKind::SplitMerge,
            servers: 50,
            tasks_per_job: k,
            arrival: ArrivalConfig { interarrival: "det:0.001".into() },
            service: ServiceConfig { execution: format!("exp:{}", k as f64 / 50.0) },
            jobs: 4,
            warmup: 0,
            seed: 3,
            overhead: Some(tiny_tasks::config::OverheadConfig::paper()),
            workers: None,
            redundancy: None,
        };
        let res = sim::run(
            &cfg,
            RunOptions { trace: true, record_jobs: true, ..Default::default() },
        )
        .map_err(anyhow::Error::msg)?;

        println!("\n=== {label} ===");
        // ASCII strip: 12 executors x 100 columns over the first 5 s;
        // digit = job index running, '.' = idle.
        let horizon = 5.0;
        let cols = 100usize;
        for server in 0..12u32 {
            let mut row = vec!['.'; cols];
            for ev in res.trace.events().iter().filter(|e| e.server == server) {
                let c0 = ((ev.start / horizon) * cols as f64) as usize;
                let c1 = ((ev.end / horizon) * cols as f64).ceil() as usize;
                for cell in row.iter_mut().take(c1.min(cols)).skip(c0.min(cols)) {
                    *cell = char::from_digit(ev.job % 10, 10).unwrap_or('#');
                }
            }
            println!("exec {server:>2} |{}|", row.iter().collect::<String>());
        }
        let util = res.trace.utilization(50, 0.0, horizon);
        println!(
            "mean utilization over first {horizon}s: {:.1}% | 4th job departs at {:.2}s",
            100.0 * util.iter().sum::<f64>() / util.len() as f64,
            res.jobs.last().unwrap().departure
        );
        let path = format!("reports/gantt_k{k}.csv");
        res.trace.to_csv().write_file(&path)?;
        println!("full trace -> {path}");
    }
    println!(
        "\nFiner granularity fills the merge-barrier idle gaps — the visual\n\
         motivation for tiny tasks (paper Figs. 1 vs 2)."
    );
    Ok(())
}
