//! Executor activity traces (Figs. 1–2): render an ASCII Gantt strip of a
//! split-merge run at coarse vs. fine task granularity — from a *saved
//! trace file*, not only an in-memory run.
//!
//! Run: `cargo run --release --example gantt`
//!   — records both runs to `reports/gantt_k{400,1500}.trace.ndjson`,
//!     reloads them, and renders from the reloaded traces.
//!
//! Run: `cargo run --release --example gantt -- path/to/trace.ndjson`
//!   — renders any previously recorded trace file (e.g. one written by
//!     `tiny-tasks trace record`), no simulation at all.

use tiny_tasks::config::{ArrivalConfig, ModelKind, ServiceConfig, SimulationConfig};
use tiny_tasks::sim::{self, RunOptions};
use tiny_tasks::trace::Trace;

/// ASCII strip + utilization line, straight off a trace's task rows.
fn render(label: &str, trace: &Trace) {
    println!("\n=== {label} ===");
    // 12 executors x 100 columns over the first 5 s; digit = job index
    // running, '.' = idle.
    let horizon = 5.0;
    let cols = 100usize;
    let servers = trace.meta.servers.min(12);
    for server in 0..servers {
        let mut row = vec!['.'; cols];
        for ev in trace.tasks.iter().filter(|t| t.server == server) {
            let c0 = ((ev.start / horizon) * cols as f64) as usize;
            let c1 = ((ev.end / horizon) * cols as f64).ceil() as usize;
            for cell in row.iter_mut().take(c1.min(cols)).skip(c0.min(cols)) {
                *cell = char::from_digit(ev.job % 10, 10).unwrap_or('#');
            }
        }
        println!("exec {server:>2} |{}|", row.iter().collect::<String>());
    }
    // Busy fraction per executor over [0, horizon].
    let util = trace.utilization(0.0, horizon);
    let mean_util = util.iter().sum::<f64>() / util.len() as f64;
    let last_departure = trace
        .jobs
        .iter()
        .map(|j| j.departure)
        .fold(f64::NAN, f64::max);
    println!(
        "mean utilization over first {horizon}s: {:.1}% | last job departs at {last_departure:.2}s",
        100.0 * mean_util
    );
}

fn main() -> anyhow::Result<()> {
    // A trace file argument skips simulation entirely: load and render.
    if let Some(path) = std::env::args().nth(1) {
        let trace = Trace::read_file(&path).map_err(anyhow::Error::msg)?;
        render(&path, &trace);
        return Ok(());
    }

    for (label, k) in [("COARSE (k=400, Fig. 1)", 400usize), ("FINE (k=1500, Fig. 2)", 1500)] {
        let cfg = SimulationConfig {
            model: ModelKind::SplitMerge,
            servers: 50,
            tasks_per_job: k,
            arrival: ArrivalConfig { interarrival: "det:0.001".into() },
            service: ServiceConfig { execution: format!("exp:{}", k as f64 / 50.0) },
            jobs: 4,
            warmup: 0,
            seed: 3,
            overhead: Some(tiny_tasks::config::OverheadConfig::paper()),
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let res = sim::run(
            &cfg,
            RunOptions { trace: true, record_jobs: true, ..Default::default() },
        )
        .map_err(anyhow::Error::msg)?;

        // Persist, reload, and render from the *reloaded* trace — the
        // same path `tiny-tasks trace record` + this example's file-arg
        // mode exercise.
        let path = format!("reports/gantt_k{k}.trace.ndjson");
        let trace = Trace::from_sim(&res).map_err(anyhow::Error::msg)?;
        trace.write_file(&path, None).map_err(anyhow::Error::msg)?;
        let reloaded = Trace::read_file(&path).map_err(anyhow::Error::msg)?;
        render(label, &reloaded);
        println!("saved trace -> {path} (render it again: cargo run --example gantt -- {path})");
        // The legacy CSV export stays available for spreadsheet users.
        res.trace.to_csv().write_file(format!("reports/gantt_k{k}.csv"))?;
    }
    println!(
        "\nFiner granularity fills the merge-barrier idle gaps — the visual\n\
         motivation for tiny tasks (paper Figs. 1 vs 2)."
    );
    Ok(())
}
