//! Stability-region explorer: maps the maximum stable utilization of
//! split-merge across (l, κ), with and without overhead — an interactive
//! tour of Eq. 20, Eq. 23, and the Fig. 11/12(a) shapes.
//!
//! Run: `cargo run --release --example stability_explorer`

use tiny_tasks::analysis::stability::sm_tiny_tasks;
use tiny_tasks::config::OverheadConfig;
use tiny_tasks::dist::{Distribution, Exponential};
use tiny_tasks::runtime::{BoundsEngine, ErlangQuery};
use tiny_tasks::sim::stability::sm_max_utilization;
use tiny_tasks::sim::OverheadModel;

fn main() -> anyhow::Result<()> {
    println!("Maximum stable utilization ρ* of split-merge (Eq. 20)\n");
    let kappas = [1usize, 2, 4, 8, 20, 50, 200];
    let ls = [2usize, 5, 10, 20, 50, 100, 500];
    print!("{:>6}", "l\\κ");
    for &k in &kappas {
        print!("{k:>8}");
    }
    println!();
    for &l in &ls {
        print!("{l:>6}");
        for &kappa in &kappas {
            print!("{:>8.3}", sm_tiny_tasks(l, kappa * l));
        }
        println!();
    }

    println!("\nDirect refinement at κ = 20, μ = 20 (Fig. 12a): tiny vs big tasks");
    let engine = BoundsEngine::auto();
    let ls2 = [2usize, 5, 10, 20, 50];
    let big = engine.erlang(
        &ls2.iter()
            .map(|&l| ErlangQuery { l, kappa: 20, lambda: 0.5, mu: 20.0, epsilon: 1e-6 })
            .collect::<Vec<_>>(),
    )?;
    println!("{:>6} {:>12} {:>12}", "l", "tiny (Eq.20)", "big (Eq.23)");
    for (i, &l) in ls2.iter().enumerate() {
        println!(
            "{l:>6} {:>12.4} {:>12.4}",
            sm_tiny_tasks(l, 20 * l),
            big[i].max_utilization
        );
    }

    println!("\nOverhead effect at l = 50 (Fig. 11 ridge): Monte-Carlo E[Δ]");
    println!("{:>8} {:>14} {:>14}", "k", "no overhead", "paper overhead");
    for k in [200usize, 1000, 2000, 4000, 8000] {
        let mu = k as f64 / 50.0;
        let exec = Exponential::new(mu);
        let _ = exec.mean();
        let clean = sm_max_utilization(50, k, &exec, &OverheadModel::none(), 8000, 1);
        let dirty = sm_max_utilization(
            50,
            k,
            &exec,
            &OverheadModel::new(OverheadConfig::paper()),
            8000,
            1,
        );
        println!("{k:>8} {clean:>14.4} {dirty:>14.4}");
    }
    println!(
        "\nρ* climbs toward 1 with κ — until overhead turns it back down\n\
         (the Fig. 11 peak near k ≈ 2000 for l = 50)."
    );
    Ok(())
}
