//! # tiny-tasks
//!
//! Reproduction of *"The Tiny-Tasks Granularity Trade-Off: Balancing
//! overhead vs. performance in parallel systems"* (Bora, Walker, Fidler,
//! 2022) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate provides:
//!
//! * [`sim`] — an event-driven simulator for split-merge, single-queue
//!   fork-join, per-server fork-join and ideal-partition parallel systems
//!   with tiny tasks and the paper's four-parameter overhead model
//!   (a reproduction of the *forkulator* simulator used in the paper).
//! * [`emulator`] — **sparklite**, a thread-based mini map-reduce engine
//!   (driver, central scheduler, executors, task serialization) standing in
//!   for the paper's Apache Spark cluster, instrumented with the Fig.-7
//!   overhead taxonomy.
//! * [`analysis`] — the paper's stochastic network-calculus results in pure
//!   Rust: (σ,ρ)-envelopes, Theorem 1, Lemma 1, Theorem 2, stability
//!   regions, and the Sec.-6 overhead-augmented approximations.
//! * [`approx`] — analytic approximations beyond the paper's homogeneous
//!   setting: heterogeneous worker speeds via non-i.i.d. rate envelopes,
//!   first-finish-wins redundancy via replica groups, and the
//!   replica-launch extension of the Sec.-2.6 overhead model; degenerate
//!   scenarios delegate bit-for-bit to [`analysis`].
//! * [`runtime`] — a PJRT client that loads the AOT-compiled JAX/Pallas
//!   bound-evaluation artifacts (`artifacts/*.hlo.txt`) and executes them
//!   from the coordinator hot path (Python is never on the request path).
//! * [`coordinator`] — experiment harness: parameter sweeps, overhead
//!   calibration (Sec. 2.6 methodology), and one pipeline per paper figure.
//! * [`trace`] — persistent task traces: a versioned on-disk format
//!   (NDJSON + compact binary), capture from both engines, trace-driven
//!   replay, and empirical-distribution extraction.
//! * [`obs`] — engine-wide observability: always-on raw engine tallies,
//!   a lock-free-when-off metrics registry (counters, phase timers,
//!   fixed-bucket histograms), the `RUN_METRICS.json` report, and the
//!   `--progress` heartbeat — all with zero determinism cost (bitwise
//!   identical simulation output with metrics on vs. off).
//! * [`dist`], [`rng`], [`stats`], [`config`], [`cli`], [`util`] —
//!   supporting substrates (offline environment: no external crates beyond
//!   the vendored `xla`/`anyhow`/`log`; see DESIGN.md §2).

pub mod analysis;
pub mod approx;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod emulator;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod trace;
pub mod util;
