//! Sampling distributions for inter-arrival times, task execution times,
//! and worker speeds (Sec. 2.3's controlled experiments), parsed from
//! compact `"name:param:..."` spec strings.
//!
//! The offline registry has no `rand_distr`; samplers are hand-rolled
//! inverse-CDF transforms over a uniform source in `(0, 1]` (see
//! [`crate::rng::Rng::next_f64_open`]). The simulator shares one PCG64
//! stream between workload and overhead sampling, which is what makes
//! runs bit-reproducible.
//!
//! Two dispatch paths, one formula set:
//!
//! * [`Dist`] — a closed enum over the built-in laws with an `#[inline]`
//!   [`Dist::draw`] taking the concrete RNG. This is the simulator's hot
//!   path: the innermost task-sampling loop monomorphizes to straight
//!   arithmetic, no vtable call and no `&mut dyn FnMut` closure.
//! * [`Distribution`] — the open trait, kept for extension points
//!   (scripted test distributions, analytic helpers that only need a
//!   uniform source). `Dist::Custom` boxes a trait object, so nothing is
//!   lost by the enum being closed.
//!
//! Every variant's `draw` uses the *same* formula and draw count as its
//! trait `sample`, so enum and dyn dispatch are bit-for-bit identical on
//! the same RNG stream (`TT_NO_FAST_EXP=1` A/B-tests exactly this).

use crate::rng::{Pcg64, Rng};
use std::fmt::Debug;

/// A sampling distribution over non-negative reals.
///
/// `rng` must yield uniform values in `(0, 1]` (safe for `ln`).
pub trait Distribution: Send + Sync + Debug {
    /// Draw one sample.
    fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64;
    /// Distribution mean (possibly `f64::INFINITY`).
    fn mean(&self) -> f64;
    /// Distribution variance (possibly `f64::INFINITY`).
    fn variance(&self) -> f64;
    /// Human/machine-readable label, e.g. `"Exp(0.5)"`.
    fn label(&self) -> String;
}

/// Exponential with rate `mu` (mean `1/mu`).
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    rate: f64,
}

impl Exponential {
    /// New `Exp(rate)`.
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0 && rate.is_finite(), "exp rate must be positive");
        Self { rate }
    }

    /// The rate parameter.
    pub fn rate(&self) -> f64 {
        self.rate
    }
}

impl Distribution for Exponential {
    fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64 {
        // Must stay formula-identical to Dist::draw's Exp arm
        // (bit-for-bit reproducibility, TT_NO_FAST_EXP).
        -rng().ln() / self.rate
    }
    fn mean(&self) -> f64 {
        1.0 / self.rate
    }
    fn variance(&self) -> f64 {
        1.0 / (self.rate * self.rate)
    }
    fn label(&self) -> String {
        format!("Exp({})", self.rate)
    }
}

/// Point mass at `value` (consumes no randomness).
#[derive(Clone, Copy, Debug)]
pub struct Deterministic {
    value: f64,
}

impl Deterministic {
    /// New point mass.
    pub fn new(value: f64) -> Self {
        assert!(value >= 0.0 && value.is_finite(), "det value must be >= 0");
        Self { value }
    }
}

impl Distribution for Deterministic {
    fn sample(&self, _rng: &mut dyn FnMut() -> f64) -> f64 {
        self.value
    }
    fn mean(&self) -> f64 {
        self.value
    }
    fn variance(&self) -> f64 {
        0.0
    }
    fn label(&self) -> String {
        format!("Det({})", self.value)
    }
}

/// Erlang with integer shape `kappa` and stage rate `mu`
/// (sum of `kappa` iid `Exp(mu)` stages; mean `kappa/mu`).
#[derive(Clone, Copy, Debug)]
pub struct Erlang {
    kappa: u32,
    mu: f64,
}

impl Erlang {
    /// New `Erlang(kappa, mu)`.
    pub fn new(kappa: u32, mu: f64) -> Self {
        assert!(kappa >= 1, "erlang shape must be >= 1");
        assert!(mu > 0.0 && mu.is_finite(), "erlang rate must be positive");
        Self { kappa, mu }
    }

    /// CDF `F(x) = 1 − e^{−μx} Σ_{i=0}^{κ−1} (μx)^i / i!`.
    pub fn cdf(&self, x: f64) -> f64 {
        if x <= 0.0 {
            return 0.0;
        }
        let mx = self.mu * x;
        // Term recurrence keeps the partial sum stable for κ up to ~1e3.
        let mut term = 1.0f64;
        let mut sum = 1.0f64;
        for i in 1..self.kappa {
            term *= mx / i as f64;
            sum += term;
        }
        let ccdf = (-mx).exp() * sum;
        (1.0 - ccdf).clamp(0.0, 1.0)
    }
}

impl Distribution for Erlang {
    fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64 {
        // Sum of κ exponential stages (κ draws — dispatch order and draw
        // counts are part of the reproducibility contract).
        let mut total = 0.0;
        for _ in 0..self.kappa {
            total += -rng().ln() / self.mu;
        }
        total
    }
    fn mean(&self) -> f64 {
        self.kappa as f64 / self.mu
    }
    fn variance(&self) -> f64 {
        self.kappa as f64 / (self.mu * self.mu)
    }
    fn label(&self) -> String {
        format!("Erlang({},{})", self.kappa, self.mu)
    }
}

/// Pareto with tail index `alpha` and scale (minimum) `xm`.
#[derive(Clone, Copy, Debug)]
pub struct Pareto {
    alpha: f64,
    xm: f64,
}

impl Pareto {
    /// New `Pareto(alpha, xm)`.
    pub fn new(alpha: f64, xm: f64) -> Self {
        assert!(alpha > 0.0 && alpha.is_finite(), "pareto alpha must be positive");
        assert!(xm > 0.0 && xm.is_finite(), "pareto xm must be positive");
        Self { alpha, xm }
    }
}

impl Distribution for Pareto {
    fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64 {
        // Inverse CDF with U in (0, 1]: x = xm · U^{−1/α}.
        self.xm * rng().powf(-1.0 / self.alpha)
    }
    fn mean(&self) -> f64 {
        if self.alpha > 1.0 {
            self.alpha * self.xm / (self.alpha - 1.0)
        } else {
            f64::INFINITY
        }
    }
    fn variance(&self) -> f64 {
        if self.alpha > 2.0 {
            let a = self.alpha;
            self.xm * self.xm * a / ((a - 1.0) * (a - 1.0) * (a - 2.0))
        } else {
            f64::INFINITY
        }
    }
    fn label(&self) -> String {
        format!("Pareto({},{})", self.alpha, self.xm)
    }
}

/// Weibull with shape `k` and scale `lambda`.
#[derive(Clone, Copy, Debug)]
pub struct Weibull {
    shape: f64,
    scale: f64,
}

impl Weibull {
    /// New `Weibull(shape, scale)`.
    pub fn new(shape: f64, scale: f64) -> Self {
        assert!(shape > 0.0 && shape.is_finite(), "weibull shape must be positive");
        assert!(scale > 0.0 && scale.is_finite(), "weibull scale must be positive");
        Self { shape, scale }
    }
}

impl Distribution for Weibull {
    fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64 {
        // −ln U ~ Exp(1) for U in (0, 1]; x = λ (−ln U)^{1/k}.
        self.scale * (-rng().ln()).powf(1.0 / self.shape)
    }
    fn mean(&self) -> f64 {
        self.scale * crate::util::math::ln_gamma(1.0 + 1.0 / self.shape).exp()
    }
    fn variance(&self) -> f64 {
        let g1 = crate::util::math::ln_gamma(1.0 + 1.0 / self.shape).exp();
        let g2 = crate::util::math::ln_gamma(1.0 + 2.0 / self.shape).exp();
        self.scale * self.scale * (g2 - g1 * g1)
    }
    fn label(&self) -> String {
        format!("Weibull({},{})", self.shape, self.scale)
    }
}

/// An immutable, shareable sample bank (ascending-sorted samples plus
/// moments). Banks loaded from files are cached process-wide and shared
/// across [`Empirical`] instances via `Arc`.
#[derive(Debug)]
struct SampleBank {
    /// Ascending-sorted sample bank.
    sorted: Vec<f64>,
    mean: f64,
    variance: f64,
}

impl SampleBank {
    fn new(mut samples: Vec<f64>) -> Result<Self, String> {
        if samples.is_empty() {
            return Err("empirical distribution needs at least one sample".into());
        }
        for &s in &samples {
            if !(s >= 0.0 && s.is_finite()) {
                return Err(format!("empirical samples must be finite and >= 0, got {s}"));
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let variance = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Ok(Self { sorted: samples, mean, variance })
    }
}

/// Cache key for file-loaded banks: canonical path plus the file's size
/// and mtime, so rewriting a file (different content) reloads instead of
/// serving the stale bank.
type BankKey = (std::path::PathBuf, u64, Option<std::time::SystemTime>);

/// The process-wide bank cache table.
type BankMap = std::collections::HashMap<BankKey, std::sync::Arc<SampleBank>>;

fn bank_cache() -> &'static std::sync::Mutex<BankMap> {
    static CACHE: std::sync::OnceLock<std::sync::Mutex<BankMap>> = std::sync::OnceLock::new();
    CACHE.get_or_init(Default::default)
}

/// Empirical distribution over a recorded sample bank: inverse-transform
/// sampling off the sorted samples (type-7 interpolated quantiles, so a
/// draw at uniform `u` equals [`crate::stats::Ecdf::inverse`]`(u)` on the
/// same bank). This is how recorded task-size traces drive the
/// simulators *empirically* instead of through a fitted parametric law
/// (spec: `empirical:<file>`).
///
/// File-backed banks are **cached across [`parse_spec`] calls**, keyed
/// by canonical path (+ file size and mtime): re-validating and re-using
/// the same `empirical:<file>` spec — e.g. once at `validate()` and once
/// per sweep point — shares one sorted bank instead of re-reading and
/// re-sorting the file each time.
#[derive(Clone, Debug)]
pub struct Empirical {
    bank: std::sync::Arc<SampleBank>,
}

impl Empirical {
    /// Build from raw samples (sorted internally; needs ≥ 1 finite,
    /// non-negative sample). Not cached — only file loads are.
    pub fn new(samples: Vec<f64>) -> Result<Self, String> {
        Ok(Self { bank: std::sync::Arc::new(SampleBank::new(samples)?) })
    }

    /// Load a sample bank from a file: a recorded trace (binary or
    /// NDJSON; the bank is its per-task service times) or a plain text
    /// file of one sample per line (`#` comments and blanks skipped).
    /// Served from the process-wide cache when the same file (same
    /// canonical path, size, and mtime) was loaded before.
    pub fn load<P: AsRef<std::path::Path>>(path: P) -> Result<Self, String> {
        let path = path.as_ref();
        let meta = std::fs::metadata(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let canonical = std::fs::canonicalize(path).unwrap_or_else(|_| path.to_path_buf());
        let key: BankKey = (canonical, meta.len(), meta.modified().ok());
        if let Some(bank) = bank_cache().lock().unwrap().get(&key) {
            return Ok(Self { bank: std::sync::Arc::clone(bank) });
        }
        let bytes =
            std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let looks_like_trace = crate::trace::is_binary(&bytes)
            || bytes.iter().find(|b| !b.is_ascii_whitespace()) == Some(&b'{');
        let samples = if looks_like_trace {
            let trace = crate::trace::Trace::from_bytes(&bytes)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            trace.task_services()
        } else {
            let text = std::str::from_utf8(&bytes)
                .map_err(|_| format!("{}: not UTF-8 text", path.display()))?;
            let mut out = Vec::new();
            for (i, line) in text.lines().enumerate() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                out.push(line.parse::<f64>().map_err(|_| {
                    format!("{}:{}: bad sample {line:?}", path.display(), i + 1)
                })?);
            }
            out
        };
        let bank = std::sync::Arc::new(
            SampleBank::new(samples).map_err(|e| format!("{}: {e}", path.display()))?,
        );
        bank_cache().lock().unwrap().insert(key, std::sync::Arc::clone(&bank));
        Ok(Self { bank })
    }

    /// Number of samples in the bank.
    pub fn len(&self) -> usize {
        self.bank.sorted.len()
    }

    /// True when the bank is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.bank.sorted.is_empty()
    }

    /// Interpolated quantile at `u` ∈ [0, 1] — the inverse transform.
    #[inline]
    pub fn quantile(&self, u: f64) -> f64 {
        crate::stats::quantile_of_sorted(&self.bank.sorted, u)
    }

    /// True when two instances share one cached bank allocation (the
    /// observable effect of the `empirical:<file>` cache).
    pub fn shares_bank(&self, other: &Empirical) -> bool {
        std::sync::Arc::ptr_eq(&self.bank, &other.bank)
    }
}

impl Distribution for Empirical {
    fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64 {
        // Must stay formula-identical to Dist::draw's Empirical arm.
        self.quantile(rng())
    }
    fn mean(&self) -> f64 {
        self.bank.mean
    }
    fn variance(&self) -> f64 {
        self.bank.variance
    }
    fn label(&self) -> String {
        format!("Empirical(n={})", self.bank.sorted.len())
    }
}

/// Uniform on `[lo, hi)` — used for worker-speed skew scenarios.
#[derive(Clone, Copy, Debug)]
pub struct Uniform {
    lo: f64,
    hi: f64,
}

impl Uniform {
    /// New `Uniform(lo, hi)`.
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(lo.is_finite() && hi.is_finite() && hi > lo, "uniform needs hi > lo");
        Self { lo, hi }
    }
}

impl Distribution for Uniform {
    fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64 {
        // rng() is in (0, 1]; 1 − rng() is in [0, 1).
        self.lo + (self.hi - self.lo) * (1.0 - rng())
    }
    fn mean(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }
    fn variance(&self) -> f64 {
        let w = self.hi - self.lo;
        w * w / 12.0
    }
    fn label(&self) -> String {
        format!("Uniform({},{})", self.lo, self.hi)
    }
}

/// Enum-dispatched distribution — the simulator's hot-path sampler.
///
/// Each built-in law is a dedicated variant so [`Dist::draw`] compiles to
/// a jump table over inlined formulas instead of a vtable call through
/// `Box<dyn Distribution>` plus a `&mut dyn FnMut` uniform-source
/// closure. [`Dist::Custom`] keeps the open trait usable where
/// extensibility matters (scripted test distributions).
///
/// The inherent `sample`/`mean`/`variance`/`label` methods mirror the
/// [`Distribution`] trait so existing `parse_spec(..).sample(&mut f)`
/// call sites compile unchanged.
#[derive(Debug)]
pub enum Dist {
    /// `Exp(rate)`.
    Exp(Exponential),
    /// Point mass.
    Det(Deterministic),
    /// `Erlang(kappa, mu)`.
    Erlang(Erlang),
    /// `Pareto(alpha, xm)`.
    Pareto(Pareto),
    /// `Weibull(shape, scale)`.
    Weibull(Weibull),
    /// `Uniform(lo, hi)`.
    Uniform(Uniform),
    /// Inverse-transform sampling off a recorded sample bank.
    Empirical(Empirical),
    /// Escape hatch: any [`Distribution`] implementation (dyn-dispatched).
    Custom(Box<dyn Distribution>),
}

impl Dist {
    /// Wrap an arbitrary trait object (dyn-dispatched sampling).
    pub fn custom(d: Box<dyn Distribution>) -> Self {
        Dist::Custom(d)
    }

    /// Draw one sample from the concrete RNG — the devirtualized hot
    /// path. Formula- and draw-count-identical to the trait `sample`
    /// (bit-for-bit on the same stream; test-enforced).
    #[inline]
    pub fn draw(&self, rng: &mut Pcg64) -> f64 {
        match self {
            Dist::Exp(d) => -rng.next_f64_open().ln() / d.rate,
            Dist::Det(d) => d.value,
            Dist::Erlang(d) => {
                let mut total = 0.0;
                for _ in 0..d.kappa {
                    total += -rng.next_f64_open().ln() / d.mu;
                }
                total
            }
            Dist::Pareto(d) => d.xm * rng.next_f64_open().powf(-1.0 / d.alpha),
            Dist::Weibull(d) => d.scale * (-rng.next_f64_open().ln()).powf(1.0 / d.shape),
            Dist::Uniform(d) => d.lo + (d.hi - d.lo) * (1.0 - rng.next_f64_open()),
            Dist::Empirical(d) => d.quantile(rng.next_f64_open()),
            Dist::Custom(d) => {
                let mut f = || rng.next_f64_open();
                d.sample(&mut f)
            }
        }
    }

    /// Fill `out` with samples — the batch hot path used by the calendar
    /// engine's pre-drawn stage tasks. The variant match is hoisted out
    /// of the loop for the two samplers that dominate the paper's
    /// workloads (Exp, Erlang); everything else falls back to repeated
    /// [`Dist::draw`]. Formulas and draw counts are identical to `draw`,
    /// so the output is bit-for-bit the same stream (test-enforced, and
    /// escape-hatched via `TT_NO_FAST_EXP` at the [`crate::sim::Workload`]
    /// layer like the rest of the devirtualized path).
    #[inline]
    pub fn draw_batch(&self, rng: &mut Pcg64, out: &mut [f64]) {
        match self {
            Dist::Exp(d) => {
                for o in out {
                    *o = -rng.next_f64_open().ln() / d.rate;
                }
            }
            Dist::Erlang(d) => {
                for o in out {
                    let mut total = 0.0;
                    for _ in 0..d.kappa {
                        total += -rng.next_f64_open().ln() / d.mu;
                    }
                    *o = total;
                }
            }
            other => {
                for o in out {
                    *o = other.draw(rng);
                }
            }
        }
    }

    /// The variant as a trait object (the one delegation match; every
    /// non-hot accessor routes through it).
    fn as_dyn(&self) -> &dyn Distribution {
        match self {
            Dist::Exp(d) => d,
            Dist::Det(d) => d,
            Dist::Erlang(d) => d,
            Dist::Pareto(d) => d,
            Dist::Weibull(d) => d,
            Dist::Uniform(d) => d,
            Dist::Empirical(d) => d,
            Dist::Custom(d) => &**d,
        }
    }

    /// Draw one sample from a caller-supplied uniform source (the trait
    /// path; used for A/B-measuring dispatch cost and by legacy callers).
    pub fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64 {
        self.as_dyn().sample(rng)
    }

    /// Distribution mean (possibly `f64::INFINITY`).
    pub fn mean(&self) -> f64 {
        self.as_dyn().mean()
    }

    /// Distribution variance (possibly `f64::INFINITY`).
    pub fn variance(&self) -> f64 {
        self.as_dyn().variance()
    }

    /// Human/machine-readable label, e.g. `"Exp(0.5)"`.
    pub fn label(&self) -> String {
        self.as_dyn().label()
    }
}

impl Distribution for Dist {
    fn sample(&self, rng: &mut dyn FnMut() -> f64) -> f64 {
        Dist::sample(self, rng)
    }
    fn mean(&self) -> f64 {
        Dist::mean(self)
    }
    fn variance(&self) -> f64 {
        Dist::variance(self)
    }
    fn label(&self) -> String {
        Dist::label(self)
    }
}

impl From<Exponential> for Dist {
    fn from(d: Exponential) -> Self {
        Dist::Exp(d)
    }
}
impl From<Deterministic> for Dist {
    fn from(d: Deterministic) -> Self {
        Dist::Det(d)
    }
}
impl From<Erlang> for Dist {
    fn from(d: Erlang) -> Self {
        Dist::Erlang(d)
    }
}
impl From<Pareto> for Dist {
    fn from(d: Pareto) -> Self {
        Dist::Pareto(d)
    }
}
impl From<Weibull> for Dist {
    fn from(d: Weibull) -> Self {
        Dist::Weibull(d)
    }
}
impl From<Uniform> for Dist {
    fn from(d: Uniform) -> Self {
        Dist::Uniform(d)
    }
}
impl From<Empirical> for Dist {
    fn from(d: Empirical) -> Self {
        Dist::Empirical(d)
    }
}

fn parse_params<'a>(spec: &'a str, name: &str, n: usize) -> Result<Vec<f64>, String> {
    let parts: Vec<&'a str> = spec.split(':').collect();
    if parts.len() != n + 1 {
        return Err(format!("{name} spec needs {n} parameter(s): {spec:?}"));
    }
    parts[1..]
        .iter()
        .map(|p| {
            p.trim()
                .parse::<f64>()
                .map_err(|_| format!("bad number {p:?} in spec {spec:?}"))
        })
        .collect()
}

/// Parse a distribution spec string into an enum-dispatched [`Dist`].
///
/// Supported: `exp:RATE`, `det:VALUE`, `erlang:SHAPE:RATE`,
/// `pareto:ALPHA:XM`, `weibull:SHAPE:SCALE`, `uniform:LO:HI`, and
/// `empirical:FILE` (a recorded trace or a text file of samples — note
/// this spec performs file I/O at parse time).
pub fn parse_spec(spec: &str) -> Result<Dist, String> {
    let spec = spec.trim();
    let name = spec.split(':').next().unwrap_or("");
    match name {
        "empirical" => {
            // The whole remainder is the path (it may itself contain ':').
            let path = spec
                .split_once(':')
                .map(|(_, p)| p.trim())
                .filter(|p| !p.is_empty())
                .ok_or_else(|| format!("empirical spec needs a file: {spec:?}"))?;
            Ok(Dist::Empirical(Empirical::load(path)?))
        }
        "exp" => {
            let p = parse_params(spec, "exp", 1)?;
            if !(p[0] > 0.0 && p[0].is_finite()) {
                return Err(format!("exp rate must be positive: {spec:?}"));
            }
            Ok(Dist::Exp(Exponential::new(p[0])))
        }
        "det" => {
            let p = parse_params(spec, "det", 1)?;
            if !(p[0] >= 0.0 && p[0].is_finite()) {
                return Err(format!("det value must be >= 0: {spec:?}"));
            }
            Ok(Dist::Det(Deterministic::new(p[0])))
        }
        "erlang" => {
            let p = parse_params(spec, "erlang", 2)?;
            if p[0] < 1.0 || p[0].fract() != 0.0 || p[0] > u32::MAX as f64 {
                return Err(format!("erlang shape must be a positive integer: {spec:?}"));
            }
            if !(p[1] > 0.0 && p[1].is_finite()) {
                return Err(format!("erlang rate must be positive: {spec:?}"));
            }
            Ok(Dist::Erlang(Erlang::new(p[0] as u32, p[1])))
        }
        "pareto" => {
            let p = parse_params(spec, "pareto", 2)?;
            if !(p[0] > 0.0 && p[1] > 0.0 && p[0].is_finite() && p[1].is_finite()) {
                return Err(format!("pareto parameters must be positive: {spec:?}"));
            }
            Ok(Dist::Pareto(Pareto::new(p[0], p[1])))
        }
        "weibull" => {
            let p = parse_params(spec, "weibull", 2)?;
            if !(p[0] > 0.0 && p[1] > 0.0 && p[0].is_finite() && p[1].is_finite()) {
                return Err(format!("weibull parameters must be positive: {spec:?}"));
            }
            Ok(Dist::Weibull(Weibull::new(p[0], p[1])))
        }
        "uniform" => {
            let p = parse_params(spec, "uniform", 2)?;
            if !(p[0].is_finite() && p[1].is_finite() && p[1] > p[0]) {
                return Err(format!("uniform needs hi > lo: {spec:?}"));
            }
            Ok(Dist::Uniform(Uniform::new(p[0], p[1])))
        }
        _ => Err(format!(
            "unknown distribution {spec:?} (exp|det|erlang|pareto|weibull|uniform|empirical)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn sample_mean(d: &dyn Distribution, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut s = 0.0;
        let mut s2 = 0.0;
        for _ in 0..n {
            let mut f = || rng.next_f64_open();
            let x = d.sample(&mut f);
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        (mean, s2 / n as f64 - mean * mean)
    }

    /// `draw_batch` is a pure refactor of `draw`: same formulas on the
    /// same stream, bit-for-bit — for the dedicated Exp/Erlang arms and
    /// for the fallback loop alike.
    #[test]
    fn draw_batch_matches_draw_bitwise() {
        let dists: Vec<Dist> = vec![
            Exponential::new(0.7).into(),
            Erlang::new(3, 1.4).into(),
            Deterministic::new(2.5).into(),
            Pareto::new(2.5, 1.0).into(),
            Weibull::new(1.5, 2.0).into(),
            Uniform::new(0.5, 1.5).into(),
        ];
        for d in &dists {
            let mut a = Pcg64::seed_from_u64(41);
            let mut b = Pcg64::seed_from_u64(41);
            let loop_draws: Vec<f64> = (0..257).map(|_| d.draw(&mut a)).collect();
            let mut batch = vec![0.0; 257];
            d.draw_batch(&mut b, &mut batch);
            assert_eq!(loop_draws, batch, "{}", d.label());
            // RNGs end in the same state: identical draw counts.
            assert_eq!(a.next_f64(), b.next_f64(), "{}", d.label());
        }
    }

    #[test]
    fn exponential_moments_and_label() {
        let d = Exponential::new(0.5);
        assert_eq!(d.mean(), 2.0);
        assert_eq!(d.variance(), 4.0);
        assert_eq!(d.label(), "Exp(0.5)");
        let (m, v) = sample_mean(&d, 200_000, 1);
        assert!((m - 2.0).abs() < 0.03, "mean={m}");
        assert!((v - 4.0).abs() < 0.2, "var={v}");
    }

    #[test]
    fn deterministic_is_constant() {
        let d = Deterministic::new(3.5);
        let mut calls = 0usize;
        let mut f = || {
            calls += 1;
            0.5
        };
        assert_eq!(d.sample(&mut f), 3.5);
        assert_eq!(calls, 0, "det must not consume randomness");
        assert_eq!(d.mean(), 3.5);
        assert_eq!(d.variance(), 0.0);
    }

    #[test]
    fn erlang_moments_and_cdf() {
        let d = Erlang::new(4, 2.0);
        assert_eq!(d.mean(), 2.0);
        assert_eq!(d.variance(), 1.0);
        let (m, v) = sample_mean(&d, 100_000, 2);
        assert!((m - 2.0).abs() < 0.03, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
        // CDF sanity: monotone, F(0)=0, F(∞)→1, median near mean for κ=4.
        assert_eq!(d.cdf(0.0), 0.0);
        assert!(d.cdf(1.0) < d.cdf(2.0) && d.cdf(2.0) < d.cdf(4.0));
        assert!(d.cdf(50.0) > 0.999999);
        // Erlang(1, μ) is Exp(μ): F(x) = 1 − e^{−μx}.
        let e1 = Erlang::new(1, 0.7);
        for x in [0.1, 1.0, 3.0] {
            let expect = 1.0 - (-0.7f64 * x).exp();
            assert!((e1.cdf(x) - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn pareto_and_weibull_means() {
        let p = Pareto::new(2.5, 0.6);
        assert!((p.mean() - 1.0).abs() < 1e-12);
        let (m, _) = sample_mean(&p, 400_000, 3);
        assert!((m - 1.0).abs() < 0.05, "pareto mean={m}");
        // Weibull(2, 1.1284): mean = 1.1284·Γ(1.5) ≈ 1.0.
        let w = Weibull::new(2.0, 1.1284);
        assert!((w.mean() - 1.0).abs() < 1e-3, "{}", w.mean());
        let (m, _) = sample_mean(&w, 200_000, 4);
        assert!((m - 1.0).abs() < 0.01, "weibull mean={m}");
    }

    #[test]
    fn uniform_range_and_moments() {
        let u = Uniform::new(0.5, 1.5);
        assert_eq!(u.mean(), 1.0);
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..10_000 {
            let mut f = || rng.next_f64_open();
            let x = u.sample(&mut f);
            assert!((0.5..1.5).contains(&x), "{x}");
        }
    }

    #[test]
    fn parse_spec_roundtrip() {
        assert_eq!(parse_spec("exp:0.25").unwrap().mean(), 4.0);
        assert_eq!(parse_spec("det:2.0").unwrap().mean(), 2.0);
        assert_eq!(parse_spec("erlang:4:2.0").unwrap().mean(), 2.0);
        assert!((parse_spec("pareto:2.5:0.6").unwrap().mean() - 1.0).abs() < 1e-12);
        assert!(parse_spec("weibull:2:1.1284").unwrap().mean() > 0.9);
        assert_eq!(parse_spec("uniform:0.5:1.5").unwrap().mean(), 1.0);
        assert_eq!(parse_spec("exp:0.5").unwrap().label(), "Exp(0.5)");
    }

    #[test]
    fn parse_spec_rejects_malformed() {
        for bad in [
            "zipf:1.1",
            "exp",
            "exp:0",
            "exp:-1",
            "exp:abc",
            "det:-2",
            "erlang:0:1",
            "erlang:2.5:1",
            "uniform:2:1",
            "empirical",
            "empirical:",
            "empirical:/no/such/file-i-hope",
            "",
        ] {
            assert!(parse_spec(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    /// `Dist::Empirical` draws are exactly `Ecdf::inverse` at the same
    /// uniform — the inverse-transform contract the trace subsystem's
    /// tests lean on.
    #[test]
    fn empirical_draws_match_ecdf_quantiles() {
        let samples = vec![3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.3];
        let d: Dist = Empirical::new(samples.clone()).unwrap().into();
        let ecdf = crate::stats::Ecdf::new(samples.clone());
        let mut a = Pcg64::seed_from_u64(21);
        let mut b = Pcg64::seed_from_u64(21);
        let (lo, hi) = (1.0, 9.0);
        for _ in 0..2000 {
            let x = d.draw(&mut a);
            let u = b.next_f64_open();
            assert!(x == ecdf.inverse(u), "draw {x} != Ecdf inverse at {u}");
            assert!((lo..=hi).contains(&x));
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((d.mean() - mean).abs() < 1e-12);
        assert_eq!(d.label(), "Empirical(n=7)");
    }

    #[test]
    fn empirical_spec_loads_text_file() {
        let dir = std::env::temp_dir().join(format!("tt-dist-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bank.txt");
        std::fs::write(&path, "# samples\n1.0\n2.0\n\n3.0\n").unwrap();
        let d = parse_spec(&format!("empirical:{}", path.display())).unwrap();
        assert_eq!(d.mean(), 2.0);
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..100 {
            let x = d.draw(&mut rng);
            assert!((1.0..=3.0).contains(&x), "{x}");
        }
        // Malformed sample lines are reported, not panicked on.
        std::fs::write(&path, "1.0\nnot-a-number\n").unwrap();
        assert!(parse_spec(&format!("empirical:{}", path.display())).is_err());
    }

    /// The satellite acceptance: two `parse_spec` calls on the same
    /// `empirical:<file>` spec hit the cache (one shared bank, proven by
    /// pointer identity) and draw identically; rewriting the file with
    /// different content invalidates the entry.
    #[test]
    fn empirical_cache_shares_banks_across_parses() {
        let dir = std::env::temp_dir().join(format!("tt-dist-cache-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cached-bank.txt");
        std::fs::write(&path, "1.0\n2.0\n3.0\n4.0\n").unwrap();
        let spec = format!("empirical:{}", path.display());
        let a = parse_spec(&spec).unwrap();
        let b = parse_spec(&spec).unwrap();
        let (Dist::Empirical(ea), Dist::Empirical(eb)) = (&a, &b) else {
            panic!("empirical spec must parse to Dist::Empirical");
        };
        assert!(ea.shares_bank(eb), "second parse must hit the cache");
        // Cache hits draw identically (same bank, same RNG stream).
        let mut ra = Pcg64::seed_from_u64(11);
        let mut rb = Pcg64::seed_from_u64(11);
        for _ in 0..500 {
            assert_eq!(a.draw(&mut ra).to_bits(), b.draw(&mut rb).to_bits());
        }
        // A rewrite with different content must not serve the stale bank.
        std::fs::write(&path, "10.0\n20.0\n30.0\n40.0\n50.0\n").unwrap();
        let c = parse_spec(&spec).unwrap();
        let Dist::Empirical(ec) = &c else { unreachable!() };
        assert!(!ea.shares_bank(ec), "rewritten file must reload");
        assert_eq!(c.mean(), 30.0);
    }

    #[test]
    fn exponential_matches_fast_path_formula() {
        // Bit-for-bit: dist sampling equals the inlined formula on the
        // same RNG stream.
        let d = Exponential::new(1.7);
        let mut a = Pcg64::seed_from_u64(9);
        let mut b = Pcg64::seed_from_u64(9);
        for _ in 0..1000 {
            let mut f = || a.next_f64_open();
            let x = d.sample(&mut f);
            let y = -b.next_f64_open().ln() / 1.7;
            assert!(x == y, "fast path diverges: {x} vs {y}");
        }
    }

    /// The enum fast path (`Dist::draw`) is bit-for-bit identical to the
    /// dyn-dispatch trait path (`Dist::sample`) for every variant — the
    /// reproducibility contract behind the devirtualization refactor.
    #[test]
    fn enum_draw_matches_trait_sample_bitwise() {
        let dists: Vec<Dist> = vec![
            Exponential::new(0.7).into(),
            Deterministic::new(2.5).into(),
            Erlang::new(4, 2.0).into(),
            Pareto::new(2.5, 0.6).into(),
            Weibull::new(2.0, 1.1).into(),
            Uniform::new(0.5, 1.5).into(),
            Empirical::new(vec![0.25, 1.0, 2.5, 4.0]).unwrap().into(),
            Dist::custom(Box::new(Exponential::new(0.7))),
        ];
        for d in &dists {
            let mut a = Pcg64::seed_from_u64(17);
            let mut b = Pcg64::seed_from_u64(17);
            for _ in 0..500 {
                let x = d.draw(&mut a);
                let mut f = || b.next_f64_open();
                let y = Dist::sample(d, &mut f);
                assert!(x == y, "{}: draw {x} vs sample {y}", d.label());
            }
            // Identical draw counts: both streams are in the same state.
            assert_eq!(a.next_u64(), b.next_u64(), "{}", d.label());
        }
    }
}
