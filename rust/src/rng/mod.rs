//! Pseudo-random number generation.
//!
//! The offline toolchain has no `rand` crate, so we implement PCG64
//! (O'Neill's permuted congruential generator, XSL-RR 128/64 variant) with
//! SplitMix64 seeding. PCG64 passes BigCrush and is the default engine of
//! the `rand` crate family, so simulation results are statistically
//! comparable to what forkulator-style tooling would produce.

mod pcg;
mod splitmix;

pub use pcg::Pcg64;
pub use splitmix::SplitMix64;

/// Uniform source of randomness used by every sampler in [`crate::dist`].
pub trait Rng {
    /// Next 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in the half-open interval `[0, 1)` with 53-bit
    /// resolution.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits: they are the most well-mixed in PCG64's
        // output permutation and give the full f64 mantissa resolution.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in the open interval `(0, 1]`; safe as an argument to
    /// `ln()` when sampling exponentials.
    #[inline]
    fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in `[0, n)` using Lemire's multiply-shift rejection.
    #[inline]
    fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }
}

/// Derive `count` statistically independent child seeds from a master seed.
///
/// Used by the sweep executor so each (configuration, replication) pair has
/// a reproducible, non-overlapping stream.
pub fn spawn_seeds(master: u64, count: usize) -> Vec<u64> {
    let mut sm = SplitMix64::new(master);
    (0..count).map(|_| sm.next_u64()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_range() {
        let mut r = Pcg64::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Pcg64::seed_from_u64(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues reachable");
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::seed_from_u64(42);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_f64();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn spawn_seeds_distinct() {
        let seeds = spawn_seeds(1, 64);
        for i in 0..seeds.len() {
            for j in (i + 1)..seeds.len() {
                assert_ne!(seeds[i], seeds[j]);
            }
        }
    }
}
