//! PCG64 (XSL-RR 128/64): 128-bit LCG state with an xor-shift-low,
//! random-rotate output permutation. Reference: M.E. O'Neill, "PCG: A
//! Family of Simple Fast Space-Efficient Statistically Good Algorithms for
//! Random Number Generation", HMC-CS-2014-0905.

use super::{Rng, SplitMix64};

const MULTIPLIER: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG64 generator — the workhorse RNG of the simulator and emulator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    /// Stream selector (must be odd); distinct increments give independent
    /// sequences even from identical states.
    increment: u128,
}

impl Pcg64 {
    /// Construct from full 128-bit state and stream id.
    pub fn new(state: u128, stream: u128) -> Self {
        let increment = (stream << 1) | 1;
        let mut pcg = Self { state: 0, increment };
        pcg.state = pcg.state.wrapping_add(increment).wrapping_add(state);
        pcg.step();
        pcg
    }

    /// Seed from a single u64 via SplitMix64 expansion (the same approach
    /// `rand_pcg` uses for `seed_from_u64`).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let a = sm.next_u64() as u128;
        let b = sm.next_u64() as u128;
        let c = sm.next_u64() as u128;
        let d = sm.next_u64() as u128;
        Self::new(a << 64 | b, c << 64 | d)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(MULTIPLIER)
            .wrapping_add(self.increment);
    }

    #[inline]
    fn output(state: u128) -> u64 {
        // XSL-RR: xor the halves, rotate right by the top 6 bits.
        let rot = (state >> 122) as u32;
        let xored = ((state >> 64) as u64) ^ (state as u64);
        xored.rotate_right(rot)
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let out = Self::output(self.state);
        self.step();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::seed_from_u64(123);
        let mut b = Pcg64::seed_from_u64(123);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn different_streams_diverge() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    /// Bit-balance sanity: each of the 64 output bits should be ~50% ones.
    #[test]
    fn bit_balance() {
        let mut r = Pcg64::seed_from_u64(7);
        let n = 20_000;
        let mut counts = [0u32; 64];
        for _ in 0..n {
            let x = r.next_u64();
            for (i, c) in counts.iter_mut().enumerate() {
                *c += ((x >> i) & 1) as u32;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.5).abs() < 0.02, "bit {i}: {frac}");
        }
    }
}
