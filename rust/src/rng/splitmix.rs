//! SplitMix64 — Steele, Lea & Flood's fast 64-bit mixer. Used only for
//! seeding (expanding one u64 into independent streams); not used for
//! simulation draws directly.

use super::Rng;

/// SplitMix64 generator. One addition and three xor-shift-multiply rounds
/// per output; passes BigCrush when used as a seeder.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a seeder from a master seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vector from the public-domain splitmix64.c (Vigna):
    /// seed=0 produces 0xE220A8397B1DCDAF first.
    #[test]
    fn reference_vector() {
        let mut s = SplitMix64::new(0);
        assert_eq!(s.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(s.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(s.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(99);
        let mut b = SplitMix64::new(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
