//! The dispatch-policy abstraction — the scheduling-policy axis.
//!
//! The paper fixes one dispatch discipline (FCFS to the earliest-free
//! server); this module inverts control at that seam so the granularity
//! trade-off can be asked under other schedulers, following the
//! fork-join scheduling-bounds lineage (KhudaBukhsh et al.):
//!
//! * **SITA** (`Sita { boundaries }`) — size-interval task assignment:
//!   the cluster is partitioned into `boundaries.len() + 1` contiguous
//!   server groups and each task is routed to the group matching its
//!   drawn execution time (short tasks never queue behind long ones);
//! * **priority classes** (`Priority { classes }`) — jobs cycle through
//!   classes round-robin by arrival index; each class owns a dedicated
//!   server partition sized by its weight, and per-class sojourn
//!   summaries surface in `SimResult`;
//! * **work stealing** (`WorkSteal { threshold }`) — tasks carry a
//!   round-robin server affinity; when the affinity server's backlog
//!   exceeds the idlest server's by more than `threshold` seconds the
//!   task is stolen by the idle server.
//!
//! `policy = "fcfs"` (or an absent `[policy]` section) resolves to
//! `None`: no policy state is built and every engine keeps its seed
//! dispatch path untouched, the same bit-exact degeneracy discipline
//! the scenario and fault axes follow (`rust/tests/policy_equivalence.rs`).
//!
//! Group sub-heaps keep **global** server ids, so per-worker crash
//! schedules (fault injection) and per-worker speeds (scenarios) stay
//! valid under any partition.

use super::faults::{FaultInjector, FaultOutcome};
use super::scenario::{Scenario, TaskOutcome};
use super::{OverheadModel, ServerHeap, TraceEvent, TraceLog, Workload};
use crate::config::{PolicyKind, SimulationConfig};
use crate::trace::cause;

/// Outcome of dispatching one logical task under a policy — the union
/// of the fault-free and faulty dispatcher outcomes plus the class the
/// task was routed by.
#[derive(Clone, Copy, Debug)]
pub struct PolicyTaskOutcome {
    /// Earliest instant any attempt of this task began service.
    pub first_start: f64,
    /// Completion time of the winning attempt.
    pub finish: f64,
    /// Useful work (the winning attempt's execution draw).
    pub work: f64,
    /// Task-service overhead charged across attempts.
    pub overhead: f64,
    /// Server time consumed by cancelled replicas.
    pub redundant: f64,
    /// Work lost to crashes and failed attempts.
    pub lost: f64,
    /// Retry count (0 without fault injection).
    pub retries: u32,
    /// SITA size interval or priority class (0 under work stealing).
    pub class: u32,
}

impl PolicyTaskOutcome {
    fn from_task(out: TaskOutcome, class: u32) -> Self {
        Self {
            first_start: out.first_start,
            finish: out.finish,
            work: out.work,
            overhead: out.overhead,
            redundant: out.redundant_time,
            lost: 0.0,
            retries: 0,
            class,
        }
    }

    fn from_fault(out: FaultOutcome, class: u32) -> Self {
        Self {
            first_start: out.first_start,
            finish: out.finish,
            work: out.work,
            overhead: out.overhead,
            redundant: out.redundant,
            lost: out.lost,
            retries: out.retries,
            class,
        }
    }
}

/// Resolved dispatch-policy state: the server partition (or free-time
/// vector) one model instance routes every task through.
#[derive(Clone, Debug)]
pub enum PolicyState {
    /// Size-interval task assignment over `boundaries.len() + 1` groups.
    Sita {
        /// Strictly ascending execution-time boundaries.
        boundaries: Vec<f64>,
        /// Per-interval server sub-heaps (global ids).
        groups: Vec<ServerHeap>,
    },
    /// Multi-class priority with dedicated server partitions.
    Priority {
        /// Number of job classes (round-robin by job index).
        classes: usize,
        /// Per-class server sub-heaps (global ids).
        groups: Vec<ServerHeap>,
    },
    /// Round-robin affinity with idle-server stealing.
    WorkSteal {
        /// Steal when affinity backlog exceeds the idlest by this.
        threshold: f64,
        /// Per-server free times (indexed by global server id).
        free: Vec<f64>,
        /// Round-robin affinity cursor.
        next: usize,
        /// Raw tally of tasks stolen away from their affinity server
        /// (obs layer; no behavior change).
        steals: u64,
    },
}

impl PolicyState {
    /// Resolve a config's policy. `Ok(None)` when no `[policy]` section
    /// is configured or it selects FCFS, so models keep the seed
    /// dispatch paths bit-exactly.
    pub fn from_config(cfg: &SimulationConfig) -> Result<Option<Self>, String> {
        let Some(p) = &cfg.policy else {
            return Ok(None);
        };
        if !p.is_active() {
            return Ok(None);
        }
        let groups_of = || -> Result<Vec<ServerHeap>, String> {
            let sizes = p.partition_sizes(cfg.servers);
            let mut groups = Vec::with_capacity(sizes.len());
            let mut next_id = 0u32;
            for &s in &sizes {
                if s == 0 {
                    return Err(format!(
                        "policy partition produced an empty server group \
                         ({} servers across {} groups)",
                        cfg.servers,
                        sizes.len()
                    ));
                }
                groups.push(ServerHeap::from_servers(next_id..next_id + s as u32, 0.0));
                next_id += s as u32;
            }
            Ok(groups)
        };
        match p.kind {
            PolicyKind::Fcfs => unreachable!("inactive policy handled above"),
            PolicyKind::Sita => Ok(Some(Self::Sita {
                boundaries: p.sita_boundaries.clone(),
                groups: groups_of()?,
            })),
            PolicyKind::Priority => Ok(Some(Self::Priority {
                classes: p.classes,
                groups: groups_of()?,
            })),
            PolicyKind::WorkSteal => Ok(Some(Self::WorkSteal {
                threshold: p.steal_threshold,
                free: vec![0.0; cfg.servers],
                next: 0,
                steals: 0,
            })),
        }
    }

    /// Set every server free at exactly `t` (split-merge start barrier).
    pub fn reset_all(&mut self, t: f64) {
        match self {
            Self::Sita { groups, .. } | Self::Priority { groups, .. } => {
                for g in groups {
                    g.reset_all(t);
                }
            }
            Self::WorkSteal { free, .. } => {
                for f in free {
                    *f = t;
                }
            }
        }
    }

    /// Raise every server's free time to at least `t` (split-merge
    /// barrier under faults: repair times may extend past it).
    pub fn raise_to(&mut self, t: f64) {
        match self {
            Self::Sita { groups, .. } | Self::Priority { groups, .. } => {
                for g in groups {
                    g.raise_to(t);
                }
            }
            Self::WorkSteal { free, .. } => {
                for f in free {
                    if *f < t {
                        *f = t;
                    }
                }
            }
        }
    }

    /// Largest free time across every server (split-merge makespan).
    pub fn max_time(&self) -> f64 {
        match self {
            Self::Sita { groups, .. } | Self::Priority { groups, .. } => groups
                .iter()
                .map(ServerHeap::max_time)
                .fold(f64::NEG_INFINITY, f64::max),
            Self::WorkSteal { free, .. } => {
                free.iter().copied().fold(f64::NEG_INFINITY, f64::max)
            }
        }
    }

    /// Tasks stolen from their affinity server (0 outside work stealing).
    pub fn steal_count(&self) -> u64 {
        match self {
            Self::WorkSteal { steals, .. } => *steals,
            _ => 0,
        }
    }

    /// Summed (pushes, pops) across every group sub-heap — the policy
    /// partitions' share of the engine's heap traffic (work stealing
    /// keeps a flat free-time vector, so its share is (0, 0)).
    pub fn heap_ops(&self) -> (u64, u64) {
        match self {
            Self::Sita { groups, .. } | Self::Priority { groups, .. } => groups
                .iter()
                .map(ServerHeap::ops)
                .fold((0, 0), |(a, b), (p, q)| (a + p, b + q)),
            Self::WorkSteal { .. } => (0, 0),
        }
    }

    /// The SITA size interval of an execution draw.
    #[inline]
    fn sita_class(boundaries: &[f64], exec: f64) -> u32 {
        boundaries.iter().filter(|&&b| exec >= b).count() as u32
    }

    /// Dispatch one logical task through the policy, composing with the
    /// scenario dispatcher (priority only — SITA/work-steal reject
    /// `[workers]`/`[redundancy]` at validation) and the fault injector
    /// (any policy). `floor` is the earliest permissible start; `job`
    /// is the job index (the priority class source).
    ///
    /// Draw order is the seed engines' order — execution then overhead
    /// per task from the workload stream — so a policy run is
    /// reproducible per seed and perturbs nothing outside its own
    /// routing decisions.
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_task(
        &mut self,
        floor: f64,
        job: usize,
        task: u32,
        scenario: &mut Option<Scenario>,
        faults: &mut Option<FaultInjector>,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> PolicyTaskOutcome {
        match self {
            Self::Sita { boundaries, groups } => {
                debug_assert!(scenario.is_none(), "SITA rejects scenarios at validation");
                let exec = workload.next_execution();
                let oh = overhead.sample_task(workload.rng());
                let class = Self::sita_class(boundaries, exec);
                let heap = &mut groups[class as usize];
                match faults.as_mut() {
                    Some(fi) => PolicyTaskOutcome::from_fault(
                        fi.dispatch_task_drawn(
                            heap, floor, exec, oh, workload, overhead, job as u32, task,
                            class, trace,
                        ),
                        class,
                    ),
                    None => dispatch_plain(heap, floor, exec, oh, job as u32, task, class, trace),
                }
            }
            Self::Priority { classes, groups } => {
                let class = (job % *classes) as u32;
                let heap = &mut groups[class as usize];
                if let Some(sc) = scenario.as_mut() {
                    match faults.as_mut() {
                        Some(fi) => PolicyTaskOutcome::from_fault(
                            sc.dispatch_task_faulty(
                                heap, floor, workload, overhead, fi, job as u32, task,
                                class, trace,
                            ),
                            class,
                        ),
                        None => PolicyTaskOutcome::from_task(
                            sc.dispatch_task(
                                heap, floor, workload, overhead, job as u32, task, class,
                                trace,
                            ),
                            class,
                        ),
                    }
                } else {
                    let exec = workload.next_execution();
                    let oh = overhead.sample_task(workload.rng());
                    match faults.as_mut() {
                        Some(fi) => PolicyTaskOutcome::from_fault(
                            fi.dispatch_task_drawn(
                                heap, floor, exec, oh, workload, overhead, job as u32,
                                task, class, trace,
                            ),
                            class,
                        ),
                        None => dispatch_plain(
                            heap, floor, exec, oh, job as u32, task, class, trace,
                        ),
                    }
                }
            }
            Self::WorkSteal { threshold, free, next, steals } => {
                debug_assert!(
                    scenario.is_none(),
                    "work stealing rejects scenarios at validation"
                );
                let l = free.len();
                let affinity = *next % l;
                *next = (*next + 1) % l;
                let mut min_idx = 0usize;
                let mut min_free = free[0];
                for (i, &f) in free.iter().enumerate().skip(1) {
                    if f < min_free {
                        min_free = f;
                        min_idx = i;
                    }
                }
                // Steal only when the affinity backlog is worth it.
                let server = if free[affinity] - min_free > *threshold {
                    *steals += 1;
                    min_idx
                } else {
                    affinity
                };
                match faults.as_mut() {
                    Some(fi) => {
                        let (out, new_free) = fi.dispatch_task_on(
                            server as u32,
                            free[server],
                            floor,
                            workload,
                            overhead,
                            job as u32,
                            task,
                            trace,
                        );
                        free[server] = new_free;
                        PolicyTaskOutcome::from_fault(out, 0)
                    }
                    None => {
                        let exec = workload.next_execution();
                        let oh = overhead.sample_task(workload.rng());
                        let t_free = free[server];
                        let start = if floor > t_free { floor } else { t_free };
                        let finish = start + exec + oh;
                        free[server] = finish;
                        if trace.is_enabled() {
                            trace.record(TraceEvent {
                                job: job as u32,
                                task,
                                server: server as u32,
                                start,
                                end: finish,
                                overhead: oh,
                                winner: true,
                                attempt: 1,
                                cause: cause::NONE,
                                class: 0,
                            });
                        }
                        PolicyTaskOutcome {
                            first_start: start,
                            finish,
                            work: exec,
                            overhead: oh,
                            redundant: 0.0,
                            lost: 0.0,
                            retries: 0,
                            class: 0,
                        }
                    }
                }
            }
        }
    }
}

/// Single-attempt FCFS dispatch inside one policy group — the seed
/// engines' arithmetic (`start = max(t_free, floor)`, `finish = start +
/// exec + oh`) on the group's sub-heap, which is why single-group SITA
/// reproduces FCFS sojourns exactly.
#[allow(clippy::too_many_arguments)]
fn dispatch_plain(
    heap: &mut ServerHeap,
    floor: f64,
    exec: f64,
    oh: f64,
    job: u32,
    task: u32,
    class: u32,
    trace: &mut TraceLog,
) -> PolicyTaskOutcome {
    let (t_free, server) = heap.pop();
    let start = if floor > t_free { floor } else { t_free };
    let finish = start + exec + oh;
    heap.push(finish, server);
    if trace.is_enabled() {
        trace.record(TraceEvent {
            job,
            task,
            server,
            start,
            end: finish,
            overhead: oh,
            winner: true,
            attempt: 1,
            cause: cause::NONE,
            class,
        });
    }
    PolicyTaskOutcome {
        first_start: start,
        finish,
        work: exec,
        overhead: oh,
        redundant: 0.0,
        lost: 0.0,
        retries: 0,
        class,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyConfig, SimulationConfig};
    use crate::dist::Deterministic;

    fn det_workload(exec: f64) -> Workload {
        Workload::new(Deterministic::new(100.0).into(), Deterministic::new(exec).into(), 1)
    }

    fn cfg_with(policy: PolicyConfig, servers: usize) -> SimulationConfig {
        SimulationConfig {
            servers,
            tasks_per_job: servers * 2,
            policy: Some(policy),
            ..SimulationConfig::default()
        }
    }

    #[test]
    fn fcfs_resolves_to_none() {
        let cfg = SimulationConfig::default();
        assert!(PolicyState::from_config(&cfg).unwrap().is_none());
        let cfg = cfg_with(PolicyConfig::default(), 4);
        assert!(PolicyState::from_config(&cfg).unwrap().is_none());
    }

    #[test]
    fn sita_routes_by_size() {
        let cfg = cfg_with(
            PolicyConfig {
                kind: PolicyKind::Sita,
                sita_boundaries: vec![2.0],
                ..PolicyConfig::default()
            },
            4,
        );
        let mut pol = PolicyState::from_config(&cfg).unwrap().unwrap();
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let mut sc = None;
        let mut fi = None;
        // Short task (exec 1.0 < 2.0) → interval 0; long (3.0) → 1.
        let mut w = det_workload(1.0);
        let a = pol.dispatch_task(0.0, 0, 0, &mut sc, &mut fi, &mut w, &oh, &mut tr);
        assert_eq!(a.class, 0);
        let mut w = det_workload(3.0);
        let b = pol.dispatch_task(0.0, 0, 1, &mut sc, &mut fi, &mut w, &oh, &mut tr);
        assert_eq!(b.class, 1);
        // Groups are disjoint: the long task ran on a high-id server.
        let evs = tr.events();
        assert!(evs[0].server < 2 && evs[1].server >= 2, "{evs:?}");
        assert_eq!(evs[0].class, 0);
        assert_eq!(evs[1].class, 1);
    }

    #[test]
    fn priority_classes_cycle_by_job() {
        let cfg = cfg_with(
            PolicyConfig { kind: PolicyKind::Priority, classes: 2, ..PolicyConfig::default() },
            4,
        );
        let mut pol = PolicyState::from_config(&cfg).unwrap().unwrap();
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let (mut sc, mut fi) = (None, None);
        let mut w = det_workload(1.0);
        for job in 0..4usize {
            let out = pol.dispatch_task(0.0, job, 0, &mut sc, &mut fi, &mut w, &oh, &mut tr);
            assert_eq!(out.class, (job % 2) as u32);
        }
        // Each class stays inside its own server partition.
        for e in tr.events() {
            assert_eq!(e.server / 2, e.class, "{e:?}");
        }
    }

    #[test]
    fn worksteal_steals_past_threshold() {
        let cfg = cfg_with(
            PolicyConfig {
                kind: PolicyKind::WorkSteal,
                steal_threshold: 0.5,
                ..PolicyConfig::default()
            },
            2,
        );
        let mut pol = PolicyState::from_config(&cfg).unwrap().unwrap();
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let (mut sc, mut fi) = (None, None);
        let mut w = det_workload(1.0);
        // Jobs land round-robin: task 0 → server 0, task 1 → server 1.
        pol.dispatch_task(0.0, 0, 0, &mut sc, &mut fi, &mut w, &oh, &mut tr);
        pol.dispatch_task(0.0, 0, 1, &mut sc, &mut fi, &mut w, &oh, &mut tr);
        // Server 0's backlog now equals server 1's; affinity returns to
        // 0 and the gap (0.0) is under the threshold — no steal.
        pol.dispatch_task(0.0, 0, 2, &mut sc, &mut fi, &mut w, &oh, &mut tr);
        let evs = tr.events();
        assert_eq!(evs[2].server, 0);
        // Pile more work on server 0 via a raised free time, then the
        // next affinity-0 task is stolen by server 1.
        if let PolicyState::WorkSteal { free, next, .. } = &mut pol {
            free[0] = 10.0;
            *next = 0;
        }
        let out = pol.dispatch_task(0.0, 0, 3, &mut sc, &mut fi, &mut w, &oh, &mut tr);
        assert_eq!(tr.events()[3].server, 1);
        assert!(out.finish < 10.0);
        assert_eq!(pol.steal_count(), 1);
        assert_eq!(pol.heap_ops(), (0, 0));
    }

    #[test]
    fn single_interval_sita_is_plain_fcfs() {
        // Empty boundary list → one group spanning the whole cluster;
        // finish times match the seed earliest-free arithmetic.
        let cfg = cfg_with(
            PolicyConfig { kind: PolicyKind::Sita, ..PolicyConfig::default() },
            3,
        );
        let mut pol = PolicyState::from_config(&cfg).unwrap().unwrap();
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let (mut sc, mut fi) = (None, None);
        let mut w = det_workload(2.0);
        let mut finishes = Vec::new();
        for t in 0..6 {
            let out = pol.dispatch_task(0.0, 0, t, &mut sc, &mut fi, &mut w, &oh, &mut tr);
            finishes.push(out.finish);
        }
        assert_eq!(finishes, vec![2.0, 2.0, 2.0, 4.0, 4.0, 4.0]);
        assert_eq!(pol.max_time(), 4.0);
    }
}
