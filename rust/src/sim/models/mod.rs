//! The four parallel-system models, each as an exact per-job recursion.

mod fork_join_ps;
mod fork_join_sq;
mod ideal;
mod split_merge;

pub use fork_join_ps::ForkJoinPerServer;
pub use fork_join_sq::ForkJoinSingleQueue;
pub use ideal::IdealPartition;
pub use split_merge::SplitMerge;

use super::{JobRecord, OverheadModel, TraceLog, Workload};

/// A parallel-system model simulated job by job.
///
/// `advance` consumes the next job (its arrival time and its tasks drawn
/// from `workload`) and returns the completed [`JobRecord`]. Models carry
/// their cross-job state (server free times, previous departure) inside.
pub trait Model {
    /// Simulate job `n` arriving at `arrival`.
    fn advance(
        &mut self,
        n: usize,
        arrival: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord;

    /// Human-readable model name.
    fn name(&self) -> &'static str;

    /// Raw observability tallies accumulated so far (dispatches, heap
    /// ops, fault/scenario/policy counts). The runner harvests this once
    /// per run when `--metrics` is on; the default covers model
    /// implementations that do not tally (e.g. trace replay).
    fn tallies(&self) -> crate::obs::Tallies {
        crate::obs::Tallies::default()
    }
}
