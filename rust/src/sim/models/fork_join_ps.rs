//! Classic per-server fork-join (Fig. 4(a)): task i of each job is bound
//! to server i on arrival; each server runs its own FIFO queue. This is
//! the k = l baseline of Fig. 3 — tiny tasks make no difference here
//! (Sec. 1.2), so the model requires k = l.

use super::Model;
use crate::sim::{JobRecord, OverheadModel, TraceEvent, TraceLog, Workload};

/// Per-server fork-join with l servers (k = l tasks per job).
pub struct ForkJoinPerServer {
    /// Per-server "free at" times (tail of each server's FIFO queue).
    free: Vec<f64>,
}

impl ForkJoinPerServer {
    /// New model with `l` servers.
    pub fn new(l: usize) -> Self {
        assert!(l >= 1);
        Self { free: vec![0.0; l] }
    }
}

impl Model for ForkJoinPerServer {
    fn advance(
        &mut self,
        n: usize,
        arrival: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        let mut workload_sum = 0.0;
        let mut overhead_sum = 0.0;
        let mut last_finish = f64::NEG_INFINITY;
        let mut first_start = f64::INFINITY;
        for (i, free) in self.free.iter_mut().enumerate() {
            let e = workload.next_execution();
            let o = overhead.sample_task(workload.rng());
            workload_sum += e;
            overhead_sum += o;
            let start = free.max(arrival);
            let finish = start + e + o;
            *free = finish;
            first_start = first_start.min(start);
            last_finish = last_finish.max(finish);
            if trace.is_enabled() {
                trace.record(TraceEvent {
                    job: n as u32,
                    task: i as u32,
                    server: i as u32,
                    start,
                    end: finish,
                });
            }
        }
        let pd = overhead.pre_departure(self.free.len());
        JobRecord {
            index: n,
            arrival,
            departure: last_finish + pd,
            first_start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
        }
    }

    fn name(&self) -> &'static str {
        "fork-join-per-server"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Deterministic, Exponential};

    /// l = 1 reduces to the single-server Lindley recursion.
    #[test]
    fn single_server_case() {
        let mut m = ForkJoinPerServer::new(1);
        let mut w = Workload::new(
            Box::new(Exponential::new(0.5)),
            Box::new(Exponential::new(1.0)),
            11,
        );
        let mut w2 = Workload::new(
            Box::new(Exponential::new(0.5)),
            Box::new(Exponential::new(1.0)),
            11,
        );
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let mut d_prev: f64 = 0.0;
        for n in 0..2000 {
            let a = w.next_arrival();
            let r = m.advance(n, a, &mut w, &oh, &mut tr);
            let a2 = w2.next_arrival();
            let s2 = w2.next_execution();
            d_prev = a2.max(d_prev) + s2;
            assert!((r.departure - d_prev).abs() < 1e-9);
        }
    }

    /// A straggler on one server blocks later jobs' tasks on that server
    /// even while other servers idle — the defining FJ-per-server effect.
    #[test]
    fn straggler_blocks_per_server_queue() {
        let mut m = ForkJoinPerServer::new(2);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        // Job 0: tasks (10, 10) — both servers busy until t = 10.
        let mut w0 = Workload::new(
            Box::new(Deterministic::new(0.0)),
            Box::new(Deterministic::new(10.0)),
            1,
        );
        let r0 = m.advance(0, 0.0, &mut w0, &oh, &mut tr);
        assert!((r0.departure - 10.0).abs() < 1e-12);
        // Job 1 arrives at t = 1 with short tasks; must wait until 10.
        let mut w1 = Workload::new(
            Box::new(Deterministic::new(1.0)),
            Box::new(Deterministic::new(0.5)),
            1,
        );
        let a1 = w1.next_arrival();
        let r1 = m.advance(1, a1, &mut w1, &oh, &mut tr);
        assert!((r1.first_start - 10.0).abs() < 1e-12);
        assert!((r1.departure - 10.5).abs() < 1e-12);
    }
}
