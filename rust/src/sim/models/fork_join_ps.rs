//! Classic per-server fork-join (Fig. 4(a)): task i of each job is bound
//! to server i on arrival; each server runs its own FIFO queue. This is
//! the k = l baseline of Fig. 3 — tiny tasks make no difference here
//! (Sec. 1.2), so the model requires k = l.

use super::Model;
use crate::sim::{
    FaultInjector, JobRecord, OverheadModel, Scenario, TraceEvent, TraceLog, Workload,
};
use crate::trace::cause;

/// Per-server fork-join with l servers (k = l tasks per job).
pub struct ForkJoinPerServer {
    /// Per-server "free at" times (tail of each server's FIFO queue).
    free: Vec<f64>,
    /// Heterogeneous-speed / redundancy scenario; `None` keeps the
    /// homogeneous hot path bit-for-bit unchanged. Task `i`'s replicas
    /// are bound to servers `i, i+1, …, i+r−1 (mod l)` — placement is
    /// static (the defining property of this model), only widened.
    scenario: Option<Scenario>,
    /// Fault injection (crashes + bounded retries on the task's own
    /// server; speculation and scenario composition are rejected for
    /// this model at config validation). `None` keeps the fault-free
    /// paths bit-for-bit unchanged.
    faults: Option<FaultInjector>,
    /// Raw obs tallies (jobs, dispatches).
    tallies: crate::obs::Tallies,
}

impl ForkJoinPerServer {
    /// New model with `l` servers.
    pub fn new(l: usize) -> Self {
        assert!(l >= 1);
        Self {
            free: vec![0.0; l],
            scenario: None,
            faults: None,
            tallies: crate::obs::Tallies::default(),
        }
    }

    /// Attach a heterogeneous-worker / redundancy scenario.
    pub fn with_scenario(mut self, scenario: Option<Scenario>) -> Self {
        if let Some(sc) = &scenario {
            assert_eq!(sc.speeds().len(), self.free.len(), "scenario arity");
        }
        self.scenario = scenario;
        self
    }

    /// Attach a fault injector (worker crashes + per-task retries).
    pub fn with_faults(mut self, faults: Option<FaultInjector>) -> Self {
        self.faults = faults;
        self
    }

    /// Job body under fault injection: each task retries on its own
    /// bound server (static placement is the defining property of this
    /// model, so recovery cannot migrate the task).
    fn advance_faulty(
        &mut self,
        n: usize,
        arrival: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        let fi = self.faults.as_mut().expect("faulty path");
        let mut workload_sum = 0.0;
        let mut overhead_sum = 0.0;
        let mut lost_sum = 0.0;
        let mut retries_sum = 0u32;
        let mut last_finish = f64::NEG_INFINITY;
        let mut first_start = f64::INFINITY;
        for (i, free) in self.free.iter_mut().enumerate() {
            let (out, new_free) = fi.dispatch_task_on(
                i as u32,
                *free,
                arrival,
                workload,
                overhead,
                n as u32,
                i as u32,
                trace,
            );
            *free = new_free;
            workload_sum += out.work;
            overhead_sum += out.overhead;
            lost_sum += out.lost;
            retries_sum += out.retries;
            first_start = first_start.min(out.first_start);
            last_finish = last_finish.max(out.finish);
        }
        let pd = overhead.pre_departure(self.free.len());
        JobRecord {
            index: n,
            arrival,
            departure: last_finish + pd,
            first_start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
            redundant_work: 0.0,
            lost_work: lost_sum,
            retries: retries_sum,
        }
    }

    fn advance_scenario(
        &mut self,
        n: usize,
        arrival: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        let sc = self.scenario.as_ref().expect("scenario path");
        let l = self.free.len();
        let r = sc.replicas().min(l);
        let mut workload_sum = 0.0;
        let mut overhead_sum = 0.0;
        let mut redundant_sum = 0.0;
        let mut last_finish = f64::NEG_INFINITY;
        let mut first_start = f64::INFINITY;
        // (start, finish, exec, overhead) per replica of the current task.
        let mut reps: Vec<(f64, f64, f64, f64)> = Vec::with_capacity(r);
        for i in 0..l {
            reps.clear();
            for j in 0..r {
                let s = (i + j) % l;
                let e = workload.next_execution();
                let o = overhead.sample_task(workload.rng());
                let start = self.free[s].max(arrival);
                // Term-by-term so speed 1.0 matches `start + e + o` bitwise.
                let speed = sc.speed(s as u32);
                let finish = start + e / speed + o / speed;
                reps.push((start, finish, e, o));
            }
            let mut win = 0usize;
            for (j, rep) in reps.iter().enumerate().skip(1) {
                if rep.1 < reps[win].1 {
                    win = j;
                }
            }
            let t_win = reps[win].1;
            workload_sum += reps[win].2;
            overhead_sum += reps[win].3;
            last_finish = last_finish.max(t_win);
            for (j, &(start, finish, _, oh)) in reps.iter().enumerate() {
                let s = (i + j) % l;
                let ran = j == win || start < t_win;
                if !ran {
                    continue; // never started: server queue unchanged
                }
                let freed = if j == win { finish } else { t_win };
                self.free[s] = freed;
                first_start = first_start.min(start);
                if j != win {
                    redundant_sum += t_win - start;
                    // Losers resolve inline here (not via the Scenario
                    // dispatcher), so tally them on the model.
                    self.tallies.replica_losers += 1;
                }
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job: n as u32,
                        task: i as u32,
                        server: s as u32,
                        start,
                        end: freed,
                        // Wall overhead on this worker, clipped for
                        // replicas cancelled before finishing theirs.
                        overhead: (oh / sc.speed(s as u32)).min(freed - start),
                        winner: j == win,
                        attempt: 1,
                        cause: cause::NONE,
                        class: 0,
                    });
                }
            }
        }
        let pd = overhead.pre_departure(l);
        JobRecord {
            index: n,
            arrival,
            departure: last_finish + pd,
            first_start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
            redundant_work: redundant_sum,
            lost_work: 0.0,
            retries: 0,
        }
    }
}

impl Model for ForkJoinPerServer {
    fn advance(
        &mut self,
        n: usize,
        arrival: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        self.tallies.jobs += 1;
        self.tallies.dispatched += self.free.len() as u64;
        if self.faults.is_some() {
            return self.advance_faulty(n, arrival, workload, overhead, trace);
        }
        if self.scenario.is_some() {
            return self.advance_scenario(n, arrival, workload, overhead, trace);
        }
        let mut workload_sum = 0.0;
        let mut overhead_sum = 0.0;
        let mut last_finish = f64::NEG_INFINITY;
        let mut first_start = f64::INFINITY;
        for (i, free) in self.free.iter_mut().enumerate() {
            let e = workload.next_execution();
            let o = overhead.sample_task(workload.rng());
            workload_sum += e;
            overhead_sum += o;
            let start = free.max(arrival);
            let finish = start + e + o;
            *free = finish;
            first_start = first_start.min(start);
            last_finish = last_finish.max(finish);
            if trace.is_enabled() {
                trace.record(TraceEvent {
                    job: n as u32,
                    task: i as u32,
                    server: i as u32,
                    start,
                    end: finish,
                    overhead: o,
                    winner: true,
                    attempt: 1,
                    cause: cause::NONE,
                    class: 0,
                });
            }
        }
        let pd = overhead.pre_departure(self.free.len());
        JobRecord {
            index: n,
            arrival,
            departure: last_finish + pd,
            first_start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
            redundant_work: 0.0,
            lost_work: 0.0,
            retries: 0,
        }
    }

    fn name(&self) -> &'static str {
        "fork-join-per-server"
    }

    fn tallies(&self) -> crate::obs::Tallies {
        // No ServerHeap here — per-server queues are a flat free-time
        // vector, so the model contributes no heap ops.
        let mut t = self.tallies.clone();
        if let Some(sc) = &self.scenario {
            t.replica_losers += sc.loser_count();
        }
        if let Some(fi) = &self.faults {
            t.crashes += fi.crash_count();
            t.retries += fi.retry_count();
            t.spec_launches += fi.spec_count();
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Deterministic, Exponential};

    /// l = 1 reduces to the single-server Lindley recursion.
    #[test]
    fn single_server_case() {
        let mut m = ForkJoinPerServer::new(1);
        let mut w = Workload::new(Exponential::new(0.5).into(), Exponential::new(1.0).into(), 11);
        let mut w2 = Workload::new(Exponential::new(0.5).into(), Exponential::new(1.0).into(), 11);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let mut d_prev: f64 = 0.0;
        for n in 0..2000 {
            let a = w.next_arrival();
            let r = m.advance(n, a, &mut w, &oh, &mut tr);
            let a2 = w2.next_arrival();
            let s2 = w2.next_execution();
            d_prev = a2.max(d_prev) + s2;
            assert!((r.departure - d_prev).abs() < 1e-9);
        }
    }

    /// A straggler on one server blocks later jobs' tasks on that server
    /// even while other servers idle — the defining FJ-per-server effect.
    #[test]
    fn straggler_blocks_per_server_queue() {
        let mut m = ForkJoinPerServer::new(2);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        // Job 0: tasks (10, 10) — both servers busy until t = 10.
        let mut w0 = Workload::new(
            Deterministic::new(0.0).into(),
            Deterministic::new(10.0).into(),
            1,
        );
        let r0 = m.advance(0, 0.0, &mut w0, &oh, &mut tr);
        assert!((r0.departure - 10.0).abs() < 1e-12);
        // Job 1 arrives at t = 1 with short tasks; must wait until 10.
        let mut w1 = Workload::new(
            Deterministic::new(1.0).into(),
            Deterministic::new(0.5).into(),
            1,
        );
        let a1 = w1.next_arrival();
        let r1 = m.advance(1, a1, &mut w1, &oh, &mut tr);
        assert!((r1.first_start - 10.0).abs() < 1e-12);
        assert!((r1.departure - 10.5).abs() < 1e-12);
    }
}
