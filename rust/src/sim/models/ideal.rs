//! Ideal job partition (Sec. 3.2.4): each job's workload L(n) — generated
//! as k task draws to keep the workload distribution identical to the
//! other models — is divided into l *equisized* tasks. All l tasks start
//! and finish in unison, so the system behaves exactly like a single
//! FIFO server with service time `L(n)/l` (plus overhead).

use super::Model;
use crate::sim::{JobRecord, OverheadModel, Scenario, TraceEvent, TraceLog, Workload};
use crate::trace::cause;

/// Ideal partition over l servers; workload sampled as k task draws.
pub struct IdealPartition {
    l: usize,
    k: usize,
    /// Aggregate service capacity: `l` for homogeneous workers, Σ speeds
    /// for a heterogeneous scenario. The ideal partitioner is assumed to
    /// know the speeds and split the workload proportionally, so the job
    /// service share is `L(n) / total_speed`. Redundancy is meaningless
    /// under perfect equisized partitioning; `SimulationConfig::validate`
    /// rejects `replicas > 1` for this model.
    total_speed: f64,
    prev_departure: f64,
    /// Raw obs tallies (jobs, dispatches — the `l` equisized shares).
    tallies: crate::obs::Tallies,
}

impl IdealPartition {
    /// New model: workload = sum of `k` execution draws, run as `l` equal
    /// tasks on `l` servers.
    pub fn new(l: usize, k: usize) -> Self {
        assert!(l >= 1 && k >= 1);
        Self {
            l,
            k,
            total_speed: l as f64,
            prev_departure: 0.0,
            tallies: crate::obs::Tallies::default(),
        }
    }

    /// Attach a heterogeneous-worker scenario (speeds only).
    pub fn with_scenario(mut self, scenario: Option<Scenario>) -> Self {
        if let Some(sc) = &scenario {
            assert_eq!(sc.speeds().len(), self.l, "scenario arity");
            self.total_speed = sc.total_speed();
        }
        self
    }
}

impl Model for IdealPartition {
    fn advance(
        &mut self,
        n: usize,
        arrival: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        self.tallies.jobs += 1;
        self.tallies.dispatched += self.l as u64;
        let mut workload_sum = 0.0;
        for _ in 0..self.k {
            workload_sum += workload.next_execution();
        }
        // Each of the l equisized tasks pays task-service overhead; they
        // run in lockstep so the job's service time is governed by the
        // slowest (max overhead) share.
        let mut max_overhead = 0.0f64;
        let mut overhead_sum = 0.0;
        for _ in 0..self.l {
            let o = overhead.sample_task(workload.rng());
            overhead_sum += o;
            max_overhead = max_overhead.max(o);
        }
        let start = arrival.max(self.prev_departure);
        let share = workload_sum / self.total_speed;
        let finish = start + share + max_overhead;
        let pd = overhead.pre_departure(self.l);
        let departure = finish + pd;
        self.prev_departure = departure;
        if trace.is_enabled() {
            for s in 0..self.l {
                trace.record(TraceEvent {
                    job: n as u32,
                    task: s as u32,
                    server: s as u32,
                    start,
                    end: finish,
                    // All l equisized shares stall on the slowest draw.
                    overhead: max_overhead,
                    winner: true,
                    attempt: 1,
                    cause: cause::NONE,
                    class: 0,
                });
            }
        }
        JobRecord {
            index: n,
            arrival,
            departure,
            first_start: start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
            redundant_work: 0.0,
            lost_work: 0.0,
            retries: 0,
        }
    }

    fn name(&self) -> &'static str {
        "ideal"
    }

    fn tallies(&self) -> crate::obs::Tallies {
        self.tallies.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Deterministic, Exponential};

    #[test]
    fn behaves_as_single_server_with_scaled_service() {
        let (l, k) = (4usize, 4usize);
        let mut m = IdealPartition::new(l, k);
        let mut w = Workload::new(
            Deterministic::new(3.0).into(),
            Deterministic::new(1.0).into(),
            1,
        );
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let a = w.next_arrival();
        let r = m.advance(0, a, &mut w, &oh, &mut tr);
        // L = 4, share = 1 → sojourn 1.
        assert!((r.sojourn() - 1.0).abs() < 1e-12);
    }

    /// The ideal partition's mean job service time is E[L]/l — strictly
    /// smaller than split-merge's Lemma-1 value for the same workload.
    #[test]
    fn beats_split_merge_service_time() {
        let (l, k) = (10usize, 10usize);
        let mut m = IdealPartition::new(l, k);
        let mut w = Workload::new(Deterministic::new(1e6).into(), Exponential::new(1.0).into(), 5);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let n = 10_000;
        let mut sum = 0.0;
        for i in 0..n {
            let a = w.next_arrival();
            sum += m.advance(i, a, &mut w, &oh, &mut tr).service_time();
        }
        let mean = sum / n as f64;
        // E[L]/l = k/(mu l) = 1.
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
        // Split-merge equivalent is H_10 ≈ 2.93 — ideal is far better.
        assert!(mean < 1.5);
    }
}
