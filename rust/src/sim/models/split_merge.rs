//! Tiny-tasks split-merge model (Fig. 5).
//!
//! Jobs queue FIFO; the head-of-line job is split into k tasks which feed
//! the l servers from a task queue; when all k tasks finish (merge) plus
//! the pre-departure overhead elapses, the job departs and the next one
//! may start. All servers are idle at the start of each job (the defining
//! barrier of the model), so the per-job makespan Δ(n) is computed on a
//! freshly reset server heap — exactly Eq. 15/16's recursion
//! `D(n) = max(A(n), D(n−1)) + Δ(n)`.

use super::Model;
use crate::sim::{
    FaultInjector, JobRecord, OverheadModel, PolicyState, Scenario, ServerHeap, TraceEvent,
    TraceLog, Workload,
};
use crate::trace::cause;

/// Split-merge with l servers and k tasks per job.
pub struct SplitMerge {
    k: usize,
    heap: ServerHeap,
    prev_departure: f64,
    /// Heterogeneous-speed / redundancy scenario; `None` keeps the
    /// homogeneous hot path bit-for-bit unchanged.
    scenario: Option<Scenario>,
    /// Fault injection (crashes, retries, speculation); `None` keeps
    /// every fault-free path bit-for-bit unchanged.
    faults: Option<FaultInjector>,
    /// Dispatch policy (SITA / priority / work stealing); `None` keeps
    /// the seed FCFS dispatch bit-for-bit unchanged.
    policy: Option<PolicyState>,
    /// Raw obs tallies (jobs, dispatches, per-class routing).
    tallies: crate::obs::Tallies,
}

impl SplitMerge {
    /// New model with `l` servers, `k ≥ l` tasks per job.
    pub fn new(l: usize, k: usize) -> Self {
        assert!(l >= 1 && k >= l, "split-merge requires k >= l >= 1");
        Self {
            k,
            heap: ServerHeap::new(l, 0.0),
            prev_departure: 0.0,
            scenario: None,
            faults: None,
            policy: None,
            tallies: crate::obs::Tallies::default(),
        }
    }

    /// Attach a heterogeneous-worker / redundancy scenario.
    pub fn with_scenario(mut self, scenario: Option<Scenario>) -> Self {
        if let Some(sc) = &scenario {
            assert_eq!(sc.speeds().len(), self.heap.len(), "scenario arity");
        }
        self.scenario = scenario;
        self
    }

    /// Attach a fault injector (worker crashes, retries, speculation).
    pub fn with_faults(mut self, faults: Option<FaultInjector>) -> Self {
        self.faults = faults;
        self
    }

    /// Attach a dispatch policy (SITA / priority / work stealing).
    pub fn with_policy(mut self, policy: Option<PolicyState>) -> Self {
        self.policy = policy;
        self
    }

    /// Job body under an active dispatch policy, composing with the
    /// scenario dispatcher and fault injector per task. The split-merge
    /// barrier applies to the policy's own server state: fault-free it
    /// resets every group to the start (all servers idle), under faults
    /// it only raises free times (repairs span the barrier); the
    /// makespan is the last task finish either way.
    fn advance_policy(
        &mut self,
        n: usize,
        arrival: f64,
        start: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        let pol = self.policy.as_mut().expect("policy path");
        if self.faults.is_some() {
            pol.raise_to(start);
        } else {
            pol.reset_all(start);
        }
        let mut workload_sum = 0.0;
        let mut overhead_sum = 0.0;
        let mut redundant_sum = 0.0;
        let mut lost_sum = 0.0;
        let mut retries_sum = 0u32;
        let mut last_finish = f64::NEG_INFINITY;
        for i in 0..self.k {
            let out = pol.dispatch_task(
                start,
                n,
                i as u32,
                &mut self.scenario,
                &mut self.faults,
                workload,
                overhead,
                trace,
            );
            self.tallies.class_dispatch(out.class as usize);
            workload_sum += out.work;
            overhead_sum += out.overhead;
            redundant_sum += out.redundant;
            lost_sum += out.lost;
            retries_sum += out.retries;
            if out.finish > last_finish {
                last_finish = out.finish;
            }
        }
        let pd = overhead.pre_departure(self.k);
        let departure = last_finish + pd;
        self.prev_departure = departure;
        JobRecord {
            index: n,
            arrival,
            departure,
            first_start: start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
            redundant_work: redundant_sum,
            lost_work: lost_sum,
            retries: retries_sum,
        }
    }

    /// Job body under fault injection. Differs from the fault-free path
    /// in two load-bearing ways: the barrier *raises* free times to the
    /// start instead of resetting them (a worker under repair rejoins
    /// only when repaired, even across the barrier), and the makespan is
    /// the last **task** finish rather than `heap.max_time()` (a repair
    /// window outlasting every task must not delay the departure).
    fn advance_faulty(
        &mut self,
        n: usize,
        arrival: f64,
        start: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        self.heap.raise_to(start);
        let mut workload_sum = 0.0;
        let mut overhead_sum = 0.0;
        let mut redundant_sum = 0.0;
        let mut lost_sum = 0.0;
        let mut retries_sum = 0u32;
        let mut last_finish = f64::NEG_INFINITY;
        for i in 0..self.k {
            let out = if let Some(sc) = &mut self.scenario {
                let fi = self.faults.as_mut().expect("faulty path");
                sc.dispatch_task_faulty(
                    &mut self.heap,
                    start,
                    workload,
                    overhead,
                    fi,
                    n as u32,
                    i as u32,
                    0,
                    trace,
                )
            } else {
                let fi = self.faults.as_mut().expect("faulty path");
                fi.dispatch_task(
                    &mut self.heap,
                    start,
                    workload,
                    overhead,
                    n as u32,
                    i as u32,
                    trace,
                )
            };
            workload_sum += out.work;
            overhead_sum += out.overhead;
            redundant_sum += out.redundant;
            lost_sum += out.lost;
            retries_sum += out.retries;
            if out.finish > last_finish {
                last_finish = out.finish;
            }
        }
        let pd = overhead.pre_departure(self.k);
        let departure = last_finish + pd;
        self.prev_departure = departure;
        JobRecord {
            index: n,
            arrival,
            departure,
            first_start: start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
            redundant_work: redundant_sum,
            lost_work: lost_sum,
            retries: retries_sum,
        }
    }
}

impl Model for SplitMerge {
    fn advance(
        &mut self,
        n: usize,
        arrival: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        // Start barrier: job starts when it arrives AND the previous job
        // has departed; all servers are idle at that instant.
        let start = arrival.max(self.prev_departure);
        self.tallies.jobs += 1;
        self.tallies.dispatched += self.k as u64;
        if self.policy.is_some() {
            return self.advance_policy(n, arrival, start, workload, overhead, trace);
        }
        if self.faults.is_some() {
            return self.advance_faulty(n, arrival, start, workload, overhead, trace);
        }
        self.heap.reset_all(start);

        let mut workload_sum = 0.0;
        let mut overhead_sum = 0.0;
        let mut redundant_sum = 0.0;
        if let Some(sc) = &mut self.scenario {
            for i in 0..self.k {
                let out = sc.dispatch_task(
                    &mut self.heap,
                    start,
                    workload,
                    overhead,
                    n as u32,
                    i as u32,
                    0,
                    trace,
                );
                workload_sum += out.work;
                overhead_sum += out.overhead;
                redundant_sum += out.redundant_time;
            }
        } else if trace.is_enabled() {
            for i in 0..self.k {
                let e = workload.next_execution();
                let o = overhead.sample_task(workload.rng());
                workload_sum += e;
                overhead_sum += o;
                let (t_free, server) = self.heap.peek();
                let finish = t_free + e + o;
                self.heap.assign(finish);
                trace.record(TraceEvent {
                    job: n as u32,
                    task: i as u32,
                    server,
                    start: t_free,
                    end: finish,
                    overhead: o,
                    winner: true,
                    attempt: 1,
                    cause: cause::NONE,
                    class: 0,
                });
            }
        } else {
            for _ in 0..self.k {
                let e = workload.next_execution();
                let o = overhead.sample_task(workload.rng());
                workload_sum += e;
                overhead_sum += o;
                let (t_free, _) = self.heap.peek();
                self.heap.assign(t_free + e + o);
            }
        }

        let makespan_end = self.heap.max_time();
        // Pre-departure overhead blocks the next job in split-merge.
        let pd = overhead.pre_departure(self.k);
        let departure = makespan_end + pd;
        self.prev_departure = departure;

        JobRecord {
            index: n,
            arrival,
            departure,
            first_start: start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
            redundant_work: redundant_sum,
            lost_work: 0.0,
            retries: 0,
        }
    }

    fn name(&self) -> &'static str {
        "split-merge"
    }

    fn tallies(&self) -> crate::obs::Tallies {
        let mut t = self.tallies.clone();
        let (pushes, pops) = self.heap.ops();
        t.heap_pushes += pushes;
        t.heap_pops += pops;
        if let Some(sc) = &self.scenario {
            t.replica_losers += sc.loser_count();
        }
        if let Some(fi) = &self.faults {
            t.crashes += fi.crash_count();
            t.retries += fi.retry_count();
            t.spec_launches += fi.spec_count();
        }
        if let Some(pol) = &self.policy {
            t.steals += pol.steal_count();
            let (p, q) = pol.heap_ops();
            t.heap_pushes += p;
            t.heap_pops += q;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Deterministic, Exponential};

    fn det_workload(interarrival: f64, exec: f64) -> Workload {
        Workload::new(Deterministic::new(interarrival).into(), Deterministic::new(exec).into(), 1)
    }

    /// Deterministic sanity: l=2, k=4, exec=1 → each server runs 2 tasks,
    /// Δ = 2; with inter-arrival 10 the system idles between jobs.
    #[test]
    fn deterministic_makespan() {
        let mut m = SplitMerge::new(2, 4);
        let mut w = det_workload(10.0, 1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let a1 = w.next_arrival();
        let r1 = m.advance(0, a1, &mut w, &oh, &mut tr);
        assert!((r1.sojourn() - 2.0).abs() < 1e-12);
        assert!((r1.workload - 4.0).abs() < 1e-12);
        let a2 = w.next_arrival();
        let r2 = m.advance(1, a2, &mut w, &oh, &mut tr);
        assert!((r2.arrival - 20.0).abs() < 1e-12);
        assert!((r2.sojourn() - 2.0).abs() < 1e-12);
    }

    /// Blocking: with inter-arrival 1 and Δ=2, job n waits for job n−1.
    #[test]
    fn departure_barrier_blocks() {
        let mut m = SplitMerge::new(2, 4);
        let mut w = det_workload(1.0, 1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let mut last_departure = 0.0;
        for n in 0..10 {
            let a = w.next_arrival();
            let r = m.advance(n, a, &mut w, &oh, &mut tr);
            assert!(r.first_start >= last_departure - 1e-12, "start barrier");
            assert!(r.departure >= last_departure, "FIFO departures");
            last_departure = r.departure;
        }
        // First arrival at t = 1; D(n) = D(n−1) + 2 → D(9) = 3 + 18 = 21.
        assert!((last_departure - 21.0).abs() < 1e-12);
    }

    /// k = l with exponential tasks: E[Δ] should approach the harmonic
    /// mean-of-maximum identity E[max] = H_l / mu (Sec. 4.2).
    #[test]
    fn big_tasks_mean_makespan_matches_harmonic() {
        let l = 10;
        let mut m = SplitMerge::new(l, l);
        let mut w = Workload::new(
            Deterministic::new(1000.0).into(), // no queueing
            Exponential::new(1.0).into(),
            42,
        );
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let n = 20_000;
        let mut sum = 0.0;
        for i in 0..n {
            let a = w.next_arrival();
            sum += m.advance(i, a, &mut w, &oh, &mut tr).service_time();
        }
        let mean = sum / n as f64;
        let expect = crate::util::math::harmonic(l as u64);
        assert!(
            (mean - expect).abs() / expect < 0.02,
            "E[Δ]={mean} vs H_l={expect}"
        );
    }

    /// Tiny-tasks mean service time matches Lemma 1:
    /// E[Δ] = (k/l + Σ_{i=2}^{l} 1/i) / mu.
    #[test]
    fn tiny_tasks_mean_service_matches_lemma1() {
        let (l, k) = (10usize, 50usize);
        let mut m = SplitMerge::new(l, k);
        let mut w = Workload::new(
            Deterministic::new(1000.0).into(),
            Exponential::new(1.0).into(),
            7,
        );
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let n = 20_000;
        let mut sum = 0.0;
        for i in 0..n {
            let a = w.next_arrival();
            sum += m.advance(i, a, &mut w, &oh, &mut tr).service_time();
        }
        let mean = sum / n as f64;
        let expect =
            k as f64 / l as f64 + crate::util::math::harmonic(l as u64) - 1.0;
        assert!(
            (mean - expect).abs() / expect < 0.02,
            "E[Δ]={mean} vs Lemma 1 {expect}"
        );
    }

    /// A fast worker shortens the deterministic makespan: with speeds
    /// (1, 3) the fast server clears three unit tasks while the slow one
    /// serves one, so Δ = 1 instead of the homogeneous 2.
    #[test]
    fn heterogeneous_speeds_shorten_makespan() {
        let mut m = SplitMerge::new(2, 4)
            .with_scenario(Some(Scenario::new(vec![1.0, 3.0], 1)));
        let mut w = det_workload(10.0, 1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let a = w.next_arrival();
        let r = m.advance(0, a, &mut w, &oh, &mut tr);
        assert!((r.sojourn() - 1.0).abs() < 1e-12, "{}", r.sojourn());
    }

    /// First-finish-wins redundancy cuts the exponential makespan:
    /// l = k = 2, r = 2 serializes the two tasks but each takes
    /// min(Exp, Exp) — E[Δ] = 1 versus E[max(Exp, Exp)] = 1.5 at r = 1.
    #[test]
    fn redundancy_beats_stragglers_for_exponential_tasks() {
        let run_mean = |replicas: usize| {
            let sc = Scenario::new(vec![1.0, 1.0], replicas);
            let mut m = SplitMerge::new(2, 2).with_scenario(Some(sc));
            let mut w = Workload::new(
                Deterministic::new(1000.0).into(),
                Exponential::new(1.0).into(),
                13,
            );
            let oh = OverheadModel::none();
            let mut tr = TraceLog::disabled();
            let n = 20_000;
            let mut sum = 0.0;
            let mut redundant = 0.0;
            for i in 0..n {
                let a = w.next_arrival();
                let r = m.advance(i, a, &mut w, &oh, &mut tr);
                sum += r.service_time();
                redundant += r.redundant_work;
            }
            (sum / n as f64, redundant / n as f64)
        };
        let (m1, red1) = run_mean(1);
        let (m2, red2) = run_mean(2);
        assert!((m1 - 1.5).abs() < 0.03, "r=1 E[Δ]={m1}");
        assert!((m2 - 1.0).abs() < 0.03, "r=2 E[Δ]={m2}");
        assert_eq!(red1, 0.0);
        assert!(red2 > 0.5, "cancelled replicas must be accounted: {red2}");
    }

    /// Pre-departure overhead delays the next job (blocking).
    #[test]
    fn pre_departure_blocks_next_job() {
        let oh = OverheadModel::new(crate::config::OverheadConfig {
            c_task_ts: 0.0,
            mu_task_ts: f64::INFINITY,
            c_job_pd: 5.0,
            c_task_pd: 0.0,
        });
        let mut m = SplitMerge::new(1, 1);
        let mut w = det_workload(0.5, 1.0);
        let mut tr = TraceLog::disabled();
        let a1 = w.next_arrival();
        let r1 = m.advance(0, a1, &mut w, &oh, &mut tr);
        assert!((r1.departure - (0.5 + 1.0 + 5.0)).abs() < 1e-12);
        let a2 = w.next_arrival();
        let r2 = m.advance(1, a2, &mut w, &oh, &mut tr);
        // Job 2 can only start at r1.departure.
        assert!((r2.first_start - r1.departure).abs() < 1e-12);
    }
}
