//! Tiny-tasks single-queue fork-join model (Sec. 5).
//!
//! All tasks of all jobs wait in one global FIFO queue; a server takes the
//! head-of-line task the moment it becomes free. There is no start or
//! departure barrier, so small jobs can overtake jobs with stragglers —
//! the behaviour of Spark/Hadoop with a multi-threaded driver (Sec. 1.1).
//!
//! The exact recursion: tasks are dequeued in global FIFO order, so the
//! i-th task overall is served by the earliest-free server, starting at
//! `max(server_free, A(n))`. The paper's analytic model (Th. 2) adds an
//! in-order-departure constraint (`D(n) ≤ D(n+1)`); simulation supports
//! both the real system (default) and the constrained variant for
//! apples-to-apples bound validation.

use super::Model;
use crate::sim::{
    FaultInjector, JobRecord, OverheadModel, PolicyState, Scenario, ServerHeap, TraceEvent,
    TraceLog, Workload,
};
use crate::trace::cause;

/// Single-queue fork-join with l servers and k tasks per job.
pub struct ForkJoinSingleQueue {
    k: usize,
    heap: ServerHeap,
    /// Enforce `D(n) ≥ D(n−1)` as in the Th.-2 model (default false).
    in_order_departures: bool,
    prev_departure: f64,
    /// Heterogeneous-speed / redundancy scenario; `None` keeps the
    /// homogeneous hot path bit-for-bit unchanged.
    scenario: Option<Scenario>,
    /// Fault injection (crashes, retries, speculation); `None` keeps
    /// every fault-free path bit-for-bit unchanged.
    faults: Option<FaultInjector>,
    /// Dispatch policy (SITA / priority / work stealing); `None` keeps
    /// the seed FCFS dispatch bit-for-bit unchanged.
    policy: Option<PolicyState>,
    /// Raw obs tallies (jobs, dispatches, per-class routing).
    tallies: crate::obs::Tallies,
}

impl ForkJoinSingleQueue {
    /// New model with `l` servers and `k ≥ l` tasks per job.
    pub fn new(l: usize, k: usize) -> Self {
        assert!(l >= 1 && k >= 1, "fork-join requires k,l >= 1");
        Self {
            k,
            heap: ServerHeap::new(l, 0.0),
            in_order_departures: false,
            prev_departure: 0.0,
            scenario: None,
            faults: None,
            policy: None,
            tallies: crate::obs::Tallies::default(),
        }
    }

    /// Enable the Th.-2 in-order departure constraint.
    pub fn with_in_order_departures(mut self, yes: bool) -> Self {
        self.in_order_departures = yes;
        self
    }

    /// Attach a heterogeneous-worker / redundancy scenario.
    pub fn with_scenario(mut self, scenario: Option<Scenario>) -> Self {
        if let Some(sc) = &scenario {
            assert_eq!(sc.speeds().len(), self.heap.len(), "scenario arity");
        }
        self.scenario = scenario;
        self
    }

    /// Attach a fault injector (worker crashes, retries, speculation).
    pub fn with_faults(mut self, faults: Option<FaultInjector>) -> Self {
        self.faults = faults;
        self
    }

    /// Attach a dispatch policy (SITA / priority / work stealing).
    pub fn with_policy(mut self, policy: Option<PolicyState>) -> Self {
        self.policy = policy;
        self
    }
}

impl Model for ForkJoinSingleQueue {
    fn advance(
        &mut self,
        n: usize,
        arrival: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> JobRecord {
        let mut workload_sum = 0.0;
        let mut overhead_sum = 0.0;
        let mut redundant_sum = 0.0;
        let mut lost_sum = 0.0;
        let mut retries_sum = 0u32;
        let mut last_finish = f64::NEG_INFINITY;
        let mut first_start = f64::INFINITY;
        self.tallies.jobs += 1;
        self.tallies.dispatched += self.k as u64;

        if let Some(pol) = &mut self.policy {
            // Policy routing (composing with scenario/faults per task);
            // no barrier — the job's floor is its arrival.
            for i in 0..self.k {
                let out = pol.dispatch_task(
                    arrival,
                    n,
                    i as u32,
                    &mut self.scenario,
                    &mut self.faults,
                    workload,
                    overhead,
                    trace,
                );
                self.tallies.class_dispatch(out.class as usize);
                workload_sum += out.work;
                overhead_sum += out.overhead;
                redundant_sum += out.redundant;
                lost_sum += out.lost;
                retries_sum += out.retries;
                if out.first_start < first_start {
                    first_start = out.first_start;
                }
                if out.finish > last_finish {
                    last_finish = out.finish;
                }
            }
        } else if let Some(sc) = &mut self.scenario {
            if let Some(fi) = &mut self.faults {
                for i in 0..self.k {
                    let out = sc.dispatch_task_faulty(
                        &mut self.heap,
                        arrival,
                        workload,
                        overhead,
                        fi,
                        n as u32,
                        i as u32,
                        0,
                        trace,
                    );
                    workload_sum += out.work;
                    overhead_sum += out.overhead;
                    redundant_sum += out.redundant;
                    lost_sum += out.lost;
                    retries_sum += out.retries;
                    if out.first_start < first_start {
                        first_start = out.first_start;
                    }
                    if out.finish > last_finish {
                        last_finish = out.finish;
                    }
                }
            } else {
                for i in 0..self.k {
                    let out = sc.dispatch_task(
                        &mut self.heap,
                        arrival,
                        workload,
                        overhead,
                        n as u32,
                        i as u32,
                        0,
                        trace,
                    );
                    workload_sum += out.work;
                    overhead_sum += out.overhead;
                    redundant_sum += out.redundant_time;
                    if out.first_start < first_start {
                        first_start = out.first_start;
                    }
                    if out.finish > last_finish {
                        last_finish = out.finish;
                    }
                }
            }
        } else if let Some(fi) = &mut self.faults {
            for i in 0..self.k {
                let out = fi.dispatch_task(
                    &mut self.heap,
                    arrival,
                    workload,
                    overhead,
                    n as u32,
                    i as u32,
                    trace,
                );
                workload_sum += out.work;
                overhead_sum += out.overhead;
                redundant_sum += out.redundant;
                lost_sum += out.lost;
                retries_sum += out.retries;
                if out.first_start < first_start {
                    first_start = out.first_start;
                }
                if out.finish > last_finish {
                    last_finish = out.finish;
                }
            }
        } else {
            for i in 0..self.k {
                let e = workload.next_execution();
                let o = overhead.sample_task(workload.rng());
                workload_sum += e;
                overhead_sum += o;
                let (t_free, server) = self.heap.peek();
                // A task cannot start before its job arrives; idle servers
                // wait for the queue to refill.
                let start = t_free.max(arrival);
                let finish = start + e + o;
                self.heap.assign(finish);
                if start < first_start {
                    first_start = start;
                }
                if finish > last_finish {
                    last_finish = finish;
                }
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job: n as u32,
                        task: i as u32,
                        server,
                        start,
                        end: finish,
                        overhead: o,
                        winner: true,
                        attempt: 1,
                        cause: cause::NONE,
                        class: 0,
                    });
                }
            }
        }

        // Pre-departure overhead is non-blocking in fork-join: it delays
        // this job's departure but not subsequent tasks (Sec. 2.6).
        let pd = overhead.pre_departure(self.k);
        let mut departure = last_finish + pd;
        if self.in_order_departures && departure < self.prev_departure {
            departure = self.prev_departure;
        }
        self.prev_departure = departure;

        JobRecord {
            index: n,
            arrival,
            departure,
            first_start,
            workload: workload_sum,
            task_overhead: overhead_sum,
            pre_departure_overhead: pd,
            redundant_work: redundant_sum,
            lost_work: lost_sum,
            retries: retries_sum,
        }
    }

    fn name(&self) -> &'static str {
        "single-queue-fork-join"
    }

    fn tallies(&self) -> crate::obs::Tallies {
        let mut t = self.tallies.clone();
        let (pushes, pops) = self.heap.ops();
        t.heap_pushes += pushes;
        t.heap_pops += pops;
        if let Some(sc) = &self.scenario {
            t.replica_losers += sc.loser_count();
        }
        if let Some(fi) = &self.faults {
            t.crashes += fi.crash_count();
            t.retries += fi.retry_count();
            t.spec_launches += fi.spec_count();
        }
        if let Some(pol) = &self.policy {
            t.steals += pol.steal_count();
            let (p, q) = pol.heap_ops();
            t.heap_pushes += p;
            t.heap_pops += q;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Deterministic, Exponential};

    fn det_workload(interarrival: f64, exec: f64) -> Workload {
        Workload::new(Deterministic::new(interarrival).into(), Deterministic::new(exec).into(), 1)
    }

    /// No start barrier: with saturating arrivals the servers never idle,
    /// unlike split-merge under identical input.
    #[test]
    fn work_conserving_under_load() {
        let (l, k) = (2usize, 4usize);
        let mut m = ForkJoinSingleQueue::new(l, k);
        let mut w = det_workload(1.0, 1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        for n in 0..20 {
            let a = w.next_arrival();
            m.advance(n, a, &mut w, &oh, &mut tr);
        }
        // Total busy time across both servers over [1, 41]: 20 jobs × 4
        // tasks × 1 s = 80 s of work on 2 servers → fully busy after ramp.
        let u = tr.utilization(l, 5.0, 30.0);
        for &ui in &u {
            assert!(ui > 0.999, "server under-utilized: {ui}");
        }
    }

    /// k = l = 1 must reduce exactly to an M/M/1-style single queue
    /// (Lindley recursion).
    #[test]
    fn reduces_to_single_server() {
        let mut m = ForkJoinSingleQueue::new(1, 1);
        let mut w = Workload::new(Exponential::new(0.5).into(), Exponential::new(1.0).into(), 3);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        // Re-derive the Lindley recursion independently and compare.
        let mut w2 = Workload::new(Exponential::new(0.5).into(), Exponential::new(1.0).into(), 3);
        let mut d_prev = 0.0f64;
        for n in 0..5000 {
            let a = w.next_arrival();
            let r = m.advance(n, a, &mut w, &oh, &mut tr);
            let a2 = w2.next_arrival();
            let s2 = w2.next_execution();
            let d2 = a2.max(d_prev) + s2;
            d_prev = d2;
            assert!((r.departure - d2).abs() < 1e-9, "job {n}");
        }
    }

    /// Jobs can overtake: a job of tiny tasks arriving behind a straggler
    /// departs first when in_order_departures is off, not when it's on.
    #[test]
    fn overtaking_and_in_order_variant() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        /// Scripted "distribution" replaying a fixed task-time sequence.
        #[derive(Debug)]
        struct Script(Vec<f64>, AtomicUsize);
        impl crate::dist::Distribution for Script {
            fn sample(&self, _rng: &mut dyn FnMut() -> f64) -> f64 {
                let i = self.1.fetch_add(1, Ordering::Relaxed);
                self.0[i % self.0.len()]
            }
            fn mean(&self) -> f64 {
                self.0.iter().sum::<f64>() / self.0.len() as f64
            }
            fn variance(&self) -> f64 {
                0.0
            }
            fn label(&self) -> String {
                "script".into()
            }
        }
        // l = 2; job 0 = (straggler 10 s, 0.1 s), job 1 = (0.1 s, 0.1 s)
        // arriving at t = 0.05: server 1 clears job 1 while server 0 is
        // stuck on job 0's straggler.
        let run = |in_order: bool| -> (f64, f64) {
            let mut m = ForkJoinSingleQueue::new(2, 2).with_in_order_departures(in_order);
            let oh = OverheadModel::none();
            let mut tr = TraceLog::disabled();
            let mut w = Workload::new(
                Deterministic::new(0.05).into(),
                crate::dist::Dist::custom(Box::new(Script(
                    vec![10.0, 0.1, 0.1, 0.1],
                    AtomicUsize::new(0),
                ))),
                1,
            );
            let r0 = m.advance(0, 0.0, &mut w, &oh, &mut tr);
            let a1 = w.next_arrival();
            let r1 = m.advance(1, a1, &mut w, &oh, &mut tr);
            (r0.departure, r1.departure)
        };
        let (d0, d1) = run(false);
        assert!(d1 < d0, "overtaking allowed: {d1} !< {d0}");
        let (d0o, d1o) = run(true);
        assert!(d1o >= d0o, "in-order enforced");
    }

    /// Redundancy masks a slow worker: with speeds (1, 0.1) a unit task
    /// landing on the slow server takes 10 s at r = 1; at r = 2 the fast
    /// replica wins and the job departs at 2 s.
    #[test]
    fn redundancy_masks_slow_worker() {
        let run = |replicas: usize| {
            let sc = Scenario::new(vec![1.0, 0.1], replicas);
            let mut m = ForkJoinSingleQueue::new(2, 2).with_scenario(Some(sc));
            let mut w = det_workload(100.0, 1.0);
            let oh = OverheadModel::none();
            let mut tr = TraceLog::disabled();
            let a = w.next_arrival();
            m.advance(0, a, &mut w, &oh, &mut tr).sojourn()
        };
        assert!((run(1) - 10.0).abs() < 1e-12, "{}", run(1));
        assert!((run(2) - 2.0).abs() < 1e-12, "{}", run(2));
    }

    /// Pre-departure overhead does NOT delay subsequent tasks in FJ.
    #[test]
    fn pre_departure_non_blocking() {
        let oh = OverheadModel::new(crate::config::OverheadConfig {
            c_task_ts: 0.0,
            mu_task_ts: f64::INFINITY,
            c_job_pd: 100.0,
            c_task_pd: 0.0,
        });
        let mut m = ForkJoinSingleQueue::new(1, 1);
        let mut w = det_workload(1.0, 0.5);
        let mut tr = TraceLog::disabled();
        let a1 = w.next_arrival();
        let r1 = m.advance(0, a1, &mut w, &oh, &mut tr);
        let a2 = w.next_arrival();
        let r2 = m.advance(1, a2, &mut w, &oh, &mut tr);
        // Job 2's task starts as soon as the server is free from job 1's
        // *task* (1.5), not from job 1's padded departure (101.5).
        assert!((r1.departure - 101.5).abs() < 1e-12);
        assert!((r2.first_start - 2.0).abs() < 1e-12, "{}", r2.first_start);
    }
}
