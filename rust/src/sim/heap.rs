//! A specialized binary min-heap of server free-times.
//!
//! The simulator's innermost loop is "pop the earliest-free server, push
//! back its new free time" — executed once per task (up to 10⁸ times per
//! figure). A hand-rolled flat-array heap over `(free_time, server_id)`
//! avoids `BinaryHeap<Reverse<OrderedFloat>>` wrapper churn and keeps the
//! hot path allocation-free.

/// Min-heap keyed on `f64` free time, carrying the server id for traces.
#[derive(Clone, Debug)]
pub struct ServerHeap {
    // (free_time, server_id), heap-ordered by free_time.
    slots: Vec<(f64, u32)>,
    // Raw op tallies for the obs layer. Unconditional u64 increments on
    // the hot path are cheaper than a would-be enabled-check branch, so
    // metrics-off runs pay nothing they would not pay anyway.
    pushes: u64,
    pops: u64,
}

impl ServerHeap {
    /// Heap of `l` servers, all free at time `t0`.
    pub fn new(l: usize, t0: f64) -> Self {
        assert!(l >= 1, "at least one server");
        Self { slots: (0..l).map(|i| (t0, i as u32)).collect(), pushes: 0, pops: 0 }
    }

    /// Heap over an explicit set of global server ids, all free at `t0` —
    /// the dispatch-policy groups (SITA size intervals, priority classes)
    /// partition one physical cluster into sub-heaps that keep the global
    /// ids, so worker crash schedules and per-worker speeds stay valid.
    pub fn from_servers(ids: impl IntoIterator<Item = u32>, t0: f64) -> Self {
        let slots: Vec<(f64, u32)> = ids.into_iter().map(|i| (t0, i)).collect();
        assert!(!slots.is_empty(), "at least one server");
        // Equal keys: already a valid heap.
        Self { slots, pushes: 0, pops: 0 }
    }

    /// Raw (pushes, pops) op tallies since construction. An [`assign`]
    /// counts as one pop plus one push (it is the fused form of the
    /// pop/push pair the redundancy dispatcher performs explicitly).
    ///
    /// [`assign`]: ServerHeap::assign
    #[inline]
    pub fn ops(&self) -> (u64, u64) {
        (self.pushes, self.pops)
    }

    /// Number of servers.
    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Never empty by construction.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Earliest free time (the heap root) without removing it.
    #[inline]
    pub fn peek(&self) -> (f64, u32) {
        self.slots[0]
    }

    /// Replace the root's free time with `new_time` (the popped server has
    /// been given a task finishing then) and restore heap order.
    /// Returns the server id that received the task.
    #[inline]
    pub fn assign(&mut self, new_time: f64) -> u32 {
        self.pops += 1;
        self.pushes += 1;
        let id = self.slots[0].1;
        self.slots[0].0 = new_time;
        self.sift_down(0);
        id
    }

    /// Remove and return the earliest-free server. Used by the redundancy
    /// dispatcher to reserve `r` distinct servers for one task's replicas;
    /// every pop must be balanced by a [`ServerHeap::push`] before the
    /// next task is dispatched.
    #[inline]
    pub fn pop(&mut self) -> (f64, u32) {
        self.try_pop().expect("pop from empty server heap")
    }

    /// Checked [`ServerHeap::pop`]: `None` on an empty heap instead of a
    /// panic, so dispatcher call sites can surface a misconfiguration
    /// (e.g. a zero-server worker group) as a clean error.
    #[inline]
    pub fn try_pop(&mut self) -> Option<(f64, u32)> {
        if self.slots.is_empty() {
            return None;
        }
        self.pops += 1;
        let root = self.slots[0];
        let last = self.slots.pop().expect("non-empty");
        if !self.slots.is_empty() {
            self.slots[0] = last;
            self.sift_down(0);
        }
        Some(root)
    }

    /// Re-insert a server with its new free time.
    #[inline]
    pub fn push(&mut self, free_time: f64, server: u32) {
        self.pushes += 1;
        self.slots.push((free_time, server));
        self.sift_up(self.slots.len() - 1);
    }

    /// Reset every server's free time to `max(current, t)` — used at the
    /// start barrier of the split-merge model where idle servers wait for
    /// the next job's arrival.
    pub fn raise_to(&mut self, t: f64) {
        for s in &mut self.slots {
            if s.0 < t {
                s.0 = t;
            }
        }
        // Raising to a common floor preserves heap order only partially;
        // rebuild (l is small and this is once per job).
        self.rebuild();
    }

    /// Set every server free at exactly `t` (split-merge barrier: all
    /// servers idle when a job starts).
    pub fn reset_all(&mut self, t: f64) {
        for s in &mut self.slots {
            s.0 = t;
        }
        // Equal keys: already a valid heap.
    }

    /// Largest free time — the job makespan once all its tasks are
    /// assigned (split-merge Δ computation).
    pub fn max_time(&self) -> f64 {
        self.slots.iter().map(|s| s.0).fold(f64::NEG_INFINITY, f64::max)
    }

    fn rebuild(&mut self) {
        for i in (0..self.slots.len() / 2).rev() {
            self.sift_down(i);
        }
    }

    #[inline]
    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.slots[i].0 < self.slots[parent].0 {
                self.slots.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    #[inline]
    fn sift_down(&mut self, mut i: usize) {
        let n = self.slots.len();
        loop {
            let left = 2 * i + 1;
            if left >= n {
                break;
            }
            let right = left + 1;
            let mut smallest = left;
            if right < n && self.slots[right].0 < self.slots[left].0 {
                smallest = right;
            }
            if self.slots[smallest].0 < self.slots[i].0 {
                self.slots.swap(i, smallest);
                i = smallest;
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn pops_in_order() {
        let mut h = ServerHeap::new(4, 0.0);
        // Assign tasks with varying finish times; earliest-free always wins.
        h.assign(3.0);
        h.assign(1.0);
        h.assign(2.0);
        h.assign(4.0);
        // Heap roots should now come out 1,2,3,4 as we re-assign.
        let mut seen = Vec::new();
        for _ in 0..4 {
            let (t, _) = h.peek();
            seen.push(t);
            h.assign(t + 100.0);
        }
        assert_eq!(seen, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn matches_naive_min_scan() {
        let mut h = ServerHeap::new(13, 0.0);
        let mut naive = vec![0.0f64; 13];
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..10_000 {
            let dur = rng.next_f64() * 3.0;
            let (t_heap, _) = h.peek();
            // naive: find min
            let (idx, &t_naive) = naive
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            assert!((t_heap - t_naive).abs() < 1e-12);
            h.assign(t_heap + dur);
            naive[idx] = t_naive + dur;
        }
        assert!((h.max_time() - naive.iter().fold(f64::MIN, |a, &b| a.max(b))).abs() < 1e-9);
    }

    #[test]
    fn raise_and_reset() {
        let mut h = ServerHeap::new(3, 0.0);
        h.assign(5.0);
        h.raise_to(2.0);
        assert_eq!(h.peek().0, 2.0);
        assert_eq!(h.max_time(), 5.0);
        h.reset_all(7.0);
        assert_eq!(h.peek().0, 7.0);
        assert_eq!(h.max_time(), 7.0);
    }

    #[test]
    fn pop_push_matches_peek_assign() {
        // Popping r servers and pushing them back with new times must
        // leave the heap equivalent to a peek/assign sequence.
        let mut h = ServerHeap::new(6, 0.0);
        let mut rng = Pcg64::seed_from_u64(11);
        for _ in 0..5_000 {
            let r = 1 + (rng.next_u64() % 3) as usize;
            let mut picks = Vec::new();
            for _ in 0..r {
                picks.push(h.pop());
            }
            // Picks come out in nondecreasing free-time order.
            for w in picks.windows(2) {
                assert!(w[0].0 <= w[1].0);
            }
            for (t, id) in picks {
                h.push(t + rng.next_f64() * 2.0, id);
            }
            assert_eq!(h.len(), 6);
        }
        // All ids still present exactly once.
        let mut ids = std::collections::BTreeSet::new();
        for _ in 0..6 {
            ids.insert(h.pop().1);
        }
        assert_eq!(ids.len(), 6);
    }

    #[test]
    fn try_pop_drains_then_yields_none() {
        let mut h = ServerHeap::new(3, 1.0);
        for _ in 0..3 {
            assert!(h.try_pop().is_some());
        }
        assert!(h.try_pop().is_none());
        assert!(h.is_empty());
    }

    #[test]
    fn from_servers_keeps_global_ids() {
        let mut h = ServerHeap::from_servers([4u32, 7, 9], 2.0);
        assert_eq!(h.len(), 3);
        let mut ids = Vec::new();
        for _ in 0..3 {
            let (t, id) = h.pop();
            assert_eq!(t, 2.0);
            ids.push(id);
        }
        ids.sort_unstable();
        assert_eq!(ids, vec![4, 7, 9]);
    }

    #[test]
    fn op_tallies_count_assign_pop_push() {
        let mut h = ServerHeap::new(3, 0.0);
        assert_eq!(h.ops(), (0, 0));
        h.assign(1.0); // fused pop+push
        assert_eq!(h.ops(), (1, 1));
        let (t, id) = h.pop();
        h.push(t + 1.0, id);
        assert_eq!(h.ops(), (2, 2));
        assert!(h.try_pop().is_some());
        assert_eq!(h.ops(), (2, 3));
    }

    #[test]
    fn server_ids_cover_all() {
        let mut h = ServerHeap::new(5, 0.0);
        let mut ids = std::collections::BTreeSet::new();
        for _ in 0..5 {
            let (t, _) = h.peek();
            ids.insert(h.assign(t + 1.0));
        }
        assert_eq!(ids.len(), 5);
    }
}
