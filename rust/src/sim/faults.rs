//! Fault injection & recovery: worker failures, task retries, and
//! speculative re-execution.
//!
//! Three failure mechanisms, configured by the `[faults]` section
//! ([`crate::config::FaultsConfig`]) and composable with every
//! recursion-based engine plus the calendar DES:
//!
//! * **Markov on/off worker failures** — each worker alternates
//!   exponential up-times (mean `mtbf`) and repair windows (mean
//!   `mttr`). A crash kills the in-flight task (its partial work is
//!   wasted) and the worker rejoins after repair; crashes retry
//!   immediately and do not consume the retry budget.
//! * **Per-task failure probability** — an attempt that runs to
//!   completion fails with probability `task_fail_p`; the task retries
//!   after a fixed or exponential backoff, up to `max_retries` failed
//!   attempts, and each retry is re-charged the Sec.-2.6 task-service
//!   overhead with a fresh draw. The attempt after the last allowed
//!   retry always succeeds, so every job departs and retry accounting
//!   is exact (`task_overhead` = completed attempts × overhead).
//! * **Speculative re-execution** — a primary copy whose service time
//!   exceeds `spec_timeout ×` the expected task service launches a
//!   backup copy on the next-free server at that deadline; the first
//!   copy to finish wins and the loser is cancelled at that instant,
//!   exactly the first-finish-wins mechanics of the redundancy
//!   dispatcher in [`super::scenario`]. Backup copies redraw their size
//!   and overhead (fresh luck is the point of the hedge) and are
//!   modeled crash- and failure-free — a documented simplification.
//!
//! **Determinism & degeneracy.** All fault randomness lives in streams
//! separate from the workload stream: each worker owns a crash-schedule
//! RNG and one shared task-level RNG serves failure draws, retry
//! overheads, and backup copies (seeds from [`spawn_seeds`] over a mix
//! of `simulation.seed` and `faults.seed`, so replication shards get
//! independent fault schedules). Primary execution/overhead draws still
//! come from the workload stream in the engine's original order, and a
//! config without an active `[faults]` section resolves to `None`, so
//! fault-free runs are bit-for-bit identical to the seed engines
//! (enforced by `rust/tests/fault_injection.rs`).

use super::{OverheadModel, ServerHeap, TraceEvent, TraceLog, Workload};
use crate::config::{FaultsConfig, SimulationConfig};
use crate::rng::{spawn_seeds, Pcg64, Rng, SplitMix64};
use crate::trace::cause;

/// Salt separating the fault stream family from the workload seed.
const FAULT_STREAM_SALT: u64 = 0xFA17_1E57_C0FF_EE01;

/// Outcome of dispatching one logical task under fault injection, over
/// all of its attempts.
#[derive(Clone, Copy, Debug)]
pub struct FaultOutcome {
    /// Earliest instant any attempt of this task began service.
    pub first_start: f64,
    /// Finish time of the successful attempt (its winning copy).
    pub finish: f64,
    /// Execution draw of the winning copy (the useful work).
    pub work: f64,
    /// Total charged task-service overhead: one draw per attempt that
    /// ran to completion (failed or successful); crashed attempts are
    /// killed mid-run and charge nothing here.
    pub overhead: f64,
    /// Server time wasted by crashed and failed attempts.
    pub lost: f64,
    /// Server time consumed by cancelled speculative copies (merged
    /// into the job's `redundant_work`, like cancelled replicas).
    pub redundant: f64,
    /// Attempts beyond the first (crashes + failures).
    pub retries: u32,
}

/// Per-run fault state: worker crash schedules plus the task-level
/// fault stream. One injector per engine instance; workers are indexed
/// by the same server ids the engines use.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    cfg: FaultsConfig,
    /// Next crash instant per worker (`INFINITY` when crashes are off).
    next_crash: Vec<f64>,
    /// Per-worker crash-schedule RNGs — crash schedules are a property
    /// of the worker, independent of which tasks it serves.
    worker_rng: Vec<Pcg64>,
    /// Task-level fault stream: failure draws, retry overhead redraws,
    /// backup-copy draws.
    task_rng: Pcg64,
    /// Absolute speculation deadline (seconds of service time);
    /// `INFINITY` when speculation is off.
    spec_deadline: f64,
    // Raw tallies for the obs layer: crashes consumed, retry attempts,
    // and speculative backups that actually started. Unconditional u64
    // increments off the hot path; no RNG, no behavior change.
    n_crashes: u64,
    n_retries: u64,
    n_spec: u64,
}

#[inline]
fn draw_exp(rng: &mut Pcg64, mean: f64) -> f64 {
    -rng.next_f64_open().ln() * mean
}

impl FaultInjector {
    /// Resolve a config's fault model. `None` when no `[faults]` section
    /// is configured or every mechanism is off — the engines then keep
    /// their fault-free hot paths bit-for-bit. `expected_task` is the
    /// mean task service time E[exec] + E[overhead], the base of the
    /// speculation deadline.
    pub fn from_config(cfg: &SimulationConfig, expected_task: f64) -> Option<Self> {
        let f = cfg.faults?;
        if !f.is_active() {
            return None;
        }
        Some(Self::new(f, cfg.servers, cfg.seed, expected_task))
    }

    /// Build directly from a fault config (`servers` workers, fault
    /// streams derived from `sim_seed` and `cfg.seed`).
    pub fn new(cfg: FaultsConfig, servers: usize, sim_seed: u64, expected_task: f64) -> Self {
        let master = SplitMix64::new(sim_seed ^ FAULT_STREAM_SALT).next_u64() ^ cfg.seed;
        let seeds = spawn_seeds(master, servers + 1);
        let mut worker_rng: Vec<Pcg64> =
            seeds[..servers].iter().map(|&s| Pcg64::seed_from_u64(s)).collect();
        let task_rng = Pcg64::seed_from_u64(seeds[servers]);
        let next_crash = if cfg.crashes_enabled() {
            worker_rng.iter_mut().map(|r| draw_exp(r, cfg.mtbf)).collect()
        } else {
            vec![f64::INFINITY; servers]
        };
        let deadline = cfg.spec_timeout * expected_task;
        let spec_deadline = if cfg.speculation_enabled() && deadline > 0.0 && deadline.is_finite()
        {
            deadline
        } else {
            f64::INFINITY
        };
        Self {
            cfg,
            next_crash,
            worker_rng,
            task_rng,
            spec_deadline,
            n_crashes: 0,
            n_retries: 0,
            n_spec: 0,
        }
    }

    /// Crashes consumed since construction (both the recursion engines'
    /// [`FaultInjector::crash_within`] path and the calendar's
    /// [`FaultInjector::consume_crash`] path — each crash is consumed on
    /// exactly one of them).
    #[inline]
    pub fn crash_count(&self) -> u64 {
        self.n_crashes
    }

    /// Retry attempts tallied by the injector's own dispatchers (the
    /// calendar engine runs its own retry loop and tallies separately).
    #[inline]
    pub fn retry_count(&self) -> u64 {
        self.n_retries
    }

    /// Speculative backup copies that actually started.
    #[inline]
    pub fn spec_count(&self) -> u64 {
        self.n_spec
    }

    /// Tally one retry attempt resolved outside the injector's own
    /// dispatch loops (the redundancy dispatcher's attempt loop).
    #[inline]
    pub(crate) fn note_retry(&mut self) {
        self.n_retries += 1;
    }

    /// The fault parameters in use.
    pub fn config(&self) -> &FaultsConfig {
        &self.cfg
    }

    /// Absolute speculation deadline in seconds of service time
    /// (`INFINITY` when speculation is off).
    pub fn spec_deadline(&self) -> f64 {
        self.spec_deadline
    }

    /// Earliest instant `>= t` at which `server` is up, consuming any
    /// repair windows that begin at or before `t`. Per-worker queries
    /// must be time-monotone (they are: a server's free time only
    /// grows), so the crash schedule is consumed strictly forward.
    pub fn up_at(&mut self, server: u32, t: f64) -> f64 {
        let w = server as usize;
        let mut t = t;
        while self.next_crash[w] <= t {
            let c = self.next_crash[w];
            let up = c + draw_exp(&mut self.worker_rng[w], self.cfg.mttr);
            self.next_crash[w] = up + draw_exp(&mut self.worker_rng[w], self.cfg.mtbf);
            if up > t {
                t = up;
            }
        }
        t
    }

    /// Does `server` crash during an attempt running over
    /// `(start, finish)`? If so, consume the crash and return
    /// `(crash instant, repair-done instant)`. Callers must have
    /// resolved `start` through [`FaultInjector::up_at`] first, so the
    /// pending crash is strictly after `start`.
    pub fn crash_within(&mut self, server: u32, start: f64, finish: f64) -> Option<(f64, f64)> {
        let w = server as usize;
        let c = self.next_crash[w];
        if c >= finish {
            return None;
        }
        debug_assert!(c > start, "crash schedule not resolved via up_at");
        self.n_crashes += 1;
        let up = c + draw_exp(&mut self.worker_rng[w], self.cfg.mttr);
        self.next_crash[w] = up + draw_exp(&mut self.worker_rng[w], self.cfg.mtbf);
        Some((c, up))
    }

    /// Peek `server`'s next scheduled crash instant (calendar engine:
    /// the Crash event's heap key).
    pub fn peek_crash(&self, server: u32) -> f64 {
        self.next_crash[server as usize]
    }

    /// Consume `server`'s pending crash (calendar engine: the Crash
    /// event fired): draw its repair, schedule the next crash, and
    /// return `(repair-done instant, next crash instant)`.
    pub fn consume_crash(&mut self, server: u32) -> (f64, f64) {
        let w = server as usize;
        let c = self.next_crash[w];
        debug_assert!(c.is_finite(), "consume_crash with crashes disabled");
        self.n_crashes += 1;
        let up = c + draw_exp(&mut self.worker_rng[w], self.cfg.mttr);
        self.next_crash[w] = up + draw_exp(&mut self.worker_rng[w], self.cfg.mtbf);
        (up, self.next_crash[w])
    }

    /// One per-attempt failure draw (false when failures are off).
    pub fn failure_draw(&mut self) -> bool {
        self.cfg.failures_enabled() && self.task_rng.next_f64() < self.cfg.task_fail_p
    }

    /// Fresh task-service overhead for a retry, drawn from the fault
    /// stream ("each retry re-charges the Sec.-2.6 task overhead").
    pub fn retry_overhead(&mut self, overhead: &OverheadModel) -> f64 {
        overhead.sample_task(&mut self.task_rng)
    }

    /// Fresh `(execution, overhead)` draws for a backup or retry copy,
    /// from the fault stream.
    pub fn backup_draws(&mut self, workload: &Workload, overhead: &OverheadModel) -> (f64, f64) {
        let exec = workload.execution_with(&mut self.task_rng);
        let oh = overhead.sample_task(&mut self.task_rng);
        (exec, oh)
    }

    /// Dispatch one logical task on the homogeneous earliest-free-server
    /// heap (split-merge / single-queue fork-join) under fault
    /// injection: resolve crashes, bounded retries with backoff, and
    /// speculative backups until one attempt succeeds.
    ///
    /// The primary execution/overhead draws come from the workload
    /// stream in exactly the fault-free engines' order; every extra
    /// draw comes from the injector's streams.
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_task(
        &mut self,
        heap: &mut ServerHeap,
        floor: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        job: u32,
        task: u32,
        trace: &mut TraceLog,
    ) -> FaultOutcome {
        let exec = workload.next_execution();
        let oh = overhead.sample_task(workload.rng());
        self.dispatch_task_drawn(heap, floor, exec, oh, workload, overhead, job, task, 0, trace)
    }

    /// [`FaultInjector::dispatch_task`] with the primary execution and
    /// overhead draws supplied by the caller. Dispatch policies use this
    /// seam: SITA must classify a task by its execution draw *before*
    /// choosing a server group, and the priority policy stamps its class
    /// on the trace — both draw `(exec, oh)` in the fault-free stream
    /// order and then hand dispatch to the injector on the group's
    /// sub-heap. `dispatch_task` delegates here, so the two paths stay
    /// draw-for-draw identical.
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_task_drawn(
        &mut self,
        heap: &mut ServerHeap,
        floor: f64,
        exec: f64,
        oh: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        job: u32,
        task: u32,
        class: u32,
        trace: &mut TraceLog,
    ) -> FaultOutcome {
        let mut oh = oh;
        let mut retries = 0u32;
        let mut fail_budget =
            if self.cfg.failures_enabled() { self.cfg.max_retries } else { 0 };
        let mut failed_attempts = 0u32;
        let mut retry_floor = floor;
        let mut first_start = f64::INFINITY;
        let mut overhead_sum = 0.0;
        let mut lost = 0.0;
        let mut redundant = 0.0;

        loop {
            let attempt = 1 + retries;
            let (t_free, server) = heap.pop();
            let start = self.up_at(server, if retry_floor > t_free { retry_floor } else { t_free });
            if start < first_start {
                first_start = start;
            }
            let finish = start + exec + oh;

            // (1) Worker crash mid-attempt: the partial work is lost,
            // the worker rejoins after repair, the task retries
            // immediately (crashes do not consume the retry budget).
            if let Some((c, up)) = self.crash_within(server, start, finish) {
                lost += c - start;
                heap.push(up, server);
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job,
                        task,
                        server,
                        start,
                        end: c,
                        overhead: oh.min(c - start),
                        winner: false,
                        attempt,
                        cause: cause::CRASHED,
                        class,
                    });
                }
                retries += 1;
                self.n_retries += 1;
                continue;
            }

            // (2) Straggler hedge: a primary exceeding the deadline
            // launches a backup on the next-free server; first finish
            // wins, the loser is cancelled at that instant.
            let mut win_server = server;
            let mut win_start = start;
            let mut win_finish = finish;
            let mut win_exec = exec;
            let mut win_oh = oh;
            if finish - start > self.spec_deadline && !heap.is_empty() {
                let (t_free_b, server_b) = heap.pop();
                let launch = start + self.spec_deadline;
                let bstart =
                    self.up_at(server_b, if launch > t_free_b { launch } else { t_free_b });
                let (bexec, boh) = self.backup_draws(workload, overhead);
                let bfinish = bstart + bexec + boh;
                // A backup "launched" iff it started before the primary
                // finished (bfinish < finish implies bstart < finish).
                if bstart < finish {
                    self.n_spec += 1;
                }
                if bfinish < finish {
                    // Backup wins; cancel the primary at that instant.
                    redundant += bfinish - start;
                    heap.push(bfinish, server);
                    if trace.is_enabled() {
                        trace.record(TraceEvent {
                            job,
                            task,
                            server,
                            start,
                            end: bfinish,
                            overhead: oh.min(bfinish - start),
                            winner: false,
                            attempt,
                            cause: cause::SPECULATION,
                            class,
                        });
                    }
                    win_server = server_b;
                    win_start = bstart;
                    win_finish = bfinish;
                    win_exec = bexec;
                    win_oh = boh;
                } else if bstart < finish {
                    // Backup started but lost; cancelled mid-run.
                    redundant += finish - bstart;
                    heap.push(finish, server_b);
                    if trace.is_enabled() {
                        trace.record(TraceEvent {
                            job,
                            task,
                            server: server_b,
                            start: bstart,
                            end: finish,
                            overhead: boh.min(finish - bstart),
                            winner: false,
                            attempt,
                            cause: cause::SPECULATION,
                            class,
                        });
                    }
                } else {
                    // Backup never started; release its reservation.
                    heap.push(t_free_b, server_b);
                }
            }

            // (3) Failure surfaces when the attempt completes: the full
            // service time is wasted and the task retries after backoff
            // with a re-charged overhead draw. Once the retry budget is
            // spent the attempt is forced to succeed, so every job
            // departs and the accounting is exact.
            overhead_sum += win_oh;
            if fail_budget > 0 && self.failure_draw() {
                fail_budget -= 1;
                failed_attempts += 1;
                lost += win_finish - win_start;
                heap.push(win_finish, win_server);
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job,
                        task,
                        server: win_server,
                        start: win_start,
                        end: win_finish,
                        overhead: win_oh,
                        winner: false,
                        attempt,
                        cause: cause::FAILED,
                        class,
                    });
                }
                retries += 1;
                self.n_retries += 1;
                retry_floor = win_finish + self.cfg.backoff_delay(failed_attempts);
                oh = self.retry_overhead(overhead);
                continue;
            }

            heap.push(win_finish, win_server);
            if trace.is_enabled() {
                trace.record(TraceEvent {
                    job,
                    task,
                    server: win_server,
                    start: win_start,
                    end: win_finish,
                    overhead: win_oh,
                    winner: true,
                    attempt,
                    cause: cause::NONE,
                    class,
                });
            }
            return FaultOutcome {
                first_start,
                finish: win_finish,
                work: win_exec,
                overhead: overhead_sum,
                lost,
                redundant,
                retries,
            };
        }
    }

    /// Dispatch one task bound to a fixed server (per-server fork-join):
    /// crashes and retries resolve on the same server; speculation is
    /// rejected for this model at config validation. Returns the
    /// outcome and the server's new free time.
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_task_on(
        &mut self,
        server: u32,
        t_free: f64,
        floor: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        job: u32,
        task: u32,
        trace: &mut TraceLog,
    ) -> (FaultOutcome, f64) {
        let exec = workload.next_execution();
        let mut oh = overhead.sample_task(workload.rng());

        let mut retries = 0u32;
        let mut fail_budget =
            if self.cfg.failures_enabled() { self.cfg.max_retries } else { 0 };
        let mut failed_attempts = 0u32;
        let mut free = t_free;
        let mut retry_floor = floor;
        let mut first_start = f64::INFINITY;
        let mut overhead_sum = 0.0;
        let mut lost = 0.0;

        loop {
            let attempt = 1 + retries;
            let start = self.up_at(server, if retry_floor > free { retry_floor } else { free });
            if start < first_start {
                first_start = start;
            }
            let finish = start + exec + oh;

            if let Some((c, up)) = self.crash_within(server, start, finish) {
                lost += c - start;
                free = up;
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job,
                        task,
                        server,
                        start,
                        end: c,
                        overhead: oh.min(c - start),
                        winner: false,
                        attempt,
                        cause: cause::CRASHED,
                        class: 0,
                    });
                }
                retries += 1;
                self.n_retries += 1;
                continue;
            }

            overhead_sum += oh;
            if fail_budget > 0 && self.failure_draw() {
                fail_budget -= 1;
                failed_attempts += 1;
                lost += finish - start;
                free = finish;
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job,
                        task,
                        server,
                        start,
                        end: finish,
                        overhead: oh,
                        winner: false,
                        attempt,
                        cause: cause::FAILED,
                        class: 0,
                    });
                }
                retries += 1;
                self.n_retries += 1;
                retry_floor = finish + self.cfg.backoff_delay(failed_attempts);
                oh = self.retry_overhead(overhead);
                continue;
            }

            if trace.is_enabled() {
                trace.record(TraceEvent {
                    job,
                    task,
                    server,
                    start,
                    end: finish,
                    overhead: oh,
                    winner: true,
                    attempt,
                    cause: cause::NONE,
                    class: 0,
                });
            }
            return (
                FaultOutcome {
                    first_start,
                    finish,
                    work: exec,
                    overhead: overhead_sum,
                    lost,
                    redundant: 0.0,
                    retries,
                },
                finish,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Deterministic;

    fn det_workload(exec: f64) -> Workload {
        Workload::new(Deterministic::new(100.0).into(), Deterministic::new(exec).into(), 1)
    }

    fn faults(f: impl FnOnce(&mut FaultsConfig)) -> FaultsConfig {
        let mut cfg = FaultsConfig::default();
        f(&mut cfg);
        cfg
    }

    #[test]
    fn inactive_config_resolves_to_none() {
        let cfg = SimulationConfig::default();
        assert!(FaultInjector::from_config(&cfg, 1.0).is_none());
        let cfg = SimulationConfig {
            faults: Some(FaultsConfig::default()),
            ..SimulationConfig::default()
        };
        assert!(FaultInjector::from_config(&cfg, 1.0).is_none());
    }

    #[test]
    fn no_crash_queries_when_crashes_disabled() {
        let mut fi =
            FaultInjector::new(faults(|f| f.task_fail_p = 0.1), 4, 7, 1.0);
        for w in 0..4 {
            assert_eq!(fi.peek_crash(w), f64::INFINITY);
            assert_eq!(fi.up_at(w, 123.0), 123.0);
            assert!(fi.crash_within(w, 0.0, 1e12).is_none());
        }
    }

    #[test]
    fn crash_schedule_deterministic_and_monotone() {
        let mk = || {
            FaultInjector::new(
                faults(|f| {
                    f.mtbf = 5.0;
                    f.mttr = 1.0;
                }),
                3,
                42,
                1.0,
            )
        };
        let (mut a, mut b) = (mk(), mk());
        for w in 0..3 {
            let mut prev = 0.0;
            for _ in 0..50 {
                let ca = a.peek_crash(w);
                assert_eq!(ca, b.peek_crash(w), "worker {w}");
                assert!(ca > prev);
                let (up_a, next_a) = a.consume_crash(w);
                let (up_b, next_b) = b.consume_crash(w);
                assert_eq!((up_a, next_a), (up_b, next_b));
                assert!(up_a > ca && next_a > up_a);
                prev = ca;
            }
        }
        // Distinct workers get distinct schedules.
        let fresh = mk();
        assert_ne!(fresh.peek_crash(0), fresh.peek_crash(1));
    }

    #[test]
    fn up_at_skips_repair_windows() {
        let mut fi = FaultInjector::new(
            faults(|f| {
                f.mtbf = 2.0;
                f.mttr = 0.5;
            }),
            1,
            9,
            1.0,
        );
        let c = fi.peek_crash(0);
        // Querying exactly at / after the crash lands after the repair.
        let t = fi.up_at(0, c);
        assert!(t > c);
        assert!(fi.peek_crash(0) > t);
    }

    /// Retry accounting is exact: with a near-certain failure
    /// probability and a deterministic overhead constant, the task
    /// burns its whole retry budget, then the forced success lands —
    /// total charged overhead = attempts × c, lost = failures × (e+c).
    #[test]
    fn retry_accounting_sums_exactly() {
        let mut fi = FaultInjector::new(
            faults(|f| {
                f.task_fail_p = 1.0 - 1e-12;
                f.max_retries = 3;
                f.backoff_base = 0.0;
            }),
            2,
            5,
            1.0,
        );
        let mut heap = ServerHeap::new(2, 0.0);
        let mut w = det_workload(1.0);
        let oh = OverheadModel::new(crate::config::OverheadConfig {
            c_task_ts: 0.25,
            mu_task_ts: f64::INFINITY,
            c_job_pd: 0.0,
            c_task_pd: 0.0,
        });
        let mut tr = TraceLog::enabled();
        let out = fi.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 0, &mut tr);
        assert_eq!(out.retries, 3);
        assert_eq!(fi.retry_count(), 3);
        assert_eq!(fi.crash_count(), 0);
        assert_eq!(fi.spec_count(), 0);
        assert!((out.overhead - 4.0 * 0.25).abs() < 1e-12, "{}", out.overhead);
        assert!((out.lost - 3.0 * 1.25).abs() < 1e-12, "{}", out.lost);
        assert_eq!(out.work, 1.0);
        // 3 failed events + 1 winner, attempts 1..=4, causes recorded.
        assert_eq!(tr.events().len(), 4);
        assert_eq!(tr.events().iter().filter(|e| e.winner).count(), 1);
        let win = tr.events().iter().find(|e| e.winner).unwrap();
        assert_eq!((win.attempt, win.cause), (4, cause::NONE));
        assert!(tr
            .events()
            .iter()
            .filter(|e| !e.winner)
            .all(|e| e.cause == cause::FAILED));
    }

    /// Backoff delays the retry: with base 2.0 fixed backoff the second
    /// attempt cannot start before the first failure + 2.0.
    #[test]
    fn backoff_delays_retry() {
        let mut fi = FaultInjector::new(
            faults(|f| {
                f.task_fail_p = 1.0 - 1e-12;
                f.max_retries = 1;
                f.backoff_base = 2.0;
            }),
            1,
            5,
            1.0,
        );
        let mut heap = ServerHeap::new(1, 0.0);
        let mut w = det_workload(1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let out = fi.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 0, &mut tr);
        // Attempt 1: [0, 1], fails. Retry floor = 1 + 2. Attempt 2: [3, 4].
        assert_eq!(out.retries, 1);
        assert!((out.finish - 4.0).abs() < 1e-12, "{}", out.finish);
    }

    /// Speculation launches a backup at the deadline and resolves
    /// first-finish-wins with loser time accounted as redundant work.
    #[test]
    fn speculation_first_finish_wins() {
        // expected_task 0.5, spec_timeout 1 → deadline 0.5; det exec 1.0
        // means the backup (also det 1.0) starts at 0.5 and finishes at
        // 1.5 > 1.0 — the primary wins, loser ran [0.5, 1.0].
        let mut fi = FaultInjector::new(faults(|f| f.spec_timeout = 1.0), 2, 3, 0.5);
        assert_eq!(fi.spec_deadline(), 0.5);
        let mut heap = ServerHeap::new(2, 0.0);
        let mut w = det_workload(1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let out = fi.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 0, &mut tr);
        assert_eq!(out.finish, 1.0);
        assert_eq!(out.retries, 0);
        assert_eq!(fi.spec_count(), 1);
        assert!((out.redundant - 0.5).abs() < 1e-12, "{}", out.redundant);
        let loser = tr.events().iter().find(|e| !e.winner).unwrap();
        assert_eq!(loser.cause, cause::SPECULATION);
        assert_eq!((loser.start, loser.end), (0.5, 1.0));
        // Both servers are free again at the winner's finish.
        assert_eq!(heap.peek().0, 1.0);
        assert_eq!(heap.max_time(), 1.0);
    }

    /// Crashes kill in-flight work deterministically per seed: two
    /// injectors with the same seeds produce bitwise-equal outcomes,
    /// and crash losses show up in `lost` with untouched retry budget.
    #[test]
    fn crashes_deterministic_and_accounted() {
        let run = || {
            let mut fi = FaultInjector::new(
                faults(|f| {
                    f.mtbf = 2.0;
                    f.mttr = 0.5;
                }),
                2,
                11,
                1.0,
            );
            let mut heap = ServerHeap::new(2, 0.0);
            let mut w = det_workload(1.0);
            let oh = OverheadModel::none();
            let mut tr = TraceLog::disabled();
            let mut lost = 0.0;
            let mut retries = 0;
            let mut finish = 0.0;
            for t in 0..200 {
                let out = fi.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, t, &mut tr);
                lost += out.lost;
                retries += out.retries;
                finish = out.finish;
            }
            (lost, retries, finish)
        };
        let (lost, retries, finish) = run();
        assert_eq!(run(), (lost, retries, finish));
        assert!(lost > 0.0, "200 unit tasks at MTBF 2 must hit crashes");
        assert!(retries > 0);
    }

    /// The per-server variant retries on its own server and reports the
    /// new free time.
    #[test]
    fn per_server_dispatch_accounts_and_frees() {
        let mut fi = FaultInjector::new(
            faults(|f| {
                f.task_fail_p = 1.0 - 1e-12;
                f.max_retries = 2;
                f.backoff_base = 0.5;
            }),
            1,
            5,
            1.0,
        );
        let mut w = det_workload(1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let (out, free) = fi.dispatch_task_on(0, 0.0, 0.0, &mut w, &oh, 0, 0, &mut tr);
        // [0,1] fail, [1.5,2.5] fail, [3,4] forced success.
        assert_eq!(out.retries, 2);
        assert!((out.finish - 4.0).abs() < 1e-12, "{}", out.finish);
        assert_eq!(free, out.finish);
        assert!((out.lost - 2.0).abs() < 1e-12);
    }
}
