//! The paper's four-parameter overhead model (Sec. 2.6).
//!
//! Task-service overhead (Eq. 2): `O_i(n) = c_task_ts + Exp(mu_task_ts)` —
//! blocking, it extends the task's occupancy of its server.
//! Pre-departure overhead (Eq. 3): `c_job_pd + k * c_task_pd` — delays the
//! job's departure; in fork-join it does **not** block subsequent tasks,
//! in split-merge it blocks the next job (Sec. 2.6, last paragraph).

use crate::config::OverheadConfig;
use crate::rng::{Pcg64, Rng};

/// Sampler for the overhead model; `None`-like behaviour via
/// [`OverheadModel::none`] keeps the hot path branch-light.
#[derive(Clone, Debug)]
pub struct OverheadModel {
    cfg: OverheadConfig,
    enabled: bool,
}

impl OverheadModel {
    /// Overhead per the given parameters.
    pub fn new(cfg: OverheadConfig) -> Self {
        Self { cfg, enabled: true }
    }

    /// No overhead (idealized model).
    pub fn none() -> Self {
        Self { cfg: OverheadConfig::zero(), enabled: false }
    }

    /// From an optional config.
    pub fn from_option(cfg: Option<OverheadConfig>) -> Self {
        match cfg {
            Some(c) => Self::new(c),
            None => Self::none(),
        }
    }

    /// Whether any overhead is being injected.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The parameters in use.
    pub fn config(&self) -> &OverheadConfig {
        &self.cfg
    }

    /// Sample one task-service overhead `O_i(n)` (Eq. 2).
    #[inline]
    pub fn sample_task(&self, rng: &mut Pcg64) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        let exp_part = if self.cfg.mu_task_ts.is_finite() {
            -rng.next_f64_open().ln() / self.cfg.mu_task_ts
        } else {
            0.0
        };
        self.cfg.c_task_ts + exp_part
    }

    /// Deterministic pre-departure overhead for a k-task job (Eq. 3).
    #[inline]
    pub fn pre_departure(&self, k: usize) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        self.cfg.pre_departure(k)
    }

    /// Mean task-service overhead (Eq. 24) — used by the analytic layer.
    pub fn mean_task(&self) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        self.cfg.mean_task_overhead()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_zero() {
        let m = OverheadModel::none();
        let mut rng = Pcg64::seed_from_u64(1);
        assert_eq!(m.sample_task(&mut rng), 0.0);
        assert_eq!(m.pre_departure(1000), 0.0);
        assert!(!m.enabled());
    }

    #[test]
    fn task_overhead_moments() {
        let m = OverheadModel::new(OverheadConfig::paper());
        let mut rng = Pcg64::seed_from_u64(2);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| m.sample_task(&mut rng)).sum::<f64>() / n as f64;
        // E[O] = 2.6 ms + 0.5 ms = 3.1 ms.
        assert!((mean - 3.1e-3).abs() < 5e-5, "mean={mean}");
        // Always at least the constant part.
        for _ in 0..1000 {
            assert!(m.sample_task(&mut rng) >= 2.6e-3);
        }
    }

    #[test]
    fn pre_departure_linear_in_k() {
        let m = OverheadModel::new(OverheadConfig::paper());
        let d1 = m.pre_departure(100);
        let d2 = m.pre_departure(200);
        assert!((d2 - d1 - 100.0 * 7.4e-6).abs() < 1e-12);
        assert!((m.pre_departure(0) - 20e-3).abs() < 1e-12);
    }
}
