//! Event-calendar DES engine — a second, independent implementation of
//! the split-merge and single-queue fork-join models, faithful to
//! forkulator's architecture (explicit event queue, arrival/start/finish
//! events) rather than the per-job recursions in `models/`.
//!
//! Purpose: *cross-validation*. Two simulators written in structurally
//! different styles agreeing sample-for-sample (same seed) or
//! distribution-for-distribution is strong evidence both are right; the
//! integration suite (`rust/tests/calendar_crosscheck.rs`) asserts exact
//! agreement for split-merge and single-queue fork-join.
//!
//! The engine also supports what the recursions cannot express directly:
//! multi-stage jobs with shuffle barriers (Sec. 2.1's DAG stages), used
//! by [`crate::sim::models::MultiStage`]-style experiments.

use super::{JobRecord, OverheadModel, TraceEvent, TraceLog, Workload};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Discrete event kinds.
#[derive(Clone, Copy, Debug, PartialEq)]
enum EventKind {
    /// A job arrives (index into the pre-generated arrival list).
    Arrival(u32),
    /// Server finished its current task.
    TaskFinish {
        /// Which server.
        server: u32,
        /// Owning job.
        job: u32,
        /// Task index within the job's current stage.
        task: u32,
    },
    /// Split-merge: the in-service job departs (scheduled at
    /// last-task-finish + pre-departure overhead; the overhead *blocks*
    /// the next job, Sec. 2.6).
    Departure(u32),
}

#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    seq: u64, // tie-breaker for determinism
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed compare; ties broken by insertion order.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Scheduling discipline of the calendar engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Discipline {
    /// Blocking fork-join: one job in service at a time (Fig. 5).
    SplitMerge,
    /// Global FIFO task queue, no barriers (Sec. 5).
    SingleQueueForkJoin,
}

/// Per-job bookkeeping.
#[derive(Clone, Debug)]
struct JobState {
    arrival: f64,
    /// Stages: remaining tasks to *dispatch* per stage (front = current).
    stages: VecDeque<u32>,
    /// Tasks of the current stage still running.
    outstanding: u32,
    /// Tasks of the current stage not yet dispatched.
    to_dispatch: u32,
    first_start: f64,
    workload: f64,
    task_overhead: f64,
    /// Pre-departure overhead applied (set when the departure event is
    /// scheduled / the job completes).
    pd: f64,
    done: bool,
}

/// Event-calendar simulator for (possibly multi-stage) tiny-task jobs.
pub struct Calendar {
    discipline: Discipline,
    #[allow(dead_code)] // kept for introspection & future disciplines
    servers: usize,
    /// Tasks per stage; single-stage jobs use `vec![k]`.
    stage_tasks: Vec<u32>,
    heap: BinaryHeap<Event>,
    seq: u64,
    /// Idle server ids.
    idle: Vec<u32>,
    /// Global FIFO of (job, task-in-stage) ready to run.
    ready: VecDeque<(u32, u32)>,
    /// Job queue for split-merge (jobs not yet started).
    pending_jobs: VecDeque<u32>,
    /// Split-merge: a job currently in service?
    in_service: Option<u32>,
    jobs: Vec<JobState>,
    completed: Vec<JobRecord>,
}

impl Calendar {
    /// New engine for `servers` workers and jobs of `stage_tasks` tasks
    /// per stage (e.g. `vec![k]` single stage, `vec![k, m]` map+reduce).
    pub fn new(discipline: Discipline, servers: usize, stage_tasks: Vec<u32>) -> Self {
        assert!(servers >= 1 && !stage_tasks.is_empty());
        assert!(stage_tasks.iter().all(|&t| t >= 1));
        Self {
            discipline,
            servers,
            stage_tasks,
            heap: BinaryHeap::new(),
            seq: 0,
            idle: (0..servers as u32).rev().collect(),
            ready: VecDeque::new(),
            pending_jobs: VecDeque::new(),
            in_service: None,
            jobs: Vec::new(),
            completed: Vec::new(),
        }
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Event { time, seq: self.seq, kind });
    }

    /// Run `n_jobs` jobs to completion; returns per-job records in
    /// arrival order.
    pub fn run(
        &mut self,
        n_jobs: usize,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> Vec<JobRecord> {
        // Pre-generate arrivals so RNG draw order matches the recursion
        // engines (arrival stream first is not required — recursions draw
        // arrival-then-tasks per job; we draw tasks lazily at dispatch,
        // which has a DIFFERENT draw order, so cross-checks compare
        // distributions... except single-stage FIFO dispatch order equals
        // generation order, making draws identical. See crosscheck test.)
        for j in 0..n_jobs as u32 {
            let t = workload.next_arrival();
            self.push_event(t, EventKind::Arrival(j));
        }
        while let Some(ev) = self.heap.pop() {
            match ev.kind {
                EventKind::Arrival(j) => self.on_arrival(ev.time, j),
                EventKind::TaskFinish { server, job, task } => {
                    self.on_finish(ev.time, server, job, task, overhead, trace)
                }
                EventKind::Departure(j) => {
                    // Split-merge floor clears at the padded instant.
                    self.record_departure(ev.time, j);
                    self.in_service = None;
                }
            }
            self.dispatch(ev.time, workload, overhead, trace);
        }
        let mut out = std::mem::take(&mut self.completed);
        out.sort_by_key(|r| r.index);
        out
    }

    fn on_arrival(&mut self, _now: f64, j: u32) {
        debug_assert_eq!(j as usize, self.jobs.len());
        let mut stages: VecDeque<u32> = self.stage_tasks.iter().copied().collect();
        let first = stages.pop_front().unwrap();
        self.jobs.push(JobState {
            arrival: _now,
            stages,
            outstanding: 0,
            to_dispatch: first,
            first_start: f64::INFINITY,
            workload: 0.0,
            task_overhead: 0.0,
            pd: 0.0,
            done: false,
        });
        match self.discipline {
            Discipline::SplitMerge => self.pending_jobs.push_back(j),
            Discipline::SingleQueueForkJoin => {
                let k = self.jobs[j as usize].to_dispatch;
                for t in 0..k {
                    self.ready.push_back((j, t));
                }
                self.jobs[j as usize].to_dispatch = 0;
                self.jobs[j as usize].outstanding = k;
            }
        }
    }

    fn on_finish(
        &mut self,
        now: f64,
        server: u32,
        job: u32,
        _task: u32,
        overhead: &OverheadModel,
        _trace: &mut TraceLog,
    ) {
        self.idle.push(server);
        let js = &mut self.jobs[job as usize];
        js.outstanding -= 1;
        if js.outstanding == 0 && js.to_dispatch == 0 {
            if let Some(next_stage) = js.stages.pop_front() {
                // Shuffle barrier crossed: enqueue the next stage.
                match self.discipline {
                    Discipline::SplitMerge => {
                        js.to_dispatch = next_stage;
                        // tasks enqueued by dispatch() below
                        js.outstanding = 0;
                        let k = js.to_dispatch;
                        for t in 0..k {
                            self.ready.push_back((job, t));
                        }
                        js.outstanding = k;
                        js.to_dispatch = 0;
                    }
                    Discipline::SingleQueueForkJoin => {
                        for t in 0..next_stage {
                            self.ready.push_back((job, t));
                        }
                        js.outstanding = next_stage;
                    }
                }
            } else {
                // Job complete.
                js.done = true;
                let total: u32 = self.stage_tasks.iter().sum();
                let pd = overhead.pre_departure(total as usize);
                self.jobs[job as usize].pd = pd;
                if self.discipline == Discipline::SplitMerge {
                    // The pre-departure overhead blocks the floor until
                    // the departure instant.
                    self.push_event(now + pd, EventKind::Departure(job));
                }
            }
        }
    }

    /// Record a (split-merge) departure at exactly `time` (the scheduled
    /// instant already includes the pre-departure overhead).
    fn record_departure(&mut self, time: f64, j: u32) {
        let js = &mut self.jobs[j as usize];
        js.done = false; // consumed
        self.completed.push(JobRecord {
            index: j as usize,
            arrival: js.arrival,
            departure: time,
            first_start: js.first_start,
            workload: js.workload,
            task_overhead: js.task_overhead,
            pre_departure_overhead: js.pd,
            redundant_work: 0.0,
        });
    }

    fn dispatch(
        &mut self,
        now: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) {
        // Split-merge: admit the next job when the floor is clear (the
        // Departure event clears `in_service` at finish + pre-departure).
        if self.discipline == Discipline::SplitMerge {
            if self.in_service.is_none() {
                if let Some(&next) = self.pending_jobs.front() {
                    // Pre-departure overhead of the previous job delays
                    // the next start; model by shifting admission time.
                    self.pending_jobs.pop_front();
                    self.in_service = Some(next);
                    let js = &mut self.jobs[next as usize];
                    let k = js.to_dispatch;
                    for t in 0..k {
                        self.ready.push_back((next, t));
                    }
                    js.outstanding = k;
                    js.to_dispatch = 0;
                }
            }
        } else {
            // FJ: complete any finished jobs immediately.
            let done_jobs: Vec<u32> = self
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, j)| j.done)
                .map(|(i, _)| i as u32)
                .collect();
            for j in done_jobs {
                self.complete_job(now, j, overhead);
            }
        }

        while !self.idle.is_empty() && !self.ready.is_empty() {
            let (job, task) = self.ready.pop_front().unwrap();
            let server = self.idle.pop().unwrap();
            let e = workload.next_execution();
            let o = overhead.sample_task(workload.rng());
            let js = &mut self.jobs[job as usize];
            let start = now.max(js.arrival);
            js.workload += e;
            js.task_overhead += o;
            if start < js.first_start {
                js.first_start = start;
            }
            let finish = start + e + o;
            trace.record(TraceEvent { job, task, server, start, end: finish });
            self.push_event(finish, EventKind::TaskFinish { server, job, task });
        }
    }

    fn complete_job(&mut self, now: f64, j: u32, overhead: &OverheadModel) {
        let js = &mut self.jobs[j as usize];
        if !js.done {
            return;
        }
        js.done = false; // consumed
        let total_tasks: u32 = self.stage_tasks.iter().sum();
        let pd = overhead.pre_departure(total_tasks as usize);
        self.completed.push(JobRecord {
            index: j as usize,
            arrival: js.arrival,
            departure: now + pd,
            first_start: js.first_start,
            workload: js.workload,
            task_overhead: js.task_overhead,
            pre_departure_overhead: pd,
            redundant_work: 0.0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Deterministic, Exponential};

    fn workload(ia: f64, ex: f64, seed: u64) -> Workload {
        Workload::new(
            Box::new(Deterministic::new(ia)),
            Box::new(Deterministic::new(ex)),
            seed,
        )
    }

    #[test]
    fn single_stage_fj_deterministic() {
        // l=2, k=4, exec=1, arrivals every 10: each job takes 2 s.
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4]);
        let mut w = workload(10.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(3, &mut w, &oh, &mut tr);
        assert_eq!(recs.len(), 3);
        for r in &recs {
            assert!((r.sojourn() - 2.0).abs() < 1e-12, "{}", r.sojourn());
        }
    }

    #[test]
    fn split_merge_blocks() {
        // l=2, k=4, exec=1 → Δ=2; arrivals every 1 s → serial service.
        let mut cal = Calendar::new(Discipline::SplitMerge, 2, vec![4]);
        let mut w = workload(1.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(5, &mut w, &oh, &mut tr);
        // D(n) = 3 + 2n (first arrival at t=1).
        for (n, r) in recs.iter().enumerate() {
            assert!(
                (r.departure - (3.0 + 2.0 * n as f64)).abs() < 1e-9,
                "job {n}: {}",
                r.departure
            );
        }
    }

    /// Two-stage job (map k=4, reduce m=2) with a shuffle barrier: the
    /// reduce stage cannot start before every map task finished.
    #[test]
    fn shuffle_barrier_enforced() {
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4, 2]);
        let mut w = workload(100.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let recs = cal.run(1, &mut w, &oh, &mut tr);
        // Map: 4 tasks on 2 servers = done at arrival+2; reduce: 2 tasks
        // in parallel = +1 → sojourn 3.
        assert!((recs[0].sojourn() - 3.0).abs() < 1e-12, "{}", recs[0].sojourn());
        // Trace: 6 tasks total; no reduce task starts before t=arrival+2.
        let events = tr.events();
        assert_eq!(events.len(), 6);
        let map_end = recs[0].arrival + 2.0;
        let late_starts = events.iter().filter(|e| e.start >= map_end - 1e-9).count();
        assert_eq!(late_starts, 2, "exactly the reduce tasks start after the barrier");
    }

    /// Exponential two-stage FJ: adding a reduce stage increases sojourn
    /// versus single-stage with the same total work.
    #[test]
    fn second_stage_costs_synchronization() {
        let run = |stages: Vec<u32>| -> f64 {
            let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 4, stages);
            let mut w = Workload::new(
                Box::new(Exponential::new(0.3)),
                Box::new(Exponential::new(2.0)),
                7,
            );
            let oh = OverheadModel::none();
            let mut tr = TraceLog::disabled();
            let recs = cal.run(4000, &mut w, &oh, &mut tr);
            recs.iter().map(|r| r.sojourn()).sum::<f64>() / recs.len() as f64
        };
        // 12 tasks in one stage vs 8 map + 4 reduce (same count, same
        // per-task law → same workload, extra barrier).
        let single = run(vec![12]);
        let staged = run(vec![8, 4]);
        assert!(staged > single, "barrier must cost: {staged} !> {single}");
    }
}
