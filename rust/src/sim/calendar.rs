//! Event-calendar DES engine — a second, independent implementation of
//! the split-merge and single-queue fork-join models, faithful to
//! forkulator's architecture (explicit event queue, arrival/start/finish
//! events) rather than the per-job recursions in `models/`.
//!
//! Purpose: *cross-validation*. Two simulators written in structurally
//! different styles agreeing sample-for-sample (same seed) is strong
//! evidence both are right; the integration suite
//! (`rust/tests/calendar_crosscheck.rs`) asserts exact agreement for
//! split-merge and single-queue fork-join.
//!
//! The engine also supports what the recursions cannot express directly:
//! multi-stage jobs with shuffle barriers (Sec. 2.1's DAG stages).
//!
//! # Hot-path design (§Perf)
//!
//! The engine is O(events · log h) with a heap of h ≤ l + 2 entries.
//! Memory is O(l + queued tasks) — bounded by the jobs arrived but not
//! yet departed (times k for their undispatched tasks, a deliberate cost
//! of the draw-order contract below), never by the run length:
//!
//! * **lazy arrivals** — exactly one outstanding `Arrival` event at a
//!   time instead of pre-heaping all n jobs, so the event heap stays
//!   tiny and a 10⁸-job run does not allocate 10⁸ events up front;
//! * **slab job states** — finished jobs are retired into a free list
//!   and their slots reused, so memory is bounded by the number of jobs
//!   *in flight*, not the number simulated;
//! * **direct completion** — a job is recorded the instant its last
//!   task finishes (the event handler knows which job that is), instead
//!   of re-scanning every job ever created after each event (the old
//!   engine's O(jobs²) disease);
//! * **pre-drawn tasks** — each stage's execution/overhead samples are
//!   drawn when the stage is enqueued and carried in the ready queue, so
//!   the per-event path does no sampling closure setup and no per-job
//!   allocation (`JobState` is plain-old-data; the old per-job
//!   `VecDeque` of stages is gone).
//!
//! Pre-drawing also pins the RNG draw order to the recursion engines'
//! (arrival, then k × (execution, overhead) per job, in arrival order),
//! which upgrades the cross-check from distributional agreement to
//! bit-for-bit equality for single-stage workloads — including with the
//! overhead model enabled (`rust/tests/calendar_crosscheck.rs`). The
//! price is that a backlogged split-merge floor holds every waiting
//! job's k pre-drawn tasks in the ready queue (samples drawn at arrival
//! must live until dispatch); the old engine drew at dispatch and kept
//! O(1) per waiting job, but had no bitwise contract to honour.

use super::{FaultInjector, JobRecord, OverheadModel, TraceEvent, TraceLog, Workload};
use crate::config::{PolicyConfig, PolicyKind};
use crate::obs::{Span, SpanSet, Tallies};
use crate::trace::cause;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Discrete event kinds.
#[derive(Clone, Copy, Debug, PartialEq)]
enum EventKind {
    /// Job `index` (arrival order) arrives; the next arrival is drawn
    /// and scheduled when this one fires (lazy arrival stream).
    Arrival(u32),
    /// Server finished its current task of the job in `slot`.
    TaskFinish {
        /// Which server.
        server: u32,
        /// Owning job's slab slot.
        slot: u32,
        /// Dispatch sequence of the attempt (fault mode only): a finish
        /// whose `dseq` no longer matches the server's running attempt
        /// is stale — the attempt was killed by a crash or lost a
        /// speculation race — and is ignored.
        dseq: u64,
    },
    /// Split-merge: the in-service job departs (scheduled at
    /// last-task-finish + pre-departure overhead; the overhead *blocks*
    /// the next job, Sec. 2.6).
    Departure(u32),
    /// Fault injection: the server goes down, killing its in-flight
    /// attempt (Markov on/off worker process).
    Crash(u32),
    /// Fault injection: the server's repair completes and it rejoins the
    /// idle pool; the next crash is scheduled from the injector.
    Repair(u32),
    /// Fault injection: a failed attempt re-enters the ready queue after
    /// its backoff delay (carries the retry's pre-drawn samples).
    Retry(ReadyTask),
    /// Fault injection: the attempt dispatched at `dseq` exceeded the
    /// speculation deadline; launch a backup copy if a server is idle.
    SpecLaunch { server: u32, dseq: u64 },
    /// Work stealing: a queued stage's steal deadline elapsed; re-run
    /// dispatch so off-affinity idle servers may now take its tasks. The
    /// event itself is a no-op — dispatch runs after every event.
    StealTick,
}

#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    seq: u64, // tie-breaker for determinism
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed compare; ties broken by insertion order.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Scheduling discipline of the calendar engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Discipline {
    /// Blocking fork-join: one job in service at a time (Fig. 5).
    SplitMerge,
    /// Global FIFO task queue, no barriers (Sec. 5).
    SingleQueueForkJoin,
}

/// Per-job bookkeeping — plain old data, slab-allocated and reused.
#[derive(Clone, Copy, Debug)]
struct JobState {
    /// Arrival-order job index (the `JobRecord.index`).
    index: u32,
    arrival: f64,
    /// Current stage (index into `Calendar::stage_tasks`).
    stage: u32,
    /// Tasks of the current stage in service.
    outstanding: u32,
    /// Tasks of the current stage queued but not yet started.
    to_dispatch: u32,
    first_start: f64,
    workload: f64,
    task_overhead: f64,
    /// Pre-departure overhead (set when the job completes; read when the
    /// split-merge departure event fires).
    pd: f64,
    /// Server time lost to crashed/failed attempts (fault mode).
    lost: f64,
    /// Server time burnt by cancelled speculation copies (fault mode).
    redundant: f64,
    /// Attempts beyond the first across the job's tasks (fault mode).
    retries: u32,
}

/// One queued task with its pre-drawn samples.
#[derive(Clone, Copy, Debug, PartialEq)]
struct ReadyTask {
    /// Owning job's slab slot.
    slot: u32,
    /// Task index within the job's current stage (trace label).
    task: u32,
    /// Attempt number, 1-based (always 1 without fault injection).
    attempt: u32,
    /// Pre-drawn execution time.
    exec: f64,
    /// Pre-drawn task-service overhead.
    overhead: f64,
    /// Dispatch-policy class (SITA size interval / priority class);
    /// 0 without a policy.
    class: u32,
    /// Work stealing: the task's preferred server; 0 otherwise.
    affinity: u32,
    /// Work stealing: instant from which any idle server may steal the
    /// task (enqueue time + threshold); ∞ otherwise. Stored as the
    /// absolute instant so the matching `StealTick` event compares
    /// bit-equal.
    steal_at: f64,
}

/// Policy routing table for the calendar engine — the event-calendar
/// counterpart of [`super::PolicyState`] (which speaks the recursion
/// engines' server-heap API). Built only for an *active* policy; FCFS
/// configs build `None` and leave the engine bit-for-bit unchanged.
#[derive(Clone, Debug)]
struct PolicyDispatch {
    kind: PolicyKind,
    /// SITA size boundaries (class = number of boundaries ≤ exec).
    boundaries: Vec<f64>,
    /// Priority class count (class = job index mod classes).
    classes: usize,
    /// Server id → group index (contiguous largest-remainder partition,
    /// as in the recursion engines). All zeros for work stealing.
    server_group: Vec<u32>,
    /// Work stealing: wait threshold before any server may steal.
    threshold: f64,
    /// Work stealing: round-robin affinity cursor (reset per run).
    next: usize,
}

impl PolicyDispatch {
    /// Build the routing table, or `None` for FCFS/absent policies.
    fn from_config(p: &PolicyConfig, servers: usize) -> Option<Self> {
        if !p.is_active() {
            return None;
        }
        let mut server_group = vec![0u32; servers];
        let mut s = 0usize;
        for (g, size) in p.partition_sizes(servers).into_iter().enumerate() {
            for _ in 0..size {
                server_group[s] = g as u32;
                s += 1;
            }
        }
        Some(Self {
            kind: p.kind,
            boundaries: p.sita_boundaries.clone(),
            classes: p.classes,
            server_group,
            threshold: p.steal_threshold,
            next: 0,
        })
    }

    /// Route one task: its policy class and (work stealing) preferred
    /// server.
    fn route(&mut self, job_index: u32, exec: f64) -> (u32, u32) {
        match self.kind {
            PolicyKind::Sita => {
                let class = self.boundaries.iter().filter(|&&b| exec >= b).count();
                (class as u32, 0)
            }
            PolicyKind::Priority => ((job_index as usize % self.classes) as u32, 0),
            PolicyKind::WorkSteal => {
                let a = (self.next % self.server_group.len()) as u32;
                self.next += 1;
                (0, a)
            }
            // Inactive policies never construct a table.
            PolicyKind::Fcfs => unreachable!("FCFS builds no PolicyDispatch"),
        }
    }

    /// May `server` run `rt` at `now`?
    fn compatible(&self, server: u32, rt: &ReadyTask, now: f64) -> bool {
        match self.kind {
            PolicyKind::Sita | PolicyKind::Priority => {
                self.server_group[server as usize] == rt.class
            }
            PolicyKind::WorkSteal => rt.affinity == server || now >= rt.steal_at,
            PolicyKind::Fcfs => unreachable!("FCFS builds no PolicyDispatch"),
        }
    }
}

/// A task attempt currently occupying a server (fault mode only; the
/// fault-free path never reads or writes these).
#[derive(Clone, Copy, Debug)]
struct Running {
    /// Dispatch sequence — the staleness token carried by the attempt's
    /// `TaskFinish`/`SpecLaunch` events.
    seq: u64,
    /// The attempt's task and samples.
    rt: ReadyTask,
    start: f64,
    /// Server running this attempt's speculation twin, if hedged.
    partner: Option<u32>,
    /// True for a speculative backup copy.
    is_backup: bool,
}

/// Event-calendar simulator for (possibly multi-stage) tiny-task jobs.
pub struct Calendar {
    discipline: Discipline,
    servers: usize,
    /// Tasks per stage; single-stage jobs use `vec![k]`.
    stage_tasks: Vec<u32>,
    /// Σ stage tasks (the pre-departure overhead argument).
    total_tasks: u32,
    heap: BinaryHeap<Event>,
    seq: u64,
    /// Idle server ids (stack).
    idle: Vec<u32>,
    /// Global FIFO of pre-drawn tasks ready to run.
    ready: VecDeque<ReadyTask>,
    /// Scratch for barrier-stage front insertion (reused, no per-event
    /// allocation).
    scratch: Vec<ReadyTask>,
    /// Scratch for batched stage execution draws (reused, no per-stage
    /// allocation).
    exec_buf: Vec<f64>,
    /// Split-merge: arrived jobs (slots) awaiting the floor.
    pending_jobs: VecDeque<u32>,
    /// Split-merge: the slot currently holding the floor.
    in_service: Option<u32>,
    /// Job slab; retired slots are recycled through `free_slots`.
    jobs: Vec<JobState>,
    free_slots: Vec<u32>,
    total_jobs: u32,
    completed: Vec<JobRecord>,
    /// Fault injection (crashes, retries, speculation). `None` keeps the
    /// fault-free event flow bit-for-bit unchanged.
    faults: Option<FaultInjector>,
    /// Dispatch-policy routing table. `None` (absent or FCFS config)
    /// keeps the FIFO dispatch path bit-for-bit unchanged.
    policy: Option<PolicyDispatch>,
    /// Per-server in-flight attempt (fault mode only).
    running: Vec<Option<Running>>,
    /// Per-server down flag (fault mode only).
    down: Vec<bool>,
    /// Dispatch counter: each attempt gets a unique sequence number so
    /// crashes and speculation races can invalidate its pending events.
    dseq: u64,
    /// Raw obs tallies for the current run (reset on every [`Calendar::run`]).
    /// Plain u64 increments on paths the engine already branches through —
    /// cheaper than gating, and they consume no RNG.
    tallies: Tallies,
    /// Measure where the event loop's wall time goes (the hierarchical
    /// span profile plus the Sampling phase). Off by default: the hot
    /// path then never reads the clock.
    profile: bool,
    /// Per-span wall time and enter counts under `profile` (reset on
    /// every [`Calendar::run`]). Spans read only the wall clock — no
    /// RNG, no feedback into simulation state — so profiled runs stay
    /// bitwise identical to unprofiled ones.
    spans: SpanSet,
}

impl Calendar {
    /// New engine for `servers` workers and jobs of `stage_tasks` tasks
    /// per stage (e.g. `vec![k]` single stage, `vec![k, m]` map+reduce).
    pub fn new(discipline: Discipline, servers: usize, stage_tasks: Vec<u32>) -> Self {
        assert!(servers >= 1 && !stage_tasks.is_empty());
        assert!(stage_tasks.iter().all(|&t| t >= 1));
        let total_tasks = stage_tasks.iter().sum();
        Self {
            discipline,
            servers,
            stage_tasks,
            total_tasks,
            heap: BinaryHeap::new(),
            seq: 0,
            idle: Vec::with_capacity(servers),
            ready: VecDeque::new(),
            scratch: Vec::new(),
            exec_buf: Vec::new(),
            pending_jobs: VecDeque::new(),
            in_service: None,
            jobs: Vec::new(),
            free_slots: Vec::new(),
            total_jobs: 0,
            completed: Vec::new(),
            faults: None,
            policy: None,
            running: Vec::new(),
            down: Vec::new(),
            dseq: 0,
            tallies: Tallies::default(),
            profile: false,
            spans: SpanSet::default(),
        }
    }

    /// Attach a fault injector (worker crashes, bounded retries,
    /// speculative backups). The injector's crash schedule is consumed
    /// forward across runs, so attach a fresh injector per measured run.
    ///
    /// Accounting note: `workload`/`task_overhead` always reflect the
    /// primary pre-drawn samples (the draw-order contract); a winning
    /// backup contributes its finish time, and the cancelled copy's wall
    /// time lands in `redundant_work`.
    pub fn with_faults(mut self, faults: Option<FaultInjector>) -> Self {
        self.faults = faults;
        self
    }

    /// Attach a dispatch policy (SITA / priority / work stealing). FCFS
    /// or absent configs build no routing table and leave the engine
    /// bit-for-bit unchanged. Policies are fault-free in this engine
    /// (config validation already rejects the combination for the
    /// calendar's consumers); [`Calendar::run`] asserts it.
    pub fn with_policy(mut self, policy: Option<&PolicyConfig>) -> Self {
        self.policy = policy.and_then(|p| PolicyDispatch::from_config(p, self.servers));
        self
    }

    /// Profile the event loop during `run`: per-event-kind spans with
    /// nested sampling/stats/policy sub-spans ([`Calendar::spans`]),
    /// including the wall clock spent pre-drawing stage samples
    /// ([`Calendar::sampling_seconds`]). Disabled engines never read
    /// the clock.
    pub fn with_profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }

    /// Raw obs tallies for the most recent [`Calendar::run`]. Crashes are
    /// consumed through the injector's `consume_crash` on this engine, so
    /// its count is folded in here.
    pub fn tallies(&self) -> Tallies {
        let mut t = self.tallies.clone();
        if let Some(fi) = &self.faults {
            t.crashes += fi.crash_count();
        }
        t
    }

    /// Wall-clock seconds the most recent run spent pre-drawing stage
    /// samples (0 unless [`Calendar::with_profile`] was enabled).
    pub fn sampling_seconds(&self) -> f64 {
        self.spans.seconds(Span::ArrivalSampling) + self.spans.seconds(Span::FinishSampling)
    }

    /// Event-loop span profile of the most recent run (empty unless
    /// [`Calendar::with_profile`] was enabled).
    pub fn spans(&self) -> &SpanSet {
        &self.spans
    }

    /// Read the wall clock iff profiling is on — the disabled hot path
    /// never takes an `Instant` (the [`crate::obs::PhaseClock`] rule).
    #[inline]
    fn clock(&self) -> Option<std::time::Instant> {
        if self.profile {
            Some(std::time::Instant::now())
        } else {
            None
        }
    }

    /// Close a span clock opened by [`Calendar::clock`] (no-op when
    /// profiling is off).
    #[inline]
    fn span_close(&mut self, span: Span, t0: Option<std::time::Instant>) {
        if let Some(t) = t0 {
            self.spans.add(span, t.elapsed().as_secs_f64());
        }
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        self.seq += 1;
        self.tallies.heap_pushes += 1;
        self.heap.push(Event { time, seq: self.seq, kind });
    }

    /// Run `n_jobs` jobs to completion; returns per-job records in
    /// arrival order. The engine is reusable: every call starts from an
    /// empty system.
    pub fn run(
        &mut self,
        n_jobs: usize,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) -> Vec<JobRecord> {
        assert!(
            self.faults.is_none() || self.policy.is_none(),
            "the calendar engine composes faults with FCFS only; \
             policy+faults runs go through the recursion engines"
        );
        // Reset to an empty system (slab and queues keep their capacity).
        self.heap.clear();
        self.idle.clear();
        self.idle.extend((0..self.servers as u32).rev());
        self.ready.clear();
        self.pending_jobs.clear();
        self.in_service = None;
        self.jobs.clear();
        self.free_slots.clear();
        self.completed.clear();
        self.total_jobs = n_jobs as u32;
        self.running.clear();
        self.running.resize(self.servers, None);
        self.down.clear();
        self.down.resize(self.servers, false);
        self.dseq = 0;
        self.tallies = Tallies::default();
        self.spans = SpanSet::default();
        if let Some(p) = &mut self.policy {
            p.next = 0;
        }
        if n_jobs == 0 {
            return Vec::new();
        }
        // Seed the crash calendar: one pending Crash event per server,
        // rescheduled from each Repair. The worker on/off process runs
        // regardless of load, so these live on the heap from t = 0.
        if self.faults.is_some() {
            for s in 0..self.servers as u32 {
                let c = self.faults.as_ref().expect("checked").peek_crash(s);
                if c.is_finite() {
                    self.push_event(c, EventKind::Crash(s));
                }
            }
        }

        // Lazy arrival stream: draw only the first arrival here; each
        // Arrival handler draws its successor. Together with pre-drawn
        // stage tasks this yields the draw order A(0), tasks(0), A(1),
        // tasks(1), … — identical to the recursion engines'.
        let t0 = workload.next_arrival();
        self.push_event(t0, EventKind::Arrival(0));

        // Span clocks are only read under `profile` (see `clock`); the
        // kind span nests the handler, Dispatch nests the post-event
        // dispatch pass, and EventLoop wraps the whole loop.
        let loop_t0 = self.clock();
        loop {
            let pop_t0 = self.clock();
            let Some(ev) = self.heap.pop() else { break };
            self.span_close(Span::HeapPop, pop_t0);
            self.tallies.events += 1;
            self.tallies.heap_pops += 1;
            let kind_span = match ev.kind {
                EventKind::Arrival(_) => Span::Arrival,
                EventKind::TaskFinish { .. } => Span::Finish,
                EventKind::Departure(_) => Span::Departure,
                EventKind::Crash(_)
                | EventKind::Repair(_)
                | EventKind::Retry(_)
                | EventKind::SpecLaunch { .. } => Span::Fault,
                EventKind::StealTick => Span::StealTick,
            };
            let ev_t0 = self.clock();
            match ev.kind {
                EventKind::Arrival(j) => self.on_arrival(ev.time, j, workload, overhead),
                EventKind::TaskFinish { server, slot, dseq } => {
                    self.on_finish(ev.time, server, slot, dseq, workload, overhead, trace)
                }
                EventKind::Departure(slot) => {
                    // Split-merge floor clears at the padded instant.
                    self.record_departure(ev.time, slot);
                    self.in_service = None;
                }
                EventKind::Crash(s) => self.on_crash(ev.time, s, trace),
                EventKind::Repair(s) => self.on_repair(s),
                // The backoff delay elapsed: the retry re-enters at the
                // queue front (in split-merge the in-service job's task
                // must run ahead of pending jobs' queued tasks).
                EventKind::Retry(rt) => self.ready.push_front(rt),
                EventKind::SpecLaunch { server, dseq } => {
                    self.on_spec_launch(ev.time, server, dseq, workload, overhead)
                }
                // Steal deadline reached: nothing to do here — the
                // dispatch pass below re-evaluates the queue at ev.time.
                EventKind::StealTick => {}
            }
            self.span_close(kind_span, ev_t0);
            let dispatch_t0 = self.clock();
            self.dispatch(ev.time, trace);
            self.span_close(Span::Dispatch, dispatch_t0);
            // The crash/repair calendar reschedules itself forever; stop
            // once every job has departed (no-op without faults — the
            // heap simply drains).
            if self.completed.len() as u32 == self.total_jobs {
                break;
            }
        }
        self.span_close(Span::EventLoop, loop_t0);
        let mut out = std::mem::take(&mut self.completed);
        out.sort_by_key(|r| r.index);
        out
    }

    /// Allocate a slab slot for a newly arrived job.
    fn alloc_slot(&mut self, now: f64, index: u32) -> u32 {
        let js = JobState {
            index,
            arrival: now,
            stage: 0,
            outstanding: 0,
            to_dispatch: 0,
            first_start: f64::INFINITY,
            workload: 0.0,
            task_overhead: 0.0,
            pd: 0.0,
            lost: 0.0,
            redundant: 0.0,
            retries: 0,
        };
        match self.free_slots.pop() {
            Some(s) => {
                self.jobs[s as usize] = js;
                s
            }
            None => {
                self.jobs.push(js);
                (self.jobs.len() - 1) as u32
            }
        }
    }

    /// Draw `count` (execution, overhead) pairs for `slot`'s current
    /// stage — in task order, the reproducibility contract — and enqueue
    /// them. `front` inserts ahead of already-queued tasks (split-merge
    /// barrier stages must run before the next pending job's tasks).
    fn enqueue_stage(
        &mut self,
        now: f64,
        slot: u32,
        count: u32,
        front: bool,
        workload: &mut Workload,
        overhead: &OverheadModel,
    ) {
        // Work stealing: every task of this stage becomes stealable at
        // the same absolute instant; one StealTick re-runs dispatch then.
        // Stored absolute so the tick and the compatibility check compare
        // the identical f64.
        let steal_at = match &self.policy {
            Some(p) if p.kind == PolicyKind::WorkSteal => now + p.threshold,
            _ => f64::INFINITY,
        };
        let sample_t0 = self.clock();
        let js = &mut self.jobs[slot as usize];
        js.to_dispatch = count;
        if !overhead.enabled() {
            // Batched fast path: with overhead off, `sample_task` draws
            // nothing, so the per-task stream is execution draws only —
            // one `draw_batch` produces the identical stream with the
            // distribution match hoisted out of the loop.
            self.exec_buf.resize(count as usize, 0.0);
            workload.next_executions(&mut self.exec_buf);
            if front {
                self.scratch.clear();
                for (task, &exec) in (0..count).zip(self.exec_buf.iter()) {
                    js.workload += exec;
                    let (class, affinity) = match &mut self.policy {
                        Some(p) => p.route(js.index, exec),
                        None => (0, 0),
                    };
                    self.scratch.push(ReadyTask {
                        slot, task, attempt: 1, exec, overhead: 0.0, class, affinity, steal_at,
                    });
                }
                for rt in self.scratch.drain(..).rev() {
                    self.ready.push_front(rt);
                }
            } else {
                for (task, &exec) in (0..count).zip(self.exec_buf.iter()) {
                    js.workload += exec;
                    let (class, affinity) = match &mut self.policy {
                        Some(p) => p.route(js.index, exec),
                        None => (0, 0),
                    };
                    self.ready.push_back(ReadyTask {
                        slot, task, attempt: 1, exec, overhead: 0.0, class, affinity, steal_at,
                    });
                }
            }
            if steal_at.is_finite() {
                self.push_event(steal_at, EventKind::StealTick);
            }
            if sample_t0.is_some() {
                self.close_sampling_span(slot, sample_t0);
            }
            return;
        }
        // Overhead on: execution and overhead draws interleave per task
        // (the reproducibility contract), so no batching is possible.
        if front {
            self.scratch.clear();
            for task in 0..count {
                let exec = workload.next_execution();
                let oh = overhead.sample_task(workload.rng());
                js.workload += exec;
                js.task_overhead += oh;
                let (class, affinity) = match &mut self.policy {
                    Some(p) => p.route(js.index, exec),
                    None => (0, 0),
                };
                self.scratch.push(ReadyTask {
                    slot, task, attempt: 1, exec, overhead: oh, class, affinity, steal_at,
                });
            }
            for rt in self.scratch.drain(..).rev() {
                self.ready.push_front(rt);
            }
        } else {
            for task in 0..count {
                let exec = workload.next_execution();
                let oh = overhead.sample_task(workload.rng());
                js.workload += exec;
                js.task_overhead += oh;
                let (class, affinity) = match &mut self.policy {
                    Some(p) => p.route(js.index, exec),
                    None => (0, 0),
                };
                self.ready.push_back(ReadyTask {
                    slot, task, attempt: 1, exec, overhead: oh, class, affinity, steal_at,
                });
            }
        }
        if steal_at.is_finite() {
            self.push_event(steal_at, EventKind::StealTick);
        }
        if sample_t0.is_some() {
            self.close_sampling_span(slot, sample_t0);
        }
    }

    /// Close a stage pre-draw clock into the sub-span matching where the
    /// stage was enqueued from: stage 0 under an arrival, barrier stages
    /// (≥ 1) under the finish that crossed the barrier.
    fn close_sampling_span(&mut self, slot: u32, t0: Option<std::time::Instant>) {
        let span = if self.jobs[slot as usize].stage == 0 {
            Span::ArrivalSampling
        } else {
            Span::FinishSampling
        };
        self.span_close(span, t0);
    }

    fn on_arrival(&mut self, now: f64, j: u32, workload: &mut Workload, overhead: &OverheadModel) {
        let slot = self.alloc_slot(now, j);
        // Draw this job's first-stage tasks immediately (recursion-engine
        // draw order: arrival, then k × (execution, overhead)).
        let k = self.stage_tasks[0];
        self.enqueue_stage(now, slot, k, false, workload, overhead);
        if self.discipline == Discipline::SplitMerge {
            self.pending_jobs.push_back(slot);
        }
        // Lazily schedule the successor arrival: one outstanding arrival
        // event instead of n pre-heaped ones.
        let next = j + 1;
        if next < self.total_jobs {
            let t = workload.next_arrival();
            self.push_event(t, EventKind::Arrival(next));
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_finish(
        &mut self,
        now: f64,
        server: u32,
        slot: u32,
        dseq: u64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) {
        if self.faults.is_some() {
            return self.on_finish_faulty(now, server, dseq, workload, overhead, trace);
        }
        self.idle.push(server);
        self.finish_logical_task(now, slot, workload, overhead);
    }

    /// Fault-mode finish: validate the attempt, resolve speculation
    /// races, draw task failure, and either retry or complete.
    fn on_finish_faulty(
        &mut self,
        now: f64,
        server: u32,
        dseq: u64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        trace: &mut TraceLog,
    ) {
        let sv = server as usize;
        let run = match self.running[sv] {
            Some(r) if r.seq == dseq => r,
            // Stale: the attempt was killed by a crash or lost a
            // speculation race; its server was released back then.
            _ => return,
        };
        self.running[sv] = None;
        self.idle.push(server);
        let slot = run.rt.slot;
        // First finish wins a speculation race: cancel the twin and
        // charge its wall time as redundant work.
        if let Some(p) = run.partner {
            if let Some(loser) = self.running[p as usize].take() {
                self.tallies.replica_losers += 1;
                let js = &mut self.jobs[slot as usize];
                js.redundant += now - loser.start;
                if trace.is_enabled() {
                    let index = self.jobs[slot as usize].index;
                    trace.record(TraceEvent {
                        job: index,
                        task: loser.rt.task,
                        server: p,
                        start: loser.start,
                        end: now,
                        overhead: loser.rt.overhead.min(now - loser.start),
                        winner: false,
                        attempt: loser.rt.attempt,
                        cause: cause::SPECULATION,
                        class: 0,
                    });
                }
                self.idle.push(p);
            }
        }
        let fi = self.faults.as_mut().expect("fault path");
        let attempt = run.rt.attempt;
        if attempt <= fi.config().max_retries && fi.failure_draw() {
            // The attempt fails at completion: its full service time is
            // lost and the retry re-enters after the backoff delay with
            // a freshly charged task overhead (Sec. 2.6 re-charge).
            let oh = fi.retry_overhead(overhead);
            let delay = fi.config().backoff_delay(attempt);
            self.tallies.retries += 1;
            let js = &mut self.jobs[slot as usize];
            js.lost += now - run.start;
            js.retries += 1;
            js.task_overhead += oh;
            js.outstanding -= 1;
            js.to_dispatch += 1;
            if trace.is_enabled() {
                trace.record(TraceEvent {
                    job: self.jobs[slot as usize].index,
                    task: run.rt.task,
                    server,
                    start: run.start,
                    end: now,
                    overhead: run.rt.overhead,
                    winner: false,
                    attempt,
                    cause: cause::FAILED,
                    class: 0,
                });
            }
            let retry = ReadyTask { attempt: attempt + 1, overhead: oh, ..run.rt };
            self.push_event(now + delay, EventKind::Retry(retry));
            return;
        }
        if trace.is_enabled() {
            trace.record(TraceEvent {
                job: self.jobs[slot as usize].index,
                task: run.rt.task,
                server,
                start: run.start,
                end: now,
                overhead: run.rt.overhead,
                winner: true,
                attempt,
                cause: if run.is_backup { cause::SPECULATION } else { cause::NONE },
                class: 0,
            });
        }
        self.finish_logical_task(now, slot, workload, overhead);
    }

    /// Worker crash: consume the injector's pending crash, kill any
    /// in-flight attempt (elapsed service is lost work; no retry budget
    /// is spent), and schedule the repair.
    fn on_crash(&mut self, now: f64, server: u32, trace: &mut TraceLog) {
        let sv = server as usize;
        let fi = self.faults.as_mut().expect("crash without injector");
        let (up, _next) = fi.consume_crash(server);
        self.down[sv] = true;
        self.push_event(up, EventKind::Repair(server));
        match self.running[sv].take() {
            Some(run) => {
                self.jobs[run.rt.slot as usize].lost += now - run.start;
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job: self.jobs[run.rt.slot as usize].index,
                        task: run.rt.task,
                        server,
                        start: run.start,
                        end: now,
                        overhead: run.rt.overhead.min(now - run.start),
                        winner: false,
                        attempt: run.rt.attempt,
                        cause: cause::CRASHED,
                        class: 0,
                    });
                }
                match run.partner {
                    // A speculation copy dies with its worker; the
                    // surviving twin carries the logical task alone.
                    Some(p) => {
                        if let Some(other) = &mut self.running[p as usize] {
                            other.partner = None;
                        }
                    }
                    // A solo attempt dies: requeue it at the front for
                    // immediate re-dispatch with the same draws.
                    None => {
                        let js = &mut self.jobs[run.rt.slot as usize];
                        js.outstanding -= 1;
                        js.to_dispatch += 1;
                        self.ready.push_front(run.rt);
                    }
                }
            }
            // Idle worker goes down: pull it from the idle stack.
            None => self.idle.retain(|&s| s != server),
        }
    }

    /// Repair done: the worker rejoins the idle pool and its next crash
    /// goes on the calendar.
    fn on_repair(&mut self, server: u32) {
        self.down[server as usize] = false;
        self.idle.push(server);
        let next = self.faults.as_ref().expect("repair without injector").peek_crash(server);
        if next.is_finite() {
            self.push_event(next, EventKind::Crash(server));
        }
    }

    /// The attempt at (`server`, `dseq`) outlived the speculation
    /// deadline: launch a backup copy with fresh fault-stream draws on
    /// an idle server, first finish wins. No idle server → no hedge.
    fn on_spec_launch(
        &mut self,
        now: f64,
        server: u32,
        dseq: u64,
        workload: &mut Workload,
        overhead: &OverheadModel,
    ) {
        let sv = server as usize;
        let rt = match self.running[sv] {
            Some(r) if r.seq == dseq && r.partner.is_none() => r.rt,
            _ => return,
        };
        let Some(backup_server) = self.idle.pop() else {
            return;
        };
        self.tallies.spec_launches += 1;
        let fi = self.faults.as_mut().expect("speculation without injector");
        let (exec, oh) = fi.backup_draws(workload, overhead);
        self.dseq += 1;
        let backup = Running {
            seq: self.dseq,
            rt: ReadyTask { exec, overhead: oh, ..rt },
            start: now,
            partner: Some(server),
            is_backup: true,
        };
        self.running[backup_server as usize] = Some(backup);
        self.running[sv].as_mut().expect("validated above").partner = Some(backup_server);
        self.push_event(
            now + exec + oh,
            EventKind::TaskFinish { server: backup_server, slot: rt.slot, dseq: self.dseq },
        );
    }

    /// Shared tail of a logical task's completion: decrement the
    /// outstanding count and cross the stage barrier / complete the job
    /// when it was the last one.
    fn finish_logical_task(
        &mut self,
        now: f64,
        slot: u32,
        workload: &mut Workload,
        overhead: &OverheadModel,
    ) {
        let js = &mut self.jobs[slot as usize];
        js.outstanding -= 1;
        if js.outstanding > 0 || js.to_dispatch > 0 {
            return;
        }
        let next_stage = js.stage + 1;
        if (next_stage as usize) < self.stage_tasks.len() {
            // Shuffle barrier crossed: enqueue the next stage. In
            // split-merge the in-service job's new stage must run ahead
            // of pending jobs' queued tasks; in fork-join the stage joins
            // the back of the global FIFO like any other work.
            js.stage = next_stage;
            let count = self.stage_tasks[next_stage as usize];
            let front = self.discipline == Discipline::SplitMerge;
            self.enqueue_stage(now, slot, count, front, workload, overhead);
        } else {
            // Job complete: record it right here (the handler knows the
            // finishing job, so no scan over the job table is needed).
            let pd = overhead.pre_departure(self.total_tasks as usize);
            match self.discipline {
                Discipline::SplitMerge => {
                    // The pre-departure overhead blocks the floor until
                    // the departure instant.
                    self.jobs[slot as usize].pd = pd;
                    self.push_event(now + pd, EventKind::Departure(slot));
                }
                Discipline::SingleQueueForkJoin => self.complete_job(now, slot, pd),
            }
        }
    }

    /// Record a completed fork-join job departing at `now + pd` and
    /// retire its slot.
    fn complete_job(&mut self, now: f64, slot: u32, pd: f64) {
        let stats_t0 = self.clock();
        self.tallies.jobs += 1;
        let js = &self.jobs[slot as usize];
        self.completed.push(JobRecord {
            index: js.index as usize,
            arrival: js.arrival,
            departure: now + pd,
            first_start: js.first_start,
            workload: js.workload,
            task_overhead: js.task_overhead,
            pre_departure_overhead: pd,
            redundant_work: js.redundant,
            lost_work: js.lost,
            retries: js.retries,
        });
        self.free_slots.push(slot);
        self.span_close(Span::FinishStats, stats_t0);
    }

    /// Record a (split-merge) departure at exactly `time` (the scheduled
    /// instant already includes the pre-departure overhead) and retire
    /// the slot.
    fn record_departure(&mut self, time: f64, slot: u32) {
        self.tallies.jobs += 1;
        let js = &self.jobs[slot as usize];
        self.completed.push(JobRecord {
            index: js.index as usize,
            arrival: js.arrival,
            departure: time,
            first_start: js.first_start,
            workload: js.workload,
            task_overhead: js.task_overhead,
            pre_departure_overhead: js.pd,
            redundant_work: js.redundant,
            lost_work: js.lost,
            retries: js.retries,
        });
        self.free_slots.push(slot);
    }

    fn dispatch(&mut self, now: f64, trace: &mut TraceLog) {
        if self.policy.is_some() {
            let t0 = self.clock();
            self.dispatch_policy(now, trace);
            self.span_close(Span::PolicyDispatch, t0);
            return;
        }
        // Split-merge: admit the next job when the floor is clear (the
        // Departure event clears `in_service` at finish + pre-departure).
        if self.discipline == Discipline::SplitMerge && self.in_service.is_none() {
            if let Some(slot) = self.pending_jobs.pop_front() {
                self.in_service = Some(slot);
            }
        }
        while !self.idle.is_empty() {
            let Some(rt) = self.ready.front() else { break };
            // Split-merge gate: only the in-service job's tasks may run;
            // pending jobs' queued tasks wait behind the floor.
            if self.discipline == Discipline::SplitMerge && Some(rt.slot) != self.in_service {
                break;
            }
            let rt = *rt;
            self.ready.pop_front();
            let server = self.idle.pop().expect("checked non-empty");
            self.tallies.dispatched += 1;
            let js = &mut self.jobs[rt.slot as usize];
            js.to_dispatch -= 1;
            js.outstanding += 1;
            // A task cannot start before its job arrives; idle servers
            // wait for the queue to refill.
            let start = now.max(js.arrival);
            if start < js.first_start {
                js.first_start = start;
            }
            let finish = start + rt.exec + rt.overhead;
            if self.faults.is_some() {
                // Fault mode: register the attempt (its events carry the
                // dispatch sequence for staleness checks) and put it on
                // the speculation calendar if it outlives the deadline.
                // Trace events are recorded at resolution, not here —
                // the attempt may yet crash, fail, or lose a race.
                self.dseq += 1;
                self.running[server as usize] =
                    Some(Running { seq: self.dseq, rt, start, partner: None, is_backup: false });
                let deadline = self.faults.as_ref().expect("checked").spec_deadline();
                if finish - start > deadline {
                    self.push_event(
                        start + deadline,
                        EventKind::SpecLaunch { server, dseq: self.dseq },
                    );
                }
            } else if trace.is_enabled() {
                trace.record(TraceEvent {
                    job: js.index,
                    task: rt.task,
                    server,
                    start,
                    end: finish,
                    overhead: rt.overhead,
                    winner: true,
                    attempt: 1,
                    cause: cause::NONE,
                    class: 0,
                });
            }
            self.push_event(
                finish,
                EventKind::TaskFinish { server, slot: rt.slot, dseq: self.dseq },
            );
        }
    }

    /// Policy dispatch pass: pair each idle server with the first queued
    /// task it may run — class-matched partitions for SITA/priority,
    /// affinity-or-stolen for work stealing — instead of the strict-FIFO
    /// head-of-queue rule. Fault-free by construction (asserted in
    /// [`Calendar::run`]), so attempts complete unconditionally.
    fn dispatch_policy(&mut self, now: f64, trace: &mut TraceLog) {
        if self.discipline == Discipline::SplitMerge && self.in_service.is_none() {
            if let Some(slot) = self.pending_jobs.pop_front() {
                self.in_service = Some(slot);
            }
        }
        let in_service = self.in_service;
        let gated = self.discipline == Discipline::SplitMerge;
        let mut i = 0;
        while i < self.idle.len() {
            let server = self.idle[i];
            let found = {
                let p = self.policy.as_ref().expect("policy dispatch");
                self.ready.iter().position(|rt| {
                    (!gated || Some(rt.slot) == in_service) && p.compatible(server, rt, now)
                })
            };
            match found {
                Some(idx) => {
                    let rt = self.ready.remove(idx).expect("index from position");
                    self.idle.swap_remove(i);
                    let p = self.policy.as_ref().expect("policy dispatch");
                    if p.kind == PolicyKind::WorkSteal && rt.affinity != server {
                        self.tallies.steals += 1;
                    }
                    self.tallies.dispatched += 1;
                    self.tallies.class_dispatch(rt.class as usize);
                    self.start_task(now, server, rt, trace);
                    // Don't advance: swap_remove moved a new server here.
                }
                None => i += 1,
            }
        }
    }

    /// Start `rt` on `server` at `now` (fault-free policy path): the
    /// shared accounting + trace + finish-event tail of a dispatch.
    fn start_task(&mut self, now: f64, server: u32, rt: ReadyTask, trace: &mut TraceLog) {
        let js = &mut self.jobs[rt.slot as usize];
        js.to_dispatch -= 1;
        js.outstanding += 1;
        let start = now.max(js.arrival);
        if start < js.first_start {
            js.first_start = start;
        }
        let finish = start + rt.exec + rt.overhead;
        if trace.is_enabled() {
            trace.record(TraceEvent {
                job: js.index,
                task: rt.task,
                server,
                start,
                end: finish,
                overhead: rt.overhead,
                winner: true,
                attempt: 1,
                cause: cause::NONE,
                class: rt.class,
            });
        }
        self.push_event(
            finish,
            EventKind::TaskFinish { server, slot: rt.slot, dseq: self.dseq },
        );
    }

    /// Slab capacity (test hook: bounded by in-flight jobs, not run
    /// length).
    #[cfg(test)]
    fn slab_len(&self) -> usize {
        self.jobs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Deterministic, Exponential};

    fn workload(ia: f64, ex: f64, seed: u64) -> Workload {
        Workload::new(Deterministic::new(ia).into(), Deterministic::new(ex).into(), seed)
    }

    #[test]
    fn single_stage_fj_deterministic() {
        // l=2, k=4, exec=1, arrivals every 10: each job takes 2 s.
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4]);
        let mut w = workload(10.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(3, &mut w, &oh, &mut tr);
        assert_eq!(recs.len(), 3);
        for r in &recs {
            assert!((r.sojourn() - 2.0).abs() < 1e-12, "{}", r.sojourn());
        }
    }

    #[test]
    fn split_merge_blocks() {
        // l=2, k=4, exec=1 → Δ=2; arrivals every 1 s → serial service.
        let mut cal = Calendar::new(Discipline::SplitMerge, 2, vec![4]);
        let mut w = workload(1.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(5, &mut w, &oh, &mut tr);
        // D(n) = 3 + 2n (first arrival at t=1).
        for (n, r) in recs.iter().enumerate() {
            assert!(
                (r.departure - (3.0 + 2.0 * n as f64)).abs() < 1e-9,
                "job {n}: {}",
                r.departure
            );
        }
    }

    /// Two-stage job (map k=4, reduce m=2) with a shuffle barrier: the
    /// reduce stage cannot start before every map task finished.
    #[test]
    fn shuffle_barrier_enforced() {
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4, 2]);
        let mut w = workload(100.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let recs = cal.run(1, &mut w, &oh, &mut tr);
        // Map: 4 tasks on 2 servers = done at arrival+2; reduce: 2 tasks
        // in parallel = +1 → sojourn 3.
        assert!((recs[0].sojourn() - 3.0).abs() < 1e-12, "{}", recs[0].sojourn());
        // Trace: 6 tasks total; no reduce task starts before t=arrival+2.
        let events = tr.events();
        assert_eq!(events.len(), 6);
        let map_end = recs[0].arrival + 2.0;
        let late_starts = events.iter().filter(|e| e.start >= map_end - 1e-9).count();
        assert_eq!(late_starts, 2, "exactly the reduce tasks start after the barrier");
    }

    /// Multi-stage split-merge: the in-service job's barrier stage runs
    /// ahead of the next pending job's queued tasks (front insertion).
    #[test]
    fn split_merge_multi_stage_keeps_floor() {
        let mut cal = Calendar::new(Discipline::SplitMerge, 2, vec![2, 2]);
        // Arrivals every 1 s, exec 1 s: job 0 holds the floor over
        // [1, 3) (two stages × 1 s) while job 1 waits.
        let mut w = workload(1.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(3, &mut w, &oh, &mut tr);
        for (n, r) in recs.iter().enumerate() {
            assert!(
                (r.departure - (3.0 + 2.0 * n as f64)).abs() < 1e-9,
                "job {n}: {}",
                r.departure
            );
            assert!((r.workload - 4.0).abs() < 1e-12);
        }
    }

    /// Exponential two-stage FJ: adding a reduce stage increases sojourn
    /// versus single-stage with the same total work.
    #[test]
    fn second_stage_costs_synchronization() {
        let run = |stages: Vec<u32>| -> f64 {
            let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 4, stages);
            let mut w = Workload::new(
                Exponential::new(0.3).into(),
                Exponential::new(2.0).into(),
                7,
            );
            let oh = OverheadModel::none();
            let mut tr = TraceLog::disabled();
            let recs = cal.run(4000, &mut w, &oh, &mut tr);
            recs.iter().map(|r| r.sojourn()).sum::<f64>() / recs.len() as f64
        };
        // 12 tasks in one stage vs 8 map + 4 reduce (same count, same
        // per-task law → same workload, extra barrier).
        let single = run(vec![12]);
        let staged = run(vec![8, 4]);
        assert!(staged > single, "barrier must cost: {staged} !> {single}");
    }

    /// Raw tallies track the run's event flow and reset between runs;
    /// the span profile only measures when enabled, and its enter
    /// counts reconcile exactly with the (deterministic) event flow.
    #[test]
    fn tallies_and_profile_track_run() {
        let mut cal =
            Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4]).with_profile(true);
        let mut w = workload(10.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(3, &mut w, &oh, &mut tr);
        assert_eq!(recs.len(), 3);
        let t = cal.tallies();
        assert_eq!(t.jobs, 3);
        assert_eq!(t.dispatched, 12, "3 jobs × 4 tasks");
        assert_eq!(t.heap_pushes, t.heap_pops, "every event pushed is popped");
        assert_eq!(t.events, t.heap_pops);
        assert!(cal.sampling_seconds() >= 0.0);
        let spans = cal.spans();
        assert_eq!(spans.count(Span::EventLoop), 1);
        assert_eq!(spans.count(Span::HeapPop), t.heap_pops);
        assert_eq!(spans.count(Span::Dispatch), t.events, "one pass per event");
        assert_eq!(spans.count(Span::Arrival), 3);
        assert_eq!(spans.count(Span::Finish), 12);
        assert_eq!(spans.count(Span::ArrivalSampling), 3, "one stage pre-draw per arrival");
        assert_eq!(spans.count(Span::FinishStats), 3, "one completion record per job");
        assert_eq!(spans.count(Span::FinishSampling), 0, "single-stage: no barrier");
        assert_eq!(spans.count(Span::PolicyDispatch), 0, "no policy attached");
        assert!(spans.seconds(Span::EventLoop) > 0.0);
        // A second run resets the tallies and spans instead of
        // accumulating.
        cal.run(3, &mut workload(10.0, 1.0, 1), &oh, &mut tr);
        assert_eq!(cal.tallies().jobs, 3);
        assert_eq!(cal.spans().count(Span::EventLoop), 1);
        // An unprofiled engine records no spans at all.
        let mut cold = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4]);
        cold.run(3, &mut workload(10.0, 1.0, 1), &oh, &mut tr);
        assert!(cold.spans().is_empty());
    }

    /// Profiling never perturbs the simulation: same seed, spans on vs
    /// off, bit-for-bit identical records — across plain, multi-stage,
    /// faulty, and policy-routed runs.
    #[test]
    fn profile_on_is_bitwise_identical() {
        let fault_cfg = crate::config::FaultsConfig {
            mtbf: 5.0,
            mttr: 0.5,
            task_fail_p: 0.2,
            max_retries: 2,
            backoff_base: 0.05,
            spec_timeout: 1.5,
            ..Default::default()
        };
        let sita = PolicyConfig {
            kind: PolicyKind::Sita,
            sita_boundaries: vec![0.5],
            ..Default::default()
        };
        let steal = PolicyConfig {
            kind: PolicyKind::WorkSteal,
            steal_threshold: 0.25,
            ..Default::default()
        };
        type Build = Box<dyn Fn() -> Calendar>;
        let cases: Vec<(&str, Build)> = vec![
            (
                "fj/plain",
                Box::new(|| Calendar::new(Discipline::SingleQueueForkJoin, 3, vec![6])),
            ),
            ("sm/stages", Box::new(|| Calendar::new(Discipline::SplitMerge, 3, vec![4, 2]))),
            (
                "fj/faults",
                Box::new(move || {
                    Calendar::new(Discipline::SingleQueueForkJoin, 3, vec![6])
                        .with_faults(Some(faults(fault_cfg, 3, 42)))
                }),
            ),
            (
                "fj/sita",
                Box::new(move || {
                    Calendar::new(Discipline::SingleQueueForkJoin, 4, vec![4])
                        .with_policy(Some(&sita))
                }),
            ),
            (
                "sm/steal",
                Box::new(move || {
                    Calendar::new(Discipline::SplitMerge, 3, vec![6]).with_policy(Some(&steal))
                }),
            ),
        ];
        for (name, build) in cases {
            let mk_w = || {
                Workload::new(Exponential::new(0.4).into(), Exponential::new(2.0).into(), 5)
            };
            let oh = OverheadModel::paper_default();
            let mut tr = TraceLog::disabled();
            let mut off = build();
            let a = off.run(300, &mut mk_w(), &oh, &mut tr);
            let mut on = build().with_profile(true);
            let b = on.run(300, &mut mk_w(), &oh, &mut tr);
            assert!(off.spans().is_empty(), "{name}: unprofiled run recorded spans");
            assert!(!on.spans().is_empty(), "{name}: profiled run recorded nothing");
            assert_eq!(a.len(), b.len(), "{name}");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival, y.arrival, "{name}");
                assert_eq!(x.departure, y.departure, "{name}");
                assert_eq!(x.first_start, y.first_start, "{name}");
                assert_eq!(x.workload, y.workload, "{name}");
                assert_eq!(x.task_overhead, y.task_overhead, "{name}");
                assert_eq!(x.lost_work, y.lost_work, "{name}");
                assert_eq!(x.redundant_work, y.redundant_work, "{name}");
                assert_eq!(x.retries, y.retries, "{name}");
            }
        }
    }

    /// Retired job slots are recycled: a long lightly-loaded run keeps
    /// the slab at the in-flight width, not the run length.
    #[test]
    fn slab_stays_bounded_by_in_flight_jobs() {
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4]);
        // Arrivals every 10 s, service 2 s: at most one job in flight.
        let mut w = workload(10.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(500, &mut w, &oh, &mut tr);
        assert_eq!(recs.len(), 500);
        assert!(cal.slab_len() <= 2, "slab grew to {} for a 1-in-flight run", cal.slab_len());
    }

    fn faults(cfg: crate::config::FaultsConfig, servers: usize, seed: u64) -> FaultInjector {
        FaultInjector::new(cfg, servers, seed, 1.0)
    }

    /// Crashes kill in-flight attempts (lost work accrues) yet every job
    /// still departs, deterministically in the seed.
    #[test]
    fn crashes_lose_work_deterministically() {
        let cfg = crate::config::FaultsConfig {
            mtbf: 5.0,
            mttr: 0.5,
            ..Default::default()
        };
        let run_once = || {
            let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4])
                .with_faults(Some(faults(cfg, 2, 42)));
            let mut w = workload(4.0, 1.0, 1);
            let oh = OverheadModel::none();
            let mut tr = TraceLog::disabled();
            cal.run(50, &mut w, &oh, &mut tr)
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a.len(), 50);
        let lost: f64 = a.iter().map(|r| r.lost_work).sum();
        assert!(lost > 0.0, "crashes must lose work");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.departure, y.departure);
            assert_eq!(x.lost_work, y.lost_work);
        }
    }

    /// Task failures trigger counted retries with backoff; jobs depart.
    #[test]
    fn failures_retry_and_depart() {
        let cfg = crate::config::FaultsConfig {
            task_fail_p: 0.6,
            max_retries: 3,
            backoff_base: 0.1,
            ..Default::default()
        };
        let mut cal = Calendar::new(Discipline::SplitMerge, 2, vec![4])
            .with_faults(Some(faults(cfg, 2, 7)));
        let mut w = workload(20.0, 1.0, 7);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(30, &mut w, &oh, &mut tr);
        assert_eq!(recs.len(), 30);
        let retries: u32 = recs.iter().map(|r| r.retries).sum();
        let lost: f64 = recs.iter().map(|r| r.lost_work).sum();
        assert!(retries > 0, "p=0.6 over 120 tasks must retry");
        assert!(lost > 0.0);
        assert_eq!(cal.tallies().retries, u64::from(retries));
        for r in &recs {
            assert!(r.departure >= r.arrival);
        }
    }

    /// A straggling attempt is hedged at the speculation deadline; first
    /// finish wins and the loser's wall time is redundant.
    #[test]
    fn speculation_hedges_stragglers() {
        let cfg = crate::config::FaultsConfig {
            spec_timeout: 0.5, // deadline = 0.5 × expected_task(=1.0)
            ..Default::default()
        };
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![1])
            .with_faults(Some(faults(cfg, 2, 3)));
        // Deterministic exec 1.0 > deadline 0.5: every task is hedged;
        // the earlier-started primary always wins.
        let mut w = workload(10.0, 1.0, 1);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = cal.run(3, &mut w, &oh, &mut tr);
        for r in &recs {
            assert!((r.sojourn() - 1.0).abs() < 1e-12, "{}", r.sojourn());
            assert!((r.redundant_work - 0.5).abs() < 1e-12, "{}", r.redundant_work);
            assert_eq!(r.retries, 0);
        }
        let t = cal.tallies();
        assert_eq!(t.spec_launches, 3, "every task is hedged");
        assert_eq!(t.replica_losers, 3, "every backup loses the race");
    }

    /// An FCFS (or absent) policy builds no routing table: the run is
    /// bit-for-bit the plain engine.
    #[test]
    fn fcfs_policy_is_bit_identical() {
        let mk_w = || Workload::new(Exponential::new(0.4).into(), Exponential::new(2.0).into(), 5);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let mut plain = Calendar::new(Discipline::SingleQueueForkJoin, 3, vec![6]);
        let a = plain.run(400, &mut mk_w(), &oh, &mut tr);
        let pc = PolicyConfig { kind: PolicyKind::Fcfs, ..Default::default() };
        let mut gated = Calendar::new(Discipline::SingleQueueForkJoin, 3, vec![6])
            .with_policy(Some(&pc));
        let b = gated.run(400, &mut mk_w(), &oh, &mut tr);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.departure, y.departure);
            assert_eq!(x.first_start, y.first_start);
        }
    }

    /// SITA: every dispatched task lands inside its size class's server
    /// partition (servers 0–1 ↔ small, 2–3 ↔ large for one boundary over
    /// four servers).
    #[test]
    fn sita_routes_size_classes_to_partitions() {
        let pc = PolicyConfig {
            kind: PolicyKind::Sita,
            sita_boundaries: vec![0.5],
            ..Default::default()
        };
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 4, vec![4])
            .with_policy(Some(&pc));
        let mut w = Workload::new(Exponential::new(0.3).into(), Exponential::new(2.0).into(), 9);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let recs = cal.run(200, &mut w, &oh, &mut tr);
        assert_eq!(recs.len(), 200);
        let mut seen = [false, false];
        for e in tr.events() {
            // Overhead is off, so the occupancy is the pre-drawn size
            // (up to fp re-rounding of start + exec − start; skip the
            // knife-edge).
            let occ = e.end - e.start;
            if (occ - 0.5).abs() > 1e-9 {
                assert_eq!(e.class, u32::from(occ >= 0.5), "class from the size");
            }
            assert_eq!(e.server / 2, e.class, "server partition must match class");
            seen[e.class as usize] = true;
        }
        assert!(seen[0] && seen[1], "both size classes must occur");
    }

    /// Priority: class = job mod classes, dispatched on the class's
    /// partition.
    #[test]
    fn priority_partitions_by_job_class() {
        let pc = PolicyConfig { kind: PolicyKind::Priority, classes: 2, ..Default::default() };
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 4, vec![2])
            .with_policy(Some(&pc));
        let mut w = Workload::new(Exponential::new(0.3).into(), Exponential::new(2.0).into(), 9);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let recs = cal.run(100, &mut w, &oh, &mut tr);
        assert_eq!(recs.len(), 100);
        for e in tr.events() {
            assert_eq!(e.class, e.job % 2);
            assert_eq!(e.server / 2, e.class);
        }
    }

    /// Work stealing: at threshold 0 every task is instantly stealable —
    /// exactly the FCFS head-of-queue rule — and a prohibitive threshold
    /// (tasks pinned to their round-robin server) costs sojourn time.
    #[test]
    fn worksteal_threshold_shapes_sojourn() {
        let mean = |threshold: f64| {
            let pc = PolicyConfig {
                kind: PolicyKind::WorkSteal,
                steal_threshold: threshold,
                ..Default::default()
            };
            let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4])
                .with_policy(Some(&pc));
            let mut w =
                Workload::new(Exponential::new(0.2).into(), Exponential::new(2.0).into(), 11);
            let oh = OverheadModel::none();
            let mut tr = TraceLog::disabled();
            let recs = cal.run(2000, &mut w, &oh, &mut tr);
            recs.iter().map(|r| r.sojourn()).sum::<f64>() / recs.len() as f64
        };
        let free = mean(0.0);
        let pinned = mean(1e9);
        assert!(
            pinned > free,
            "pinned affinities must queue longer: {pinned} !> {free}"
        );
        // Threshold 0 reduces to the plain FIFO engine sample-for-sample.
        let mut plain = Calendar::new(Discipline::SingleQueueForkJoin, 2, vec![4]);
        let mut w = Workload::new(Exponential::new(0.2).into(), Exponential::new(2.0).into(), 11);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let recs = plain.run(2000, &mut w, &oh, &mut tr);
        let plain_mean = recs.iter().map(|r| r.sojourn()).sum::<f64>() / recs.len() as f64;
        assert_eq!(free, plain_mean, "threshold 0 ≡ FCFS");
    }

    /// The engine is reusable: back-to-back runs from the same instance
    /// give identical results to a fresh instance.
    #[test]
    fn reusable_across_runs() {
        let mk_w = || Workload::new(Exponential::new(0.4).into(), Exponential::new(2.0).into(), 7);
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, 3, vec![6]);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let first = cal.run(300, &mut mk_w(), &oh, &mut tr);
        let second = cal.run(300, &mut mk_w(), &oh, &mut tr);
        let mut fresh_cal = Calendar::new(Discipline::SingleQueueForkJoin, 3, vec![6]);
        let fresh = fresh_cal.run(300, &mut mk_w(), &oh, &mut tr);
        assert_eq!(first.len(), 300);
        for ((a, b), c) in first.iter().zip(&second).zip(&fresh) {
            assert_eq!(a.departure, b.departure);
            assert_eq!(a.departure, c.departure);
        }
    }
}
