//! Workload generation: job arrivals and task execution times drawn from
//! the configured distributions (Sec. 2.3's controlled experiments).

use crate::config::SimulationConfig;
use crate::dist::{parse_spec, Distribution};
use crate::rng::{Pcg64, Rng};

/// A reproducible stream of job arrivals and task execution times.
pub struct Workload {
    interarrival: Box<dyn Distribution>,
    execution: Box<dyn Distribution>,
    /// Devirtualized fast path: exponential execution rate, if the
    /// execution distribution is `Exp` (the paper's canonical case; §Perf
    /// log — saves a dyn call + closure per task on the hot loop).
    exec_exp_rate: Option<f64>,
    rng: Pcg64,
    clock: f64,
}

impl Workload {
    /// Build from a simulation config (validated specs).
    pub fn from_config(cfg: &SimulationConfig) -> Result<Self, String> {
        Ok(Self::new(
            parse_spec(&cfg.arrival.interarrival)?,
            parse_spec(&cfg.service.execution)?,
            cfg.seed,
        ))
    }

    /// Build from explicit distributions and a seed.
    pub fn new(
        interarrival: Box<dyn Distribution>,
        execution: Box<dyn Distribution>,
        seed: u64,
    ) -> Self {
        // Recognize the exponential case for the devirtualized fast path
        // (identical sampling formula, so results are bit-for-bit equal).
        // TT_NO_FAST_EXP=1 disables it for §Perf A/B measurement.
        let exec_exp_rate = if std::env::var_os("TT_NO_FAST_EXP").is_some() {
            None
        } else {
            let label = execution.label();
            label
                .strip_prefix("Exp(")
                .and_then(|s| s.strip_suffix(')'))
                .and_then(|s| s.parse::<f64>().ok())
        };
        Self {
            interarrival,
            execution,
            exec_exp_rate,
            rng: Pcg64::seed_from_u64(seed),
            clock: 0.0,
        }
    }

    /// Advance to and return the next job arrival time.
    #[inline]
    pub fn next_arrival(&mut self) -> f64 {
        let mut f = || self.rng.next_f64_open();
        self.clock += self.interarrival.sample(&mut f);
        self.clock
    }

    /// Draw one task execution time `E_i(n)`.
    #[inline]
    pub fn next_execution(&mut self) -> f64 {
        if let Some(rate) = self.exec_exp_rate {
            return -self.rng.next_f64_open().ln() / rate;
        }
        let mut f = || self.rng.next_f64_open();
        self.execution.sample(&mut f)
    }

    /// Mean task execution time of the configured distribution.
    pub fn mean_execution(&self) -> f64 {
        self.execution.mean()
    }

    /// Mean inter-arrival time of the configured distribution.
    pub fn mean_interarrival(&self) -> f64 {
        self.interarrival.mean()
    }

    /// Mutable access to the underlying RNG (overhead sampling shares it).
    #[inline]
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Exponential;

    #[test]
    fn arrivals_increase() {
        let mut w = Workload::new(
            Box::new(Exponential::new(0.5)),
            Box::new(Exponential::new(1.0)),
            7,
        );
        let mut prev = 0.0;
        for _ in 0..1000 {
            let a = w.next_arrival();
            assert!(a > prev);
            prev = a;
        }
        // Mean inter-arrival ≈ 2.
        assert!((prev / 1000.0 - 2.0).abs() < 0.2);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            Workload::new(
                Box::new(Exponential::new(1.0)),
                Box::new(Exponential::new(2.0)),
                99,
            )
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..100 {
            assert_eq!(a.next_arrival(), b.next_arrival());
            assert_eq!(a.next_execution(), b.next_execution());
        }
    }

    #[test]
    fn from_config_honours_specs() {
        let cfg = SimulationConfig {
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.25".into() },
            service: crate::config::ServiceConfig { execution: "det:2.0".into() },
            ..Default::default()
        };
        let mut w = Workload::from_config(&cfg).unwrap();
        assert_eq!(w.mean_interarrival(), 4.0);
        assert_eq!(w.next_execution(), 2.0);
    }
}
