//! Workload generation: job arrivals and task execution times drawn from
//! the configured distributions (Sec. 2.3's controlled experiments).

use crate::config::SimulationConfig;
use crate::dist::{parse_spec, Dist, Distribution};
use crate::rng::{Pcg64, Rng};

/// A reproducible stream of job arrivals and task execution times.
///
/// Sampling is enum-dispatched through [`Dist::draw`] — the innermost
/// loop of every simulator engine monomorphizes to straight arithmetic
/// with no vtable call and no `&mut dyn FnMut` closure (§Perf log).
/// `TT_NO_FAST_EXP=1` routes execution draws through dyn dispatch
/// instead, for A/B-measuring the dispatch cost; both paths use the same
/// formulas on the same stream, so results are bit-for-bit identical
/// (enforced by `rust/tests/scenario_equivalence.rs`).
pub struct Workload {
    interarrival: Dist,
    execution: Dist,
    /// `TT_NO_FAST_EXP=1`: force the dyn-dispatch sampling path.
    force_dyn: bool,
    rng: Pcg64,
    clock: f64,
    // Raw draw tallies for the obs layer (unconditional u64 increments;
    // no branch, no RNG consumption, no effect on the draw stream).
    arrival_draws: u64,
    execution_draws: u64,
    batch_draws: u64,
}

impl Workload {
    /// Build from a simulation config (validated specs).
    pub fn from_config(cfg: &SimulationConfig) -> Result<Self, String> {
        Ok(Self::new(
            parse_spec(&cfg.arrival.interarrival)?,
            parse_spec(&cfg.service.execution)?,
            cfg.seed,
        ))
    }

    /// Build from explicit distributions and a seed.
    pub fn new(interarrival: Dist, execution: Dist, seed: u64) -> Self {
        Self {
            interarrival,
            execution,
            force_dyn: std::env::var_os("TT_NO_FAST_EXP").is_some(),
            rng: Pcg64::seed_from_u64(seed),
            clock: 0.0,
            arrival_draws: 0,
            execution_draws: 0,
            batch_draws: 0,
        }
    }

    /// Raw (arrival, execution, batch) draw tallies since construction.
    /// Batch calls count each slot as an execution draw plus one batch
    /// draw; [`Workload::execution_with`] draws are excluded (they come
    /// from the caller's RNG stream, not the workload's).
    #[inline]
    pub fn draw_counts(&self) -> (u64, u64, u64) {
        (self.arrival_draws, self.execution_draws, self.batch_draws)
    }

    /// Advance to and return the next job arrival time.
    #[inline]
    pub fn next_arrival(&mut self) -> f64 {
        self.arrival_draws += 1;
        self.clock += self.interarrival.draw(&mut self.rng);
        self.clock
    }

    /// Draw one task execution time `E_i(n)`.
    #[inline]
    pub fn next_execution(&mut self) -> f64 {
        self.execution_draws += 1;
        if self.force_dyn {
            let mut f = || self.rng.next_f64_open();
            let d: &dyn Distribution = &self.execution;
            return d.sample(&mut f);
        }
        self.execution.draw(&mut self.rng)
    }

    /// Draw one execution time per slot of `out` — the batch hot path
    /// for pre-drawn stage tasks. Identical stream to calling
    /// [`Workload::next_execution`] `out.len()` times (bit-for-bit);
    /// `TT_NO_FAST_EXP=1` forces the dyn-dispatch loop here too.
    #[inline]
    pub fn next_executions(&mut self, out: &mut [f64]) {
        self.execution_draws += out.len() as u64;
        self.batch_draws += 1;
        if self.force_dyn {
            for o in out {
                let mut f = || self.rng.next_f64_open();
                let d: &dyn Distribution = &self.execution;
                *o = d.sample(&mut f);
            }
            return;
        }
        self.execution.draw_batch(&mut self.rng, out)
    }

    /// Draw one execution time from the task distribution with a
    /// caller-provided RNG. Fault-injection backup copies and retries
    /// redraw task sizes from the injector's own stream through this,
    /// leaving the workload stream untouched (so fault-free portions of
    /// a faulty run still see the exact seed-engine draws).
    #[inline]
    pub fn execution_with(&self, rng: &mut Pcg64) -> f64 {
        self.execution.draw(rng)
    }

    /// Mean task execution time of the configured distribution.
    pub fn mean_execution(&self) -> f64 {
        self.execution.mean()
    }

    /// Mean inter-arrival time of the configured distribution.
    pub fn mean_interarrival(&self) -> f64 {
        self.interarrival.mean()
    }

    /// Mutable access to the underlying RNG (overhead sampling shares it).
    #[inline]
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Exponential;

    #[test]
    fn arrivals_increase() {
        let mut w = Workload::new(Exponential::new(0.5).into(), Exponential::new(1.0).into(), 7);
        let mut prev = 0.0;
        for _ in 0..1000 {
            let a = w.next_arrival();
            assert!(a > prev);
            prev = a;
        }
        // Mean inter-arrival ≈ 2.
        assert!((prev / 1000.0 - 2.0).abs() < 0.2);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || Workload::new(Exponential::new(1.0).into(), Exponential::new(2.0).into(), 99);
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..100 {
            assert_eq!(a.next_arrival(), b.next_arrival());
            assert_eq!(a.next_execution(), b.next_execution());
        }
    }

    #[test]
    fn draw_tallies_track_every_path() {
        let mut w = Workload::new(Exponential::new(1.0).into(), Exponential::new(2.0).into(), 3);
        w.next_arrival();
        w.next_execution();
        let mut buf = [0.0; 4];
        w.next_executions(&mut buf);
        assert_eq!(w.draw_counts(), (1, 5, 1));
        // execution_with uses a foreign RNG stream: not tallied.
        let mut rng = crate::rng::Pcg64::seed_from_u64(1);
        let _ = w.execution_with(&mut rng);
        assert_eq!(w.draw_counts(), (1, 5, 1));
    }

    #[test]
    fn from_config_honours_specs() {
        let cfg = SimulationConfig {
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.25".into() },
            service: crate::config::ServiceConfig { execution: "det:2.0".into() },
            ..Default::default()
        };
        let mut w = Workload::from_config(&cfg).unwrap();
        assert_eq!(w.mean_interarrival(), 4.0);
        assert_eq!(w.next_execution(), 2.0);
    }
}
