//! Simulation runner: builds the model from a [`SimulationConfig`], runs
//! warmup + measured jobs, and gathers statistics.

use super::models::{ForkJoinPerServer, ForkJoinSingleQueue, IdealPartition, Model, SplitMerge};
use super::{JobRecord, OverheadModel, Scenario, TraceLog, Workload};
use crate::config::{ModelKind, SimulationConfig};
use crate::stats::{QuantileEstimator, Summary};

/// Quantiles tracked by the streaming (P²) runner mode — the grid every
/// consumer prints (`simulate`, sweeps, the advisor curve).
pub const STREAMING_QS: [f64; 5] = [0.5, 0.9, 0.95, 0.99, 0.999];

/// Runner options beyond the experiment config.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunOptions {
    /// Keep every [`JobRecord`] (needed for ECDF/PP analyses).
    pub record_jobs: bool,
    /// Record per-task trace events (Figs. 1–2; memory heavy).
    pub trace: bool,
    /// Enforce in-order departures in the single-queue fork-join model
    /// (the Th.-2 analytic variant).
    pub in_order_departures: bool,
    /// O(1)-memory mode: estimate quantiles with the P² bank
    /// ([`STREAMING_QS`] plus `streaming_q`) instead of storing every
    /// sample — stability scans and million-job sweep points no longer
    /// cost O(jobs) memory per point.
    pub streaming: bool,
    /// Extra quantile to track in streaming mode (e.g. a sweep's target
    /// quantile when it is not on the default grid).
    pub streaming_q: Option<f64>,
}

/// Aggregated simulation output.
pub struct SimResult {
    /// Echo of the configuration that produced this result.
    pub config: SimulationConfig,
    /// Per-job records (empty unless `record_jobs`).
    pub jobs: Vec<JobRecord>,
    /// Sojourn-time quantiles (exact samples, or P² in streaming mode).
    pub sojourn: QuantileEstimator,
    /// Waiting-time quantiles (exact samples, or P² in streaming mode).
    pub waiting: QuantileEstimator,
    /// Sojourn summary statistics.
    pub sojourn_summary: Summary,
    /// Per-job total task overhead summary.
    pub overhead_summary: Summary,
    /// Per-job cancelled-replica server time (all zeros unless a
    /// redundancy scenario is active).
    pub redundant_summary: Summary,
    /// Sojourn summaries over the run's thirds (in measured-job order) —
    /// the stability detector's divergence signal, O(1) memory.
    pub thirds: [Summary; 3],
    /// Trace log (empty unless `trace`).
    pub trace: TraceLog,
    /// Wall-clock seconds spent simulating.
    pub wall_seconds: f64,
}

impl SimResult {
    /// Sojourn-time quantile.
    pub fn sojourn_quantile(&mut self, q: f64) -> f64 {
        self.sojourn.quantile(q)
    }
    /// Waiting-time quantile.
    pub fn waiting_quantile(&mut self, q: f64) -> f64 {
        self.waiting.quantile(q)
    }
    /// Simulated jobs per wall second (events/sec proxy for §Perf).
    pub fn jobs_per_second(&self) -> f64 {
        let n = self.sojourn.len() + self.config.warmup;
        n as f64 / self.wall_seconds.max(1e-12)
    }
}

fn build_model(cfg: &SimulationConfig, opts: &RunOptions) -> Result<Box<dyn Model>, String> {
    let scenario = Scenario::from_config(cfg)?;
    Ok(match cfg.model {
        ModelKind::SplitMerge => Box::new(
            SplitMerge::new(cfg.servers, cfg.tasks_per_job).with_scenario(scenario),
        ),
        ModelKind::ForkJoinSingleQueue => Box::new(
            ForkJoinSingleQueue::new(cfg.servers, cfg.tasks_per_job)
                .with_in_order_departures(opts.in_order_departures)
                .with_scenario(scenario),
        ),
        ModelKind::ForkJoinPerServer => {
            assert_eq!(
                cfg.tasks_per_job, cfg.servers,
                "per-server fork-join requires k = l"
            );
            Box::new(ForkJoinPerServer::new(cfg.servers).with_scenario(scenario))
        }
        ModelKind::Ideal => Box::new(
            IdealPartition::new(cfg.servers, cfg.tasks_per_job).with_scenario(scenario),
        ),
    })
}

/// Build the quantile estimator for one run: exact by default, the P²
/// bank (default grid + the caller's extra quantile) in streaming mode.
fn make_estimator(cfg: &SimulationConfig, opts: &RunOptions) -> QuantileEstimator {
    if !opts.streaming {
        return QuantileEstimator::exact_with_capacity(cfg.jobs);
    }
    let mut qs: Vec<f64> = STREAMING_QS.to_vec();
    if let Some(q) = opts.streaming_q {
        qs.push(q); // duplicates within 1e-12 are merged by the bank
    }
    QuantileEstimator::streaming(&qs)
}

/// Run one simulation to completion.
pub fn run(cfg: &SimulationConfig, opts: RunOptions) -> Result<SimResult, String> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    let mut workload = Workload::from_config(cfg)?;
    let overhead = OverheadModel::from_option(cfg.overhead);
    let mut model = build_model(cfg, &opts)?;
    let mut trace = if opts.trace { TraceLog::enabled() } else { TraceLog::disabled() };

    let total = cfg.warmup + cfg.jobs;
    let mut jobs = Vec::with_capacity(if opts.record_jobs { cfg.jobs } else { 0 });
    let mut sojourn = make_estimator(cfg, &opts);
    let mut waiting = make_estimator(cfg, &opts);
    let mut sojourn_summary = Summary::new();
    let mut overhead_summary = Summary::new();
    let mut redundant_summary = Summary::new();
    let mut thirds = [Summary::new(), Summary::new(), Summary::new()];
    // Same partition as slicing measured jobs at [..t], [t..2t], [2t..]:
    // the remainder lands in the last third.
    let third = cfg.jobs / 3;

    for n in 0..total {
        let arrival = workload.next_arrival();
        let rec = model.advance(n, arrival, &mut workload, &overhead, &mut trace);
        if n < cfg.warmup {
            continue;
        }
        let measured = n - cfg.warmup;
        sojourn.push(rec.sojourn());
        waiting.push(rec.waiting());
        sojourn_summary.push(rec.sojourn());
        overhead_summary.push(rec.task_overhead + rec.pre_departure_overhead);
        redundant_summary.push(rec.redundant_work);
        if third > 0 {
            thirds[(measured / third).min(2)].push(rec.sojourn());
        } else {
            thirds[2].push(rec.sojourn());
        }
        if opts.record_jobs {
            jobs.push(rec);
        }
    }

    Ok(SimResult {
        config: cfg.clone(),
        jobs,
        sojourn,
        waiting,
        sojourn_summary,
        overhead_summary,
        redundant_summary,
        thirds,
        trace,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> SimulationConfig {
        SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 4,
            tasks_per_job: 8,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.3".into() },
            service: crate::config::ServiceConfig { execution: "exp:2.0".into() },
            jobs: 2000,
            warmup: 200,
            seed: 9,
            overhead: None,
            workers: None,
            redundancy: None,
        }
    }

    #[test]
    fn runs_and_collects() {
        let mut res = run(&base_cfg(), RunOptions { record_jobs: true, ..Default::default() })
            .unwrap();
        assert_eq!(res.jobs.len(), 2000);
        assert_eq!(res.sojourn.len(), 2000);
        let p50 = res.sojourn_quantile(0.5);
        let p99 = res.sojourn_quantile(0.99);
        assert!(p50 > 0.0 && p99 >= p50);
        // Sojourn ≥ waiting + max task time ≥ waiting.
        for j in &res.jobs {
            assert!(j.sojourn() >= j.waiting() - 1e-9);
            assert!(j.departure >= j.arrival);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = run(&base_cfg(), RunOptions::default()).unwrap();
        let mut b = run(&base_cfg(), RunOptions::default()).unwrap();
        assert_eq!(a.sojourn_quantile(0.9), b.sojourn_quantile(0.9));
    }

    #[test]
    fn all_models_run() {
        for (model, k) in [
            (ModelKind::SplitMerge, 8),
            (ModelKind::ForkJoinSingleQueue, 8),
            (ModelKind::ForkJoinPerServer, 4),
            (ModelKind::Ideal, 8),
        ] {
            let cfg = SimulationConfig {
                model,
                tasks_per_job: k,
                jobs: 500,
                warmup: 50,
                ..base_cfg()
            };
            let res = run(&cfg, RunOptions::default()).unwrap();
            assert_eq!(res.sojourn.len(), 500, "{model}");
        }
    }

    /// A heterogeneous + redundant scenario runs end to end through the
    /// public runner for every model that supports it.
    #[test]
    fn scenario_configs_run_end_to_end() {
        for model in [ModelKind::SplitMerge, ModelKind::ForkJoinSingleQueue] {
            let cfg = SimulationConfig {
                model,
                workers: Some(crate::config::WorkersConfig::Speeds(vec![
                    0.5, 1.0, 1.5, 2.0,
                ])),
                redundancy: Some(crate::config::RedundancyConfig::new(2)),
                jobs: 1500,
                warmup: 150,
                ..base_cfg()
            };
            let res = run(&cfg, RunOptions { record_jobs: true, ..Default::default() })
                .unwrap();
            assert_eq!(res.sojourn.len(), 1500, "{model}");
            // Redundancy burns server time on cancelled replicas.
            let redundant: f64 = res.jobs.iter().map(|j| j.redundant_work).sum();
            assert!(redundant > 0.0, "{model}: no cancelled replicas recorded");
            for j in &res.jobs {
                assert!(j.sojourn() > 0.0 && j.departure >= j.arrival);
            }
        }
    }

    /// Scenario runs are deterministic in the seed, like the base model.
    #[test]
    fn scenario_deterministic_given_seed() {
        let cfg = SimulationConfig {
            workers: Some(crate::config::WorkersConfig::Distribution {
                spec: "uniform:0.5:1.5".into(),
                seed: 3,
            }),
            redundancy: Some(crate::config::RedundancyConfig::new(2)),
            jobs: 1000,
            warmup: 100,
            ..base_cfg()
        };
        let mut a = run(&cfg, RunOptions::default()).unwrap();
        let mut b = run(&cfg, RunOptions::default()).unwrap();
        assert_eq!(a.sojourn_quantile(0.9), b.sojourn_quantile(0.9));
        assert_eq!(a.sojourn_summary.mean(), b.sojourn_summary.mean());
    }

    /// Streaming mode: identical simulation (bitwise-equal summaries,
    /// since the sample stream is untouched), P² quantiles close to the
    /// exact ones, and no sample storage.
    #[test]
    fn streaming_mode_matches_exact_run() {
        let cfg = SimulationConfig { jobs: 20_000, warmup: 2_000, ..base_cfg() };
        let mut exact = run(&cfg, RunOptions::default()).unwrap();
        let mut stream = run(
            &cfg,
            RunOptions { streaming: true, streaming_q: Some(0.75), ..Default::default() },
        )
        .unwrap();
        assert_eq!(exact.sojourn_summary.mean(), stream.sojourn_summary.mean());
        assert_eq!(exact.sojourn.len(), stream.sojourn.len());
        for q in [0.5, 0.9, 0.99] {
            let (a, b) = (exact.sojourn_quantile(q), stream.sojourn_quantile(q));
            assert!((a - b).abs() / a < 0.15, "q={q}: exact {a} vs P2 {b}");
        }
        // The extra tracked quantile is served too.
        let extra = stream.sojourn_quantile(0.75);
        let exact75 = exact.sojourn_quantile(0.75);
        assert!((extra - exact75).abs() / exact75 < 0.15);
        // Thirds partition covers every measured job exactly once.
        let n: u64 = stream.thirds.iter().map(|t| t.count()).sum();
        assert_eq!(n, 20_000);
    }

    /// Overhead strictly increases sojourn times (coupling: same seed).
    #[test]
    fn overhead_increases_sojourn() {
        let cfg = base_cfg();
        let mut without = run(&cfg, RunOptions::default()).unwrap();
        let cfg_oh = SimulationConfig {
            overhead: Some(crate::config::OverheadConfig::paper()),
            ..cfg
        };
        let mut with = run(&cfg_oh, RunOptions::default()).unwrap();
        assert!(with.sojourn_quantile(0.5) > without.sojourn_quantile(0.5));
    }

    /// M/M/1 closed form: with k=l=1, P[T > τ] = e^{-(mu-lambda)τ};
    /// the 0.99 sojourn quantile is ln(100)/(mu−lambda).
    #[test]
    fn mm1_quantile_closed_form() {
        let cfg = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 1,
            tasks_per_job: 1,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.5".into() },
            service: crate::config::ServiceConfig { execution: "exp:1.0".into() },
            jobs: 200_000,
            warmup: 5_000,
            seed: 17,
            overhead: None,
            workers: None,
            redundancy: None,
        };
        let mut res = run(&cfg, RunOptions::default()).unwrap();
        let expect = (100.0f64).ln() / (1.0 - 0.5);
        let got = res.sojourn_quantile(0.99);
        assert!(
            (got - expect).abs() / expect < 0.05,
            "M/M/1 p99: {got} vs {expect}"
        );
    }
}
