//! Simulation runner: builds the model from a [`SimulationConfig`], runs
//! warmup + measured jobs, and gathers statistics.

use super::models::{ForkJoinPerServer, ForkJoinSingleQueue, IdealPartition, Model, SplitMerge};
use super::{FaultInjector, JobRecord, OverheadModel, PolicyState, Scenario, TraceLog, Workload};
use crate::config::{ModelKind, SimulationConfig};
use crate::obs::{progress, Counter, Metrics, Phase};
use crate::rng::spawn_seeds;
use crate::stats::{QuantileEstimator, Summary};
use crate::util::threadpool::ThreadPool;

/// Quantiles tracked by the streaming (P²) runner mode — the grid every
/// consumer prints (`simulate`, sweeps, the advisor curve).
pub const STREAMING_QS: [f64; 5] = [0.5, 0.9, 0.95, 0.99, 0.999];

/// Runner options beyond the experiment config.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunOptions {
    /// Keep every [`JobRecord`] (needed for ECDF/PP analyses).
    pub record_jobs: bool,
    /// Record per-task trace events (Figs. 1–2; memory heavy).
    pub trace: bool,
    /// Enforce in-order departures in the single-queue fork-join model
    /// (the Th.-2 analytic variant).
    pub in_order_departures: bool,
    /// O(1)-memory mode: estimate quantiles with the P² bank
    /// ([`STREAMING_QS`] plus `streaming_q`) instead of storing every
    /// sample — stability scans and million-job sweep points no longer
    /// cost O(jobs) memory per point.
    pub streaming: bool,
    /// Extra quantile to track in streaming mode (e.g. a sweep's target
    /// quantile when it is not on the default grid).
    pub streaming_q: Option<f64>,
    /// Replication shards: split the run into `shards` independent
    /// replications of `jobs/shards` measured jobs each (per-shard seeds
    /// from [`spawn_seeds`], per-shard warmup) and merge their
    /// statistics. Sharding is a **replication scheme**: the shard count
    /// changes the sample stream, so determinism is per
    /// (seed, shard count). `0` means "match `threads`"; `0`/`1` with
    /// `threads ≤ 1` is exactly the unsharded engine.
    pub shards: usize,
    /// Worker threads executing the shards (`0` = one per shard, capped
    /// at the machine's parallelism). The thread count never affects
    /// results — shards merge in shard-index order regardless of which
    /// worker finished first.
    pub threads: usize,
    /// Collect the obs registry (counters, phase timers, histograms)
    /// into [`SimResult::metrics`]. Off by default; metrics consume no
    /// RNG and never perturb results, so output is bitwise identical
    /// either way (`rust/tests/obs_metrics.rs`).
    pub metrics: bool,
    /// Emit the `--progress` stderr heartbeat while running.
    pub progress: bool,
    /// This run's shard index in a sharded parent run (progress lag
    /// attribution only; 0 for unsharded runs).
    pub shard_index: usize,
}

/// Aggregated simulation output.
pub struct SimResult {
    /// Echo of the configuration that produced this result.
    pub config: SimulationConfig,
    /// Per-job records (empty unless `record_jobs`).
    pub jobs: Vec<JobRecord>,
    /// Sojourn-time quantiles (exact samples, or P² in streaming mode).
    pub sojourn: QuantileEstimator,
    /// Waiting-time quantiles (exact samples, or P² in streaming mode).
    pub waiting: QuantileEstimator,
    /// Sojourn summary statistics.
    pub sojourn_summary: Summary,
    /// Per-job total task overhead summary.
    pub overhead_summary: Summary,
    /// Per-job cancelled-replica server time (all zeros unless a
    /// redundancy scenario or speculative re-execution is active).
    pub redundant_summary: Summary,
    /// Per-job server time lost to crashed/failed attempts (all zeros
    /// unless fault injection is active).
    pub lost_summary: Summary,
    /// Per-job retry counts — attempts beyond the first (all zeros
    /// unless fault injection is active).
    pub retry_summary: Summary,
    /// Sojourn summaries over the run's thirds (in measured-job order) —
    /// the stability detector's divergence signal, O(1) memory.
    pub thirds: [Summary; 3],
    /// Per-priority-class sojourn summaries, indexed by class (empty
    /// unless a priority dispatch policy is active). Class membership is
    /// the policy's static assignment (`job index mod classes`), so the
    /// buckets are identical across shard counts and merge bitwise in
    /// shard-index order.
    pub class_sojourn: Vec<Summary>,
    /// Trace log (empty unless `trace`).
    pub trace: TraceLog,
    /// Wall-clock seconds spent simulating.
    pub wall_seconds: f64,
    /// Obs registry for the run: counters, phase timers, and latency
    /// histograms (disabled no-op unless [`RunOptions::metrics`]).
    pub metrics: Metrics,
}

impl SimResult {
    /// Sojourn-time quantile.
    pub fn sojourn_quantile(&mut self, q: f64) -> f64 {
        self.sojourn.quantile(q)
    }
    /// Waiting-time quantile.
    pub fn waiting_quantile(&mut self, q: f64) -> f64 {
        self.waiting.quantile(q)
    }
    /// Simulated jobs per wall second (events/sec proxy for §Perf).
    pub fn jobs_per_second(&self) -> f64 {
        let n = self.sojourn.len() + self.config.warmup;
        n as f64 / self.wall_seconds.max(1e-12)
    }
}

fn build_model(
    cfg: &SimulationConfig,
    opts: &RunOptions,
    faults: Option<FaultInjector>,
) -> Result<Box<dyn Model>, String> {
    let scenario = Scenario::from_config(cfg)?;
    let policy = PolicyState::from_config(cfg)?;
    // k = l for per-server fork-join, the faults/model compatibility
    // matrix, and the policy/model matrix (policies only reach the
    // split-merge and single-queue models) are enforced by
    // `SimulationConfig::validate` (run before this), so bad CLI input
    // errors out instead of panicking here.
    Ok(match cfg.model {
        ModelKind::SplitMerge => Box::new(
            SplitMerge::new(cfg.servers, cfg.tasks_per_job)
                .with_scenario(scenario)
                .with_faults(faults)
                .with_policy(policy),
        ),
        ModelKind::ForkJoinSingleQueue => Box::new(
            ForkJoinSingleQueue::new(cfg.servers, cfg.tasks_per_job)
                .with_in_order_departures(opts.in_order_departures)
                .with_scenario(scenario)
                .with_faults(faults)
                .with_policy(policy),
        ),
        ModelKind::ForkJoinPerServer => Box::new(
            ForkJoinPerServer::new(cfg.servers)
                .with_scenario(scenario)
                .with_faults(faults),
        ),
        ModelKind::Ideal => Box::new(
            IdealPartition::new(cfg.servers, cfg.tasks_per_job).with_scenario(scenario),
        ),
    })
}

/// Build the quantile estimator for one run: exact by default, the P²
/// bank (default grid + the caller's extra quantile) in streaming mode.
fn make_estimator(cfg: &SimulationConfig, opts: &RunOptions) -> QuantileEstimator {
    if !opts.streaming {
        return QuantileEstimator::exact_with_capacity(cfg.jobs);
    }
    let mut qs: Vec<f64> = STREAMING_QS.to_vec();
    if let Some(q) = opts.streaming_q {
        qs.push(q); // duplicates within 1e-12 are merged by the bank
    }
    QuantileEstimator::streaming(&qs)
}

/// Run one simulation to completion. With `opts.shards`/`opts.threads`
/// > 1 the run is split into independent replication shards executed on
/// a thread pool and merged (see [`RunOptions::shards`]); otherwise this
/// is the plain single-stream engine.
pub fn run(cfg: &SimulationConfig, opts: RunOptions) -> Result<SimResult, String> {
    // `shards = 0` means "match threads"; a single shard takes the
    // unsharded path bit-for-bit.
    let shards = match opts.shards {
        0 => opts.threads.max(1),
        n => n,
    };
    if opts.progress {
        // Mirror run_sharded's clamp so the heartbeat's shard-lag view
        // matches the shard count actually run.
        progress::start(cfg.jobs as u64, shards.min(cfg.jobs.max(1)).max(1));
    }
    let res = if shards <= 1 { run_single(cfg, &opts) } else { run_sharded(cfg, &opts, shards) };
    if opts.progress {
        progress::finish();
    }
    res
}

/// Split `jobs` into `shards` near-equal shares (the remainder lands on
/// the first shards, so every share differs by at most one job).
fn shard_shares(jobs: usize, shards: usize) -> Vec<usize> {
    let base = jobs / shards;
    let rem = jobs % shards;
    (0..shards).map(|i| base + usize::from(i < rem)).collect()
}

/// Replication-sharded run: `shards` independent simulations with seeds
/// from [`spawn_seeds`]`(cfg.seed, shards)` — each with the full warmup,
/// a `jobs/shards` share of the measured jobs, and its own RNG stream —
/// merged in shard-index order. Merged means are therefore stable in the
/// *thread* count (bitwise: the Welford merge order is fixed) and stable
/// in the *shard* count to fp-summation order.
fn run_sharded(
    cfg: &SimulationConfig,
    opts: &RunOptions,
    shards: usize,
) -> Result<SimResult, String> {
    cfg.validate()?;
    if opts.record_jobs || opts.trace {
        return Err(
            "per-job records and traces are single-stream outputs; \
             run with shards = threads = 1 to record them"
                .into(),
        );
    }
    let t0 = std::time::Instant::now();
    // Never spin up more shards than measured jobs.
    let shards = shards.min(cfg.jobs).max(1);
    let seeds = spawn_seeds(cfg.seed, shards);
    // Each shard carries its own options so the progress heartbeat can
    // attribute lag to a shard index; everything else is shared.
    let shard_inputs: Vec<(SimulationConfig, RunOptions)> = shard_shares(cfg.jobs, shards)
        .into_iter()
        .zip(seeds)
        .enumerate()
        .map(|(i, (share, seed))| {
            (
                SimulationConfig { jobs: share, seed, ..cfg.clone() },
                RunOptions { shards: 1, threads: 1, shard_index: i, ..*opts },
            )
        })
        .collect();
    let workers = match opts.threads {
        0 => {
            let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            shards.min(avail)
        }
        n => n.min(shards),
    }
    .max(1);
    let pool = ThreadPool::new(workers);
    let results = pool.map(shard_inputs, move |(scfg, sopts)| run_single(&scfg, &sopts))?;
    let merge_t0 = if opts.metrics { Some(std::time::Instant::now()) } else { None };
    let mut merged: Option<SimResult> = None;
    for res in results {
        let res = res?;
        match &mut merged {
            None => merged = Some(res),
            Some(acc) => {
                acc.sojourn.merge(&res.sojourn)?;
                acc.waiting.merge(&res.waiting)?;
                acc.sojourn_summary.merge(&res.sojourn_summary);
                acc.overhead_summary.merge(&res.overhead_summary);
                acc.redundant_summary.merge(&res.redundant_summary);
                acc.lost_summary.merge(&res.lost_summary);
                acc.retry_summary.merge(&res.retry_summary);
                for (a, b) in acc.thirds.iter_mut().zip(&res.thirds) {
                    a.merge(b);
                }
                for (a, b) in acc.class_sojourn.iter_mut().zip(&res.class_sojourn) {
                    a.merge(b);
                }
                // Shard-index order: the pool returns results in input
                // order, so the counter merge is deterministic.
                acc.metrics.merge(&res.metrics);
            }
        }
    }
    let mut out = merged.expect("at least one shard");
    if let Some(t) = merge_t0 {
        out.metrics.phase_add_secs(Phase::StatsMerge, t.elapsed().as_secs_f64());
    }
    // Echo the caller's config (not shard 0's slice) and report the
    // orchestration wall time, warmups included via the per-shard runs.
    out.config = cfg.clone();
    out.wall_seconds = t0.elapsed().as_secs_f64();
    Ok(out)
}

/// Run one unsharded simulation to completion.
fn run_single(cfg: &SimulationConfig, opts: &RunOptions) -> Result<SimResult, String> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    let mut metrics = if opts.metrics { Metrics::enabled() } else { Metrics::disabled() };
    let setup_clock = metrics.phase_start();
    let mut workload = Workload::from_config(cfg)?;
    let overhead = OverheadModel::from_option(cfg.overhead);
    // Speculation deadlines are a multiple of the expected task service.
    let expected_task = workload.mean_execution() + overhead.mean_task();
    let faults = FaultInjector::from_config(cfg, expected_task);
    let mut model = build_model(cfg, opts, faults)?;
    let mut trace = if opts.trace { TraceLog::enabled() } else { TraceLog::disabled() };
    metrics.phase_add(Phase::Setup, setup_clock);

    let total = cfg.warmup + cfg.jobs;
    let mut jobs = Vec::with_capacity(if opts.record_jobs { cfg.jobs } else { 0 });
    let mut sojourn = make_estimator(cfg, opts);
    let mut waiting = make_estimator(cfg, opts);
    let mut sojourn_summary = Summary::new();
    let mut overhead_summary = Summary::new();
    let mut redundant_summary = Summary::new();
    let mut lost_summary = Summary::new();
    let mut retry_summary = Summary::new();
    let mut thirds = [Summary::new(), Summary::new(), Summary::new()];
    // Same partition as slicing measured jobs at [..t], [t..2t], [2t..]:
    // the remainder lands in the last third.
    let third = cfg.jobs / 3;
    // Priority policies get per-class sojourn buckets (class = job
    // index mod classes, the policy's static assignment).
    let classes = cfg.policy.as_ref().map(|p| p.class_count()).unwrap_or(0);
    let mut class_sojourn: Vec<Summary> = (0..classes).map(|_| Summary::new()).collect();

    let dispatch_clock = metrics.phase_start();
    for n in 0..total {
        let arrival = workload.next_arrival();
        let rec = model.advance(n, arrival, &mut workload, &overhead, &mut trace);
        if n < cfg.warmup {
            continue;
        }
        let measured = n - cfg.warmup;
        metrics.observe_sojourn(rec.sojourn());
        metrics.observe_waiting(rec.waiting());
        if opts.progress && (measured + 1) % progress::TICK_JOBS == 0 {
            progress::tick(opts.shard_index, measured as u64 + 1);
        }
        sojourn.push(rec.sojourn());
        waiting.push(rec.waiting());
        sojourn_summary.push(rec.sojourn());
        overhead_summary.push(rec.task_overhead + rec.pre_departure_overhead);
        redundant_summary.push(rec.redundant_work);
        lost_summary.push(rec.lost_work);
        retry_summary.push(f64::from(rec.retries));
        if third > 0 {
            thirds[(measured / third).min(2)].push(rec.sojourn());
        } else {
            thirds[2].push(rec.sojourn());
        }
        if classes > 0 {
            class_sojourn[rec.index % classes].push(rec.sojourn());
        }
        if opts.record_jobs {
            jobs.push(rec);
        }
    }
    metrics.phase_add(Phase::Dispatch, dispatch_clock);
    if opts.progress {
        progress::tick(opts.shard_index, cfg.jobs as u64);
    }
    if metrics.is_enabled() {
        // Harvest the engines' always-on raw tallies once, at run end.
        metrics.absorb_tallies(&model.tallies());
        let (arrivals, executions, batches) = workload.draw_counts();
        metrics.add(Counter::ArrivalDraws, arrivals);
        metrics.add(Counter::ExecutionDraws, executions);
        metrics.add(Counter::BatchDraws, batches);
    }

    Ok(SimResult {
        config: cfg.clone(),
        jobs,
        sojourn,
        waiting,
        sojourn_summary,
        overhead_summary,
        redundant_summary,
        lost_summary,
        retry_summary,
        thirds,
        class_sojourn,
        trace,
        wall_seconds: t0.elapsed().as_secs_f64(),
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> SimulationConfig {
        SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 4,
            tasks_per_job: 8,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.3".into() },
            service: crate::config::ServiceConfig { execution: "exp:2.0".into() },
            jobs: 2000,
            warmup: 200,
            seed: 9,
            overhead: None,
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        }
    }

    #[test]
    fn runs_and_collects() {
        let mut res = run(&base_cfg(), RunOptions { record_jobs: true, ..Default::default() })
            .unwrap();
        assert_eq!(res.jobs.len(), 2000);
        assert_eq!(res.sojourn.len(), 2000);
        let p50 = res.sojourn_quantile(0.5);
        let p99 = res.sojourn_quantile(0.99);
        assert!(p50 > 0.0 && p99 >= p50);
        // Sojourn ≥ waiting + max task time ≥ waiting.
        for j in &res.jobs {
            assert!(j.sojourn() >= j.waiting() - 1e-9);
            assert!(j.departure >= j.arrival);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = run(&base_cfg(), RunOptions::default()).unwrap();
        let mut b = run(&base_cfg(), RunOptions::default()).unwrap();
        assert_eq!(a.sojourn_quantile(0.9), b.sojourn_quantile(0.9));
    }

    #[test]
    fn all_models_run() {
        for (model, k) in [
            (ModelKind::SplitMerge, 8),
            (ModelKind::ForkJoinSingleQueue, 8),
            (ModelKind::ForkJoinPerServer, 4),
            (ModelKind::Ideal, 8),
        ] {
            let cfg = SimulationConfig {
                model,
                tasks_per_job: k,
                jobs: 500,
                warmup: 50,
                ..base_cfg()
            };
            let res = run(&cfg, RunOptions::default()).unwrap();
            assert_eq!(res.sojourn.len(), 500, "{model}");
        }
    }

    /// A heterogeneous + redundant scenario runs end to end through the
    /// public runner for every model that supports it.
    #[test]
    fn scenario_configs_run_end_to_end() {
        for model in [ModelKind::SplitMerge, ModelKind::ForkJoinSingleQueue] {
            let cfg = SimulationConfig {
                model,
                workers: Some(crate::config::WorkersConfig::Speeds(vec![
                    0.5, 1.0, 1.5, 2.0,
                ])),
                redundancy: Some(crate::config::RedundancyConfig::new(2)),
                jobs: 1500,
                warmup: 150,
                ..base_cfg()
            };
            let res = run(&cfg, RunOptions { record_jobs: true, ..Default::default() })
                .unwrap();
            assert_eq!(res.sojourn.len(), 1500, "{model}");
            // Redundancy burns server time on cancelled replicas.
            let redundant: f64 = res.jobs.iter().map(|j| j.redundant_work).sum();
            assert!(redundant > 0.0, "{model}: no cancelled replicas recorded");
            for j in &res.jobs {
                assert!(j.sojourn() > 0.0 && j.departure >= j.arrival);
            }
        }
    }

    /// Scenario runs are deterministic in the seed, like the base model.
    #[test]
    fn scenario_deterministic_given_seed() {
        let cfg = SimulationConfig {
            workers: Some(crate::config::WorkersConfig::Distribution {
                spec: "uniform:0.5:1.5".into(),
                seed: 3,
            }),
            redundancy: Some(crate::config::RedundancyConfig::new(2)),
            jobs: 1000,
            warmup: 100,
            ..base_cfg()
        };
        let mut a = run(&cfg, RunOptions::default()).unwrap();
        let mut b = run(&cfg, RunOptions::default()).unwrap();
        assert_eq!(a.sojourn_quantile(0.9), b.sojourn_quantile(0.9));
        assert_eq!(a.sojourn_summary.mean(), b.sojourn_summary.mean());
    }

    /// Streaming mode: identical simulation (bitwise-equal summaries,
    /// since the sample stream is untouched), P² quantiles close to the
    /// exact ones, and no sample storage.
    #[test]
    fn streaming_mode_matches_exact_run() {
        let cfg = SimulationConfig { jobs: 20_000, warmup: 2_000, ..base_cfg() };
        let mut exact = run(&cfg, RunOptions::default()).unwrap();
        let mut stream = run(
            &cfg,
            RunOptions { streaming: true, streaming_q: Some(0.75), ..Default::default() },
        )
        .unwrap();
        assert_eq!(exact.sojourn_summary.mean(), stream.sojourn_summary.mean());
        assert_eq!(exact.sojourn.len(), stream.sojourn.len());
        for q in [0.5, 0.9, 0.99] {
            let (a, b) = (exact.sojourn_quantile(q), stream.sojourn_quantile(q));
            assert!((a - b).abs() / a < 0.15, "q={q}: exact {a} vs P2 {b}");
        }
        // The extra tracked quantile is served too.
        let extra = stream.sojourn_quantile(0.75);
        let exact75 = exact.sojourn_quantile(0.75);
        assert!((extra - exact75).abs() / exact75 < 0.15);
        // Thirds partition covers every measured job exactly once.
        let n: u64 = stream.thirds.iter().map(|t| t.count()).sum();
        assert_eq!(n, 20_000);
    }

    #[test]
    fn shard_shares_partition_jobs() {
        assert_eq!(shard_shares(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(shard_shares(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(shard_shares(3, 4), vec![1, 1, 1, 0]);
        for (jobs, shards) in [(1_000_001, 7), (5, 5), (2, 3)] {
            let shares = shard_shares(jobs, shards);
            assert_eq!(shares.iter().sum::<usize>(), jobs);
            assert!(shares.iter().max().unwrap() - shares.iter().min().unwrap() <= 1);
        }
    }

    /// Sharded runs refuse single-stream outputs instead of silently
    /// returning one shard's records.
    #[test]
    fn sharded_rejects_per_job_outputs() {
        let opts = RunOptions { shards: 2, record_jobs: true, ..Default::default() };
        let err = run(&base_cfg(), opts).unwrap_err();
        assert!(err.contains("single-stream"), "{err}");
        let opts = RunOptions { shards: 2, trace: true, ..Default::default() };
        assert!(run(&base_cfg(), opts).is_err());
    }

    /// A sharded run partitions the measured jobs exactly and stays
    /// deterministic in (seed, shard count).
    #[test]
    fn sharded_run_counts_and_determinism() {
        let cfg = base_cfg();
        let opts = RunOptions { shards: 3, threads: 2, ..Default::default() };
        let a = run(&cfg, opts).unwrap();
        assert_eq!(a.sojourn.len(), cfg.jobs);
        assert_eq!(a.sojourn_summary.count(), cfg.jobs as u64);
        let b = run(&cfg, opts).unwrap();
        assert_eq!(a.sojourn_summary.mean(), b.sojourn_summary.mean());
        assert_eq!(a.sojourn_summary.variance(), b.sojourn_summary.variance());
    }

    /// A priority policy run fills the per-class sojourn buckets and the
    /// buckets merge across shards without losing jobs.
    #[test]
    fn priority_run_collects_class_summaries() {
        let cfg = SimulationConfig {
            policy: Some(crate::config::PolicyConfig {
                kind: crate::config::PolicyKind::Priority,
                classes: 2,
                ..Default::default()
            }),
            ..base_cfg()
        };
        let res = run(&cfg, RunOptions::default()).unwrap();
        assert_eq!(res.class_sojourn.len(), 2);
        let n: u64 = res.class_sojourn.iter().map(|s| s.count()).sum();
        assert_eq!(n, cfg.jobs as u64);
        assert!(res.class_sojourn.iter().all(|s| s.mean() > 0.0));
        // Sharded runs merge the buckets in shard-index order.
        let opts = RunOptions { shards: 3, threads: 2, ..Default::default() };
        let a = run(&cfg, opts).unwrap();
        let b = run(&cfg, opts).unwrap();
        assert_eq!(a.class_sojourn.len(), 2);
        let n: u64 = a.class_sojourn.iter().map(|s| s.count()).sum();
        assert_eq!(n, cfg.jobs as u64);
        for (x, y) in a.class_sojourn.iter().zip(&b.class_sojourn) {
            assert_eq!(x.mean(), y.mean());
        }
    }

    /// Non-priority runs keep the class buckets empty; SITA still runs
    /// end to end through the public runner.
    #[test]
    fn sita_run_has_no_class_buckets() {
        let cfg = SimulationConfig {
            policy: Some(crate::config::PolicyConfig {
                kind: crate::config::PolicyKind::Sita,
                sita_boundaries: vec![0.5],
                ..Default::default()
            }),
            ..base_cfg()
        };
        let res = run(&cfg, RunOptions::default()).unwrap();
        assert!(res.class_sojourn.is_empty());
        assert_eq!(res.sojourn.len(), cfg.jobs);
    }

    /// Overhead strictly increases sojourn times (coupling: same seed).
    #[test]
    fn overhead_increases_sojourn() {
        let cfg = base_cfg();
        let mut without = run(&cfg, RunOptions::default()).unwrap();
        let cfg_oh = SimulationConfig {
            overhead: Some(crate::config::OverheadConfig::paper()),
            ..cfg
        };
        let mut with = run(&cfg_oh, RunOptions::default()).unwrap();
        assert!(with.sojourn_quantile(0.5) > without.sojourn_quantile(0.5));
    }

    /// M/M/1 closed form: with k=l=1, P[T > τ] = e^{-(mu-lambda)τ};
    /// the 0.99 sojourn quantile is ln(100)/(mu−lambda).
    #[test]
    fn mm1_quantile_closed_form() {
        let cfg = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 1,
            tasks_per_job: 1,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.5".into() },
            service: crate::config::ServiceConfig { execution: "exp:1.0".into() },
            jobs: 200_000,
            warmup: 5_000,
            seed: 17,
            overhead: None,
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let mut res = run(&cfg, RunOptions::default()).unwrap();
        let expect = (100.0f64).ln() / (1.0 - 0.5);
        let got = res.sojourn_quantile(0.99);
        assert!(
            (got - expect).abs() / expect < 0.05,
            "M/M/1 p99: {got} vs {expect}"
        );
    }
}
