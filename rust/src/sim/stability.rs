//! Stability-region estimation (Fig. 11).
//!
//! Split-merge is a single-server queue in disguise (service = job
//! makespan Δ), so its maximum stable utilization is
//! `ρ* = λ* · k · E[E] / l` with `λ* = 1/E[Δ]`; we estimate `E[Δ]` by
//! Monte-Carlo over the same heap recursion the simulator uses, including
//! the overhead model. Fork-join is work-conserving, so its stability is
//! governed purely by the work arriving per server:
//! `ρ* = E[E] / (E[E] + E[O])` (utilization measured in *useful* work, as
//! in the paper where ρ is set via the task execution rate).
//!
//! A simulation-based stability *detector* is provided for validation:
//! it flags divergence by comparing sojourn means across run thirds.

use super::{OverheadModel, RunOptions, ServerHeap};
use crate::config::{ModelKind, OverheadConfig, SimulationConfig};
use crate::dist::Distribution;
use crate::rng::Pcg64;

/// Monte-Carlo estimate of the split-merge expected job service time
/// E[Δ(n)] for l servers, k tasks, execution distribution `exec`, and the
/// given overhead model (pre-departure included — it blocks in SM).
pub fn sm_mean_service_mc(
    l: usize,
    k: usize,
    exec: &dyn Distribution,
    overhead: &OverheadModel,
    samples: usize,
    seed: u64,
) -> f64 {
    assert!(k >= l && l >= 1);
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut total = 0.0;
    let mut heap = ServerHeap::new(l, 0.0);
    for _ in 0..samples {
        heap.reset_all(0.0);
        for _ in 0..k {
            let mut f = || crate::rng::Rng::next_f64_open(&mut rng);
            let e = exec.sample(&mut f);
            let o = overhead.sample_task(&mut rng);
            let (t, _) = heap.peek();
            heap.assign(t + e + o);
        }
        total += heap.max_time() + overhead.pre_departure(k);
    }
    total / samples as f64
}

/// Maximum stable utilization of the tiny-tasks split-merge system.
///
/// Utilization is measured in execution work per server:
/// `ρ = λ · k · E[E] / l`, so `ρ* = k · E[E] / (l · E[Δ])`.
/// With no overhead and Exp(µ) tasks this converges to Eq. 20.
pub fn sm_max_utilization(
    l: usize,
    k: usize,
    exec: &dyn Distribution,
    overhead: &OverheadModel,
    samples: usize,
    seed: u64,
) -> f64 {
    let mean_delta = sm_mean_service_mc(l, k, exec, overhead, samples, seed);
    (k as f64 * exec.mean() / l as f64) / mean_delta
}

/// Maximum stable utilization of the (single-queue) fork-join system:
/// work conservation gives `ρ* = E[E] / (E[E] + E[O_task])`; the
/// pre-departure overhead is non-blocking and does not affect stability.
pub fn fj_max_utilization(mean_exec: f64, overhead: &OverheadModel) -> f64 {
    mean_exec / (mean_exec + overhead.mean_task())
}

/// Verdict of the simulation-based stability detector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stability {
    /// Sojourn process looks stationary.
    Stable,
    /// Sojourn process drifts upward consistently.
    Unstable,
}

/// Detect stability by simulating `jobs` jobs and comparing mean sojourn
/// over the run's thirds: monotone growth by more than `factor` flags
/// divergence. This is a *validation* tool (slow, heuristic); the MC
/// estimators above are the product path.
///
/// Runs in streaming mode: the runner accumulates the per-third sojourn
/// summaries on the fly, so long scans cost O(1) memory instead of
/// storing every [`super::JobRecord`].
pub fn detect(cfg: &SimulationConfig, factor: f64) -> Result<Stability, String> {
    let mut cfg = cfg.clone();
    cfg.warmup = 0; // transient growth is the signal
    let res = super::run(&cfg, RunOptions { streaming: true, ..Default::default() })?;
    if res.sojourn.len() < 300 {
        return Err("need >= 300 jobs to detect stability".into());
    }
    let [m1, m2, m3] = [res.thirds[0].mean(), res.thirds[1].mean(), res.thirds[2].mean()];
    if m3 > m2 * factor && m2 > m1 * factor {
        Ok(Stability::Unstable)
    } else {
        Ok(Stability::Stable)
    }
}

/// Convenience: the maximum stable utilization for either model under
/// `Exp(mu)` tasks, matching the Fig.-11 sweep axes.
pub fn max_utilization(
    model: ModelKind,
    l: usize,
    k: usize,
    mu: f64,
    overhead: Option<OverheadConfig>,
    samples: usize,
    seed: u64,
) -> f64 {
    let exec = crate::dist::Exponential::new(mu);
    let oh = OverheadModel::from_option(overhead);
    match model {
        ModelKind::SplitMerge => sm_max_utilization(l, k, &exec, &oh, samples, seed),
        ModelKind::ForkJoinSingleQueue | ModelKind::ForkJoinPerServer => {
            fj_max_utilization(exec.mean(), &oh)
        }
        ModelKind::Ideal => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Exponential;
    use crate::util::math::harmonic;

    /// k = l: ρ* = 1/H_l (paper Sec. 4.2, big-tasks stability).
    #[test]
    fn big_tasks_matches_harmonic() {
        let l = 20;
        let exec = Exponential::new(1.0);
        let rho = sm_max_utilization(l, l, &exec, &OverheadModel::none(), 40_000, 3);
        let expect = 1.0 / harmonic(l as u64);
        assert!((rho - expect).abs() / expect < 0.02, "{rho} vs {expect}");
    }

    /// Tiny tasks: ρ* = 1 / (1 + (1/κ) Σ_{i=2}^{l} 1/i) (Eq. 20).
    #[test]
    fn tiny_tasks_matches_eq20() {
        let (l, k) = (10usize, 80usize);
        let kappa = k as f64 / l as f64;
        let exec = Exponential::new(1.0);
        let rho = sm_max_utilization(l, k, &exec, &OverheadModel::none(), 40_000, 4);
        let expect = 1.0 / (1.0 + (harmonic(l as u64) - 1.0) / kappa);
        assert!((rho - expect).abs() / expect < 0.02, "{rho} vs {expect}");
    }

    /// Overhead shrinks both stability regions.
    #[test]
    fn overhead_shrinks_region() {
        let (l, k) = (10usize, 200usize);
        let mu = k as f64 / l as f64; // mean exec = l/k (paper scaling)
        let exec = Exponential::new(mu);
        let none = OverheadModel::none();
        let paper = OverheadModel::new(OverheadConfig::paper());
        let without = sm_max_utilization(l, k, &exec, &none, 20_000, 5);
        let with = sm_max_utilization(l, k, &exec, &paper, 20_000, 5);
        assert!(with < without, "{with} !< {without}");
        let fj_without = fj_max_utilization(exec.mean(), &none);
        let fj_with = fj_max_utilization(exec.mean(), &paper);
        assert!((fj_without - 1.0).abs() < 1e-12);
        assert!(fj_with < 1.0);
    }

    /// Detector agrees with theory on a clearly stable and a clearly
    /// unstable split-merge configuration (l = 50, λ = 0.5: unstable at
    /// κ = 1, stable at κ = 8 — the Fig. 8(a) observation).
    #[test]
    fn detector_matches_fig8_observation() {
        let mk = |k: usize| SimulationConfig {
            model: ModelKind::SplitMerge,
            servers: 50,
            tasks_per_job: k,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.5".into() },
            service: crate::config::ServiceConfig {
                execution: format!("exp:{}", k as f64 / 50.0),
            },
            jobs: 3000,
            warmup: 0,
            seed: 8,
            overhead: None,
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        assert_eq!(detect(&mk(50), 1.05).unwrap(), Stability::Unstable);
        assert_eq!(detect(&mk(400), 1.05).unwrap(), Stability::Stable);
    }
}
