//! Event-driven simulation of parallel systems with tiny tasks — a Rust
//! reproduction of the *forkulator* simulator used in the paper (Sec. 2.4).
//!
//! Four models (Sec. 1.1, Fig. 4):
//!
//! * **split-merge** — blocking start *and* departure barrier; the
//!   head-of-line job's k tasks feed l servers from a task queue (Fig. 5);
//! * **single-queue fork-join** — one global FIFO task queue, no start
//!   barrier; jobs may overtake (the model of Th. 2, and of Spark with a
//!   multi-threaded driver);
//! * **per-server fork-join** — tasks bound to servers on arrival
//!   (the classic model; tiny tasks make no difference here);
//! * **ideal partition** — every job split into exactly l equal tasks,
//!   which collapses the system to a single server with service `L(n)/l`.
//!
//! Rather than a general event-calendar DES, each model is simulated by
//! its exact Lindley-style recursion over a server min-heap — orders of
//! magnitude faster and bit-for-bit equivalent for these work-conserving
//! FIFO models (validated against M/M/1 closed forms and the analytic
//! bounds in the test suite).
//!
//! The [`scenario`] module extends every model with heterogeneous worker
//! speeds and first-finish-wins task redundancy (`[workers]` /
//! `[redundancy]` config sections); the degenerate scenario reduces
//! bit-for-bit to the homogeneous models. The [`policy`] module opens
//! the scheduling-policy axis (`[policy]` section: SITA, priority
//! classes, work stealing) behind the same degeneracy discipline —
//! FCFS configs build no policy state at all.

pub mod calendar;
pub mod faults;
mod heap;
pub mod models;
mod overhead;
pub mod policy;
mod runner;
pub mod scenario;
pub mod stability;
mod workload;

pub use calendar::{Calendar, Discipline};
pub use faults::{FaultInjector, FaultOutcome};
pub use heap::ServerHeap;
pub use overhead::OverheadModel;
pub use policy::{PolicyState, PolicyTaskOutcome};
pub use runner::{run, RunOptions, SimResult, STREAMING_QS};
pub use scenario::{Scenario, TaskOutcome};
// The trace log lives in the top-level `crate::trace` subsystem now;
// re-exported here so `sim::{TraceEvent, TraceLog}` call sites stand.
pub use crate::trace::{TraceEvent, TraceLog};
pub use workload::Workload;

/// Per-job outcome record.
#[derive(Clone, Copy, Debug, Default)]
pub struct JobRecord {
    /// Job index n (0-based, post-warmup indices included).
    pub index: usize,
    /// Arrival time A(n).
    pub arrival: f64,
    /// Departure time D(n) (includes pre-departure overhead).
    pub departure: f64,
    /// Time the first task of the job began service.
    pub first_start: f64,
    /// Total workload L(n) = Σ task execution times (no overhead).
    pub workload: f64,
    /// Total task-service overhead Σ O_i(n) (winning replicas only).
    pub task_overhead: f64,
    /// Pre-departure overhead applied to this job.
    pub pre_departure_overhead: f64,
    /// Server time consumed by cancelled task replicas (0 unless a
    /// redundancy scenario or speculative re-execution is active).
    pub redundant_work: f64,
    /// Server time wasted by crashed and failed task attempts (0 unless
    /// fault injection is active).
    pub lost_work: f64,
    /// Task attempts beyond the first across this job's tasks — crashes
    /// plus failed attempts (0 unless fault injection is active).
    pub retries: u32,
}

impl JobRecord {
    /// Sojourn time T(n) = D(n) − A(n).
    pub fn sojourn(&self) -> f64 {
        self.departure - self.arrival
    }
    /// Waiting time: arrival until the first task starts service.
    pub fn waiting(&self) -> f64 {
        (self.first_start - self.arrival).max(0.0)
    }
    /// Job service time Δ(n): first task start to departure.
    pub fn service_time(&self) -> f64 {
        self.departure - self.first_start
    }
}
