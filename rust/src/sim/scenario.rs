//! Heterogeneous-worker & redundant-task scenarios.
//!
//! Two orthogonal extensions of the paper's homogeneous models, following
//! the heterogeneous/redundant-jobs lineage of barrier-mode parallel
//! systems (Walker & Fidler) and HeMT-style public-cloud skew:
//!
//! * **worker speeds** — worker `s` serves a task of nominal size `e`
//!   (plus its task-service overhead `o`) in `(e + o) / speed[s]`
//!   seconds; the FIFO dispatch rule is unchanged (the earliest-*free*
//!   server takes the head-of-line task, which is how a real scheduler
//!   that does not know task sizes behaves under skew);
//! * **redundancy** — every logical task is dispatched as `r` replicas on
//!   the `r` earliest-free distinct servers, each with an independent
//!   execution/overhead draw; the first replica to finish wins and the
//!   rest are cancelled at that instant (first-finish-wins). A replica
//!   whose server would only have started it after the winner finished
//!   never runs and releases its reservation.
//!
//! The degenerate scenario (all speeds 1.0, r = 1) follows exactly the
//! same arithmetic as the homogeneous models — `x / 1.0 == x` bit-for-bit
//! — which `rust/tests/scenario_equivalence.rs` enforces.

use super::faults::{FaultInjector, FaultOutcome};
use super::{OverheadModel, ServerHeap, TraceEvent, TraceLog, Workload};
use crate::config::SimulationConfig;
use crate::trace::cause;

/// Per-replica bookkeeping for one task dispatch.
#[derive(Clone, Copy, Debug)]
struct Replica {
    t_free: f64,
    server: u32,
    start: f64,
    finish: f64,
    exec: f64,
    overhead: f64,
}

/// Outcome of dispatching one logical task (its winning replica).
#[derive(Clone, Copy, Debug)]
pub struct TaskOutcome {
    /// Earliest instant any replica of this task began service.
    pub first_start: f64,
    /// Winner finish time (= the cancellation instant for the losers).
    pub finish: f64,
    /// Winning replica's execution draw (the useful work).
    pub work: f64,
    /// Winning replica's task-service overhead draw.
    pub overhead: f64,
    /// Server time consumed by cancelled replicas (redundancy cost).
    pub redundant_time: f64,
}

/// A resolved scenario: per-worker speeds plus the replication factor
/// and its per-replica launch cost.
#[derive(Clone, Debug)]
pub struct Scenario {
    speeds: Vec<f64>,
    replicas: usize,
    /// Per-replica launch overhead (seconds), charged to every replica
    /// of a redundant dispatch (`replicas > 1` only, so r = 1 scenarios
    /// stay bit-exact with the homogeneous models).
    launch_overhead: f64,
    scratch: Vec<Replica>,
    // Raw tally of cancelled replicas that actually ran (first-finish
    // losers), harvested by the obs layer after a run.
    losers: u64,
}

impl Scenario {
    /// Build from explicit speeds and a replication factor.
    pub fn new(speeds: Vec<f64>, replicas: usize) -> Self {
        assert!(!speeds.is_empty(), "scenario needs at least one worker");
        assert!(
            speeds.iter().all(|&s| s > 0.0 && s.is_finite()),
            "speeds must be positive and finite"
        );
        assert!(
            (1..=speeds.len()).contains(&replicas),
            "replicas must be in 1..=l"
        );
        Self {
            speeds,
            replicas,
            launch_overhead: 0.0,
            scratch: Vec::with_capacity(replicas),
            losers: 0,
        }
    }

    /// Raw tally of cancelled replicas that ran (first-finish losers)
    /// since construction.
    #[inline]
    pub fn loser_count(&self) -> u64 {
        self.losers
    }

    /// Attach a per-replica launch cost (seconds).
    pub fn with_launch_overhead(mut self, launch_overhead: f64) -> Self {
        assert!(
            launch_overhead >= 0.0 && launch_overhead.is_finite(),
            "launch overhead must be finite and >= 0"
        );
        self.launch_overhead = launch_overhead;
        self
    }

    /// Resolve a config's scenario. Returns `Ok(None)` when no scenario
    /// sections are configured, so models keep the homogeneous fast path.
    pub fn from_config(cfg: &SimulationConfig) -> Result<Option<Self>, String> {
        if cfg.workers.is_none() && cfg.replicas() == 1 {
            return Ok(None);
        }
        let speeds = cfg.resolved_speeds()?;
        let replicas = cfg.replicas();
        if replicas > speeds.len() {
            return Err(format!(
                "redundancy.replicas ({replicas}) cannot exceed servers ({})",
                speeds.len()
            ));
        }
        Ok(Some(Self::new(speeds, replicas).with_launch_overhead(cfg.launch_overhead())))
    }

    /// Per-worker speed multipliers.
    pub fn speeds(&self) -> &[f64] {
        &self.speeds
    }

    /// Speed of one worker.
    #[inline]
    pub fn speed(&self, server: u32) -> f64 {
        self.speeds[server as usize]
    }

    /// Replication factor r.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Per-replica launch overhead (seconds; 0 outside redundancy).
    pub fn launch_overhead(&self) -> f64 {
        self.launch_overhead
    }

    /// Aggregate service capacity Σ speeds (the ideal-partition divisor).
    pub fn total_speed(&self) -> f64 {
        self.speeds.iter().sum()
    }

    /// Dispatch one logical task: reserve the `r` earliest-free servers,
    /// draw one execution + overhead sample per replica, resolve
    /// first-finish-wins, release every server at its post-cancellation
    /// free time, and record trace events for replicas that ran.
    ///
    /// `floor` is the earliest permissible start (the job arrival in
    /// fork-join; the start barrier in split-merge, where it is a no-op
    /// because the heap is already reset to the barrier).
    ///
    /// `class` is the dispatch-policy class recorded on trace events
    /// (0 outside an active policy; the priority policy passes the job
    /// class and hands this dispatcher its class's server sub-heap).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_task(
        &mut self,
        heap: &mut ServerHeap,
        floor: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        job: u32,
        task: u32,
        class: u32,
        trace: &mut TraceLog,
    ) -> TaskOutcome {
        let r = self.replicas.min(heap.len());
        // Redundant dispatch charges the replica-launch cost to every
        // replica; r = 1 adds literal 0.0, preserving bit-exactness.
        let launch = if self.replicas > 1 { self.launch_overhead } else { 0.0 };
        self.scratch.clear();
        for _ in 0..r {
            let (t_free, server) = heap.pop();
            let exec = workload.next_execution();
            let oh = overhead.sample_task(workload.rng()) + launch;
            let start = if floor > t_free { floor } else { t_free };
            // Summed term by term so that speed 1.0 reproduces the
            // homogeneous `start + e + o` bit-for-bit (same rounding).
            let speed = self.speeds[server as usize];
            let finish = start + exec / speed + oh / speed;
            self.scratch.push(Replica { t_free, server, start, finish, exec, overhead: oh });
        }

        let mut win = 0usize;
        for (i, rep) in self.scratch.iter().enumerate().skip(1) {
            if rep.finish < self.scratch[win].finish {
                win = i;
            }
        }
        let t_win = self.scratch[win].finish;

        let mut first_start = f64::INFINITY;
        let mut redundant = 0.0;
        for (i, rep) in self.scratch.iter().enumerate() {
            let ran = i == win || rep.start < t_win;
            let freed = if i == win {
                rep.finish
            } else if ran {
                // Cancelled mid-run when the winner finished.
                t_win
            } else {
                // Never started: the reservation is released unchanged.
                rep.t_free
            };
            if ran {
                if rep.start < first_start {
                    first_start = rep.start;
                }
                if i != win {
                    redundant += t_win - rep.start;
                    self.losers += 1;
                }
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job,
                        task,
                        server: rep.server,
                        start: rep.start,
                        end: freed,
                        // Wall overhead on this worker, clipped for
                        // replicas cancelled before finishing theirs.
                        overhead: (rep.overhead / self.speeds[rep.server as usize])
                            .min(freed - rep.start),
                        winner: i == win,
                        attempt: 1,
                        cause: cause::NONE,
                        class,
                    });
                }
            }
            heap.push(freed, rep.server);
        }

        TaskOutcome {
            first_start,
            finish: t_win,
            work: self.scratch[win].exec,
            overhead: self.scratch[win].overhead,
            redundant_time: redundant,
        }
    }

    /// [`Scenario::dispatch_task`] under fault injection: every replica
    /// can be crash-killed by its worker's Markov on/off schedule, and
    /// the winning replica's attempt can fail (bounded retries with
    /// backoff, re-dispatching the whole replica set). Speculation is
    /// rejected for redundant/heterogeneous configs at validation — it
    /// is itself a dynamic replica.
    ///
    /// The first attempt draws its replicas from the workload stream in
    /// exactly the fault-free order; retry attempts redraw every replica
    /// from the injector's fault stream. A replica whose worker crashes
    /// mid-run is accounted as crashed (its time up to the crash counts
    /// as lost work) even when another replica won earlier — the worker
    /// goes down either way and rejoins only after repair.
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_task_faulty(
        &mut self,
        heap: &mut ServerHeap,
        floor: f64,
        workload: &mut Workload,
        overhead: &OverheadModel,
        fi: &mut FaultInjector,
        job: u32,
        task: u32,
        class: u32,
        trace: &mut TraceLog,
    ) -> FaultOutcome {
        let r = self.replicas.min(heap.len());
        let launch = if self.replicas > 1 { self.launch_overhead } else { 0.0 };

        let mut retries = 0u32;
        let mut fail_budget =
            if fi.config().failures_enabled() { fi.config().max_retries } else { 0 };
        let mut failed_attempts = 0u32;
        let mut retry_floor = floor;
        let mut first_start = f64::INFINITY;
        let mut overhead_sum = 0.0;
        let mut lost = 0.0;
        let mut redundant = 0.0;
        let mut first_attempt = true;
        // Per-replica crash resolution (crash instant, repair-done),
        // kept parallel to `scratch`; rebuilt every attempt.
        let mut crashed: Vec<Option<(f64, f64)>> = Vec::with_capacity(r);

        loop {
            let attempt = 1 + retries;
            self.scratch.clear();
            crashed.clear();
            for _ in 0..r {
                let (t_free, server) = heap.pop();
                let (exec, oh) = if first_attempt {
                    // Fault-free draw order: execution then overhead,
                    // from the workload stream.
                    let e = workload.next_execution();
                    let o = overhead.sample_task(workload.rng()) + launch;
                    (e, o)
                } else {
                    let (e, o) = fi.backup_draws(workload, overhead);
                    (e, o + launch)
                };
                let floor_now = if retry_floor > t_free { retry_floor } else { t_free };
                let start = fi.up_at(server, floor_now);
                let speed = self.speeds[server as usize];
                let finish = start + exec / speed + oh / speed;
                let crash = fi.crash_within(server, start, finish);
                self.scratch.push(Replica { t_free, server, start, finish, exec, overhead: oh });
                crashed.push(crash);
            }
            first_attempt = false;

            // Winner: earliest finish among replicas that survived.
            let mut win: Option<usize> = None;
            for (i, rep) in self.scratch.iter().enumerate() {
                if crashed[i].is_some() {
                    continue;
                }
                let better = match win {
                    None => true,
                    Some(w) => rep.finish < self.scratch[w].finish,
                };
                if better {
                    win = Some(i);
                }
            }

            // Crashed replicas: lost work up to the crash, worker back
            // after repair — independent of how the attempt resolves.
            for (i, rep) in self.scratch.iter().enumerate() {
                if let Some((c, up)) = crashed[i] {
                    lost += c - rep.start;
                    if rep.start < first_start {
                        first_start = rep.start;
                    }
                    heap.push(up, rep.server);
                    if trace.is_enabled() {
                        trace.record(TraceEvent {
                            job,
                            task,
                            server: rep.server,
                            start: rep.start,
                            end: c,
                            overhead: (rep.overhead / self.speeds[rep.server as usize])
                                .min(c - rep.start),
                            winner: false,
                            attempt,
                            cause: cause::CRASHED,
                            class,
                        });
                    }
                }
            }

            let Some(win) = win else {
                // Every replica crashed: re-dispatch as a fresh attempt
                // immediately (crashes do not consume the retry budget).
                retries += 1;
                fi.note_retry();
                continue;
            };
            let t_win = self.scratch[win].finish;

            // Survivors resolve first-finish-wins exactly as the
            // fault-free dispatcher: losers cancelled at the winner's
            // finish, unstarted reservations released.
            for (i, rep) in self.scratch.iter().enumerate() {
                if crashed[i].is_some() {
                    continue;
                }
                let ran = i == win || rep.start < t_win;
                let freed = if i == win {
                    rep.finish
                } else if ran {
                    t_win
                } else {
                    rep.t_free
                };
                if ran {
                    if rep.start < first_start {
                        first_start = rep.start;
                    }
                    if i != win {
                        redundant += t_win - rep.start;
                        self.losers += 1;
                    }
                    if trace.is_enabled() && i != win {
                        trace.record(TraceEvent {
                            job,
                            task,
                            server: rep.server,
                            start: rep.start,
                            end: freed,
                            overhead: (rep.overhead / self.speeds[rep.server as usize])
                                .min(freed - rep.start),
                            winner: false,
                            attempt,
                            cause: cause::NONE,
                            class,
                        });
                    }
                }
                heap.push(freed, rep.server);
            }

            // Failure surfaces at the winning replica's completion.
            overhead_sum += self.scratch[win].overhead;
            let winner = self.scratch[win];
            if fail_budget > 0 && fi.failure_draw() {
                fail_budget -= 1;
                failed_attempts += 1;
                lost += t_win - winner.start;
                if trace.is_enabled() {
                    trace.record(TraceEvent {
                        job,
                        task,
                        server: winner.server,
                        start: winner.start,
                        end: t_win,
                        overhead: (winner.overhead
                            / self.speeds[winner.server as usize])
                            .min(t_win - winner.start),
                        winner: false,
                        attempt,
                        cause: cause::FAILED,
                        class,
                    });
                }
                retries += 1;
                fi.note_retry();
                retry_floor = t_win + fi.config().backoff_delay(failed_attempts);
                continue;
            }

            if trace.is_enabled() {
                trace.record(TraceEvent {
                    job,
                    task,
                    server: winner.server,
                    start: winner.start,
                    end: t_win,
                    overhead: (winner.overhead / self.speeds[winner.server as usize])
                        .min(t_win - winner.start),
                    winner: true,
                    attempt,
                    cause: cause::NONE,
                    class,
                });
            }
            return FaultOutcome {
                first_start,
                finish: t_win,
                work: winner.exec,
                overhead: overhead_sum,
                lost,
                redundant,
                retries,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Deterministic;

    fn det_workload(exec: f64) -> Workload {
        Workload::new(Deterministic::new(100.0).into(), Deterministic::new(exec).into(), 1)
    }

    #[test]
    fn speed_scales_service_time() {
        // Two workers, speeds 1 and 2; FIFO dispatch alternates between
        // them, and the fast worker finishes its task in half the time.
        let mut sc = Scenario::new(vec![1.0, 2.0], 1);
        let mut heap = ServerHeap::new(2, 0.0);
        let mut w = det_workload(1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let a = sc.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 0, 0, &mut tr);
        let b = sc.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 1, 0, &mut tr);
        let mut finishes = [a.finish, b.finish];
        finishes.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(finishes, [0.5, 1.0]);
    }

    #[test]
    fn replicas_first_finish_wins() {
        // Speeds 4 and 1, r = 2: both replicas start at 0; the fast
        // worker wins at 0.25 and the slow replica is cancelled then.
        let mut sc = Scenario::new(vec![4.0, 1.0], 2);
        let mut heap = ServerHeap::new(2, 0.0);
        let mut w = det_workload(1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::enabled();
        let out = sc.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 0, 0, &mut tr);
        assert_eq!(out.finish, 0.25);
        assert_eq!(out.first_start, 0.0);
        assert_eq!(out.redundant_time, 0.25);
        assert_eq!(sc.loser_count(), 1);
        // Both servers are free again at 0.25.
        assert_eq!(heap.peek().0, 0.25);
        assert_eq!(heap.max_time(), 0.25);
        // Both replicas left trace events ending at the winner's finish,
        // and exactly one is flagged as the winner.
        assert_eq!(trace_len(&tr), 2);
        assert_eq!(tr.events().iter().filter(|e| e.winner).count(), 1);
        assert!(tr.events().iter().find(|e| e.winner).unwrap().end == 0.25);
    }

    /// The per-replica launch cost stretches every replica's service
    /// (scaled by its worker's speed) and is a no-op at r = 1.
    #[test]
    fn launch_overhead_charged_per_replica() {
        // r = 2, speeds (1, 1), exec 1.0, launch 0.5: both replicas
        // finish at 1.5 (winner ties resolved by scratch order).
        let mut sc = Scenario::new(vec![1.0, 1.0], 2).with_launch_overhead(0.5);
        let mut heap = ServerHeap::new(2, 0.0);
        let mut w = det_workload(1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let out = sc.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 0, 0, &mut tr);
        assert_eq!(out.finish, 1.5);
        // r = 1: launch cost is ignored (degenerate scenarios bit-exact).
        let mut sc = Scenario::new(vec![1.0, 1.0], 1).with_launch_overhead(0.5);
        let mut heap = ServerHeap::new(2, 0.0);
        let mut w = det_workload(1.0);
        let mut tr = TraceLog::disabled();
        let out = sc.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 0, 0, &mut tr);
        assert_eq!(out.finish, 1.0);
    }

    fn trace_len(tr: &TraceLog) -> usize {
        tr.events().len()
    }

    #[test]
    fn unstarted_replica_releases_reservation() {
        // Worker 0 free at 0 (speed 10), worker 1 free at 5: the winner
        // finishes at 0.1, long before worker 1 could start, so worker 1
        // keeps its original free time.
        let mut sc = Scenario::new(vec![10.0, 1.0], 2);
        let mut heap = ServerHeap::new(2, 0.0);
        // Occupy worker 1 until t = 5.
        let (t0, s0) = heap.pop();
        let (t1, s1) = heap.pop();
        assert_eq!((t0, t1), (0.0, 0.0));
        let (slow, fast) = if s0 == 1 { (s0, s1) } else { (s1, s0) };
        heap.push(5.0, slow);
        heap.push(0.0, fast);
        let mut w = det_workload(1.0);
        let oh = OverheadModel::none();
        let mut tr = TraceLog::disabled();
        let out = sc.dispatch_task(&mut heap, 0.0, &mut w, &oh, 0, 0, 0, &mut tr);
        assert!((out.finish - 0.1).abs() < 1e-12);
        assert_eq!(out.redundant_time, 0.0);
        // Worker 1's reservation was released at its original free time.
        assert_eq!(heap.peek().0, 0.1);
        assert_eq!(heap.max_time(), 5.0);
    }

    #[test]
    fn degenerate_config_resolves_to_none() {
        let cfg = SimulationConfig::default();
        assert!(Scenario::from_config(&cfg).unwrap().is_none());
        let cfg = SimulationConfig {
            redundancy: Some(crate::config::RedundancyConfig::new(2)),
            ..SimulationConfig::default()
        };
        let sc = Scenario::from_config(&cfg).unwrap().unwrap();
        assert_eq!(sc.replicas(), 2);
        assert!(sc.speeds().iter().all(|&s| s == 1.0));
    }
}
