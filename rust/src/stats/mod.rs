//! Statistics: streaming summaries, quantiles, ECDFs, PP plots, box plots,
//! and histograms — everything the evaluation pipelines need to turn raw
//! sojourn/waiting/overhead samples into the paper's figures.

mod boxstats;
mod ci;
mod ecdf;
mod histogram;
mod ppplot;
mod quantile;
mod summary;

pub use boxstats::BoxStats;
pub use ci::quantile_ci;
pub use ecdf::Ecdf;
pub use histogram::Histogram;
pub use ppplot::{pp_distance, pp_points, PpPoint};
pub use quantile::{
    quantile_of_sorted, P2Quantile, QuantileEstimator, QuantileSketch, StreamingQuantiles,
};
pub use summary::Summary;
