//! Fixed-bin histogram — used for activity heat maps and quick-look
//! distribution summaries in reports.

/// Uniform-bin histogram over `[lo, hi)` with overflow/underflow tracking.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    /// Histogram over `[lo, hi)` with `bins` uniform bins.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0, "bad histogram [{lo},{hi})x{bins}");
        Self { lo, hi, bins: vec![0; bins], underflow: 0, overflow: 0, count: 0 }
    }

    /// Record one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    /// Bin counts.
    pub fn bins(&self) -> &[u64] {
        &self.bins
    }
    /// Observations below range.
    pub fn underflow(&self) -> u64 {
        self.underflow
    }
    /// Observations at/above the upper edge.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }
    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Midpoint of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// Normalized density estimate per bin (integrates to ≤ 1).
    pub fn density(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let n = self.count.max(1) as f64;
        self.bins.iter().map(|&c| c as f64 / (n * w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_assignment() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-1.0);
        h.push(0.0);
        h.push(5.5);
        h.push(9.999);
        h.push(10.0);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.bins()[0], 1);
        assert_eq!(h.bins()[5], 1);
        assert_eq!(h.bins()[9], 1);
        assert_eq!(h.count(), 5);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn density_normalizes() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for i in 0..1000 {
            h.push((i % 100) as f64 / 100.0);
        }
        let total: f64 = h.density().iter().sum::<f64>() * 0.25;
        assert!((total - 1.0).abs() < 1e-9);
    }
}
