//! PP plots — the paper's tool (Fig. 10) for validating the overhead model:
//! plot `F_sim(x)` against `F_spark(x)` over the pooled support; a perfect
//! match lies on the diagonal, a support offset shows as a step.

use super::Ecdf;

/// One PP-plot point: the two CDFs evaluated at a common abscissa.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PpPoint {
    /// CDF of the first (e.g. simulated) sample at x.
    pub p_first: f64,
    /// CDF of the second (e.g. measured) sample at x.
    pub p_second: f64,
}

/// PP-plot points for two ECDFs evaluated on an evenly spaced probability
/// grid of `n` points over the pooled sample range.
pub fn pp_points(first: &Ecdf, second: &Ecdf, n: usize) -> Vec<PpPoint> {
    assert!(n >= 2, "need at least 2 grid points");
    let lo = first.sorted()[0].min(second.sorted()[0]);
    let hi = first.sorted()[first.len() - 1].max(second.sorted()[second.len() - 1]);
    (0..n)
        .map(|i| {
            let x = lo + (hi - lo) * i as f64 / (n - 1) as f64;
            PpPoint { p_first: first.eval(x), p_second: second.eval(x) }
        })
        .collect()
}

/// Mean absolute deviation of the PP plot from the diagonal — the objective
/// minimized by the overhead calibration (Sec. 2.6 "fit the experimental
/// sojourn time distributions").
pub fn pp_distance(first: &Ecdf, second: &Ecdf, n: usize) -> f64 {
    let pts = pp_points(first, second, n);
    pts.iter().map(|p| (p.p_first - p.p_second).abs()).sum::<f64>() / pts.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_samples_on_diagonal() {
        let a = Ecdf::new((1..=100).map(|i| i as f64).collect());
        let b = Ecdf::new((1..=100).map(|i| i as f64).collect());
        for p in pp_points(&a, &b, 50) {
            assert!((p.p_first - p.p_second).abs() < 1e-12);
        }
        assert!(pp_distance(&a, &b, 50) < 1e-12);
    }

    /// A constant shift produces the step pattern the paper describes
    /// ("support of one of the distributions is offset").
    #[test]
    fn shift_increases_distance() {
        let a = Ecdf::new((1..=1000).map(|i| i as f64 * 0.01).collect());
        let small = Ecdf::new((1..=1000).map(|i| i as f64 * 0.01 + 0.5).collect());
        let large = Ecdf::new((1..=1000).map(|i| i as f64 * 0.01 + 5.0).collect());
        let d_small = pp_distance(&a, &small, 200);
        let d_large = pp_distance(&a, &large, 200);
        assert!(d_small > 0.01);
        assert!(d_large > d_small, "{d_large} vs {d_small}");
    }
}
