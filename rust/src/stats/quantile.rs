//! Quantile estimation.
//!
//! Two estimators: an exact store-and-sort sketch (used for figure
//! pipelines, where we keep every sojourn time anyway) and the P² streaming
//! estimator (Jain & Chlamtac 1985) for long stability scans where storing
//! tens of millions of samples is wasteful.

/// Quantile of an **ascending-sorted** slice with linear interpolation
/// (type-7, the numpy default).
pub fn quantile_of_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q out of range: {q}");
    let h = (sorted.len() as f64 - 1.0) * q;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = h - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Exact quantile sketch: stores all samples, sorts lazily.
#[derive(Clone, Debug, Default)]
pub struct QuantileSketch {
    data: Vec<f64>,
    sorted: bool,
}

impl QuantileSketch {
    /// Empty sketch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Sketch pre-sized for `n` samples.
    pub fn with_capacity(n: usize) -> Self {
        Self { data: Vec::with_capacity(n), sorted: false }
    }

    /// Add one sample.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.data.push(x);
        self.sorted = false;
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Merge another sketch's samples.
    pub fn merge(&mut self, other: &QuantileSketch) {
        self.data.extend_from_slice(&other.data);
        self.sorted = false;
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.data
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample in sketch"));
            self.sorted = true;
        }
    }

    /// Quantile `q` ∈ [0, 1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        self.ensure_sorted();
        quantile_of_sorted(&self.data, q)
    }

    /// Borrow the sorted samples (e.g. to build an ECDF without copying).
    pub fn sorted_data(&mut self) -> &[f64] {
        self.ensure_sorted();
        &self.data
    }
}

/// P² streaming quantile estimator (five markers, O(1) memory).
#[derive(Clone, Debug)]
pub struct P2Quantile {
    q: f64,
    heights: [f64; 5],
    positions: [f64; 5],
    desired: [f64; 5],
    increments: [f64; 5],
    count: usize,
    /// First five samples, kept **sorted** by insertion position so the
    /// small-sample `value()` path reads it directly (no clone + re-sort
    /// per call).
    initial: Vec<f64>,
}

impl P2Quantile {
    /// Estimator for quantile `q` ∈ (0, 1).
    pub fn new(q: f64) -> Self {
        assert!(q > 0.0 && q < 1.0, "q must be in (0,1)");
        Self {
            q,
            heights: [0.0; 5],
            positions: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            increments: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            count: 0,
            initial: Vec::with_capacity(5),
        }
    }

    /// The tracked quantile.
    pub fn q(&self) -> f64 {
        self.q
    }

    /// Observe one sample.
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if self.initial.len() < 5 {
            // Sorted insertion keeps the buffer query-ready. NaN would
            // silently corrupt the order (the old sort panicked) — keep
            // the failure loud.
            assert!(!x.is_nan(), "NaN sample");
            let pos = self.initial.partition_point(|&v| v <= x);
            self.initial.insert(pos, x);
            if self.initial.len() == 5 {
                self.heights.copy_from_slice(&self.initial);
            }
            return;
        }
        // Find cell k.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if self.heights[i] <= x && x < self.heights[i + 1] {
                    k = i;
                    break;
                }
            }
            k
        };
        for p in self.positions.iter_mut().skip(k + 1) {
            *p += 1.0;
        }
        for i in 0..5 {
            self.desired[i] += self.increments[i];
        }
        // Adjust interior markers with parabolic (fallback linear) moves.
        for i in 1..4 {
            let d = self.desired[i] - self.positions[i];
            let np = self.positions[i + 1] - self.positions[i];
            let pp = self.positions[i - 1] - self.positions[i];
            if (d >= 1.0 && np > 1.0) || (d <= -1.0 && pp < -1.0) {
                let s = d.signum();
                let parab = self.heights[i]
                    + s / (np - pp)
                        * ((self.positions[i] - self.positions[i - 1] + s)
                            * (self.heights[i + 1] - self.heights[i])
                            / np
                            + (self.positions[i + 1] - self.positions[i] - s)
                                * (self.heights[i] - self.heights[i - 1])
                                / -pp);
                self.heights[i] = if self.heights[i - 1] < parab && parab < self.heights[i + 1] {
                    parab
                } else {
                    // Linear fallback.
                    let j = (i as f64 + s) as usize;
                    self.heights[i]
                        + s * (self.heights[j] - self.heights[i])
                            / (self.positions[j] - self.positions[i])
                };
                self.positions[i] += s;
            }
        }
    }

    /// Merge another estimator for the **same** quantile (parallel-shard
    /// reduction).
    ///
    /// While either side is still in its exact small-sample phase its
    /// samples are simply replayed into the other — an exact, order-free
    /// operation at ≤ 5 samples. Once both sides carry converged marker
    /// states, markers are combined count-weighted: interior heights as
    /// weighted averages, the extreme markers as true min/max, and the
    /// marker positions reset to their ideal values for the combined
    /// count (the standard parallel-P² approximation; the estimate
    /// quality matches a single-pass P² on tail quantiles, which the
    /// sharding tests enforce against exact pooled quantiles).
    pub fn merge(&mut self, other: &P2Quantile) {
        assert!(
            (self.q - other.q).abs() < 1e-12,
            "merging P2 estimators of different quantiles: {} vs {}",
            self.q,
            other.q
        );
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            // Adopt the other shard's state but keep our own q: the
            // assert admits up to 1e-12 drift, and adopting other.q
            // would break tracked-quantile lookups and later merge
            // asserts keyed on the original value.
            let q = self.q;
            *self = other.clone();
            self.q = q;
            return;
        }
        if other.initial.len() < 5 {
            // ≤ 4 samples ⇒ other.count == other.initial.len(): the
            // shard's entire history is in its initial buffer, so a
            // replay is exact (covers empty and single-sample shards).
            for &x in &other.initial {
                self.push(x);
            }
            return;
        }
        if self.initial.len() < 5 {
            let q = self.q;
            let mut merged = other.clone();
            for &x in &self.initial {
                merged.push(x);
            }
            *self = merged;
            self.q = q;
            return;
        }
        let n1 = self.count as f64;
        let n2 = other.count as f64;
        let n = n1 + n2;
        self.heights[0] = self.heights[0].min(other.heights[0]);
        for i in 1..4 {
            self.heights[i] = (self.heights[i] * n1 + other.heights[i] * n2) / n;
        }
        self.heights[4] = self.heights[4].max(other.heights[4]);
        self.count += other.count;
        // Re-anchor marker positions on the ideal grid for the combined
        // count; future pushes adjust from there as usual.
        let q = self.q;
        let m = self.count as f64;
        self.positions = [
            1.0,
            1.0 + (m - 1.0) * q / 2.0,
            1.0 + (m - 1.0) * q,
            1.0 + (m - 1.0) * (1.0 + q) / 2.0,
            m,
        ];
        self.desired = self.positions;
    }

    /// Current estimate (exact while ≤ 5 samples seen).
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.initial.len() < 5 {
            // `initial` is maintained sorted; read it in place.
            return quantile_of_sorted(&self.initial, self.q);
        }
        self.heights[2]
    }

    /// Number of samples observed.
    pub fn count(&self) -> usize {
        self.count
    }
}

/// A bank of [`P2Quantile`] estimators sharing one streaming pass —
/// O(1) memory regardless of sample count.
#[derive(Clone, Debug)]
pub struct StreamingQuantiles {
    estimators: Vec<P2Quantile>,
    count: usize,
}

impl StreamingQuantiles {
    /// Track the given quantiles (duplicates within 1e-12 are merged).
    pub fn new(qs: &[f64]) -> Self {
        let mut estimators: Vec<P2Quantile> = Vec::with_capacity(qs.len());
        for &q in qs {
            if !estimators.iter().any(|e| (e.q() - q).abs() < 1e-12) {
                estimators.push(P2Quantile::new(q));
            }
        }
        assert!(!estimators.is_empty(), "need at least one quantile");
        Self { estimators, count: 0 }
    }

    /// Observe one sample (feeds every tracked estimator).
    #[inline]
    pub fn push(&mut self, x: f64) {
        for e in &mut self.estimators {
            e.push(x);
        }
        self.count += 1;
    }

    /// Number of samples observed.
    pub fn len(&self) -> usize {
        self.count
    }

    /// True when no samples were observed.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Estimate for a tracked quantile; `None` if `q` is not tracked.
    pub fn value(&self, q: f64) -> Option<f64> {
        self.estimators.iter().find(|e| (e.q() - q).abs() < 1e-12).map(|e| e.value())
    }

    /// The tracked quantiles.
    pub fn tracked(&self) -> Vec<f64> {
        self.estimators.iter().map(|e| e.q()).collect()
    }

    /// Merge another bank tracking the **same** quantile set (parallel-
    /// shard reduction); errors on a tracked-set mismatch instead of
    /// silently mispairing estimators.
    pub fn merge(&mut self, other: &StreamingQuantiles) -> Result<(), String> {
        if self.estimators.len() != other.estimators.len()
            || self
                .estimators
                .iter()
                .zip(&other.estimators)
                .any(|(a, b)| (a.q() - b.q()).abs() >= 1e-12)
        {
            return Err(format!(
                "cannot merge streaming banks tracking different quantiles: {:?} vs {:?}",
                self.tracked(),
                other.tracked()
            ));
        }
        for (a, b) in self.estimators.iter_mut().zip(&other.estimators) {
            a.merge(b);
        }
        self.count += other.count;
        Ok(())
    }
}

/// Quantile estimator with a run-time choice of memory/accuracy trade:
/// exact store-and-sort (figures, ECDFs) or the P² bank (stability scans
/// and million-job sweep points in O(1) memory).
#[derive(Clone, Debug)]
pub enum QuantileEstimator {
    /// Stores every sample; any quantile, exact.
    Exact(QuantileSketch),
    /// O(1)-memory streaming bank; only pre-registered quantiles.
    Streaming(StreamingQuantiles),
}

impl QuantileEstimator {
    /// Exact estimator pre-sized for `n` samples.
    pub fn exact_with_capacity(n: usize) -> Self {
        Self::Exact(QuantileSketch::with_capacity(n))
    }

    /// Streaming estimator tracking `qs`.
    pub fn streaming(qs: &[f64]) -> Self {
        Self::Streaming(StreamingQuantiles::new(qs))
    }

    /// Add one sample.
    #[inline]
    pub fn push(&mut self, x: f64) {
        match self {
            Self::Exact(s) => s.push(x),
            Self::Streaming(s) => s.push(x),
        }
    }

    /// Number of samples observed.
    pub fn len(&self) -> usize {
        match self {
            Self::Exact(s) => s.len(),
            Self::Streaming(s) => s.len(),
        }
    }

    /// True when no samples were observed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Quantile `q`. Exact mode serves any `q`; streaming mode serves
    /// only tracked quantiles and panics otherwise (a programming error:
    /// the caller chose streaming mode without registering `q`).
    pub fn quantile(&mut self, q: f64) -> f64 {
        match self {
            Self::Exact(s) => s.quantile(q),
            Self::Streaming(s) => s.value(q).unwrap_or_else(|| {
                panic!(
                    "quantile {q} not tracked in streaming mode (tracked: {:?})",
                    s.tracked()
                )
            }),
        }
    }

    /// Merge another estimator of the **same mode** (parallel-shard
    /// reduction): exact sketches pool their samples (merged quantiles
    /// stay exact), streaming banks combine their P² marker states.
    /// Mode or tracked-set mismatches are errors, not panics — they can
    /// arise from caller configuration.
    pub fn merge(&mut self, other: &QuantileEstimator) -> Result<(), String> {
        match (self, other) {
            (Self::Exact(a), Self::Exact(b)) => {
                a.merge(b);
                Ok(())
            }
            (Self::Streaming(a), Self::Streaming(b)) => a.merge(b),
            _ => Err("cannot merge exact and streaming quantile estimators".into()),
        }
    }

    /// Borrow the exact sketch, if this estimator stores samples.
    pub fn as_exact_mut(&mut self) -> Option<&mut QuantileSketch> {
        match self {
            Self::Exact(s) => Some(s),
            Self::Streaming(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn sorted_quantiles_interpolate() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_of_sorted(&v, 0.0), 1.0);
        assert_eq!(quantile_of_sorted(&v, 1.0), 4.0);
        assert!((quantile_of_sorted(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile_of_sorted(&v, 1.0 / 3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sketch_matches_direct() {
        let mut s = QuantileSketch::new();
        for i in (0..101).rev() {
            s.push(i as f64);
        }
        assert_eq!(s.quantile(0.5), 50.0);
        assert_eq!(s.quantile(0.99), 99.0);
        assert_eq!(s.len(), 101);
    }

    #[test]
    fn sketch_merge() {
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        for i in 0..50 {
            a.push(i as f64);
        }
        for i in 50..100 {
            b.push(i as f64);
        }
        a.merge(&b);
        assert!((a.quantile(0.5) - 49.5).abs() < 1e-12);
    }

    /// P² tracks the exponential 0.99 quantile within a few percent.
    #[test]
    fn p2_tracks_exponential_tail() {
        let mut p2 = P2Quantile::new(0.99);
        let mut rng = Pcg64::seed_from_u64(31);
        let n = 500_000;
        for _ in 0..n {
            p2.push(-rng.next_f64_open().ln());
        }
        let exact = -(0.01f64).ln(); // ≈ 4.605
        let est = p2.value();
        assert!(
            (est - exact).abs() / exact < 0.05,
            "P² estimate {est} vs exact {exact}"
        );
        assert_eq!(p2.count(), n);
    }

    #[test]
    fn p2_small_samples_exact() {
        let mut p2 = P2Quantile::new(0.5);
        for x in [3.0, 1.0, 2.0] {
            p2.push(x);
        }
        assert!((p2.value() - 2.0).abs() < 1e-12);
        // The sorted-insert path is queryable after every push.
        let mut p = P2Quantile::new(0.5);
        p.push(5.0);
        assert_eq!(p.value(), 5.0);
        p.push(1.0);
        assert!((p.value() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn streaming_bank_tracks_and_dedups() {
        let mut s = StreamingQuantiles::new(&[0.5, 0.9, 0.9, 0.99]);
        assert_eq!(s.tracked().len(), 3, "duplicate q merged");
        let mut rng = Pcg64::seed_from_u64(7);
        for _ in 0..200_000 {
            s.push(-rng.next_f64_open().ln());
        }
        assert_eq!(s.len(), 200_000);
        let med = s.value(0.5).unwrap();
        let exact = -(0.5f64).ln();
        assert!((med - exact).abs() / exact < 0.05, "{med} vs {exact}");
        assert!(s.value(0.123).is_none());
    }

    /// Merging P² shards tracks the pooled exact quantile about as well
    /// as a single-pass P² does.
    #[test]
    fn p2_merge_tracks_pooled_quantile() {
        let mut rng = Pcg64::seed_from_u64(97);
        let mut shards: Vec<P2Quantile> = (0..4).map(|_| P2Quantile::new(0.99)).collect();
        let mut exact = QuantileSketch::new();
        for i in 0..400_000 {
            let x = -rng.next_f64_open().ln();
            shards[i % 4].push(x);
            exact.push(x);
        }
        let mut merged = shards.remove(0);
        for s in &shards {
            merged.merge(s);
        }
        assert_eq!(merged.count(), 400_000);
        let (est, truth) = (merged.value(), exact.quantile(0.99));
        assert!(
            (est - truth).abs() / truth < 0.05,
            "merged P² {est} vs pooled exact {truth}"
        );
        // Merged state keeps accepting samples.
        merged.push(1.0);
        assert_eq!(merged.count(), 400_001);
    }

    /// Small-sample shards merge exactly (the ≤5-sample replay path).
    #[test]
    fn p2_merge_small_shards_exact() {
        let mut a = P2Quantile::new(0.5);
        let mut b = P2Quantile::new(0.5);
        for x in [1.0, 5.0] {
            a.push(x);
        }
        for x in [2.0, 4.0, 3.0] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert!((a.value() - 3.0).abs() < 1e-12);
        // Empty merges are identities in both directions.
        let mut empty = P2Quantile::new(0.5);
        empty.merge(&a);
        assert_eq!(empty.count(), 5);
        a.merge(&P2Quantile::new(0.5));
        assert_eq!(a.count(), 5);
    }

    /// A single-sample shard replays exactly into a converged estimator,
    /// and a converged shard merging into a small one keeps tracking the
    /// pooled quantile.
    #[test]
    fn p2_merge_single_sample_shard() {
        let mut rng = Pcg64::seed_from_u64(41);
        let mut big = P2Quantile::new(0.5);
        for _ in 0..50_000 {
            big.push(-rng.next_f64_open().ln());
        }
        let mut one = P2Quantile::new(0.5);
        one.push(0.7);
        let before = big.value();
        big.merge(&one);
        assert_eq!(big.count(), 50_001);
        assert!((big.value() - before).abs() < 0.1, "one sample barely moves 50k");
        // The reverse direction: small self adopts the converged shard.
        let mut small = P2Quantile::new(0.5);
        small.push(0.7);
        small.merge(&big);
        assert_eq!(small.count(), 50_002);
        let exact = -(0.5f64).ln();
        let est = small.value();
        assert!((est - exact).abs() / exact < 0.05, "{est} vs {exact}");
    }

    /// Merging preserves the estimator's own q even when the shards'
    /// q values differ within the 1e-12 assert tolerance — adopting
    /// other.q used to break tracked-quantile lookups after a merge.
    #[test]
    fn p2_merge_preserves_own_q() {
        let drifted = 0.99 + 5e-13;
        let mut shard = P2Quantile::new(drifted);
        let mut rng = Pcg64::seed_from_u64(43);
        for _ in 0..10_000 {
            shard.push(rng.next_f64_open());
        }
        // Empty-self adopt branch.
        let mut a = P2Quantile::new(0.99);
        a.merge(&shard);
        assert_eq!(a.q(), 0.99);
        // Small-self adopt branch.
        let mut b = P2Quantile::new(0.99);
        b.push(0.5);
        b.merge(&shard);
        assert_eq!(b.q(), 0.99);
        // Bank lookups keyed on the original q keep working.
        let mut bank = StreamingQuantiles::new(&[0.99]);
        let mut other = StreamingQuantiles::new(&[drifted]);
        other.push(1.0);
        bank.merge(&other).unwrap();
        assert!(bank.value(0.99).is_some(), "tracked q must survive the merge");
    }

    #[test]
    fn streaming_bank_merge_and_mismatch() {
        let mut rng = Pcg64::seed_from_u64(5);
        let mut a = StreamingQuantiles::new(&[0.5, 0.99]);
        let mut b = StreamingQuantiles::new(&[0.5, 0.99]);
        for i in 0..100_000 {
            let x = -rng.next_f64_open().ln();
            if i % 2 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
        }
        a.merge(&b).unwrap();
        assert_eq!(a.len(), 100_000);
        let med = a.value(0.5).unwrap();
        let exact = -(0.5f64).ln();
        assert!((med - exact).abs() / exact < 0.05, "{med} vs {exact}");
        let other = StreamingQuantiles::new(&[0.5, 0.9]);
        assert!(a.merge(&other).is_err(), "tracked-set mismatch must error");
    }

    #[test]
    fn estimator_merge_modes() {
        let mut a = QuantileEstimator::exact_with_capacity(4);
        let mut b = QuantileEstimator::exact_with_capacity(4);
        for x in [1.0, 2.0] {
            a.push(x);
        }
        for x in [3.0, 4.0] {
            b.push(x);
        }
        a.merge(&b).unwrap();
        assert_eq!(a.len(), 4);
        assert!((a.quantile(0.5) - 2.5).abs() < 1e-12);
        let s = QuantileEstimator::streaming(&[0.5]);
        assert!(a.merge(&s).is_err(), "mode mismatch must error");
    }

    #[test]
    fn estimator_modes_agree_within_tolerance() {
        let mut exact = QuantileEstimator::exact_with_capacity(100_000);
        let mut stream = QuantileEstimator::streaming(&[0.5, 0.99]);
        let mut rng = Pcg64::seed_from_u64(13);
        for _ in 0..100_000 {
            let x = -rng.next_f64_open().ln();
            exact.push(x);
            stream.push(x);
        }
        assert_eq!(exact.len(), stream.len());
        for q in [0.5, 0.99] {
            let (a, b) = (exact.quantile(q), stream.quantile(q));
            assert!((a - b).abs() / a < 0.05, "q={q}: exact {a} vs P2 {b}");
        }
        assert!(exact.as_exact_mut().is_some());
        assert!(stream.as_exact_mut().is_none());
    }
}
