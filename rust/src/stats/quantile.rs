//! Quantile estimation.
//!
//! Two estimators: an exact store-and-sort sketch (used for figure
//! pipelines, where we keep every sojourn time anyway) and the P² streaming
//! estimator (Jain & Chlamtac 1985) for long stability scans where storing
//! tens of millions of samples is wasteful.

/// Quantile of an **ascending-sorted** slice with linear interpolation
/// (type-7, the numpy default).
pub fn quantile_of_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q out of range: {q}");
    let h = (sorted.len() as f64 - 1.0) * q;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = h - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Exact quantile sketch: stores all samples, sorts lazily.
#[derive(Clone, Debug, Default)]
pub struct QuantileSketch {
    data: Vec<f64>,
    sorted: bool,
}

impl QuantileSketch {
    /// Empty sketch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Sketch pre-sized for `n` samples.
    pub fn with_capacity(n: usize) -> Self {
        Self { data: Vec::with_capacity(n), sorted: false }
    }

    /// Add one sample.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.data.push(x);
        self.sorted = false;
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Merge another sketch's samples.
    pub fn merge(&mut self, other: &QuantileSketch) {
        self.data.extend_from_slice(&other.data);
        self.sorted = false;
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.data
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample in sketch"));
            self.sorted = true;
        }
    }

    /// Quantile `q` ∈ [0, 1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        self.ensure_sorted();
        quantile_of_sorted(&self.data, q)
    }

    /// Borrow the sorted samples (e.g. to build an ECDF without copying).
    pub fn sorted_data(&mut self) -> &[f64] {
        self.ensure_sorted();
        &self.data
    }
}

/// P² streaming quantile estimator (five markers, O(1) memory).
#[derive(Clone, Debug)]
pub struct P2Quantile {
    q: f64,
    heights: [f64; 5],
    positions: [f64; 5],
    desired: [f64; 5],
    increments: [f64; 5],
    count: usize,
    initial: Vec<f64>,
}

impl P2Quantile {
    /// Estimator for quantile `q` ∈ (0, 1).
    pub fn new(q: f64) -> Self {
        assert!(q > 0.0 && q < 1.0, "q must be in (0,1)");
        Self {
            q,
            heights: [0.0; 5],
            positions: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            increments: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            count: 0,
            initial: Vec::with_capacity(5),
        }
    }

    /// Observe one sample.
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if self.initial.len() < 5 {
            self.initial.push(x);
            if self.initial.len() == 5 {
                self.initial
                    .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
                self.heights.copy_from_slice(&self.initial);
            }
            return;
        }
        // Find cell k.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if self.heights[i] <= x && x < self.heights[i + 1] {
                    k = i;
                    break;
                }
            }
            k
        };
        for p in self.positions.iter_mut().skip(k + 1) {
            *p += 1.0;
        }
        for i in 0..5 {
            self.desired[i] += self.increments[i];
        }
        // Adjust interior markers with parabolic (fallback linear) moves.
        for i in 1..4 {
            let d = self.desired[i] - self.positions[i];
            let np = self.positions[i + 1] - self.positions[i];
            let pp = self.positions[i - 1] - self.positions[i];
            if (d >= 1.0 && np > 1.0) || (d <= -1.0 && pp < -1.0) {
                let s = d.signum();
                let parab = self.heights[i]
                    + s / (np - pp)
                        * ((self.positions[i] - self.positions[i - 1] + s)
                            * (self.heights[i + 1] - self.heights[i])
                            / np
                            + (self.positions[i + 1] - self.positions[i] - s)
                                * (self.heights[i] - self.heights[i - 1])
                                / -pp);
                self.heights[i] = if self.heights[i - 1] < parab && parab < self.heights[i + 1] {
                    parab
                } else {
                    // Linear fallback.
                    let j = (i as f64 + s) as usize;
                    self.heights[i]
                        + s * (self.heights[j] - self.heights[i])
                            / (self.positions[j] - self.positions[i])
                };
                self.positions[i] += s;
            }
        }
    }

    /// Current estimate (exact while ≤ 5 samples seen).
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.initial.len() < 5 {
            let mut v = self.initial.clone();
            v.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            return quantile_of_sorted(&v, self.q);
        }
        self.heights[2]
    }

    /// Number of samples observed.
    pub fn count(&self) -> usize {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn sorted_quantiles_interpolate() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_of_sorted(&v, 0.0), 1.0);
        assert_eq!(quantile_of_sorted(&v, 1.0), 4.0);
        assert!((quantile_of_sorted(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile_of_sorted(&v, 1.0 / 3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sketch_matches_direct() {
        let mut s = QuantileSketch::new();
        for i in (0..101).rev() {
            s.push(i as f64);
        }
        assert_eq!(s.quantile(0.5), 50.0);
        assert_eq!(s.quantile(0.99), 99.0);
        assert_eq!(s.len(), 101);
    }

    #[test]
    fn sketch_merge() {
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        for i in 0..50 {
            a.push(i as f64);
        }
        for i in 50..100 {
            b.push(i as f64);
        }
        a.merge(&b);
        assert!((a.quantile(0.5) - 49.5).abs() < 1e-12);
    }

    /// P² tracks the exponential 0.99 quantile within a few percent.
    #[test]
    fn p2_tracks_exponential_tail() {
        let mut p2 = P2Quantile::new(0.99);
        let mut rng = Pcg64::seed_from_u64(31);
        let n = 500_000;
        for _ in 0..n {
            p2.push(-rng.next_f64_open().ln());
        }
        let exact = -(0.01f64).ln(); // ≈ 4.605
        let est = p2.value();
        assert!(
            (est - exact).abs() / exact < 0.05,
            "P² estimate {est} vs exact {exact}"
        );
        assert_eq!(p2.count(), n);
    }

    #[test]
    fn p2_small_samples_exact() {
        let mut p2 = P2Quantile::new(0.5);
        for x in [3.0, 1.0, 2.0] {
            p2.push(x);
        }
        assert!((p2.value() - 2.0).abs() < 1e-12);
    }
}
