//! Streaming moment summary (Welford's algorithm).

/// Running count/mean/variance/min/max without storing samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Merge another summary (parallel-sweep reduction).
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    /// Sample standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Half-width of the 95% CI on the mean (CLT normal approximation).
    pub fn ci95_half_width(&self) -> f64 {
        if self.n < 2 {
            return f64::INFINITY;
        }
        1.96 * self.std_dev() / (self.n as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_direct_computation() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Unbiased variance of this set is 32/7.
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.37).sin() + 2.0).collect();
        let mut whole = Summary::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..313] {
            a.push(x);
        }
        for &x in &xs[313..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn empty_behaviour() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
        let mut a = Summary::new();
        a.merge(&s);
        assert_eq!(a.count(), 0);
    }
}
