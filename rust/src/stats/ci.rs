//! Confidence intervals for empirical quantiles (order-statistics /
//! binomial method, distribution-free). Used by the figure pipelines to
//! annotate simulated quantiles with their sampling uncertainty — the
//! caveat behind "sim exceeds bound by 2% at p99 with 30k samples".

use super::quantile_of_sorted;

/// Distribution-free CI for the q-quantile from **sorted** samples.
///
/// The number of samples ≤ the true q-quantile is Binomial(n, q); the
/// normal approximation gives index bounds `n q ± z √(n q (1−q))`, which
/// map to order statistics bracketing the quantile with confidence
/// `level` (two-sided).
pub fn quantile_ci(sorted: &[f64], q: f64, level: f64) -> (f64, f64, f64) {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    assert!((0.5..1.0).contains(&level), "level in [0.5, 1)");
    let n = sorted.len() as f64;
    let z = z_for(level);
    let center = n * q;
    let half = z * (n * q * (1.0 - q)).sqrt();
    let lo_idx = ((center - half).floor().max(0.0)) as usize;
    let hi_idx = ((center + half).ceil() as usize).min(sorted.len() - 1);
    (
        sorted[lo_idx],
        quantile_of_sorted(sorted, q),
        sorted[hi_idx],
    )
}

/// Two-sided z-score for common confidence levels (linear interpolation
/// on a small table is adequate for figure annotation).
fn z_for(level: f64) -> f64 {
    const TABLE: [(f64, f64); 6] = [
        (0.50, 0.674),
        (0.80, 1.282),
        (0.90, 1.645),
        (0.95, 1.960),
        (0.99, 2.576),
        (0.999, 3.291),
    ];
    if level <= TABLE[0].0 {
        return TABLE[0].1;
    }
    for w in TABLE.windows(2) {
        let (l0, z0) = w[0];
        let (l1, z1) = w[1];
        if level <= l1 {
            return z0 + (z1 - z0) * (level - l0) / (l1 - l0);
        }
    }
    TABLE[TABLE.len() - 1].1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn brackets_the_point_estimate() {
        let v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let (lo, mid, hi) = quantile_ci(&v, 0.99, 0.95);
        assert!(lo <= mid && mid <= hi);
        assert!(hi - lo < 20.0, "CI too wide: {lo}..{hi}");
    }

    /// Coverage check: the CI for the exponential p90 contains the true
    /// quantile in ≳ 90% of repeated experiments at level 0.95.
    #[test]
    fn coverage_on_exponential() {
        let true_q = -(0.1f64).ln(); // p90 of Exp(1)
        let mut rng = Pcg64::seed_from_u64(17);
        let trials = 300;
        let mut covered = 0;
        for _ in 0..trials {
            let mut v: Vec<f64> =
                (0..500).map(|_| -rng.next_f64_open().ln()).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let (lo, _, hi) = quantile_ci(&v, 0.9, 0.95);
            if lo <= true_q && true_q <= hi {
                covered += 1;
            }
        }
        let rate = covered as f64 / trials as f64;
        assert!(rate > 0.88, "coverage {rate}");
    }

    #[test]
    fn z_table_monotone() {
        assert!(z_for(0.5) < z_for(0.9));
        assert!(z_for(0.9) < z_for(0.99));
        assert!((z_for(0.95) - 1.96).abs() < 1e-9);
    }
}
