//! Box-plot statistics — for Fig. 9's overhead-fraction and
//! total-overhead-per-job box plots.

use super::quantile_of_sorted;

/// Five-number summary + mean + whiskers (Tukey 1.5×IQR convention).
#[derive(Clone, Copy, Debug)]
pub struct BoxStats {
    /// Number of samples.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Minimum observation.
    pub min: f64,
    /// Lower whisker (smallest sample ≥ Q1 − 1.5·IQR).
    pub whisker_lo: f64,
    /// First quartile.
    pub q1: f64,
    /// Median.
    pub median: f64,
    /// Third quartile.
    pub q3: f64,
    /// Upper whisker (largest sample ≤ Q3 + 1.5·IQR).
    pub whisker_hi: f64,
    /// Maximum observation.
    pub max: f64,
    /// Count of outliers beyond the whiskers.
    pub outliers: usize,
}

impl BoxStats {
    /// Compute from unsorted samples.
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "box stats of empty set");
        let mut v = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let q1 = quantile_of_sorted(&v, 0.25);
        let median = quantile_of_sorted(&v, 0.5);
        let q3 = quantile_of_sorted(&v, 0.75);
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let whisker_lo = *v.iter().find(|&&x| x >= lo_fence).unwrap_or(&v[0]);
        let whisker_hi = *v.iter().rev().find(|&&x| x <= hi_fence).unwrap_or(&v[v.len() - 1]);
        let outliers = v.iter().filter(|&&x| x < lo_fence || x > hi_fence).count();
        Self {
            n: v.len(),
            mean: v.iter().sum::<f64>() / v.len() as f64,
            min: v[0],
            whisker_lo,
            q1,
            median,
            q3,
            whisker_hi,
            max: v[v.len() - 1],
            outliers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quartiles_of_uniform_grid() {
        let v: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        let b = BoxStats::from_samples(&v);
        assert_eq!(b.median, 50.0);
        assert_eq!(b.q1, 25.0);
        assert_eq!(b.q3, 75.0);
        assert_eq!(b.outliers, 0);
        assert_eq!(b.whisker_lo, 0.0);
        assert_eq!(b.whisker_hi, 100.0);
    }

    #[test]
    fn detects_outliers() {
        let mut v: Vec<f64> = (0..100).map(|i| i as f64 * 0.01).collect();
        v.push(50.0); // far outlier
        let b = BoxStats::from_samples(&v);
        assert!(b.outliers >= 1);
        assert!(b.whisker_hi < 50.0);
        assert_eq!(b.max, 50.0);
    }

    #[test]
    fn ordering_invariant() {
        let v = vec![5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0];
        let b = BoxStats::from_samples(&v);
        assert!(b.min <= b.whisker_lo);
        assert!(b.whisker_lo <= b.q1);
        assert!(b.q1 <= b.median);
        assert!(b.median <= b.q3);
        assert!(b.q3 <= b.whisker_hi);
        assert!(b.whisker_hi <= b.max);
    }
}
