//! Empirical CDF.

use super::quantile_of_sorted;

/// Empirical cumulative distribution function over a sample set.
#[derive(Clone, Debug)]
pub struct Ecdf {
    sorted: Vec<f64>,
}

impl Ecdf {
    /// Build from samples (sorts internally; NaNs rejected).
    pub fn new(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "ECDF of empty sample set");
        assert!(samples.iter().all(|x| !x.is_nan()), "NaN sample");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { sorted: samples }
    }

    /// `F(x)` — fraction of samples ≤ x.
    pub fn eval(&self, x: f64) -> f64 {
        // partition_point gives count of elements <= x via binary search.
        let cnt = self.sorted.partition_point(|&v| v <= x);
        cnt as f64 / self.sorted.len() as f64
    }

    /// Generalized inverse `F^{-1}(q)` with interpolation.
    pub fn inverse(&self, q: f64) -> f64 {
        quantile_of_sorted(&self.sorted, q)
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// True when empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Sorted sample view.
    pub fn sorted(&self) -> &[f64] {
        &self.sorted
    }

    /// Two-sample Kolmogorov–Smirnov distance `sup |F(x) − G(x)|`.
    pub fn ks_distance(&self, other: &Ecdf) -> f64 {
        let mut max_dev = 0.0f64;
        for &x in &self.sorted {
            max_dev = max_dev.max((self.eval(x) - other.eval(x)).abs());
        }
        for &x in &other.sorted {
            max_dev = max_dev.max((self.eval(x) - other.eval(x)).abs());
        }
        max_dev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_and_inverse() {
        let e = Ecdf::new(vec![3.0, 1.0, 2.0, 4.0]);
        assert_eq!(e.eval(0.5), 0.0);
        assert_eq!(e.eval(1.0), 0.25);
        assert_eq!(e.eval(2.5), 0.5);
        assert_eq!(e.eval(4.0), 1.0);
        assert_eq!(e.eval(9.0), 1.0);
        assert!((e.inverse(0.0) - 1.0).abs() < 1e-12);
        assert!((e.inverse(1.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn ks_identical_zero() {
        let a = Ecdf::new(vec![1.0, 2.0, 3.0]);
        let b = Ecdf::new(vec![1.0, 2.0, 3.0]);
        assert_eq!(a.ks_distance(&b), 0.0);
    }

    #[test]
    fn ks_disjoint_one() {
        let a = Ecdf::new(vec![1.0, 2.0]);
        let b = Ecdf::new(vec![10.0, 20.0]);
        assert!((a.ks_distance(&b) - 1.0).abs() < 1e-12);
    }
}
