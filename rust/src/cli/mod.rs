//! Command-line interface: a small clap-style argv parser (subcommands,
//! `--key value` / `--key=value` flags, `--bool` switches) plus help-text
//! generation. The offline registry has no `clap`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand path, positional args, and flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand, e.g. `"figure"`.
    pub command: String,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` or `--key=value` pairs; bare `--switch` maps to "true".
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an argv iterator (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("stray --".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.flags.insert(name.to_string(), "true".to_string());
                }
            } else if args.command.is_empty() {
                args.command = tok;
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Flag as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Flag as string with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Flag as f64.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    /// Flag as usize.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    /// Flag as u64.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    /// Boolean switch (present, `=true`, or `=1`).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1"))
    }

    /// Comma-separated list flag, e.g. `--ks 50,100,200`.
    pub fn get_list_f64(&self, key: &str) -> Result<Option<Vec<f64>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse::<f64>().map_err(|e| format!("--{key}: {e}")))
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
tiny-tasks — reproduction of 'The Tiny-Tasks Granularity Trade-Off'

USAGE:
    tiny-tasks <COMMAND> [FLAGS]

COMMANDS:
    simulate    Run one DES simulation and print sojourn statistics
                  --model sm|fj|fjps|ideal  --servers L --k K
                  --lambda RATE --mu RATE  --jobs N --warmup N --seed S
                  --overhead [--c-task-ts S --mu-task-ts R --c-job-pd S --c-task-pd S]
                  scenario: --speeds 1.0,0.5,.. | --speed-dist SPEC [--speed-seed S]
                  --redundancy R   (r replicas per task, first-finish-wins)
                  [--replica-launch S]  (per-replica launch cost, seconds)
                  faults: --mtbf S --mttr S  (Markov worker crashes)
                  --task-fail-p P --max-retries N  (per-attempt failures,
                  bounded retries; --fault-backoff fixed|exp
                  --fault-backoff-base S)  --spec-timeout F  (speculative
                  backup after F x E[task], first-finish-wins)
                  [--fault-seed S]  (dedicated fault RNG stream)
                  policy: --policy fcfs|sita|priority|worksteal  (dispatch
                  discipline; fcfs/absent is bit-identical to the default)
                  --sita-boundaries 0.5,2.0  (ascending size-interval
                  boundaries; n boundaries -> n+1 server partitions)
                  --classes N [--class-weights 2,1]  (priority classes,
                  partition shares; per-class sojourns are reported)
                  --steal-threshold S  (work stealing: a queued task is
                  stealable S seconds after it becomes ready)
                  --streaming      (O(1)-memory P2 quantiles, for huge --jobs)
                  --threads N      (split the run into N replication shards
                  on N workers; merged Welford/P2 stats. Deterministic per
                  (seed, shard count); --shards M decouples the shard count
                  from the worker count -- thread count never changes results)
                  --metrics FILE   (write the RUN_METRICS.json obs report:
                  counters, phase wall-times, latency histograms, peak RSS;
                  consumes no RNG draws -- results stay bitwise identical)
                  --progress       (heartbeat on stderr: jobs done, jobs/s,
                  ETA, per-shard lag)
    profile     Run one configuration with the obs registry on and print
                the phase/counter/percentile tables (plus the event-loop
                span tree on the calendar engine)
                  --engine recursion|calendar + the simulate flag set
                  [--csv FILE]  (metric,value dump)  [--metrics FILE]
                  [--folded FILE]  (collapsed-stack span profile for
                  inferno / flamegraph.pl; calendar engine only)
                  --diff BASE.json NEW.json  (align two RUN_METRICS
                  reports: counters, phases, percentiles, spans, with
                  absolute + ratio deltas; no simulation is run)
                  [--gate name:max_ratio,...]  (with --diff: exit 1 when
                  NEW exceeds max_ratio x BASE on any named row, e.g.
                  --gate dispatch:1.25,span:event_loop:1.25)
    approx      Analytic approximation for skewed/redundant clusters,
                cross-validated against a simulation sweep (CSV per k)
                  --servers L --lambda RATE --workload SECONDS --epsilon E
                  --model sm|fj  [--k-list 10,20,..| --kappa-max F]
                  --speeds .. | --speed-dist ..  --redundancy R
                  [--replica-launch S] [--jobs N] [--out FILE.csv]
                  [--threads N]  (sweep pool size; default: all cores)
                  [--no-sim]  (pure analytics, microseconds)
                  [--metrics FILE]  (merged obs report across the sweep;
                  schema v2 adds one sweep_points row per k)
                  [--check [--floor F] [--tolerance F]]  (exit 1 unless
                  analytic/sim lands in [floor, tolerance] at every
                  stable k -- the CI smoke gate)
    bench       Run the deterministic perf suite and write BENCH.json
                  [--out FILE] [--fast] [--seed S] [--threads N]
                  [--baseline BENCH_BASELINE.json [--max-regression F]]
                  [--metrics FILE]  (bench-wide obs report)
                  jobs/sec + tasks/sec per model x k, both DES engines,
                  plus the sharded multicore headline row (headline-mt);
                  rows embed a phase-profile breakdown (schema v2);
                  with --baseline, exit 1 when a gated row regresses
    emulate     Run the sparklite cluster emulator
                  --executors L --k K --mode sm|fj --jobs N
                  --time-scale S --inject-overhead [--metrics FILE]
                  --speeds 1.0,0.5,.. | --speed-dist SPEC  (slowdown-only
                  executor pinning, factors in (0,1])
    trace       Persistent task traces (schema v1-v4, ndjson or binary;
                scenario runs record worker speeds, replicas and
                replica-winner flags as schema v2; fault-injected runs
                record attempt counters and failure causes as schema v3;
                policy runs record the dispatch policy and per-task
                routing classes as schema v4)
                  record    --source sim|emulator --out FILE [--format ndjson|bin]
                            + the simulate/emulate flag sets (--model, --k,
                            --speeds, --redundancy, --mtbf, --policy, ...)
                            [--metrics FILE]  (obs report incl. I/O phase)
                  replay    --in FILE [--model sm|fj|fjps|ideal] [--servers L]
                            [--overhead ...] [--in-order] [--seed S]
                  summarize --in FILE
                  convert   --in FILE --out FILE [--format ndjson|bin]
                  replay feeds recorded arrivals + task sizes through any
                  model; 'empirical:FILE' distribution specs sample task
                  sizes straight from a recorded trace
    bounds      Evaluate analytical bounds/approximations
                  --model sm|fj|ideal|sm-big --servers L --k K
                  --lambda RATE --mu RATE --epsilon E [--overhead]
                  [--engine rust|artifact]
    stability   Stability region scans (analytic + simulated)
                  --model sm|fj --servers L --k-list 50,100,...
    figure      Regenerate a paper figure's data as CSV
                  fig1-2|fig3|fig8|fig9|fig10|fig11|fig12a|fig12b|fig13|
                  hetero|hetero-approx|faults|policy|all
                  [--out DIR] [--scale quick|paper] [--threads N]
    calibrate   Fit the 4-parameter overhead model (Sec. 2.6)
                  [--jobs N] [--k K] [--executors L]   (live sparklite)
                  --from-trace FILE                    (recorded trace)
    advisor     Recommend tasks-per-job for a cluster configuration
                  --servers L --lambda RATE --workload SECONDS [--overhead]
                  with --speeds/--speed-dist/--redundancy the advice comes
                  from the approx analytic engine (microseconds); add
                  --simulate to fall back to simulation sweeps
                  ([--threads N] sizes the sweep pool); fault flags
                  (--mtbf, --task-fail-p, --spec-timeout, ...) and policy
                  flags (--policy sita|priority|worksteal, ...) always
                  advise from a simulation sweep
    selfcheck   Run artifact-vs-rust cross validation
    help        Show this help

Run 'tiny-tasks <COMMAND> --help' for details. Figure CSVs land in
reports/ by default; every command honours --seed for reproducibility.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        // NB: a bare switch followed by a non-flag token consumes it as a
        // value (same ambiguity clap resolves via declared arity); put
        // positionals first or use `--switch=true`.
        let a = parse(&[
            "simulate", "extra", "--servers", "50", "--k=200", "--overhead",
        ]);
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get_usize("servers", 0).unwrap(), 50);
        assert_eq!(a.get_usize("k", 0).unwrap(), 200);
        assert!(a.get_bool("overhead"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn defaults_and_types() {
        let a = parse(&["bounds"]);
        assert_eq!(a.get_f64("lambda", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_or("model", "fj"), "fj");
        assert!(!a.get_bool("overhead"));
        assert_eq!(a.get_list_f64("ks").unwrap(), None);
    }

    #[test]
    fn list_flag() {
        let a = parse(&["stability", "--k-list", "50, 100,200"]);
        assert_eq!(
            a.get_list_f64("k-list").unwrap().unwrap(),
            vec![50.0, 100.0, 200.0]
        );
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["simulate", "--servers", "fifty"]);
        assert!(a.get_usize("servers", 1).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "7"]);
        assert!(a.get_bool("a"));
        assert_eq!(a.get_u64("b", 0).unwrap(), 7);
    }
}
