//! Experiment configuration.
//!
//! A TOML-subset parser (`toml`) plus the typed experiment schema
//! (`schema`) used by the CLI and launcher. The offline registry has no
//! `toml`/`serde`, so parsing is hand-rolled; the supported subset covers
//! `[section]`, `key = value` with strings, numbers, booleans and
//! homogeneous arrays — everything our config files use.

mod schema;
mod toml;

pub use schema::{
    ArrivalConfig, BackoffKind, EmulatorConfig, ExperimentConfig, FaultsConfig, ModelKind,
    OverheadConfig, PolicyConfig, PolicyKind, RedundancyConfig, ServiceConfig,
    SimulationConfig, WorkersConfig,
};
pub use toml::{parse as parse_toml, TomlValue};
