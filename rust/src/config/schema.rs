//! Typed experiment configuration schema.

use super::toml::{parse, TomlValue};
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Which parallel-system model to run (Sec. 1.1 / Fig. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// Blocking fork-join: next job starts only after the current departs.
    SplitMerge,
    /// Single task FIFO feeding all servers (Spark with a multi-threaded
    /// driver); the tiny-tasks fork-join model of Th. 2.
    ForkJoinSingleQueue,
    /// Classic fork-join with per-server task queues (tasks bound to
    /// servers on arrival); tiny tasks make no difference here — kept as
    /// the k = l baseline of Fig. 3.
    ForkJoinPerServer,
    /// Ideal partition: each job split into exactly l equal tasks.
    Ideal,
}

impl ModelKind {
    /// Parse from config/CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "split-merge" | "sm" => Ok(Self::SplitMerge),
            "fork-join" | "fj" | "single-queue-fork-join" | "sqfj" => {
                Ok(Self::ForkJoinSingleQueue)
            }
            "fork-join-per-server" | "fjps" => Ok(Self::ForkJoinPerServer),
            "ideal" => Ok(Self::Ideal),
            _ => Err(format!("unknown model {s:?}")),
        }
    }
}

impl fmt::Display for ModelKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Self::SplitMerge => "split-merge",
            Self::ForkJoinSingleQueue => "single-queue-fork-join",
            Self::ForkJoinPerServer => "fork-join-per-server",
            Self::Ideal => "ideal",
        };
        f.write_str(s)
    }
}

/// Arrival process configuration.
#[derive(Clone, Debug)]
pub struct ArrivalConfig {
    /// Distribution spec for inter-arrival times, e.g. `"exp:0.5"`.
    pub interarrival: String,
}

impl Default for ArrivalConfig {
    fn default() -> Self {
        Self { interarrival: "exp:0.5".into() }
    }
}

/// Task service (execution) time configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Distribution spec for task execution times, e.g. `"exp:1.0"`.
    pub execution: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { execution: "exp:1.0".into() }
    }
}

/// The paper's four-parameter overhead model (Sec. 2.6).
///
/// Units are **seconds** (the paper's table is in ms; defaults below are
/// the paper's fitted values converted).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverheadConfig {
    /// Constant task-service overhead `c_task^ts` added to every task.
    pub c_task_ts: f64,
    /// Rate of the exponential task-service overhead component
    /// `mu_task^ts` (the outlier model); `Exp(mu)` mean is `1/mu`.
    pub mu_task_ts: f64,
    /// Constant per-job pre-departure overhead `c_job^pd`.
    pub c_job_pd: f64,
    /// Per-task pre-departure overhead rate `c_task^pd` (multiplied by k).
    pub c_task_pd: f64,
}

impl OverheadConfig {
    /// The paper's fitted Spark parameters (§2.6 table):
    /// c_ts = 2.6 ms, mu_ts = 2000 s⁻¹, c_pd_job = 20 ms,
    /// c_pd_task = 7.4e-3 ms.
    pub fn paper() -> Self {
        Self {
            c_task_ts: 2.6e-3,
            mu_task_ts: 2000.0,
            c_job_pd: 20e-3,
            c_task_pd: 7.4e-6,
        }
    }

    /// All-zero overhead (the idealized models).
    pub fn zero() -> Self {
        Self { c_task_ts: 0.0, mu_task_ts: f64::INFINITY, c_job_pd: 0.0, c_task_pd: 0.0 }
    }

    /// Mean task-service overhead `E[O_i] = c_ts + 1/mu_ts` (Eq. 24).
    pub fn mean_task_overhead(&self) -> f64 {
        self.c_task_ts + if self.mu_task_ts.is_finite() { 1.0 / self.mu_task_ts } else { 0.0 }
    }

    /// Pre-departure overhead for a k-task job (Eq. 3).
    pub fn pre_departure(&self, k: usize) -> f64 {
        self.c_job_pd + k as f64 * self.c_task_pd
    }

    /// True when every component is zero.
    pub fn is_zero(&self) -> bool {
        self.c_task_ts == 0.0
            && self.c_job_pd == 0.0
            && self.c_task_pd == 0.0
            && !self.mu_task_ts.is_finite()
    }
}

/// Heterogeneous-worker scenario: per-worker speed multipliers.
///
/// A worker with speed `s` serves a task of nominal size `e` in `e / s`
/// seconds. Speeds of all 1.0 reduce bit-for-bit to the homogeneous
/// model (enforced by `rust/tests/scenario_equivalence.rs`).
#[derive(Clone, Debug, PartialEq)]
pub enum WorkersConfig {
    /// Explicit per-worker speeds; length must equal `servers`.
    Speeds(Vec<f64>),
    /// Speeds drawn from a distribution spec (e.g. `"uniform:0.5:1.5"`),
    /// seeded independently of the workload stream so the cluster shape
    /// is fixed across sweep points and pool sizes.
    Distribution {
        /// Distribution spec for the speed draws.
        spec: String,
        /// Seed of the dedicated speed RNG stream.
        seed: u64,
    },
}

impl WorkersConfig {
    /// Resolve to one speed per worker (validates positivity).
    pub fn resolve(&self, servers: usize) -> Result<Vec<f64>, String> {
        let speeds = match self {
            Self::Speeds(s) => {
                if s.len() != servers {
                    return Err(format!(
                        "workers.speeds has {} entries for {} servers",
                        s.len(),
                        servers
                    ));
                }
                s.clone()
            }
            Self::Distribution { spec, seed } => {
                let dist = crate::dist::parse_spec(spec)?;
                let mut rng = crate::rng::Pcg64::seed_from_u64(*seed);
                (0..servers)
                    .map(|_| {
                        let mut f = || crate::rng::Rng::next_f64_open(&mut rng);
                        dist.sample(&mut f)
                    })
                    .collect()
            }
        };
        for &s in &speeds {
            if !(s > 0.0 && s.is_finite()) {
                return Err(format!("worker speeds must be positive and finite, got {s}"));
            }
        }
        Ok(speeds)
    }

    /// True when every resolved speed is exactly 1.0 (homogeneous).
    pub fn is_homogeneous(&self, servers: usize) -> bool {
        match self.resolve(servers) {
            Ok(speeds) => speeds.iter().all(|&s| s == 1.0),
            Err(_) => false,
        }
    }
}

/// Redundant-task scenario: run `replicas` copies of every task on
/// distinct workers; the first replica to finish wins and the others are
/// cancelled (first-finish-wins, as in the heterogeneous/redundant-jobs
/// extensions of the barrier-system literature).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RedundancyConfig {
    /// Copies per task, `>= 1`; `1` reduces to the base model.
    pub replicas: usize,
    /// Per-replica launch overhead in seconds — the replica-launch cost
    /// term extending the Sec.-2.6 four-parameter fit. Charged to every
    /// replica of a redundant dispatch (`replicas > 1`); ignored at
    /// `replicas = 1` so the degenerate scenario stays bit-exact.
    pub launch_overhead: f64,
}

impl RedundancyConfig {
    /// `replicas` copies per task with no launch cost.
    pub fn new(replicas: usize) -> Self {
        Self { replicas, launch_overhead: 0.0 }
    }
}

impl Default for RedundancyConfig {
    fn default() -> Self {
        Self { replicas: 1, launch_overhead: 0.0 }
    }
}

/// Retry backoff policy for failed task attempts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackoffKind {
    /// Every retry waits `backoff_base` seconds.
    Fixed,
    /// Retry n waits `backoff_base * 2^(n-1)` seconds.
    Exponential,
}

impl BackoffKind {
    /// Parse from config/CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "fixed" => Ok(Self::Fixed),
            "exp" | "exponential" => Ok(Self::Exponential),
            _ => Err(format!("unknown backoff kind {s:?} (fixed|exp)")),
        }
    }
}

impl fmt::Display for BackoffKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Self::Fixed => "fixed",
            Self::Exponential => "exp",
        })
    }
}

/// Fault-injection scenario (`[faults]` section): Markov on/off worker
/// failures, per-task failure probability with bounded backoff retries,
/// and speculative re-execution of straggling tasks.
///
/// Every mechanism defaults to *off* (`mtbf = 0`, `task_fail_p = 0`,
/// `spec_timeout = 0`); a config with all three off is bit-for-bit the
/// fault-free engine (enforced by `rust/tests/fault_injection.rs`). All
/// fault randomness draws from a dedicated RNG stream derived from
/// `seed` mixed with the simulation seed, so the workload stream is
/// never perturbed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Mean time between per-worker failures (exponential), seconds;
    /// `0` disables worker crashes.
    pub mtbf: f64,
    /// Mean time to repair a crashed worker (exponential), seconds.
    pub mttr: f64,
    /// Per-attempt task failure probability (failure surfaces at the
    /// attempt's completion); `0` disables.
    pub task_fail_p: f64,
    /// Maximum failed attempts per task; the attempt after the last
    /// allowed failure runs to completion (bounded retries keep every
    /// job departing, so sojourn statistics stay well-defined).
    pub max_retries: u32,
    /// Backoff policy between a failure and its retry.
    pub backoff: BackoffKind,
    /// Backoff base delay in seconds.
    pub backoff_base: f64,
    /// Speculative re-execution timeout as a *multiple of the expected
    /// task service time*; a task attempt whose service exceeds it
    /// launches a backup copy (first-finish-wins); `0` disables.
    pub spec_timeout: f64,
    /// Dedicated fault-stream seed, mixed with the simulation seed (so
    /// replication shards get distinct fault schedules automatically).
    pub seed: u64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        Self {
            mtbf: 0.0,
            mttr: 0.0,
            task_fail_p: 0.0,
            max_retries: 3,
            backoff: BackoffKind::Fixed,
            backoff_base: 0.0,
            spec_timeout: 0.0,
            seed: 0,
        }
    }
}

impl FaultsConfig {
    /// True when any fault mechanism is switched on. Inactive configs
    /// take the fault-free fast path (no injector is built at all).
    pub fn is_active(&self) -> bool {
        self.crashes_enabled() || self.failures_enabled() || self.speculation_enabled()
    }

    /// Worker crashes on (`mtbf > 0`).
    pub fn crashes_enabled(&self) -> bool {
        self.mtbf > 0.0
    }

    /// Per-task failures on (`task_fail_p > 0`).
    pub fn failures_enabled(&self) -> bool {
        self.task_fail_p > 0.0
    }

    /// Speculative re-execution on (`spec_timeout > 0`).
    pub fn speculation_enabled(&self) -> bool {
        self.spec_timeout > 0.0
    }

    /// Delay before retry number `retry` (1-based).
    pub fn backoff_delay(&self, retry: u32) -> f64 {
        match self.backoff {
            BackoffKind::Fixed => self.backoff_base,
            BackoffKind::Exponential => {
                self.backoff_base * f64::from(1u32 << (retry - 1).min(30))
            }
        }
    }
}

/// Which discipline routes tasks to servers (the scheduling-policy axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// First-come-first-served to the earliest-free server — the paper's
    /// dispatch rule and the bit-exact default.
    Fcfs,
    /// Size-interval task assignment: servers are partitioned into size
    /// groups and each task is routed by its drawn execution time.
    Sita,
    /// Multi-class priority: jobs cycle through `classes` classes, each
    /// class owning a dedicated server partition sized by `weights`.
    Priority,
    /// Round-robin server affinity with idle-server stealing when the
    /// affinity server's backlog exceeds the idlest server's by more
    /// than `steal_threshold` seconds.
    WorkSteal,
}

impl PolicyKind {
    /// Parse a CLI/TOML token.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "fcfs" => Ok(Self::Fcfs),
            "sita" => Ok(Self::Sita),
            "priority" => Ok(Self::Priority),
            "worksteal" | "work-steal" | "steal" => Ok(Self::WorkSteal),
            other => Err(format!(
                "unknown policy {other:?} (use fcfs | sita | priority | worksteal)"
            )),
        }
    }
}

impl fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Self::Fcfs => "fcfs",
            Self::Sita => "sita",
            Self::Priority => "priority",
            Self::WorkSteal => "worksteal",
        })
    }
}

/// Dispatch-policy configuration (`[policy]` section).
///
/// `policy = "fcfs"` (or an absent section) is bit-for-bit the seed
/// engines — no policy state is built at all, mirroring how all-off
/// `[faults]` sections degrade (enforced by
/// `rust/tests/policy_equivalence.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyConfig {
    /// Selected discipline.
    pub kind: PolicyKind,
    /// SITA size-interval boundaries (strictly ascending, > 0). `n`
    /// boundaries split the servers into `n + 1` size groups; an empty
    /// list is the single-interval degenerate case (≡ FCFS).
    pub sita_boundaries: Vec<f64>,
    /// Number of priority classes (jobs are classed round-robin by
    /// arrival index).
    pub classes: usize,
    /// Per-class server-partition weights; empty = equal shares. Must
    /// have `classes` entries otherwise.
    pub weights: Vec<f64>,
    /// Work-stealing trigger: steal when the affinity server's free
    /// time exceeds the idlest server's by more than this (seconds).
    pub steal_threshold: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            kind: PolicyKind::Fcfs,
            sita_boundaries: Vec::new(),
            classes: 2,
            weights: Vec::new(),
            steal_threshold: 0.0,
        }
    }
}

impl PolicyConfig {
    /// True when the configured discipline departs from FCFS. Inactive
    /// configs take the seed fast path (no policy state is built).
    pub fn is_active(&self) -> bool {
        self.kind != PolicyKind::Fcfs
    }

    /// Job classes that get their own sojourn summary (priority only;
    /// SITA classes are per-task, so job sojourns are classless).
    pub fn class_count(&self) -> usize {
        match self.kind {
            PolicyKind::Priority => self.classes,
            _ => 0,
        }
    }

    /// Number of server groups the cluster is partitioned into.
    pub fn group_count(&self) -> usize {
        match self.kind {
            PolicyKind::Sita => self.sita_boundaries.len() + 1,
            PolicyKind::Priority => self.classes,
            _ => 1,
        }
    }

    /// Partition weights per group (equal when unspecified).
    pub fn group_weights(&self) -> Vec<f64> {
        match self.kind {
            PolicyKind::Priority if !self.weights.is_empty() => self.weights.clone(),
            _ => vec![1.0; self.group_count()],
        }
    }

    /// Split `servers` into `group_count()` contiguous partitions
    /// proportional to the group weights, by largest remainder (ties to
    /// the lower index), with every group guaranteed at least one
    /// server. Deterministic; requires `servers >= group_count()`
    /// (enforced by `validate`).
    pub fn partition_sizes(&self, servers: usize) -> Vec<usize> {
        let w = self.group_weights();
        let total: f64 = w.iter().sum();
        let mut sizes: Vec<usize> = w
            .iter()
            .map(|x| (servers as f64 * x / total).floor() as usize)
            .collect();
        let assigned: usize = sizes.iter().sum();
        let mut frac: Vec<(usize, f64)> = w
            .iter()
            .enumerate()
            .map(|(i, x)| (i, servers as f64 * x / total - sizes[i] as f64))
            .collect();
        frac.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        for j in 0..servers.saturating_sub(assigned) {
            sizes[frac[j % frac.len()].0] += 1;
        }
        // Heavily skewed weights can starve a group; steal from the
        // largest (servers >= groups makes this always feasible).
        for i in 0..sizes.len() {
            if sizes[i] == 0 {
                let big = (0..sizes.len())
                    .max_by_key(|&j| sizes[j])
                    .expect("non-empty partition");
                sizes[big] -= 1;
                sizes[i] += 1;
            }
        }
        sizes
    }
}

/// One simulation run configuration.
#[derive(Clone, Debug)]
pub struct SimulationConfig {
    /// Which model (split-merge, single-queue fork-join, ...).
    pub model: ModelKind,
    /// Number of workers l.
    pub servers: usize,
    /// Tasks per job k (≥ l in the tiny-tasks regime; the ideal model
    /// ignores this and uses l equisized tasks).
    pub tasks_per_job: usize,
    /// Inter-arrival distribution.
    pub arrival: ArrivalConfig,
    /// Task execution-time distribution.
    pub service: ServiceConfig,
    /// Number of jobs to simulate (after warmup).
    pub jobs: usize,
    /// Jobs discarded as warmup transient.
    pub warmup: usize,
    /// RNG seed.
    pub seed: u64,
    /// Overhead model; `None` = idealized (no overhead).
    pub overhead: Option<OverheadConfig>,
    /// Heterogeneous worker speeds; `None` = homogeneous (all 1.0).
    pub workers: Option<WorkersConfig>,
    /// Task replication; `None` = no redundancy (r = 1).
    pub redundancy: Option<RedundancyConfig>,
    /// Fault injection; `None` (or an all-off section) = fault-free.
    pub faults: Option<FaultsConfig>,
    /// Dispatch policy; `None` (or `policy = "fcfs"`) = the seed FCFS
    /// earliest-free dispatch.
    pub policy: Option<PolicyConfig>,
}

impl Default for SimulationConfig {
    fn default() -> Self {
        Self {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 50,
            tasks_per_job: 50,
            arrival: ArrivalConfig::default(),
            service: ServiceConfig::default(),
            jobs: 30_000,
            warmup: 1_000,
            seed: 1,
            overhead: None,
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        }
    }
}

impl SimulationConfig {
    /// Validate parameter coherence.
    pub fn validate(&self) -> Result<(), String> {
        if self.servers == 0 {
            return Err("servers must be >= 1".into());
        }
        if self.tasks_per_job == 0 {
            return Err("tasks_per_job must be >= 1".into());
        }
        if self.model != ModelKind::Ideal && self.tasks_per_job < self.servers {
            return Err(format!(
                "tiny-tasks regime requires k >= l (got k={}, l={})",
                self.tasks_per_job, self.servers
            ));
        }
        if self.model == ModelKind::ForkJoinPerServer && self.tasks_per_job != self.servers {
            // Was an assert in the model constructor — CLI-reachable via
            // `simulate --model fjps --k 100 --servers 50`, so it must be
            // an error with context, not a panic.
            return Err(format!(
                "per-server fork-join binds one task per server and requires k = l \
                 (got k={}, l={})",
                self.tasks_per_job, self.servers
            ));
        }
        if self.jobs == 0 {
            return Err("jobs must be >= 1".into());
        }
        crate::dist::parse_spec(&self.arrival.interarrival).map_err(|e| e.to_string())?;
        crate::dist::parse_spec(&self.service.execution).map_err(|e| e.to_string())?;
        if let Some(w) = &self.workers {
            w.resolve(self.servers)?;
        }
        if let Some(r) = &self.redundancy {
            if r.replicas == 0 {
                return Err("redundancy.replicas must be >= 1".into());
            }
            if r.replicas > self.servers {
                return Err(format!(
                    "redundancy.replicas ({}) cannot exceed servers ({})",
                    r.replicas, self.servers
                ));
            }
            if !(r.launch_overhead >= 0.0 && r.launch_overhead.is_finite()) {
                return Err(format!(
                    "redundancy.launch_overhead must be finite and >= 0, got {}",
                    r.launch_overhead
                ));
            }
            if r.replicas == 1 && r.launch_overhead > 0.0 {
                return Err(
                    "redundancy.launch_overhead needs replicas >= 2 (it is charged \
                     per replica of a redundant dispatch)"
                        .into(),
                );
            }
            if r.replicas > 1 && self.model == ModelKind::Ideal {
                return Err(
                    "redundancy has no effect under ideal equisized partitioning; \
                     remove [redundancy] or pick sm/fj/fjps"
                        .into(),
                );
            }
        }
        if let Some(f) = &self.faults {
            if !(f.mtbf >= 0.0 && f.mtbf.is_finite()) {
                return Err(format!("faults.mtbf must be finite and >= 0, got {}", f.mtbf));
            }
            if f.mtbf > 0.0 && !(f.mttr > 0.0 && f.mttr.is_finite()) {
                return Err(format!(
                    "faults.mttr must be finite and > 0 when mtbf > 0, got {}",
                    f.mttr
                ));
            }
            if !(0.0..1.0).contains(&f.task_fail_p) {
                return Err(format!(
                    "faults.task_fail_p must be in [0, 1), got {}",
                    f.task_fail_p
                ));
            }
            if f.task_fail_p > 0.0 && f.max_retries == 0 {
                return Err(
                    "faults.task_fail_p needs max_retries >= 1 (a zero retry budget \
                     makes the failure draw a no-op)"
                        .into(),
                );
            }
            if !(f.backoff_base >= 0.0 && f.backoff_base.is_finite()) {
                return Err(format!(
                    "faults.backoff_base must be finite and >= 0, got {}",
                    f.backoff_base
                ));
            }
            if !(f.spec_timeout >= 0.0 && f.spec_timeout.is_finite()) {
                return Err(format!(
                    "faults.spec_timeout must be finite and >= 0, got {}",
                    f.spec_timeout
                ));
            }
            if f.is_active() && self.model == ModelKind::Ideal {
                return Err(
                    "fault injection needs per-worker dispatch; the ideal \
                     equisized-partition model has none — pick sm/fj/fjps"
                        .into(),
                );
            }
            if f.is_active()
                && self.model == ModelKind::ForkJoinPerServer
                && (self.workers.is_some() || self.replicas() > 1)
            {
                return Err(
                    "fault injection on the per-server fork-join model supports \
                     homogeneous workers only; drop [workers]/[redundancy] or \
                     use sm/fj"
                        .into(),
                );
            }
            if f.speculation_enabled() {
                if self.servers < 2 {
                    return Err("faults.spec_timeout needs at least 2 servers".into());
                }
                if self.model == ModelKind::ForkJoinPerServer {
                    return Err(
                        "speculative re-execution hedges across a shared queue; the \
                         per-server fork-join model binds tasks to servers — use sm/fj"
                            .into(),
                    );
                }
                if self.workers.is_some() || self.replicas() > 1 {
                    return Err(
                        "faults.spec_timeout composes with the homogeneous dispatcher \
                         (it is itself a dynamic replica); drop [workers]/[redundancy] \
                         or use redundancy.replicas instead"
                            .into(),
                    );
                }
            }
        }
        if let Some(p) = &self.policy {
            match p.kind {
                PolicyKind::Fcfs => {}
                PolicyKind::Sita => {
                    for w in p.sita_boundaries.windows(2) {
                        if !(w[0] < w[1]) {
                            return Err(format!(
                                "policy.sita_boundaries must be strictly ascending, \
                                 got {:?}",
                                p.sita_boundaries
                            ));
                        }
                    }
                    if p.sita_boundaries.iter().any(|b| !(b.is_finite() && *b > 0.0)) {
                        return Err(format!(
                            "policy.sita_boundaries must be finite and > 0, got {:?}",
                            p.sita_boundaries
                        ));
                    }
                }
                PolicyKind::Priority => {
                    if p.classes < 2 {
                        return Err("policy.classes must be >= 2 for priority".into());
                    }
                    if !p.weights.is_empty() {
                        if p.weights.len() != p.classes {
                            return Err(format!(
                                "policy.weights needs one entry per class \
                                 (got {} weights for {} classes)",
                                p.weights.len(),
                                p.classes
                            ));
                        }
                        if p.weights.iter().any(|w| !(w.is_finite() && *w > 0.0)) {
                            return Err(format!(
                                "policy.weights must be finite and > 0, got {:?}",
                                p.weights
                            ));
                        }
                    }
                }
                PolicyKind::WorkSteal => {
                    if !(p.steal_threshold >= 0.0 && p.steal_threshold.is_finite()) {
                        return Err(format!(
                            "policy.steal_threshold must be finite and >= 0, got {}",
                            p.steal_threshold
                        ));
                    }
                }
            }
            if p.is_active() {
                if self.model == ModelKind::Ideal {
                    return Err(
                        "dispatch policies need per-task dispatch; the ideal \
                         equisized-partition model has none — pick sm/fj"
                            .into(),
                    );
                }
                if self.model == ModelKind::ForkJoinPerServer {
                    return Err(
                        "the per-server fork-join model binds one task per server \
                         and leaves no dispatch decision for a policy — pick sm/fj"
                            .into(),
                    );
                }
                let groups = p.group_count();
                if groups > self.servers {
                    return Err(format!(
                        "policy partitions the cluster into {} server groups but \
                         only {} servers are configured",
                        groups, self.servers
                    ));
                }
                if self
                    .faults
                    .map(|f| f.speculation_enabled())
                    .unwrap_or(false)
                {
                    return Err(
                        "faults.spec_timeout assumes the shared FCFS queue; drop \
                         speculation or use policy = \"fcfs\""
                            .into(),
                    );
                }
                match p.kind {
                    PolicyKind::Sita | PolicyKind::WorkSteal => {
                        if self.workers.is_some() || self.replicas() > 1 {
                            return Err(format!(
                                "policy = \"{}\" dispatches single attempts on a \
                                 homogeneous cluster; drop [workers]/[redundancy] \
                                 or use priority/fcfs",
                                p.kind
                            ));
                        }
                    }
                    PolicyKind::Priority => {
                        let min_group = p
                            .partition_sizes(self.servers)
                            .into_iter()
                            .min()
                            .unwrap_or(0);
                        if self.replicas() > min_group {
                            return Err(format!(
                                "redundancy.replicas ({}) cannot exceed the smallest \
                                 priority server group ({} servers)",
                                self.replicas(),
                                min_group
                            ));
                        }
                    }
                    PolicyKind::Fcfs => unreachable!("inactive"),
                }
            }
        }
        Ok(())
    }

    /// Tinyfication factor κ = k / l.
    pub fn kappa(&self) -> f64 {
        self.tasks_per_job as f64 / self.servers as f64
    }

    /// Per-worker speeds resolved to a vector (all 1.0 when homogeneous).
    pub fn resolved_speeds(&self) -> Result<Vec<f64>, String> {
        match &self.workers {
            Some(w) => w.resolve(self.servers),
            None => Ok(vec![1.0; self.servers]),
        }
    }

    /// Replicas per task (1 when no redundancy is configured).
    pub fn replicas(&self) -> usize {
        self.redundancy.map(|r| r.replicas).unwrap_or(1)
    }

    /// Per-replica launch overhead (0 when no redundancy is configured).
    pub fn launch_overhead(&self) -> f64 {
        self.redundancy.map(|r| r.launch_overhead).unwrap_or(0.0)
    }
}

/// sparklite emulator configuration.
#[derive(Clone, Debug)]
pub struct EmulatorConfig {
    /// Number of executor threads (the paper's 50 dockerised executors).
    pub executors: usize,
    /// Tasks per job.
    pub tasks_per_job: usize,
    /// Submission mode (split-merge = single-threaded driver; single-queue
    /// fork-join = multi-threaded driver).
    pub mode: ModelKind,
    /// Inter-arrival spec (in *emulated* seconds).
    pub interarrival: String,
    /// Task execution-time spec (emulated seconds).
    pub execution: String,
    /// Wall-clock seconds per emulated second (e.g. 0.01 = 100× speedup).
    pub time_scale: f64,
    /// Jobs to run.
    pub jobs: usize,
    /// Warmup jobs discarded from statistics.
    pub warmup: usize,
    /// RNG seed.
    pub seed: u64,
    /// Inject the paper's measured Spark overhead components (Fig. 7
    /// scale) on top of sparklite's intrinsic overhead.
    pub inject_overhead: Option<OverheadConfig>,
    /// Heterogeneous executor speeds; `None` = homogeneous. Executors
    /// can only be *slowed* (factors in `(0, 1]`): an executor with
    /// speed `s` dilates each task's execution by `1/s` with extra busy
    /// work — pinning slow executors the way the DES scenario does, but
    /// in real threads (real payloads cannot be sped up).
    pub workers: Option<WorkersConfig>,
}

impl Default for EmulatorConfig {
    fn default() -> Self {
        Self {
            executors: 8,
            tasks_per_job: 64,
            mode: ModelKind::ForkJoinSingleQueue,
            interarrival: "exp:0.5".into(),
            execution: "exp:1.0".into(),
            time_scale: 0.01,
            jobs: 200,
            warmup: 20,
            seed: 1,
            inject_overhead: None,
            workers: None,
        }
    }
}

impl EmulatorConfig {
    /// Validate parameter coherence.
    pub fn validate(&self) -> Result<(), String> {
        if self.executors == 0 {
            return Err("executors must be >= 1".into());
        }
        if self.tasks_per_job == 0 {
            return Err("tasks_per_job must be >= 1".into());
        }
        if !(self.time_scale > 0.0 && self.time_scale.is_finite()) {
            return Err(format!("bad time_scale {}", self.time_scale));
        }
        if !matches!(self.mode, ModelKind::SplitMerge | ModelKind::ForkJoinSingleQueue) {
            return Err(format!("emulator supports sm/sqfj, not {}", self.mode));
        }
        crate::dist::parse_spec(&self.interarrival).map_err(|e| e.to_string())?;
        crate::dist::parse_spec(&self.execution).map_err(|e| e.to_string())?;
        for s in self.resolved_speeds()? {
            if s > 1.0 {
                return Err(format!(
                    "emulator worker speeds must be in (0, 1] (slowdown only), got {s}"
                ));
            }
        }
        Ok(())
    }

    /// Per-executor speed factors (all 1.0 when homogeneous).
    pub fn resolved_speeds(&self) -> Result<Vec<f64>, String> {
        match &self.workers {
            Some(w) => w.resolve(self.executors),
            None => Ok(vec![1.0; self.executors]),
        }
    }
}

/// A whole experiment file: named simulation + emulator sections.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    /// Experiment name (used for output paths).
    pub name: String,
    /// Simulation section, if present.
    pub simulation: Option<SimulationConfig>,
    /// Emulator section, if present.
    pub emulator: Option<EmulatorConfig>,
}

impl ExperimentConfig {
    /// Load from a TOML-subset file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self, String> {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
        Self::from_str(&text)
    }

    /// Parse from TOML-subset text.
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(text: &str) -> Result<Self, String> {
        let doc = parse(text)?;
        let name = doc
            .get("")
            .and_then(|s| s.get("name"))
            .and_then(|v| v.as_str())
            .unwrap_or("experiment")
            .to_string();
        let mut simulation = match doc.get("simulation") {
            Some(sec) => Some(sim_from_section(sec)?),
            None => None,
        };
        let workers = match doc.get("workers") {
            Some(sec) => Some(workers_from_section(sec)?),
            None => None,
        };
        let redundancy = match doc.get("redundancy") {
            Some(sec) => Some(redundancy_from_section(sec)?),
            None => None,
        };
        let faults = match doc.get("faults") {
            Some(sec) => Some(faults_from_section(sec)?),
            None => None,
        };
        let policy = match doc.get("policy") {
            Some(sec) => Some(policy_from_section(sec)?),
            None => None,
        };
        if workers.is_some() || redundancy.is_some() || faults.is_some() || policy.is_some() {
            let sim = simulation.as_mut().ok_or(
                "[workers]/[redundancy]/[faults]/[policy] require a [simulation] section",
            )?;
            sim.workers = workers;
            sim.redundancy = redundancy;
            sim.faults = faults;
            sim.policy = policy;
        }
        let emulator = match doc.get("emulator") {
            Some(sec) => Some(emu_from_section(sec)?),
            None => None,
        };
        let cfg = Self { name, simulation, emulator };
        if let Some(s) = &cfg.simulation {
            s.validate()?;
        }
        if let Some(e) = &cfg.emulator {
            e.validate()?;
        }
        Ok(cfg)
    }
}

type Section = BTreeMap<String, TomlValue>;

fn get_f64(sec: &Section, key: &str, default: f64) -> Result<f64, String> {
    match sec.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| format!("{key} must be a number")),
    }
}

fn get_usize(sec: &Section, key: &str, default: usize) -> Result<usize, String> {
    match sec.get(key) {
        None => Ok(default),
        Some(v) => v.as_usize().ok_or_else(|| format!("{key} must be a non-negative integer")),
    }
}

fn get_str(sec: &Section, key: &str, default: &str) -> Result<String, String> {
    match sec.get(key) {
        None => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| format!("{key} must be a string")),
    }
}

fn overhead_from(sec: &Section) -> Result<Option<OverheadConfig>, String> {
    let enabled = match sec.get("overhead") {
        Some(v) => v.as_bool().ok_or("overhead must be a bool")?,
        None => false,
    };
    if !enabled {
        return Ok(None);
    }
    let paper = OverheadConfig::paper();
    Ok(Some(OverheadConfig {
        c_task_ts: get_f64(sec, "c_task_ts", paper.c_task_ts)?,
        mu_task_ts: get_f64(sec, "mu_task_ts", paper.mu_task_ts)?,
        c_job_pd: get_f64(sec, "c_job_pd", paper.c_job_pd)?,
        c_task_pd: get_f64(sec, "c_task_pd", paper.c_task_pd)?,
    }))
}

fn workers_from_section(sec: &Section) -> Result<WorkersConfig, String> {
    let speeds = sec.get("speeds");
    let spec = sec.get("speed_dist");
    match (speeds, spec) {
        (Some(v), None) => {
            let speeds = v
                .as_f64_array()
                .ok_or("workers.speeds must be an array of numbers")?;
            if speeds.is_empty() {
                return Err("workers.speeds must not be empty".into());
            }
            Ok(WorkersConfig::Speeds(speeds))
        }
        (None, Some(v)) => {
            let spec = v
                .as_str()
                .ok_or("workers.speed_dist must be a string spec")?
                .to_string();
            crate::dist::parse_spec(&spec)?;
            Ok(WorkersConfig::Distribution {
                spec,
                seed: get_usize(sec, "speed_seed", 1)? as u64,
            })
        }
        (Some(_), Some(_)) => {
            Err("[workers]: give either speeds or speed_dist, not both".into())
        }
        (None, None) => Err("[workers] needs speeds = [..] or speed_dist = \"..\"".into()),
    }
}

fn redundancy_from_section(sec: &Section) -> Result<RedundancyConfig, String> {
    let replicas = get_usize(sec, "replicas", 1)?;
    if replicas == 0 {
        return Err("redundancy.replicas must be >= 1".into());
    }
    let launch_overhead = get_f64(sec, "launch_overhead", 0.0)?;
    Ok(RedundancyConfig { replicas, launch_overhead })
}

fn faults_from_section(sec: &Section) -> Result<FaultsConfig, String> {
    let d = FaultsConfig::default();
    Ok(FaultsConfig {
        mtbf: get_f64(sec, "mtbf", d.mtbf)?,
        mttr: get_f64(sec, "mttr", d.mttr)?,
        task_fail_p: get_f64(sec, "task_fail_p", d.task_fail_p)?,
        max_retries: get_usize(sec, "max_retries", d.max_retries as usize)? as u32,
        backoff: BackoffKind::parse(&get_str(sec, "backoff", "fixed")?)?,
        backoff_base: get_f64(sec, "backoff_base", d.backoff_base)?,
        spec_timeout: get_f64(sec, "spec_timeout", d.spec_timeout)?,
        seed: get_usize(sec, "seed", 0)? as u64,
    })
}

fn policy_from_section(sec: &Section) -> Result<PolicyConfig, String> {
    let d = PolicyConfig::default();
    Ok(PolicyConfig {
        kind: PolicyKind::parse(&get_str(sec, "policy", "fcfs")?)?,
        sita_boundaries: match sec.get("sita_boundaries") {
            None => Vec::new(),
            Some(v) => v
                .as_f64_array()
                .ok_or("policy.sita_boundaries must be an array of numbers")?,
        },
        classes: get_usize(sec, "classes", d.classes)?,
        weights: match sec.get("weights") {
            None => Vec::new(),
            Some(v) => v
                .as_f64_array()
                .ok_or("policy.weights must be an array of numbers")?,
        },
        steal_threshold: get_f64(sec, "steal_threshold", d.steal_threshold)?,
    })
}

fn sim_from_section(sec: &Section) -> Result<SimulationConfig, String> {
    let d = SimulationConfig::default();
    Ok(SimulationConfig {
        model: ModelKind::parse(&get_str(sec, "model", "fork-join")?)?,
        servers: get_usize(sec, "servers", d.servers)?,
        tasks_per_job: get_usize(sec, "tasks_per_job", d.tasks_per_job)?,
        arrival: ArrivalConfig { interarrival: get_str(sec, "interarrival", "exp:0.5")? },
        service: ServiceConfig { execution: get_str(sec, "execution", "exp:1.0")? },
        jobs: get_usize(sec, "jobs", d.jobs)?,
        warmup: get_usize(sec, "warmup", d.warmup)?,
        seed: get_usize(sec, "seed", 1)? as u64,
        overhead: overhead_from(sec)?,
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    })
}

fn emu_from_section(sec: &Section) -> Result<EmulatorConfig, String> {
    let d = EmulatorConfig::default();
    // Executor speeds piggy-back on the [workers] key set, inline in the
    // [emulator] section (slowdown-only; validated below).
    let workers = if sec.contains_key("speeds") || sec.contains_key("speed_dist") {
        Some(workers_from_section(sec)?)
    } else {
        None
    };
    Ok(EmulatorConfig {
        executors: get_usize(sec, "executors", d.executors)?,
        tasks_per_job: get_usize(sec, "tasks_per_job", d.tasks_per_job)?,
        mode: ModelKind::parse(&get_str(sec, "mode", "fork-join")?)?,
        interarrival: get_str(sec, "interarrival", &d.interarrival)?,
        execution: get_str(sec, "execution", &d.execution)?,
        time_scale: get_f64(sec, "time_scale", d.time_scale)?,
        jobs: get_usize(sec, "jobs", d.jobs)?,
        warmup: get_usize(sec, "warmup", d.warmup)?,
        seed: get_usize(sec, "seed", 1)? as u64,
        inject_overhead: overhead_from(sec)?,
        workers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_experiment() {
        let cfg = ExperimentConfig::from_str(
            r#"
name = "fig8-point"
[simulation]
model = "split-merge"
servers = 50
tasks_per_job = 200
interarrival = "exp:0.5"
execution = "exp:4.0"
jobs = 5000
warmup = 500
seed = 42
overhead = true
[emulator]
executors = 8
tasks_per_job = 64
mode = "fork-join"
time_scale = 0.005
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig8-point");
        let sim = cfg.simulation.unwrap();
        assert_eq!(sim.model, ModelKind::SplitMerge);
        assert_eq!(sim.servers, 50);
        assert_eq!(sim.tasks_per_job, 200);
        assert_eq!(sim.kappa(), 4.0);
        let oh = sim.overhead.unwrap();
        assert!((oh.c_task_ts - 2.6e-3).abs() < 1e-12);
        let emu = cfg.emulator.unwrap();
        assert_eq!(emu.executors, 8);
        assert_eq!(emu.time_scale, 0.005);
    }

    #[test]
    fn rejects_k_below_l() {
        let err = ExperimentConfig::from_str(
            "[simulation]\nservers = 50\ntasks_per_job = 10\n",
        )
        .unwrap_err();
        assert!(err.contains("k >= l"), "{err}");
    }

    #[test]
    fn model_kind_roundtrip() {
        for (s, m) in [
            ("split-merge", ModelKind::SplitMerge),
            ("sm", ModelKind::SplitMerge),
            ("fj", ModelKind::ForkJoinSingleQueue),
            ("sqfj", ModelKind::ForkJoinSingleQueue),
            ("fjps", ModelKind::ForkJoinPerServer),
            ("ideal", ModelKind::Ideal),
        ] {
            assert_eq!(ModelKind::parse(s).unwrap(), m);
        }
        assert!(ModelKind::parse("bogus").is_err());
    }

    #[test]
    fn parse_workers_and_redundancy_sections() {
        let cfg = ExperimentConfig::from_str(
            r#"
[simulation]
model = "fj"
servers = 4
tasks_per_job = 8
[workers]
speeds = [1.0, 1.0, 0.5, 2.0]
[redundancy]
replicas = 2
launch_overhead = 0.005
"#,
        )
        .unwrap();
        let sim = cfg.simulation.unwrap();
        assert_eq!(
            sim.workers,
            Some(WorkersConfig::Speeds(vec![1.0, 1.0, 0.5, 2.0]))
        );
        assert_eq!(sim.replicas(), 2);
        assert_eq!(sim.launch_overhead(), 0.005);
        assert_eq!(sim.resolved_speeds().unwrap(), vec![1.0, 1.0, 0.5, 2.0]);
        // Launch overhead defaults to zero and must be non-negative.
        let cfg = ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n[redundancy]\nreplicas = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.simulation.unwrap().launch_overhead(), 0.0);
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n\
             [redundancy]\nreplicas = 2\nlaunch_overhead = -1.0\n",
        )
        .is_err());
        // A launch cost without replication is meaningless (and would
        // strand the trace subsystem between schema versions).
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n\
             [redundancy]\nreplicas = 1\nlaunch_overhead = 0.01\n",
        )
        .is_err());
    }

    #[test]
    fn parse_workers_speed_distribution() {
        let cfg = ExperimentConfig::from_str(
            r#"
[simulation]
servers = 10
tasks_per_job = 20
[workers]
speed_dist = "uniform:0.5:1.5"
speed_seed = 7
"#,
        )
        .unwrap();
        let sim = cfg.simulation.unwrap();
        let speeds = sim.resolved_speeds().unwrap();
        assert_eq!(speeds.len(), 10);
        assert!(speeds.iter().all(|&s| (0.5..1.5).contains(&s)));
        // Resolution is deterministic in the speed seed.
        assert_eq!(speeds, sim.resolved_speeds().unwrap());
    }

    #[test]
    fn scenario_sections_are_validated() {
        // Wrong speeds arity.
        let err = ExperimentConfig::from_str(
            "[simulation]\nservers = 4\ntasks_per_job = 8\n[workers]\nspeeds = [1.0, 2.0]\n",
        )
        .unwrap_err();
        assert!(err.contains("4 servers"), "{err}");
        // Non-positive speed.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n[workers]\nspeeds = [1.0, 0.0]\n",
        )
        .is_err());
        // r > l.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n[redundancy]\nreplicas = 3\n",
        )
        .is_err());
        // Scenario sections without a simulation.
        assert!(ExperimentConfig::from_str("[redundancy]\nreplicas = 2\n").is_err());
        // Redundancy is rejected for the ideal model (it would silently
        // have no effect there).
        assert!(ExperimentConfig::from_str(
            "[simulation]\nmodel = \"ideal\"\nservers = 4\ntasks_per_job = 8\n\
             [redundancy]\nreplicas = 2\n",
        )
        .is_err());
    }

    #[test]
    fn emulator_speeds_parse_and_validate() {
        let cfg = ExperimentConfig::from_str(
            "[emulator]\nexecutors = 4\ntasks_per_job = 8\n\
             speeds = [1.0, 1.0, 0.5, 0.25]\n",
        )
        .unwrap();
        let emu = cfg.emulator.unwrap();
        assert_eq!(emu.resolved_speeds().unwrap(), vec![1.0, 1.0, 0.5, 0.25]);
        // Speedups are rejected: real payloads cannot run faster.
        let err = ExperimentConfig::from_str(
            "[emulator]\nexecutors = 2\ntasks_per_job = 4\nspeeds = [1.0, 1.5]\n",
        )
        .unwrap_err();
        assert!(err.contains("slowdown only"), "{err}");
        // Arity is checked against executors.
        assert!(ExperimentConfig::from_str(
            "[emulator]\nexecutors = 3\ntasks_per_job = 4\nspeeds = [1.0, 0.5]\n",
        )
        .is_err());
    }

    #[test]
    fn parse_faults_section() {
        let cfg = ExperimentConfig::from_str(
            r#"
[simulation]
model = "fj"
servers = 4
tasks_per_job = 16
[faults]
mtbf = 500.0
mttr = 25.0
task_fail_p = 0.05
max_retries = 4
backoff = "exp"
backoff_base = 0.5
seed = 9
"#,
        )
        .unwrap();
        let f = cfg.simulation.unwrap().faults.unwrap();
        assert!(f.is_active() && f.crashes_enabled() && f.failures_enabled());
        assert!(!f.speculation_enabled());
        assert_eq!(f.mtbf, 500.0);
        assert_eq!(f.mttr, 25.0);
        assert_eq!(f.max_retries, 4);
        assert_eq!(f.backoff, BackoffKind::Exponential);
        assert_eq!(f.backoff_delay(1), 0.5);
        assert_eq!(f.backoff_delay(3), 2.0);
        assert_eq!(f.seed, 9);
        // An all-off section parses but reports inactive.
        let cfg = ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n[faults]\n",
        )
        .unwrap();
        assert!(!cfg.simulation.unwrap().faults.unwrap().is_active());
    }

    #[test]
    fn faults_section_is_validated() {
        // Crashes need a repair time.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n[faults]\nmtbf = 100.0\n",
        )
        .is_err());
        // Failure probability outside [0, 1).
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n[faults]\ntask_fail_p = 1.5\n",
        )
        .is_err());
        // p > 0 with a zero retry budget is a silent no-op — rejected.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n\
             [faults]\ntask_fail_p = 0.1\nmax_retries = 0\n",
        )
        .is_err());
        // Faults need per-worker dispatch; ideal has none.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nmodel = \"ideal\"\nservers = 4\ntasks_per_job = 8\n\
             [faults]\ntask_fail_p = 0.1\n",
        )
        .is_err());
        // Speculation composes with the homogeneous dispatcher only.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 4\ntasks_per_job = 8\n\
             [faults]\nspec_timeout = 3.0\n[redundancy]\nreplicas = 2\n",
        )
        .is_err());
        // Faults without a [simulation] section.
        assert!(ExperimentConfig::from_str("[faults]\ntask_fail_p = 0.1\n").is_err());
        // fjps now rejects k != l at validation (was an assert).
        let err = ExperimentConfig::from_str(
            "[simulation]\nmodel = \"fjps\"\nservers = 4\ntasks_per_job = 8\n",
        )
        .unwrap_err();
        assert!(err.contains("k = l"), "{err}");
    }

    #[test]
    fn parse_policy_section() {
        let cfg = ExperimentConfig::from_str(
            r#"
[simulation]
model = "fj"
servers = 8
tasks_per_job = 16
[policy]
policy = "sita"
sita_boundaries = [0.5, 2.0]
"#,
        )
        .unwrap();
        let p = cfg.simulation.unwrap().policy.unwrap();
        assert_eq!(p.kind, PolicyKind::Sita);
        assert!(p.is_active());
        assert_eq!(p.group_count(), 3);
        assert_eq!(p.class_count(), 0);
        assert_eq!(p.sita_boundaries, vec![0.5, 2.0]);
        // Priority with explicit weights.
        let cfg = ExperimentConfig::from_str(
            "[simulation]\nservers = 6\ntasks_per_job = 12\n\
             [policy]\npolicy = \"priority\"\nclasses = 2\nweights = [2.0, 1.0]\n",
        )
        .unwrap();
        let p = cfg.simulation.unwrap().policy.unwrap();
        assert_eq!(p.class_count(), 2);
        assert_eq!(p.partition_sizes(6), vec![4, 2]);
        // An fcfs section parses but reports inactive.
        let cfg = ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n[policy]\npolicy = \"fcfs\"\n",
        )
        .unwrap();
        assert!(!cfg.simulation.unwrap().policy.unwrap().is_active());
        // Kind token round-trip.
        for (s, k) in [
            ("fcfs", PolicyKind::Fcfs),
            ("sita", PolicyKind::Sita),
            ("priority", PolicyKind::Priority),
            ("worksteal", PolicyKind::WorkSteal),
            ("work-steal", PolicyKind::WorkSteal),
        ] {
            assert_eq!(PolicyKind::parse(s).unwrap(), k);
        }
        assert!(PolicyKind::parse("lifo").is_err());
    }

    #[test]
    fn policy_section_is_validated() {
        // Boundaries must ascend.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 4\ntasks_per_job = 8\n\
             [policy]\npolicy = \"sita\"\nsita_boundaries = [2.0, 1.0]\n",
        )
        .is_err());
        // More groups than servers.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 2\ntasks_per_job = 4\n\
             [policy]\npolicy = \"sita\"\nsita_boundaries = [1.0, 2.0]\n",
        )
        .is_err());
        // Policies need per-task dispatch; ideal has none.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nmodel = \"ideal\"\nservers = 4\ntasks_per_job = 8\n\
             [policy]\npolicy = \"worksteal\"\n",
        )
        .is_err());
        // SITA routes by size on a homogeneous cluster only.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 4\ntasks_per_job = 8\n\
             [policy]\npolicy = \"sita\"\n[redundancy]\nreplicas = 2\n",
        )
        .is_err());
        // Priority + redundancy: replicas bounded by the smallest group.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 4\ntasks_per_job = 8\n\
             [policy]\npolicy = \"priority\"\nclasses = 2\n\
             [redundancy]\nreplicas = 3\n",
        )
        .is_err());
        // Speculation assumes the shared FCFS queue.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 4\ntasks_per_job = 8\n\
             [policy]\npolicy = \"worksteal\"\n[faults]\nspec_timeout = 3.0\n",
        )
        .is_err());
        // Weight arity must match the class count.
        assert!(ExperimentConfig::from_str(
            "[simulation]\nservers = 4\ntasks_per_job = 8\n\
             [policy]\npolicy = \"priority\"\nclasses = 3\nweights = [1.0, 2.0]\n",
        )
        .is_err());
        // Policy without a [simulation] section.
        assert!(ExperimentConfig::from_str("[policy]\npolicy = \"sita\"\n").is_err());
    }

    #[test]
    fn partition_sizes_are_deterministic_and_exhaustive() {
        let p = PolicyConfig {
            kind: PolicyKind::Priority,
            classes: 3,
            weights: vec![5.0, 3.0, 1.0],
            ..PolicyConfig::default()
        };
        for servers in 3..40 {
            let sizes = p.partition_sizes(servers);
            assert_eq!(sizes.len(), 3);
            assert_eq!(sizes.iter().sum::<usize>(), servers);
            assert!(sizes.iter().all(|&s| s >= 1), "{sizes:?}");
            assert_eq!(sizes, p.partition_sizes(servers));
        }
        // Equal weights split near-evenly.
        let p = PolicyConfig {
            kind: PolicyKind::Sita,
            sita_boundaries: vec![1.0],
            ..PolicyConfig::default()
        };
        assert_eq!(p.partition_sizes(5), vec![3, 2]);
    }

    #[test]
    fn homogeneity_detection() {
        let w = WorkersConfig::Speeds(vec![1.0, 1.0, 1.0]);
        assert!(w.is_homogeneous(3));
        let w = WorkersConfig::Speeds(vec![1.0, 2.0, 1.0]);
        assert!(!w.is_homogeneous(3));
    }

    #[test]
    fn overhead_helpers() {
        let oh = OverheadConfig::paper();
        assert!((oh.mean_task_overhead() - (2.6e-3 + 5e-4)).abs() < 1e-12);
        assert!((oh.pre_departure(1000) - (20e-3 + 7.4e-3)).abs() < 1e-9);
        assert!(OverheadConfig::zero().is_zero());
        assert!(!oh.is_zero());
    }
}
