//! Minimal TOML-subset parser.
//!
//! Supported: `[section]` headers (one level), `key = value`, values of
//! type string (`"..."`), float/int, bool, and flat arrays `[a, b, c]`.
//! Comments (`# ...`) and blank lines are ignored. This deliberately
//! covers exactly what `configs/*.toml` use.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Any numeric literal (ints are widened).
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Flat homogeneous-ish array.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// As f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(x) => Some(*x),
            _ => None,
        }
    }
    /// As usize, if numeric and integral.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }
    /// As str, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    /// As bool, if boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// As vec of f64, if an array of numbers.
    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Array(v) => v.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }
}

/// `section -> key -> value`; top-level keys live under section `""`.
pub type Document = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document, String> {
    let mut doc: Document = BTreeMap::new();
    doc.insert(String::new(), BTreeMap::new());
    let mut current = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {}: malformed section {raw:?}", lineno + 1));
            }
            current = line[1..line.len() - 1].trim().to_string();
            doc.entry(current.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value, got {raw:?}", lineno + 1))?;
        let v = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&current)
            .unwrap()
            .insert(key.trim().to_string(), v);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(stripped) = s.strip_prefix('[') {
        let inner = stripped
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array {s:?}"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue; // trailing comma
                }
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    s.parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
# experiment config
name = "fig8"          # inline comment
[simulation]
servers = 50
lambda = 0.5
ks = [50, 100, 200]
overhead = true
label = "a # not-comment"
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"].as_str(), Some("fig8"));
        assert_eq!(doc["simulation"]["servers"].as_usize(), Some(50));
        assert_eq!(doc["simulation"]["lambda"].as_f64(), Some(0.5));
        assert_eq!(
            doc["simulation"]["ks"].as_f64_array(),
            Some(vec![50.0, 100.0, 200.0])
        );
        assert_eq!(doc["simulation"]["overhead"].as_bool(), Some(true));
        assert_eq!(doc["simulation"]["label"].as_str(), Some("a # not-comment"));
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse("[oops").unwrap_err().contains("line 1"));
        assert!(parse("x 5").unwrap_err().contains("key = value"));
        assert!(parse("x = ").unwrap_err().contains("empty value"));
        assert!(parse("x = \"abc").unwrap_err().contains("unterminated"));
    }

    #[test]
    fn empty_array_and_trailing_comma() {
        let doc = parse("a = []\nb = [1, 2,]\n").unwrap();
        assert_eq!(doc[""]["a"], TomlValue::Array(vec![]));
        assert_eq!(doc[""]["b"].as_f64_array(), Some(vec![1.0, 2.0]));
    }
}
