//! The approximation engine: k-sweep curves at constant mean workload —
//! the composition layer the advisor, the `tiny-tasks approx` CLI, and
//! the `figure hetero-approx` panel share.
//!
//! Each point sizes tasks so the mean job workload `k · E[exec]` stays
//! at `mean_workload` (`mu = k / mean_workload`), mirroring the Fig.-8
//! sweep parameterization and the simulated advisor, so analytic and
//! simulated curves are directly comparable point by point.

use super::{sojourn_quantile, ApproxModel, ApproxParams, ClusterSpec};
use crate::config::OverheadConfig;

/// One point of an analytic k-curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    /// Tasks per job.
    pub k: usize,
    /// Nominal task rate at this point (`k / mean_workload`).
    pub mu: f64,
    /// Sojourn ε-quantile approximation (`None` = unstable).
    pub sojourn: Option<f64>,
}

/// Evaluate the sojourn approximation over a k grid at constant mean
/// workload.
pub fn sojourn_curve(
    model: ApproxModel,
    spec: &ClusterSpec,
    lambda: f64,
    mean_workload: f64,
    epsilon: f64,
    overhead: Option<OverheadConfig>,
    ks: &[usize],
) -> Vec<CurvePoint> {
    assert!(mean_workload > 0.0 && mean_workload.is_finite());
    ks.iter()
        .map(|&k| {
            let mu = k as f64 / mean_workload;
            let p = ApproxParams { k, lambda, mu, epsilon, overhead };
            CurvePoint { k, mu, sojourn: sojourn_quantile(model, spec, &p) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// With paper overhead and skew, the curve still shows the paper's
    /// thesis: an interior optimum (tinyfication helps, overhead caps it).
    #[test]
    fn skewed_curve_has_interior_optimum() {
        let l = 10usize;
        let mut speeds = vec![1.5; l / 2];
        speeds.extend(vec![0.5; l / 2]);
        let spec = ClusterSpec::new(speeds, 1, 0.0).unwrap();
        let ks: Vec<usize> = (0..14).map(|i| l << i).collect(); // l .. l·2^13
        let curve = sojourn_curve(
            ApproxModel::ForkJoin,
            &spec,
            0.4,
            l as f64,
            0.01,
            Some(OverheadConfig::paper()),
            &ks,
        );
        assert_eq!(curve.len(), ks.len());
        let feasible: Vec<(usize, f64)> =
            curve.iter().filter_map(|c| c.sojourn.map(|t| (c.k, t))).collect();
        assert!(feasible.len() >= 5, "curve mostly infeasible: {curve:?}");
        let mut best = (0usize, f64::INFINITY);
        for &(k, t) in &feasible {
            if t < best.1 {
                best = (k, t);
            }
        }
        let (best_k, best_tau) = best;
        assert!(best_k > l, "tinyfication should help: best k = {best_k}");
        // The tail rises (or goes infeasible) past the optimum.
        let last_feasible = feasible.last().unwrap();
        let tail_rises = last_feasible.1 > best_tau || curve.last().unwrap().sojourn.is_none();
        assert!(tail_rises, "overhead should cap tinyfication: {curve:?}");
    }

    /// The degenerate curve equals the homogeneous analysis curve
    /// bitwise at every k (the advisor's delegation guarantee).
    #[test]
    fn degenerate_curve_matches_analysis_bitwise() {
        use crate::analysis::{self, BoundModel, BoundParams};
        let l = 20usize;
        let spec = ClusterSpec::homogeneous(l);
        let ks = [20usize, 60, 200, 1000];
        let oh = OverheadConfig::paper();
        let curve = sojourn_curve(
            ApproxModel::ForkJoin,
            &spec,
            0.5,
            l as f64,
            0.01,
            Some(oh),
            &ks,
        );
        for c in &curve {
            let direct = analysis::sojourn_bound(
                BoundModel::ForkJoinTiny,
                &BoundParams {
                    l,
                    k: c.k,
                    lambda: 0.5,
                    mu: c.mu,
                    epsilon: 0.01,
                    overhead: Some(oh),
                },
            );
            assert_eq!(c.sojourn.map(f64::to_bits), direct.map(f64::to_bits), "k={}", c.k);
        }
    }
}
