//! Tiny-tasks stability regions for skewed & redundant clusters — the
//! Eq.-20/Sec.-3.2.2 analogs over the effective cluster.
//!
//! Utilization is normalized by the **raw aggregate capacity**
//! `μ · Σ_j s_j`, so a number below 1 can reflect either the split-merge
//! barrier (as in the homogeneous Eq. 20) or capacity stranded by
//! replica grouping (leftover workers at `l mod r ≠ 0`).
//!
//! Degenerate scenarios (all speeds 1.0, r = 1) delegate to
//! [`crate::analysis::stability`] so the results are bit-for-bit equal
//! to the homogeneous closed forms.

use super::{ClusterSpec, EffectiveCluster};
use crate::analysis;

/// Tiny-tasks split-merge maximum stable utilization for a scenario —
/// the heterogeneous/redundant generalization of Eq. 20.
///
/// Stability requires `λ · E[Δ] < 1` with the effective-cluster mean
/// service envelope `E[Δ] = (k−L)/R_L + Σ_i 1/R_i`; dividing the
/// offered per-job load `k/(μ Σ s_j)` by `μ·E[Δ]` (μ cancels) gives the
/// maximum utilization.
pub fn sm_max_utilization(spec: &ClusterSpec, k: usize) -> f64 {
    assert!(k >= spec.len(), "tiny tasks require k >= l");
    if spec.is_degenerate() {
        return analysis::stability::sm_tiny_tasks(spec.len(), k);
    }
    let cluster = EffectiveCluster::from_spec(spec, 1.0).expect("validated spec");
    let e_delta = cluster.mean_service(k); // at μ = 1: μ·E[Δ] for any μ
    (k as f64 / spec.total_speed()) / e_delta
}

/// Fork-join (work-conserving) maximum stable utilization for a
/// scenario. Under first-finish-wins replication of exponential tasks
/// the group completes at the summed rate — redundancy is *free* in
/// throughput — so the region only shrinks by the capacity stranded in
/// leftover workers when `r` does not divide `l`.
pub fn fork_join_max_utilization(spec: &ClusterSpec) -> f64 {
    if spec.is_degenerate() {
        return analysis::stability::fork_join();
    }
    let cluster = EffectiveCluster::from_spec(spec, 1.0).expect("validated spec");
    cluster.total_rate() / spec.total_speed()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Degenerate scenario is bitwise the homogeneous Eq. 20 / Sec. 3.2.2.
    #[test]
    fn degenerate_is_bitwise_homogeneous() {
        for (l, k) in [(2usize, 4usize), (10, 50), (50, 1000)] {
            let spec = ClusterSpec::homogeneous(l);
            assert_eq!(
                sm_max_utilization(&spec, k).to_bits(),
                analysis::stability::sm_tiny_tasks(l, k).to_bits()
            );
            assert_eq!(
                fork_join_max_utilization(&spec).to_bits(),
                analysis::stability::fork_join().to_bits()
            );
        }
    }

    /// Uniform non-unit speeds leave the (speed-normalized) region at the
    /// homogeneous value: μ scaling cancels.
    #[test]
    fn uniform_speed_scaling_cancels() {
        let (l, k) = (10usize, 80usize);
        let spec = ClusterSpec::new(vec![2.5; l], 1, 0.0).unwrap();
        let got = sm_max_utilization(&spec, k);
        let expect = analysis::stability::sm_tiny_tasks(l, k);
        assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
    }

    /// Skew shrinks the split-merge region at fixed aggregate capacity
    /// (the slow workers stretch the drain phase).
    #[test]
    fn skew_shrinks_sm_region() {
        let (l, k) = (10usize, 80usize);
        let homogeneous = sm_max_utilization(&ClusterSpec::homogeneous(l), k);
        let mut speeds = vec![1.5; l / 2];
        speeds.extend(vec![0.5; l / 2]);
        let skewed = sm_max_utilization(&ClusterSpec::new(speeds, 1, 0.0).unwrap(), k);
        assert!(skewed < homogeneous, "{skewed} !< {homogeneous}");
        assert!(skewed > 0.0);
    }

    /// Tinyfication grows the region under skew too (the Fig.-12a effect
    /// survives heterogeneity).
    #[test]
    fn tinyfication_grows_skewed_region() {
        let l = 10usize;
        let mut speeds = vec![1.5; l / 2];
        speeds.extend(vec![0.5; l / 2]);
        let spec = ClusterSpec::new(speeds, 1, 0.0).unwrap();
        let r1 = sm_max_utilization(&spec, l);
        let r4 = sm_max_utilization(&spec, 4 * l);
        let r20 = sm_max_utilization(&spec, 20 * l);
        assert!(r1 < r4 && r4 < r20, "{r1} {r4} {r20}");
    }

    /// Redundancy with r | l keeps fork-join at full capacity (free for
    /// exponential tasks); a leftover worker strands its share.
    #[test]
    fn redundancy_throughput_accounting() {
        let spec = ClusterSpec::new(vec![1.0; 8], 2, 0.0).unwrap();
        assert!((fork_join_max_utilization(&spec) - 1.0).abs() < 1e-12);
        let spec = ClusterSpec::new(vec![1.0; 9], 2, 0.0).unwrap();
        let got = fork_join_max_utilization(&spec);
        assert!((got - 8.0 / 9.0).abs() < 1e-12, "{got}");
    }

    /// Redundancy *helps* the split-merge drain (min beats max on the
    /// stragglers) when r divides l.
    #[test]
    fn redundancy_helps_sm_drain() {
        let (l, k) = (8usize, 64usize);
        let r1 = sm_max_utilization(&ClusterSpec::new(vec![1.0; l], 1, 0.0).unwrap(), k);
        let r2 = sm_max_utilization(&ClusterSpec::new(vec![1.0; l], 2, 0.0).unwrap(), k);
        assert!(r2 > r1, "redundant drain should beat homogeneous: {r2} !> {r1}");
    }
}
