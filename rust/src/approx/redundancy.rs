//! Redundancy model: first-finish-wins replica groups and the
//! replica-aware overhead extension of the Sec.-2.6 fit.
//!
//! `r` replicas of one task on workers with rates `r_j` finish at
//! `min_j Exp(r_j) = Exp(Σ_j r_j)` — *exactly*, by competing
//! exponentials. An r-replicated cluster therefore maps onto `⌊l/r⌋`
//! effective super-servers whose rate is the group's summed rate. The
//! simulator picks the r earliest-free workers dynamically; the static
//! grouping here (snake-dealt so fastest pair with slowest, leftovers
//! dropped) is a conservative approximation of that work-conserving
//! placement.
//!
//! Overhead under replication: every replica pays its own task-service
//! overhead draw plus a per-replica launch cost, so a logical task burns
//! `r·(E[O] + c_launch)` of server time while only the winner's
//! `E[O] + c_launch` sits on the job's critical path. Overhead is wall
//! time on a worker, so it dilates with `1/speed`; the cluster-mean
//! inverse speed folds that in.

use crate::config::OverheadConfig;

/// Map per-worker speeds at nominal rate `mu` onto effective per-slot
/// service rates, folding `replicas`-sized first-finish-wins groups into
/// single super-server rates.
pub fn effective_rates(speeds: &[f64], mu: f64, replicas: usize) -> Result<Vec<f64>, String> {
    if speeds.is_empty() {
        return Err("effective_rates needs at least one worker".into());
    }
    if !(mu > 0.0 && mu.is_finite()) {
        return Err(format!("nominal rate mu must be positive, got {mu}"));
    }
    if !(1..=speeds.len()).contains(&replicas) {
        return Err(format!(
            "replicas ({replicas}) must be in 1..=workers ({})",
            speeds.len()
        ));
    }
    for &s in speeds {
        if !(s > 0.0 && s.is_finite()) {
            return Err(format!("worker speeds must be positive and finite, got {s}"));
        }
    }
    if replicas == 1 {
        return Ok(speeds.iter().map(|&s| mu * s).collect());
    }
    // Deal the r·⌊l/r⌋ fastest workers into ⌊l/r⌋ groups of r in snake
    // (boustrophedon) order — descending speeds, direction alternating
    // each row — which pairs fastest with slowest and maximizes the
    // smallest group rate (every downstream envelope tightens with it).
    // Leftover workers (l mod r) are dropped — conservative.
    let groups = speeds.len() / replicas;
    let mut sorted = speeds.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut sums = vec![0.0f64; groups];
    for (i, &s) in sorted.iter().take(groups * replicas).enumerate() {
        let (row, col) = (i / groups, i % groups);
        let g = if row % 2 == 0 { col } else { groups - 1 - col };
        sums[g] += s;
    }
    Ok(sums.into_iter().map(|g| mu * g).collect())
}

/// Replica-aware effective overhead (the Sec.-2.6 extension): mean
/// overhead on the winner's critical path and the total overhead burn
/// per logical task across all replicas.
#[derive(Clone, Copy, Debug)]
pub struct EffectiveOverhead {
    /// Mean overhead on the winning replica's critical path (seconds).
    pub critical: f64,
    /// Mean server time burned on overhead per logical task across all
    /// `r` replicas (the capacity-side term entering `ρ_Z°`).
    pub capacity: f64,
}

/// Compute the replica-aware overhead terms for a cluster.
///
/// `launch` is the per-replica launch cost (seconds) charged to every
/// replica of a redundant dispatch; at `replicas = 1` it is ignored and
/// both terms equal the plain Eq.-24 mean scaled by the mean inverse
/// speed (overhead is wall time on a worker and dilates with `1/s`).
pub fn effective_overhead(
    oh: &OverheadConfig,
    speeds: &[f64],
    replicas: usize,
    launch: f64,
) -> EffectiveOverhead {
    debug_assert!(!speeds.is_empty());
    let inv = speeds.iter().map(|&s| 1.0 / s).sum::<f64>() / speeds.len() as f64;
    let base = oh.mean_task_overhead() * inv;
    if replicas == 1 {
        return EffectiveOverhead { critical: base, capacity: base };
    }
    let per_replica = base + launch * inv;
    EffectiveOverhead {
        critical: per_replica,
        capacity: replicas as f64 * per_replica,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r1_is_identity_scaling() {
        let rates = effective_rates(&[1.0, 0.5, 2.0], 3.0, 1).unwrap();
        assert_eq!(rates, vec![3.0, 1.5, 6.0]);
    }

    #[test]
    fn grouping_balances_and_preserves_rate() {
        // l = 4, r = 2: sorted desc [2.0, 1.5, 1.0, 0.5]; snake dealing
        // pairs {2.0, 0.5} and {1.5, 1.0}: both groups sum to 2.5.
        let rates = effective_rates(&[1.5, 0.5, 2.0, 1.0], 1.0, 2).unwrap();
        assert_eq!(rates, vec![2.5, 2.5]);
        // Total rate is preserved when r divides l (redundancy is free in
        // throughput for exponential tasks).
        assert!((rates.iter().sum::<f64>() - 5.0).abs() < 1e-12);
        // r = 3, l = 6: rows [3, 2.5, 2] then reversed [1.5, 1, 0.5]
        // snake to groups {3, 0.5, 1} and {2.5, 1, ..}: check the min
        // group rate beats naive row-major dealing.
        let rates =
            effective_rates(&[3.0, 2.5, 2.0, 1.5, 1.0, 0.5], 1.0, 3).unwrap();
        assert_eq!(rates.len(), 2);
        assert!((rates.iter().sum::<f64>() - 10.5).abs() < 1e-12);
        assert!(rates[0] >= 5.0, "snake dealing should balance: {rates:?}");
    }

    #[test]
    fn leftover_workers_dropped() {
        // l = 5, r = 2: ⌊5/2⌋ = 2 groups over the 4 fastest; the slowest
        // worker (0.1) is dropped.
        let rates = effective_rates(&[1.0, 1.0, 1.0, 1.0, 0.1], 1.0, 2).unwrap();
        assert_eq!(rates.len(), 2);
        assert!((rates.iter().sum::<f64>() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(effective_rates(&[], 1.0, 1).is_err());
        assert!(effective_rates(&[1.0], 0.0, 1).is_err());
        assert!(effective_rates(&[1.0, -1.0], 1.0, 1).is_err());
        assert!(effective_rates(&[1.0, 1.0], 1.0, 3).is_err());
    }

    #[test]
    fn overhead_terms() {
        let oh = OverheadConfig::paper();
        let base = oh.mean_task_overhead();
        // Homogeneous, r = 1: both terms are the plain Eq.-24 mean.
        let e = effective_overhead(&oh, &[1.0, 1.0], 1, 0.5);
        assert_eq!(e.critical, base);
        assert_eq!(e.capacity, base);
        // Skew scales by the mean inverse speed.
        let e = effective_overhead(&oh, &[2.0, 0.5], 1, 0.0);
        let inv = (0.5 + 2.0) / 2.0;
        assert!((e.critical - base * inv).abs() < 1e-15);
        // r = 2 with launch: winner pays one launch, capacity pays r of
        // everything.
        let e = effective_overhead(&oh, &[1.0, 1.0], 2, 0.01);
        assert!((e.critical - (base + 0.01)).abs() < 1e-15);
        assert!((e.capacity - 2.0 * (base + 0.01)).abs() < 1e-15);
    }
}
