//! `approx` — analytic approximations for heterogeneous & redundant
//! tiny-tasks systems.
//!
//! The paper's analysis (Secs. 3–6, implemented in [`crate::analysis`])
//! assumes l *identical* workers and no task replication, while the
//! simulation side has shipped skewed worker speeds and first-finish-wins
//! replicas since the scenario subsystem landed. This module closes the
//! gap in the spirit of HeMT-style macrotasking for public-cloud skew
//! (Shan et al., arXiv:1810.00988) and the replica-aware barrier-system
//! follow-ups (arXiv:2512.14445): every scenario the simulator supports
//! can now be answered in microseconds instead of sweep-minutes.
//!
//! Three ingredients, composed by [`bounds`] and [`engine`]:
//!
//! 1. **Heterogeneous service model** ([`EffectiveCluster`]): per-worker
//!    speed multipliers map `Exp(mu)` nominal task sizes onto
//!    non-identical exponential rates `r_j = mu·s_j`. The inter-start gap
//!    is *exactly* `Exp(Σ r_j)` (min of independent exponentials), and
//!    the merge residual is bounded by a per-worker **rate envelope**:
//!    with rates sorted ascending and prefix sums `R_i = r_(1)+…+r_(i)`,
//!    any drain state with i tasks left completes at hazard ≥ `R_i`, so
//!    `max_j Exp(r_j) ≤_st Σ_{i=1}^{l} Exp(R_i)` — the non-i.i.d.
//!    generalization of the order-statistics identity behind Lemma 1
//!    (homogeneous rates make `R_i = i·mu` and recover it exactly).
//! 2. **Redundancy model** ([`redundancy`]): `r` first-finish-wins
//!    replicas of a task on workers with rates `r_j` finish at the min —
//!    `Exp(Σ r_j)` exactly — so an r-replicated cluster maps onto
//!    `⌊l/r⌋` effective super-servers whose rate is the group sum. A
//!    replica-launch cost term extends the Sec.-2.6 four-parameter
//!    overhead fit: each replica pays its own overhead plus a launch
//!    cost, burning `r×` overhead capacity while only the winner's
//!    overhead sits on the critical path. (The static grouping idealizes
//!    the simulator's dynamic earliest-free replica placement, so with
//!    r > 1 the result is an *approximation* that tracks — rather than
//!    strictly dominates — the simulated quantiles; pure skew keeps the
//!    full upper-bound property.)
//! 3. **Stability & bounds** ([`stability`], [`bounds`]): the tiny-tasks
//!    stability regions (Eq.-20 analog) and Theorem-1/2-style sojourn /
//!    waiting ε-quantile approximations over the effective cluster.
//!
//! **Degeneracy contract:** every public entry point detects the
//! degenerate scenario (all speeds exactly 1.0, replicas = 1) and
//! delegates to the homogeneous [`crate::analysis`] implementation, so
//! results are **bit-for-bit** equal to `analysis::{stability, theorem1,
//! theorem2}` there — enforced by `rust/tests/approx_equivalence.rs`.

mod bounds;
mod cluster;
mod engine;
mod redundancy;
mod stability;

pub use bounds::{sojourn_quantile, waiting_quantile, ApproxModel};
pub use cluster::EffectiveCluster;
pub use engine::{sojourn_curve, CurvePoint};
pub use redundancy::{effective_overhead, effective_rates, EffectiveOverhead};
pub use stability::{fork_join_max_utilization, sm_max_utilization};

use crate::config::{OverheadConfig, SimulationConfig};

/// The scenario shape an approximation is evaluated for: per-worker
/// speeds plus the replication factor and its launch cost.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    /// Per-worker speed multipliers (length = worker count l).
    pub speeds: Vec<f64>,
    /// First-finish-wins replicas per task (1 = no redundancy).
    pub replicas: usize,
    /// Per-replica launch overhead in seconds, charged to every replica
    /// of a redundant dispatch (`replicas > 1`) on top of the Sec.-2.6
    /// task-service overhead. Ignored at `replicas = 1`.
    pub replica_launch: f64,
}

impl ClusterSpec {
    /// A homogeneous l-worker cluster (the degenerate scenario).
    pub fn homogeneous(l: usize) -> Self {
        Self { speeds: vec![1.0; l], replicas: 1, replica_launch: 0.0 }
    }

    /// Build a validated spec.
    pub fn new(speeds: Vec<f64>, replicas: usize, replica_launch: f64) -> Result<Self, String> {
        let spec = Self { speeds, replicas, replica_launch };
        spec.validate()?;
        Ok(spec)
    }

    /// Resolve the scenario shape of a simulation config (speeds drawn
    /// from a distribution are resolved with the config's speed seed, so
    /// the analytic and simulated sides see the same cluster).
    ///
    /// Rejects active (non-FCFS) dispatch policies explicitly: every
    /// stability region and sojourn bound in this module assumes the
    /// paper's earliest-free-server FCFS dispatch, and a silently wrong
    /// answer for a SITA/priority/work-stealing config would be worse
    /// than no answer.
    pub fn from_sim_config(cfg: &SimulationConfig) -> Result<Self, String> {
        if let Some(p) = &cfg.policy {
            if p.is_active() {
                return Err(format!(
                    "the analytic approximation models FCFS dispatch only; \
                     policy \"{}\" needs a simulation sweep",
                    p.kind
                ));
            }
        }
        Self::new(cfg.resolved_speeds()?, cfg.replicas(), cfg.launch_overhead())
    }

    /// Resolve parsed scenario sections/flags (the CLI's
    /// `--speeds`/`--speed-dist` + `--redundancy [--replica-launch]`
    /// pair) into a spec; `None` workers means a homogeneous cluster.
    pub fn from_scenario(
        servers: usize,
        workers: Option<&crate::config::WorkersConfig>,
        redundancy: Option<crate::config::RedundancyConfig>,
    ) -> Result<Self, String> {
        let speeds = match workers {
            Some(w) => w.resolve(servers)?,
            None => vec![1.0; servers],
        };
        let replicas = redundancy.map(|r| r.replicas).unwrap_or(1);
        let launch = redundancy.map(|r| r.launch_overhead).unwrap_or(0.0);
        Self::new(speeds, replicas, launch)
    }

    /// Structural validation.
    pub fn validate(&self) -> Result<(), String> {
        if self.speeds.is_empty() {
            return Err("cluster spec needs at least one worker".into());
        }
        for &s in &self.speeds {
            if !(s > 0.0 && s.is_finite()) {
                return Err(format!("worker speeds must be positive and finite, got {s}"));
            }
        }
        if !(1..=self.speeds.len()).contains(&self.replicas) {
            return Err(format!(
                "replicas ({}) must be in 1..=workers ({})",
                self.replicas,
                self.speeds.len()
            ));
        }
        if !(self.replica_launch >= 0.0 && self.replica_launch.is_finite()) {
            return Err(format!(
                "replica launch overhead must be finite and >= 0, got {}",
                self.replica_launch
            ));
        }
        Ok(())
    }

    /// Worker count l.
    pub fn len(&self) -> usize {
        self.speeds.len()
    }

    /// True when there are no workers (never, for a validated spec).
    pub fn is_empty(&self) -> bool {
        self.speeds.is_empty()
    }

    /// True for the degenerate scenario — all speeds exactly 1.0 and no
    /// redundancy — where every approximation delegates to the
    /// homogeneous `analysis` implementation bit-for-bit.
    pub fn is_degenerate(&self) -> bool {
        self.replicas == 1 && self.speeds.iter().all(|&s| s == 1.0)
    }

    /// Effective parallelism: l at r = 1, else ⌊l/r⌋ replica groups
    /// (leftover workers are dropped — a conservative approximation).
    pub fn effective_servers(&self) -> usize {
        if self.replicas == 1 {
            self.speeds.len()
        } else {
            self.speeds.len() / self.replicas
        }
    }

    /// Aggregate raw capacity Σ speeds (the utilization normalizer).
    pub fn total_speed(&self) -> f64 {
        self.speeds.iter().sum()
    }
}

/// Per-query parameters shared by the bound/approximation entry points
/// (the scenario shape travels separately as [`ClusterSpec`]).
#[derive(Clone, Copy, Debug)]
pub struct ApproxParams {
    /// Tasks per job k (`≥ l`).
    pub k: usize,
    /// Poisson arrival rate λ.
    pub lambda: f64,
    /// Nominal task service rate μ (an `Exp(mu)` task on a speed-1
    /// worker; worker j serves at `mu·s_j`).
    pub mu: f64,
    /// Violation probability ε of the quantile approximation.
    pub epsilon: f64,
    /// Sec.-2.6 overhead parameters (`None` = clean bound). Replication
    /// burn and the launch cost come from the [`ClusterSpec`].
    pub overhead: Option<OverheadConfig>,
}

impl ApproxParams {
    pub(crate) fn validate(&self, spec: &ClusterSpec) {
        assert!(self.k >= spec.len(), "tiny tasks require k >= l");
        assert!(self.lambda > 0.0 && self.mu > 0.0, "rates must be positive");
        assert!(
            self.epsilon > 0.0 && self.epsilon < 1.0,
            "epsilon must be in (0, 1)"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validation() {
        assert!(ClusterSpec::new(vec![1.0, 2.0], 1, 0.0).is_ok());
        assert!(ClusterSpec::new(vec![], 1, 0.0).is_err());
        assert!(ClusterSpec::new(vec![1.0, 0.0], 1, 0.0).is_err());
        assert!(ClusterSpec::new(vec![1.0, 1.0], 3, 0.0).is_err());
        assert!(ClusterSpec::new(vec![1.0, 1.0], 2, -1.0).is_err());
        assert!(ClusterSpec::new(vec![1.0, 1.0], 2, f64::INFINITY).is_err());
    }

    #[test]
    fn degeneracy_detection() {
        assert!(ClusterSpec::homogeneous(4).is_degenerate());
        assert!(!ClusterSpec::new(vec![1.0, 1.5], 1, 0.0).unwrap().is_degenerate());
        assert!(!ClusterSpec::new(vec![1.0, 1.0], 2, 0.0).unwrap().is_degenerate());
    }

    #[test]
    fn effective_servers_grouping() {
        assert_eq!(ClusterSpec::homogeneous(7).effective_servers(), 7);
        let spec = ClusterSpec::new(vec![1.0; 7], 2, 0.0).unwrap();
        assert_eq!(spec.effective_servers(), 3); // one leftover worker dropped
        let spec = ClusterSpec::new(vec![1.0; 8], 4, 0.0).unwrap();
        assert_eq!(spec.effective_servers(), 2);
    }

    #[test]
    fn from_sim_config_resolves_scenario() {
        let cfg = SimulationConfig {
            servers: 4,
            tasks_per_job: 8,
            workers: Some(crate::config::WorkersConfig::Speeds(vec![1.5, 1.5, 0.5, 0.5])),
            redundancy: Some(crate::config::RedundancyConfig {
                replicas: 2,
                launch_overhead: 1e-3,
            }),
            ..SimulationConfig::default()
        };
        let spec = ClusterSpec::from_sim_config(&cfg).unwrap();
        assert_eq!(spec.speeds, vec![1.5, 1.5, 0.5, 0.5]);
        assert_eq!(spec.replicas, 2);
        assert_eq!(spec.replica_launch, 1e-3);
        assert_eq!(spec.total_speed(), 4.0);
        // Default config is the degenerate scenario.
        let spec = ClusterSpec::from_sim_config(&SimulationConfig::default()).unwrap();
        assert!(spec.is_degenerate());
    }

    /// Non-FCFS dispatch is rejected with a pointed error (the analytics
    /// assume the paper's FCFS rule); an explicit-but-inactive `fcfs`
    /// section still resolves.
    #[test]
    fn from_sim_config_rejects_active_policy() {
        let mut cfg = SimulationConfig {
            servers: 4,
            tasks_per_job: 8,
            policy: Some(crate::config::PolicyConfig {
                kind: crate::config::PolicyKind::Sita,
                sita_boundaries: vec![1.0],
                ..crate::config::PolicyConfig::default()
            }),
            ..SimulationConfig::default()
        };
        let err = ClusterSpec::from_sim_config(&cfg).unwrap_err();
        assert!(err.contains("FCFS"), "{err}");
        cfg.policy = Some(crate::config::PolicyConfig::default());
        assert!(ClusterSpec::from_sim_config(&cfg).is_ok());
    }
}
