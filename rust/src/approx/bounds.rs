//! Sojourn / waiting ε-quantile approximations for skewed & redundant
//! clusters — Theorem 1/2 evaluated over the effective cluster's rate
//! envelopes, with the replica-aware overhead terms.
//!
//! Degenerate scenarios delegate to [`crate::analysis::bounds`], so the
//! homogeneous results are reproduced bit-for-bit (tested in
//! `rust/tests/approx_equivalence.rs`). Non-degenerate scenarios follow
//! the same θ-optimization with:
//!
//! * `ρ_X`, `ρ_Z` from [`EffectiveCluster`] (prefix-sum rate envelopes);
//! * overhead constants from [`super::effective_overhead`]: the winner's
//!   critical-path overhead joins `ρ_X°` (Eq.-26 analog), the per-task
//!   capacity burn `r·(E[O]+c_launch)` shares over the L effective slots
//!   in `ρ_Z°` (Eq.-28 analog), and split-merge additionally blocks on
//!   the pre-departure term (Eq.-31 analog) while fork-join appends it
//!   non-blocking (Eq. 29).

use super::{effective_overhead, ApproxParams, ClusterSpec, EffectiveCluster};
use crate::analysis::envelope::rho_arrival_exp;
use crate::analysis::theorem1::{self, optimize_theta};
use crate::analysis::{self, BoundModel, BoundParams};
use crate::config::ModelKind;

/// Which model family to approximate (the tiny-tasks pair the scenario
/// subsystem supports analytically).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApproxModel {
    /// Blocking split-merge (Lemma 1 → Theorem 1 shape).
    SplitMerge,
    /// Single-queue fork-join (Theorem 2 shape).
    ForkJoin,
}

impl ApproxModel {
    /// Map a config/CLI model token; per-server fork-join and the ideal
    /// partition have no heterogeneous approximation.
    pub fn from_model_kind(model: ModelKind) -> Result<Self, String> {
        match model {
            ModelKind::SplitMerge => Ok(Self::SplitMerge),
            ModelKind::ForkJoinSingleQueue => Ok(Self::ForkJoin),
            other => Err(format!(
                "no heterogeneous approximation for {other}; use sm or fj"
            )),
        }
    }

    fn bound_model(self) -> BoundModel {
        match self {
            Self::SplitMerge => BoundModel::SplitMergeTiny,
            Self::ForkJoin => BoundModel::ForkJoinTiny,
        }
    }
}

fn bound_params(spec: &ClusterSpec, p: &ApproxParams) -> BoundParams {
    BoundParams {
        l: spec.len(),
        k: p.k,
        lambda: p.lambda,
        mu: p.mu,
        epsilon: p.epsilon,
        overhead: p.overhead,
    }
}

/// The overhead constants entering the envelopes: (critical-path term,
/// per-slot capacity share, pre-departure).
fn overhead_terms(spec: &ClusterSpec, p: &ApproxParams, slots: usize) -> (f64, f64, f64) {
    match &p.overhead {
        None => (0.0, 0.0, 0.0),
        Some(oh) => {
            let eff = effective_overhead(oh, &spec.speeds, spec.replicas, spec.replica_launch);
            (eff.critical, eff.capacity / slots as f64, oh.pre_departure(p.k))
        }
    }
}

/// Sojourn ε-quantile approximation. `None` = no feasible θ (unstable
/// under the approximation's stability condition).
pub fn sojourn_quantile(model: ApproxModel, spec: &ClusterSpec, p: &ApproxParams) -> Option<f64> {
    p.validate(spec);
    if spec.is_degenerate() {
        return analysis::sojourn_bound(model.bound_model(), &bound_params(spec, p));
    }
    let cluster = EffectiveCluster::from_spec(spec, p.mu).ok()?;
    let le = cluster.len();
    if p.k < le {
        return None;
    }
    let (crit, cap_share, pd) = overhead_terms(spec, p, le);
    let rho_a = |th: f64| rho_arrival_exp(p.lambda, th);
    match model {
        ApproxModel::SplitMerge => theorem1::sojourn_quantile(
            cluster.min_rate(),
            p.epsilon,
            // ρ_S°(θ) = [E[O°] + c^pd(k) + ρ_X] + (k−L)[E[O°]_cap/L + ρ_Z]
            |th| {
                crit + pd
                    + cluster.rho_x(th)
                    + (p.k - le) as f64 * (cap_share + cluster.rho_z(th))
            },
            rho_a,
        ),
        ApproxModel::ForkJoin => {
            let ln_inv_eps = -p.epsilon.ln();
            let tau = optimize_theta(
                cluster.min_rate(),
                |th| {
                    (p.k - 1) as f64 * (cap_share + cluster.rho_z(th))
                        + crit
                        + cluster.rho_x(th)
                        + ln_inv_eps / th
                },
                |th| p.k as f64 * (cap_share + cluster.rho_z(th)) <= rho_a(th),
            )
            .map(|(_, tau)| tau)?;
            // Pre-departure is non-blocking in fork-join (Eq. 29).
            Some(tau + pd)
        }
    }
}

/// Waiting ε-quantile approximation.
pub fn waiting_quantile(model: ApproxModel, spec: &ClusterSpec, p: &ApproxParams) -> Option<f64> {
    p.validate(spec);
    if spec.is_degenerate() {
        return analysis::waiting_bound(model.bound_model(), &bound_params(spec, p));
    }
    let cluster = EffectiveCluster::from_spec(spec, p.mu).ok()?;
    let le = cluster.len();
    if p.k < le {
        return None;
    }
    let (crit, cap_share, pd) = overhead_terms(spec, p, le);
    let rho_a = |th: f64| rho_arrival_exp(p.lambda, th);
    let ln_inv_eps = -p.epsilon.ln();
    match model {
        ApproxModel::SplitMerge => theorem1::waiting_quantile(
            cluster.min_rate(),
            p.epsilon,
            |th| {
                crit + pd
                    + cluster.rho_x(th)
                    + (p.k - le) as f64 * (cap_share + cluster.rho_z(th))
            },
            rho_a,
        ),
        ApproxModel::ForkJoin => optimize_theta(
            cluster.min_rate(),
            |th| (p.k - 1) as f64 * (cap_share + cluster.rho_z(th)) + ln_inv_eps / th,
            |th| p.k as f64 * (cap_share + cluster.rho_z(th)) <= rho_a(th),
        )
        .map(|(_, tau)| tau),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverheadConfig;

    fn params(k: usize, mu: f64) -> ApproxParams {
        ApproxParams { k, lambda: 0.4, mu, epsilon: 0.01, overhead: None }
    }

    fn two_class(l: usize, skew: f64) -> ClusterSpec {
        let mut speeds = vec![1.0 + skew; l / 2];
        speeds.extend(vec![1.0 - skew; l - l / 2]);
        ClusterSpec::new(speeds, 1, 0.0).unwrap()
    }

    /// Degenerate scenario: bitwise equal to the homogeneous bounds for
    /// both models, with and without overhead.
    #[test]
    fn degenerate_is_bitwise_homogeneous() {
        let (l, k) = (10usize, 80usize);
        let mu = k as f64 / l as f64;
        let spec = ClusterSpec::homogeneous(l);
        for overhead in [None, Some(OverheadConfig::paper())] {
            let p = ApproxParams { k, lambda: 0.4, mu, epsilon: 0.01, overhead };
            let bp = BoundParams { l, k, lambda: 0.4, mu, epsilon: 0.01, overhead };
            for (am, bm) in [
                (ApproxModel::ForkJoin, BoundModel::ForkJoinTiny),
                (ApproxModel::SplitMerge, BoundModel::SplitMergeTiny),
            ] {
                let a = sojourn_quantile(am, &spec, &p);
                let b = analysis::sojourn_bound(bm, &bp);
                assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits), "{am:?} sojourn");
                let a = waiting_quantile(am, &spec, &p);
                let b = analysis::waiting_bound(bm, &bp);
                assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits), "{am:?} waiting");
            }
        }
    }

    /// Skew at constant aggregate capacity worsens the approximation
    /// (larger quantile), for both models.
    #[test]
    fn skew_worsens_quantiles() {
        let (l, k) = (10usize, 80usize);
        let mu = k as f64 / l as f64;
        for model in [ApproxModel::ForkJoin, ApproxModel::SplitMerge] {
            let flat = sojourn_quantile(model, &ClusterSpec::homogeneous(l), &params(k, mu))
                .expect("stable homogeneous");
            let skewed = sojourn_quantile(model, &two_class(l, 0.5), &params(k, mu))
                .expect("stable skewed");
            assert!(skewed > flat, "{model:?}: {skewed} !> {flat}");
        }
    }

    /// For pure skew (r = 1) the approximation is a genuine upper bound
    /// on a simulated run — every envelope step is a stochastic
    /// domination — and is not vacuous. (Replica grouping idealizes the
    /// dynamic dispatch, so under redundancy the CI gate uses a
    /// two-sided tracking window instead.)
    #[test]
    fn dominates_skewed_simulation() {
        use crate::config::{ModelKind, SimulationConfig, WorkersConfig};
        let (l, k) = (8usize, 32usize);
        let mu = k as f64 / l as f64;
        let speeds = vec![1.5, 1.5, 1.5, 1.5, 0.5, 0.5, 0.5, 0.5];
        let spec = ClusterSpec::new(speeds.clone(), 1, 0.0).unwrap();
        let p = ApproxParams { k, lambda: 0.4, mu, epsilon: 0.01, overhead: None };
        let approx = sojourn_quantile(ApproxModel::ForkJoin, &spec, &p).unwrap();
        let cfg = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: l,
            tasks_per_job: k,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.4".into() },
            service: crate::config::ServiceConfig { execution: format!("exp:{mu}") },
            jobs: 20_000,
            warmup: 2_000,
            seed: 5,
            overhead: None,
            workers: Some(WorkersConfig::Speeds(speeds)),
            redundancy: None,
            faults: None,
            policy: None,
        };
        let mut res = crate::sim::run(&cfg, Default::default()).unwrap();
        let sim_q = res.sojourn_quantile(0.99);
        assert!(sim_q <= approx, "sim {sim_q} exceeds approximation {approx}");
        assert!(approx < sim_q * 12.0, "approximation {approx} vacuous vs {sim_q}");
    }

    /// Overhead raises the quantile; zero overhead collapses to clean.
    #[test]
    fn overhead_consistency_under_skew() {
        let (l, k) = (10usize, 200usize);
        let mu = k as f64 / l as f64;
        let spec = two_class(l, 0.5);
        let clean = sojourn_quantile(ApproxModel::ForkJoin, &spec, &params(k, mu)).unwrap();
        let zero = sojourn_quantile(
            ApproxModel::ForkJoin,
            &spec,
            &ApproxParams { overhead: Some(OverheadConfig::zero()), ..params(k, mu) },
        )
        .unwrap();
        assert!((clean - zero).abs() / clean < 1e-9, "{clean} vs {zero}");
        let oh = sojourn_quantile(
            ApproxModel::ForkJoin,
            &spec,
            &ApproxParams { overhead: Some(OverheadConfig::paper()), ..params(k, mu) },
        )
        .unwrap();
        assert!(oh > clean);
    }

    /// Redundancy (free throughput, faster drain) beats the skewed
    /// non-redundant approximation in the straggler-bound regime, and the
    /// replica-launch cost pushes it back up.
    #[test]
    fn redundancy_and_launch_cost_ordering() {
        let (l, k) = (8usize, 64usize);
        let mu = k as f64 / l as f64;
        let speeds = vec![1.5, 1.5, 1.5, 1.5, 0.5, 0.5, 0.5, 0.5];
        let p = ApproxParams {
            k,
            lambda: 0.3,
            mu,
            epsilon: 0.01,
            overhead: Some(OverheadConfig::paper()),
        };
        let r1 = ClusterSpec::new(speeds.clone(), 1, 0.0).unwrap();
        let r2 = ClusterSpec::new(speeds.clone(), 2, 0.0).unwrap();
        let r2_launch = ClusterSpec::new(speeds, 2, 0.05).unwrap();
        let q1 = sojourn_quantile(ApproxModel::SplitMerge, &r1, &p).unwrap();
        let q2 = sojourn_quantile(ApproxModel::SplitMerge, &r2, &p).unwrap();
        let q2l = sojourn_quantile(ApproxModel::SplitMerge, &r2_launch, &p).unwrap();
        assert!(q2 < q1, "redundancy should mask stragglers: {q2} !< {q1}");
        assert!(q2l > q2, "launch cost must hurt: {q2l} !> {q2}");
    }

    /// Overload has no feasible θ.
    #[test]
    fn unstable_returns_none() {
        let (l, k) = (4usize, 16usize);
        let mu = k as f64 / l as f64;
        let spec = two_class(l, 0.5);
        let p = ApproxParams { k, lambda: 1.5, mu, epsilon: 0.01, overhead: None };
        assert!(sojourn_quantile(ApproxModel::ForkJoin, &spec, &p).is_none());
        assert!(waiting_quantile(ApproxModel::SplitMerge, &spec, &p).is_none());
    }

    /// Waiting ≤ sojourn under skew.
    #[test]
    fn waiting_below_sojourn() {
        let (l, k) = (10usize, 80usize);
        let mu = k as f64 / l as f64;
        let spec = two_class(l, 0.5);
        for model in [ApproxModel::ForkJoin, ApproxModel::SplitMerge] {
            let s = sojourn_quantile(model, &spec, &params(k, mu)).unwrap();
            let w = waiting_quantile(model, &spec, &params(k, mu)).unwrap();
            assert!(w > 0.0 && w < s, "{model:?}: w={w} s={s}");
        }
    }

    #[test]
    fn model_kind_mapping() {
        assert_eq!(
            ApproxModel::from_model_kind(ModelKind::SplitMerge).unwrap(),
            ApproxModel::SplitMerge
        );
        assert_eq!(
            ApproxModel::from_model_kind(ModelKind::ForkJoinSingleQueue).unwrap(),
            ApproxModel::ForkJoin
        );
        assert!(ApproxModel::from_model_kind(ModelKind::Ideal).is_err());
        assert!(ApproxModel::from_model_kind(ModelKind::ForkJoinPerServer).is_err());
    }
}
