//! The heterogeneous service model: non-identical exponential stage
//! rates with prefix-sum rate envelopes.
//!
//! For effective per-slot rates `r_1, …, r_L` (already folded over
//! replica groups by [`super::redundancy::effective_rates`]), sort
//! ascending and form prefix sums `R_i = r_(1) + … + r_(i)`. Then:
//!
//! * the **inter-start gap** `Z` while all L slots are busy is exactly
//!   `min_j Exp(r_j) = Exp(R_L)` (competing exponentials);
//! * the **merge residual** `X = max_j Exp(r_j)` satisfies the rate
//!   envelope `X ≤_st Σ_{i=1}^{L} Exp(R_i)`: while i tasks remain, they
//!   occupy *some* i slots whose total hazard is at least the sum of the
//!   i smallest rates, so each drain gap is dominated by `Exp(R_i)`.
//!
//! With identical rates `R_i = i·mu` and both reduce to the
//! order-statistics identities behind Lemma 1 (Eq. 17) *exactly* — the
//! envelope is tight in the homogeneous limit, conservative under skew.

use crate::approx::ClusterSpec;

/// A resolved effective cluster: ascending rates plus prefix sums.
#[derive(Clone, Debug)]
pub struct EffectiveCluster {
    /// Effective per-slot rates, ascending.
    rates: Vec<f64>,
    /// `prefix[i] = rates[0] + … + rates[i]` (sum of the i+1 smallest).
    prefix: Vec<f64>,
}

impl EffectiveCluster {
    /// Build from raw effective rates (sorted internally).
    pub fn new(mut rates: Vec<f64>) -> Result<Self, String> {
        if rates.is_empty() {
            return Err("effective cluster needs at least one slot".into());
        }
        for &r in &rates {
            if !(r > 0.0 && r.is_finite()) {
                return Err(format!("effective rates must be positive and finite, got {r}"));
            }
        }
        rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prefix = Vec::with_capacity(rates.len());
        let mut acc = 0.0;
        for &r in &rates {
            acc += r;
            prefix.push(acc);
        }
        Ok(Self { rates, prefix })
    }

    /// Build from a scenario spec at nominal task rate `mu` (replica
    /// groups folded into super-server rates).
    pub fn from_spec(spec: &ClusterSpec, mu: f64) -> Result<Self, String> {
        Self::new(super::redundancy::effective_rates(&spec.speeds, mu, spec.replicas)?)
    }

    /// Effective slot count L.
    pub fn len(&self) -> usize {
        self.rates.len()
    }

    /// True when there are no slots (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.rates.is_empty()
    }

    /// Smallest effective rate `r_(1)` — the θ-domain edge of `rho_x`.
    pub fn min_rate(&self) -> f64 {
        self.rates[0]
    }

    /// Total rate `R_L = Σ r_j` — the saturated completion hazard.
    pub fn total_rate(&self) -> f64 {
        *self.prefix.last().unwrap()
    }

    /// Ascending effective rates.
    pub fn rates(&self) -> &[f64] {
        &self.rates
    }

    /// Merge-residual envelope rate
    /// `ρ_X(θ) = (1/θ) Σ_{i=1}^{L} ln(R_i / (R_i − θ))`, θ ∈ (0, R_1).
    /// Returns `f64::INFINITY` outside the domain.
    pub fn rho_x(&self, theta: f64) -> f64 {
        debug_assert!(theta > 0.0);
        if theta >= self.rates[0] {
            return f64::INFINITY;
        }
        let mut sum = 0.0;
        for &ri in &self.prefix {
            sum += (ri / (ri - theta)).ln();
        }
        sum / theta
    }

    /// Inter-start gap rate `ρ_Z(θ) = (1/θ) ln(R_L / (R_L − θ))`,
    /// θ ∈ (0, R_L). Returns `f64::INFINITY` outside the domain.
    pub fn rho_z(&self, theta: f64) -> f64 {
        debug_assert!(theta > 0.0);
        let total = self.total_rate();
        if theta >= total {
            return f64::INFINITY;
        }
        (total / (total - theta)).ln() / theta
    }

    /// Split-merge service envelope `ρ_S(θ) = ρ_X(θ) + (k−L) ρ_Z(θ)`
    /// (the Lemma-1 decomposition over the effective cluster).
    pub fn rho_s(&self, k: usize, theta: f64) -> f64 {
        debug_assert!(k >= self.len());
        self.rho_x(theta) + (k - self.len()) as f64 * self.rho_z(theta)
    }

    /// Mean job service envelope
    /// `E[Δ] ≤ (k−L)/R_L + Σ_{i=1}^{L} 1/R_i` (the θ→0 limit of ρ_S).
    pub fn mean_service(&self, k: usize) -> f64 {
        debug_assert!(k >= self.len());
        let drain: f64 = self.prefix.iter().map(|&ri| 1.0 / ri).sum();
        (k - self.len()) as f64 / self.total_rate() + drain
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lemma1;

    #[test]
    fn rejects_bad_rates() {
        assert!(EffectiveCluster::new(vec![]).is_err());
        assert!(EffectiveCluster::new(vec![1.0, 0.0]).is_err());
        assert!(EffectiveCluster::new(vec![1.0, f64::NAN]).is_err());
    }

    /// Identical rates recover the homogeneous Lemma-1 envelopes to
    /// numerical accuracy (the envelope is exact there).
    #[test]
    fn homogeneous_rates_match_lemma1() {
        let (l, mu, k) = (10usize, 2.0, 40usize);
        let c = EffectiveCluster::new(vec![mu; l]).unwrap();
        for theta in [1e-6, 0.3, 1.2, 1.9] {
            let x = c.rho_x(theta);
            let x_ref = lemma1::rho_x(l, mu, theta);
            assert!((x - x_ref).abs() / x_ref < 1e-12, "theta={theta}: {x} vs {x_ref}");
            let z = c.rho_z(theta);
            let z_ref = lemma1::rho_z(l, mu, theta);
            assert!((z - z_ref).abs() / z_ref < 1e-12);
            let s = c.rho_s(k, theta);
            let s_ref = lemma1::rho_s(l, k, mu, theta);
            assert!((s - s_ref).abs() / s_ref < 1e-12);
        }
        let m = c.mean_service(k);
        let m_ref = lemma1::mean_service(l, k, mu);
        assert!((m - m_ref).abs() / m_ref < 1e-12, "{m} vs {m_ref}");
    }

    /// Domain edges: ρ_X blows up at the smallest rate, ρ_Z at the total.
    #[test]
    fn domain_edges() {
        let c = EffectiveCluster::new(vec![0.5, 1.5, 2.0]).unwrap();
        assert_eq!(c.min_rate(), 0.5);
        assert!((c.total_rate() - 4.0).abs() < 1e-12);
        assert!(c.rho_x(0.5).is_infinite());
        assert!(c.rho_x(0.49) < f64::INFINITY);
        assert!(c.rho_z(4.0).is_infinite());
        assert!(c.rho_z(3.9) < f64::INFINITY);
    }

    /// The envelope dominates a Monte-Carlo estimate of the true max MGF
    /// under skew (validity), and θ→0 of ρ_X bounds E[max].
    #[test]
    fn envelope_dominates_monte_carlo_max() {
        use crate::rng::{Pcg64, Rng};
        let rates = vec![0.5, 1.0, 2.0, 4.0];
        let c = EffectiveCluster::new(rates.clone()).unwrap();
        let theta = 0.3;
        let mut rng = Pcg64::seed_from_u64(7);
        let n = 400_000;
        let mut acc = 0.0;
        let mut mean_acc = 0.0;
        for _ in 0..n {
            let mut mx = 0.0f64;
            for &r in &rates {
                mx = mx.max(-rng.next_f64_open().ln() / r);
            }
            acc += (theta * mx).exp();
            mean_acc += mx;
        }
        let mc_rho = (acc / n as f64).ln() / theta;
        let env = c.rho_x(theta);
        assert!(env >= mc_rho - 1e-2, "envelope {env} below MC {mc_rho}");
        // Not wildly loose either at this modest skew.
        assert!(env < 2.0 * mc_rho, "envelope {env} vacuous vs MC {mc_rho}");
        let mc_mean = mean_acc / n as f64;
        let env_mean = c.rho_x(1e-9);
        assert!(env_mean >= mc_mean - 1e-2);
    }

    /// Mean service decomposition: k = L is pure drain; each extra task
    /// adds 1/R_L.
    #[test]
    fn mean_service_increments() {
        let c = EffectiveCluster::new(vec![1.0, 3.0]).unwrap();
        let drain = 1.0 / 1.0 + 1.0 / 4.0;
        assert!((c.mean_service(2) - drain).abs() < 1e-12);
        assert!((c.mean_service(5) - (drain + 3.0 / 4.0)).abs() < 1e-12);
    }
}
