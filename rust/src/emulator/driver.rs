//! The driver: job generation/submission (split-merge or single-queue
//! fork-join mode) and the result collector that performs the merge step.

use super::codec::Encoder;
use super::metrics::{JobMetrics, MetricsListener, TaskMetrics};
use super::payload::{Payload, PayloadResult};
use super::scheduler::{decode_result, CompletionRecord, SchedMsg};
use super::task::TaskDescriptor;
use crate::config::{EmulatorConfig, ModelKind};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::time::{Duration, Instant};

/// Aggregated outcome of one job after the merge step.
#[derive(Clone, Debug)]
pub enum JobOutcome {
    /// Sum of achieved busy seconds (BusySpin jobs).
    TotalBusy(f64),
    /// Sum of Frobenius norms (MatMul jobs).
    NormSum(f64),
    /// Global word counts merged across shards (WordCount jobs).
    MergedCounts(Vec<(String, u64)>),
    /// Mixed payload kinds.
    Mixed,
}

/// Metadata the driver hands the collector at submission time.
#[derive(Clone, Copy, Debug)]
pub struct JobMeta {
    /// Job id.
    pub job_id: u64,
    /// Emulated arrival time.
    pub arrival_emu: f64,
    /// Wall submission time.
    pub submitted_wall: f64,
    /// Tasks in the job.
    pub tasks: u32,
}

/// Collector loop: receives completion records, decodes results (timed —
/// this is driver-side processing), merges a job's results when all its
/// tasks are in (timed — the measured pre-departure overhead), applies
/// injected pre-departure overhead, and records [`JobMetrics`].
#[allow(clippy::too_many_arguments)]
pub fn collector_main(
    completions: Receiver<CompletionRecord>,
    meta_rx: Receiver<JobMeta>,
    departures: Sender<(u64, f64)>,
    cfg: EmulatorConfig,
    epoch: Instant,
) -> (MetricsListener, Vec<(u64, JobOutcome)>) {
    let mut listener = MetricsListener::default();
    let mut metas: HashMap<u64, JobMeta> = HashMap::new();
    let mut partial: HashMap<u64, JobPartial> = HashMap::new();
    let mut outcomes: Vec<(u64, JobOutcome)> = Vec::new();
    let scale = cfg.time_scale;
    let now_emu = |e: Instant| e.elapsed().as_secs_f64() / scale;
    let inject_pd = cfg
        .inject_overhead
        .map(|oh| oh.pre_departure(cfg.tasks_per_job))
        .unwrap_or(0.0);

    while let Ok(rec) = completions.recv() {
        // Drain any new job metadata first (non-blocking).
        while let Ok(m) = meta_rx.try_recv() {
            metas.insert(m.job_id, m);
        }
        // Driver-side result processing (timed): deserialize the result.
        let t0 = Instant::now();
        let Some(tr) = decode_result(&rec.bytes) else {
            log::error!("collector: undecodable result");
            continue;
        };
        let driver_process = t0.elapsed().as_secs_f64();

        listener.tasks.push(TaskMetrics {
            job_id: tr.job_id,
            task_id: tr.task_id,
            executor_id: tr.executor_id,
            driver_serialize: rec.driver_serialize,
            scheduler_process: rec.scheduler_process + driver_process,
            transmission: rec.transmission,
            deserialize: tr.deserialize,
            binary_fetch: tr.binary_fetch,
            execution: tr.execution,
            result_serialize: tr.result_serialize,
            occupancy: tr.occupancy,
            finished: rec.completed_wall,
        });

        let p = partial.entry(tr.job_id).or_default();
        p.done += 1;
        p.total_exec += tr.execution;
        p.total_overhead += (tr.occupancy - tr.execution).max(0.0);
        p.results.push(tr.result);
        p.last_result_wall = rec.completed_wall;

        let expect = metas.get(&tr.job_id).map(|m| m.tasks).unwrap_or(u32::MAX);
        if p.done == expect {
            let p = partial.remove(&tr.job_id).unwrap();
            let meta = metas.remove(&tr.job_id).unwrap();
            // Merge step (timed): the job's action result, like Spark's
            // collect()/reduce() on the driver.
            let t1 = Instant::now();
            let outcome = merge(&p.results);
            let mut merge_time = t1.elapsed().as_secs_f64();
            if inject_pd > 0.0 {
                // Paper-scale pre-departure overhead (Eq. 3), scaled.
                std::thread::sleep(Duration::from_secs_f64(inject_pd * scale));
                merge_time += inject_pd * scale;
            }
            let departure_emu = now_emu(epoch);
            listener.jobs.push(JobMetrics {
                job_id: meta.job_id,
                arrival: meta.arrival_emu,
                submitted: meta.submitted_wall / scale,
                last_result: p.last_result_wall / scale,
                departure: departure_emu,
                tasks: meta.tasks,
                total_execution: p.total_exec / scale,
                total_task_overhead: p.total_overhead / scale,
                merge_time: merge_time / scale,
            });
            outcomes.push((meta.job_id, outcome));
            if departures.send((meta.job_id, departure_emu)).is_err() {
                break;
            }
        }
    }
    (listener, outcomes)
}

#[derive(Default)]
struct JobPartial {
    done: u32,
    total_exec: f64,
    total_overhead: f64,
    results: Vec<PayloadResult>,
    last_result_wall: f64,
}

fn merge(results: &[PayloadResult]) -> JobOutcome {
    let mut busy = 0.0;
    let mut norms = 0.0;
    let mut counts: HashMap<String, u64> = HashMap::new();
    let (mut n_spun, mut n_norm, mut n_counts) = (0usize, 0usize, 0usize);
    for r in results {
        match r {
            PayloadResult::Spun(s) => {
                busy += s;
                n_spun += 1;
            }
            PayloadResult::Norm(x) => {
                norms += x;
                n_norm += 1;
            }
            PayloadResult::Counts(v) => {
                for (w, c) in v {
                    *counts.entry(w.clone()).or_insert(0) += c;
                }
                n_counts += 1;
            }
        }
    }
    match (n_spun > 0, n_norm > 0, n_counts > 0) {
        (true, false, false) => JobOutcome::TotalBusy(busy),
        (false, true, false) => JobOutcome::NormSum(norms),
        (false, false, true) => {
            let mut v: Vec<(String, u64)> = counts.into_iter().collect();
            v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            v.truncate(20);
            JobOutcome::MergedCounts(v)
        }
        _ => JobOutcome::Mixed,
    }
}

/// Submission loop (runs on the caller thread). `payloads(job, task)`
/// produces each task's payload. Returns when all jobs have departed.
pub fn driver_main<F: FnMut(u64, u32) -> Payload>(
    cfg: &EmulatorConfig,
    mut payloads: F,
    arrivals: &[f64],
    scheduler: &Sender<SchedMsg>,
    meta_tx: &Sender<JobMeta>,
    departures: &Receiver<(u64, f64)>,
    epoch: Instant,
) {
    let scale = cfg.time_scale;
    let k = cfg.tasks_per_job as u32;
    let mut encoder = Encoder::new();
    let mut departed: u64 = 0;

    for (job_idx, &arrival_emu) in arrivals.iter().enumerate() {
        let job_id = job_idx as u64;
        // Wait for the arrival instant (wall = emulated * scale).
        let arrival_wall = arrival_emu * scale;
        let now_wall = epoch.elapsed().as_secs_f64();
        if arrival_wall > now_wall {
            std::thread::sleep(Duration::from_secs_f64(arrival_wall - now_wall));
        }
        // Split-merge: single-threaded driver blocks until the previous
        // job departs (Sec. 1.1's "any Spark program with a
        // single-threaded driver").
        if cfg.mode == ModelKind::SplitMerge {
            while departed < job_id {
                match departures.recv() {
                    Ok(_) => departed += 1,
                    Err(_) => return,
                }
            }
        } else {
            // Fork-join: drain departures opportunistically.
            while let Ok(_d) = departures.try_recv() {
                departed += 1;
            }
        }

        // Serialize the job's tasks (timed per task: the driver
        // serialization overhead of Fig. 7).
        let mut tasks = Vec::with_capacity(k as usize);
        for t in 0..k {
            let t0 = Instant::now();
            let desc = TaskDescriptor {
                job_id,
                task_id: t,
                stage_id: 0,
                executor_id: u32::MAX, // assigned at dispatch
                attempt: 0,
                payload: payloads(job_id, t),
                job_arrival: arrival_emu,
            };
            encoder.reset();
            desc.encode(&mut encoder);
            let bytes = encoder.finish();
            tasks.push((bytes, t0.elapsed().as_secs_f64()));
        }
        let submitted_wall = epoch.elapsed().as_secs_f64();
        let _ = meta_tx.send(JobMeta {
            job_id,
            arrival_emu,
            submitted_wall,
            tasks: k,
        });
        if scheduler
            .send(SchedMsg::Submit { job_id, tasks, submitted_wall })
            .is_err()
        {
            return;
        }
    }

    // Wait for all jobs to depart.
    let total = arrivals.len() as u64;
    while departed < total {
        match departures.recv() {
            Ok(_) => departed += 1,
            Err(_) => break,
        }
    }
}
