//! The central scheduler — sparklite's TaskScheduler analog, and the
//! component whose per-task cost is the crux of the tiny-tasks trade-off
//! (Sec. 2.2: "In any cluster with a central scheduler ... there is
//! overhead which cannot be avoided").
//!
//! Single thread, one global FIFO task queue (Spark's default FIFO
//! scheduling within a job pool): free executors pull head-of-line tasks.
//! Split-merge semantics come from the *driver* withholding the next job,
//! not from the scheduler — exactly as with a single-threaded Spark
//! driver program.

use super::codec::Decoder;
use super::task::TaskResult;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

/// Messages into the scheduler. (`job_id`/`sent_wall` fields are carried
/// for wire-compatibility with future tracing and read by tests.)
#[derive(Debug)]
#[allow(dead_code)]
pub enum SchedMsg {
    /// Driver submits one job's serialized tasks.
    Submit {
        /// Job id.
        job_id: u64,
        /// Serialized task descriptors (driver already timed their
        /// serialization) and the per-task driver serialize cost.
        tasks: Vec<(Vec<u8>, f64)>,
        /// Wall time of submission.
        submitted_wall: f64,
    },
    /// An executor finished a task.
    Completion {
        /// Executor now free.
        executor_id: u32,
        /// Wall time the executor sent this message.
        sent_wall: f64,
        /// Measured channel transit for the task message.
        transmission: f64,
        /// Serialized [`TaskResult`].
        bytes: Vec<u8>,
    },
    /// Drain and stop.
    Shutdown,
}

/// Per-completed-task record forwarded to the driver's collector.
#[derive(Debug)]
pub struct CompletionRecord {
    /// The decoded result (decoding is timed on the driver side —
    /// the collector does it; here we forward bytes).
    pub bytes: Vec<u8>,
    /// Driver serialization cost carried from submission.
    pub driver_serialize: f64,
    /// Scheduler processing time for this task (dispatch bookkeeping).
    pub scheduler_process: f64,
    /// Task-message transmission time.
    pub transmission: f64,
    /// Wall time the completion reached the scheduler.
    pub completed_wall: f64,
}

struct PendingTask {
    bytes: Vec<u8>,
    driver_serialize: f64,
}

/// Body of the scheduler thread.
pub fn scheduler_main(
    inbox: Receiver<SchedMsg>,
    executors: Vec<Sender<(f64, Vec<u8>)>>,
    collector: Sender<CompletionRecord>,
    epoch: Instant,
) {
    let mut queue: VecDeque<PendingTask> = VecDeque::new();
    let mut free: Vec<u32> = (0..executors.len() as u32).rev().collect();
    // driver_serialize is carried per task id; simplest is a side table
    // keyed on (job, task) parsed lazily — instead we keep FIFO pairing:
    // completions return the value we stowed at dispatch.
    let mut in_flight: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
    let now = |e: Instant| e.elapsed().as_secs_f64();

    let dispatch = |queue: &mut VecDeque<PendingTask>,
                        free: &mut Vec<u32>,
                        in_flight: &mut std::collections::HashMap<u32, f64>| {
        while !queue.is_empty() && !free.is_empty() {
            let t0 = Instant::now();
            let task = queue.pop_front().unwrap();
            let exec = free.pop().unwrap();
            let sched_cost = t0.elapsed().as_secs_f64();
            in_flight.insert(exec, task.driver_serialize + sched_cost);
            if executors[exec as usize]
                .send((now(epoch), task.bytes))
                .is_err()
            {
                log::error!("executor {exec} channel closed during dispatch");
            }
        }
    };

    while let Ok(msg) = inbox.recv() {
        match msg {
            SchedMsg::Submit { tasks, .. } => {
                for (bytes, ser) in tasks {
                    queue.push_back(PendingTask { bytes, driver_serialize: ser });
                }
                dispatch(&mut queue, &mut free, &mut in_flight);
            }
            SchedMsg::Completion { executor_id, transmission, bytes, .. } => {
                let t0 = Instant::now();
                let driver_serialize = in_flight.remove(&executor_id).unwrap_or(0.0);
                free.push(executor_id);
                let scheduler_process = t0.elapsed().as_secs_f64();
                let record = CompletionRecord {
                    bytes,
                    driver_serialize,
                    scheduler_process,
                    transmission,
                    completed_wall: now(epoch),
                };
                if collector.send(record).is_err() {
                    break;
                }
                dispatch(&mut queue, &mut free, &mut in_flight);
            }
            SchedMsg::Shutdown => break,
        }
    }
    // Dropping `executors` closes the task channels; executor threads
    // drain and exit.
}

/// Decode a completion's [`TaskResult`] (driver-side, timed by caller).
pub fn decode_result(bytes: &[u8]) -> Option<TaskResult> {
    TaskResult::decode(&mut Decoder::new(bytes)).ok()
}
