//! Task descriptors and results — the messages on sparklite's wire.

use super::codec::{DecodeError, Decoder, Encoder};
use super::payload::{Payload, PayloadResult};

/// What the driver serializes and the scheduler ships to an executor.
///
/// Mirrors Spark's two-part task serialization (Sec. 2.2 "driver
/// serialization time"): the task body (payload + RDD identifiers) plus a
/// description envelope with scheduling metadata — including some
/// deliberately redundant fields, as the paper notes Spark includes.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskDescriptor {
    /// Job index.
    pub job_id: u64,
    /// Task index within the job.
    pub task_id: u32,
    /// Stage id (single-stage jobs in the statistical experiments).
    pub stage_id: u32,
    /// Executor the task is bound to (filled by the scheduler, like
    /// Spark's TaskDescription.executorId).
    pub executor_id: u32,
    /// Attempt number (always 0 — no speculative execution).
    pub attempt: u32,
    /// The work itself.
    pub payload: Payload,
    /// Emulated-seconds arrival time of the owning job (for metrics).
    pub job_arrival: f64,
}

impl TaskDescriptor {
    /// Serialize (the driver-side cost the paper measures).
    pub fn encode(&self, e: &mut Encoder) {
        e.u8(1); // message tag/version
        e.u64(self.job_id);
        e.u32(self.task_id);
        e.u32(self.stage_id);
        e.u32(self.executor_id);
        e.u32(self.attempt);
        // Redundant envelope fields, as in Spark's TaskDescription.
        e.u64(self.job_id);
        e.u32(self.task_id);
        e.f64(self.job_arrival);
        self.payload.encode(e);
    }

    /// Deserialize (the executor-side cost the paper measures).
    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let _tag = d.u8()?;
        let job_id = d.u64()?;
        let task_id = d.u32()?;
        let stage_id = d.u32()?;
        let executor_id = d.u32()?;
        let attempt = d.u32()?;
        let _redundant_job = d.u64()?;
        let _redundant_task = d.u32()?;
        let job_arrival = d.f64()?;
        let payload = Payload::decode(d)?;
        Ok(Self { job_id, task_id, stage_id, executor_id, attempt, payload, job_arrival })
    }
}

/// What the executor sends back on completion.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskResult {
    /// Job index.
    pub job_id: u64,
    /// Task index within the job.
    pub task_id: u32,
    /// Executor that ran the task.
    pub executor_id: u32,
    /// The payload's result.
    pub result: PayloadResult,
    /// Wall seconds: executor dequeue → ready for next task (the task
    /// service time Q_i including all executor-side overhead).
    pub occupancy: f64,
    /// Wall seconds of pure payload execution (E_i).
    pub execution: f64,
    /// Wall seconds of executor-side deserialization.
    pub deserialize: f64,
    /// Wall seconds of task-binary fetch (first task per executor only).
    pub binary_fetch: f64,
    /// Wall seconds of result serialization.
    pub result_serialize: f64,
}

impl TaskResult {
    /// Serialize on the executor.
    pub fn encode(&self, e: &mut Encoder) {
        e.u8(2);
        e.u64(self.job_id);
        e.u32(self.task_id);
        e.u32(self.executor_id);
        e.f64(self.occupancy);
        e.f64(self.execution);
        e.f64(self.deserialize);
        e.f64(self.binary_fetch);
        e.f64(self.result_serialize);
        self.result.encode(e);
    }

    /// Deserialize on the driver.
    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let _tag = d.u8()?;
        Ok(Self {
            job_id: d.u64()?,
            task_id: d.u32()?,
            executor_id: d.u32()?,
            occupancy: d.f64()?,
            execution: d.f64()?,
            deserialize: d.f64()?,
            binary_fetch: d.f64()?,
            result_serialize: d.f64()?,
            result: PayloadResult::decode(d)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_roundtrip() {
        let t = TaskDescriptor {
            job_id: 17,
            task_id: 3,
            stage_id: 0,
            executor_id: 5,
            attempt: 0,
            payload: Payload::BusySpin { seconds: 0.25 },
            job_arrival: 12.5,
        };
        let mut e = Encoder::new();
        t.encode(&mut e);
        let bytes = e.finish();
        let got = TaskDescriptor::decode(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(got, t);
    }

    #[test]
    fn result_roundtrip() {
        let r = TaskResult {
            job_id: 17,
            task_id: 3,
            executor_id: 5,
            result: PayloadResult::Spun(0.25),
            occupancy: 0.26,
            execution: 0.25,
            deserialize: 0.004,
            binary_fetch: 0.0,
            result_serialize: 0.006,
        };
        let mut e = Encoder::new();
        r.encode(&mut e);
        let bytes = e.finish();
        let got = TaskResult::decode(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(got, r);
    }
}
