//! Task payloads — what an executor actually runs.
//!
//! `BusySpin` provides the controlled service-time distributions of the
//! paper's experiments (Sec. 2.3); `MatMul` and `WordCount` are real
//! computations for the end-to-end example (examples/e2e_cluster.rs).

use super::codec::{Decoder, Encoder};
use std::time::{Duration, Instant};

/// The work a task carries.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Occupy the core for exactly `seconds` (sleep + trailing spin) —
    /// the controlled-service-time workload.
    BusySpin {
        /// Wall-clock seconds of (scaled) service time.
        seconds: f64,
    },
    /// Multiply two `n × n` matrices seeded from `seed` and return the
    /// Frobenius norm — CPU-bound real work.
    MatMul {
        /// Matrix dimension.
        n: u32,
        /// Seed for deterministic matrix content.
        seed: u64,
    },
    /// Count words in the shipped text shard and return the counts of the
    /// `top` most frequent words — data-bearing real work (the map side
    /// of the canonical map-reduce example).
    WordCount {
        /// The text shard (serialized with the descriptor, so shard size
        /// shows up in serialization/transmission overhead — as in
        /// Spark).
        text: String,
        /// How many top words to return.
        top: u32,
    },
    /// Run `inner`, then hold the core (sleeping) until `seconds` have
    /// elapsed — models I/O-bound tasks whose compute kernel is real but
    /// whose duration is dominated by (emulated) data access. Essential
    /// on small testbeds: it lets `l` executors exceed the physical core
    /// count without oversubscription (DESIGN.md §2).
    Padded {
        /// The real computation.
        inner: Box<Payload>,
        /// Total task duration in wall seconds.
        seconds: f64,
    },
}

/// The result an executor sends back.
#[derive(Clone, Debug, PartialEq)]
pub enum PayloadResult {
    /// BusySpin: the achieved busy duration (seconds).
    Spun(f64),
    /// MatMul: Frobenius norm of the product.
    Norm(f64),
    /// WordCount: (word, count) pairs, descending by count.
    Counts(Vec<(String, u64)>),
}

impl Payload {
    /// Execute the payload, returning the result. Runs on the executor
    /// thread; duration is the *measured* task execution time.
    pub fn execute(&self) -> PayloadResult {
        match self {
            Payload::BusySpin { seconds } => {
                let target = Duration::from_secs_f64(*seconds);
                let start = Instant::now();
                // Sleep to within 200 µs, spin the remainder: precise
                // without oversubscribing cores when l > #cores (the
                // paper ran 50 executors on 12 nodes).
                if target > Duration::from_micros(300) {
                    std::thread::sleep(target - Duration::from_micros(200));
                }
                while start.elapsed() < target {
                    std::hint::spin_loop();
                }
                PayloadResult::Spun(start.elapsed().as_secs_f64())
            }
            Payload::MatMul { n, seed } => {
                let n = *n as usize;
                let mut state = *seed | 1;
                let mut next = || {
                    // xorshift64* — cheap deterministic fill.
                    state ^= state >> 12;
                    state ^= state << 25;
                    state ^= state >> 27;
                    (state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64
                        / (1u64 << 53) as f64
                };
                let a: Vec<f64> = (0..n * n).map(|_| next()).collect();
                let b: Vec<f64> = (0..n * n).map(|_| next()).collect();
                let mut c = vec![0.0f64; n * n];
                for i in 0..n {
                    for kk in 0..n {
                        let aik = a[i * n + kk];
                        for j in 0..n {
                            c[i * n + j] += aik * b[kk * n + j];
                        }
                    }
                }
                PayloadResult::Norm(c.iter().map(|x| x * x).sum::<f64>().sqrt())
            }
            Payload::WordCount { text, top } => {
                let mut counts: std::collections::HashMap<&str, u64> =
                    std::collections::HashMap::new();
                for w in text.split_whitespace() {
                    *counts.entry(w).or_insert(0) += 1;
                }
                let mut v: Vec<(String, u64)> =
                    counts.into_iter().map(|(w, c)| (w.to_string(), c)).collect();
                v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                v.truncate(*top as usize);
                PayloadResult::Counts(v)
            }
            Payload::Padded { inner, seconds } => {
                let start = Instant::now();
                let result = inner.execute();
                let target = Duration::from_secs_f64(*seconds);
                let elapsed = start.elapsed();
                if elapsed < target {
                    std::thread::sleep(target - elapsed);
                }
                result
            }
        }
    }

    /// Serialize into the task descriptor stream.
    pub fn encode(&self, e: &mut Encoder) {
        match self {
            Payload::BusySpin { seconds } => {
                e.u8(0);
                e.f64(*seconds);
            }
            Payload::MatMul { n, seed } => {
                e.u8(1);
                e.u32(*n);
                e.u64(*seed);
            }
            Payload::WordCount { text, top } => {
                e.u8(2);
                e.str(text);
                e.u32(*top);
            }
            Payload::Padded { inner, seconds } => {
                e.u8(3);
                e.f64(*seconds);
                inner.encode(e);
            }
        }
    }

    /// Deserialize from the task descriptor stream.
    pub fn decode(d: &mut Decoder) -> Result<Self, super::codec::DecodeError> {
        Ok(match d.u8()? {
            0 => Payload::BusySpin { seconds: d.f64()? },
            1 => Payload::MatMul { n: d.u32()?, seed: d.u64()? },
            3 => {
                let seconds = d.f64()?;
                let inner = Box::new(Payload::decode(d)?);
                Payload::Padded { inner, seconds }
            }
            _ => Payload::WordCount { text: d.str()?, top: d.u32()? },
        })
    }
}

impl PayloadResult {
    /// Serialize into the result stream.
    pub fn encode(&self, e: &mut Encoder) {
        match self {
            PayloadResult::Spun(s) => {
                e.u8(0);
                e.f64(*s);
            }
            PayloadResult::Norm(x) => {
                e.u8(1);
                e.f64(*x);
            }
            PayloadResult::Counts(v) => {
                e.u8(2);
                e.u32(v.len() as u32);
                for (w, c) in v {
                    e.str(w);
                    e.u64(*c);
                }
            }
        }
    }

    /// Deserialize from the result stream.
    pub fn decode(d: &mut Decoder) -> Result<Self, super::codec::DecodeError> {
        Ok(match d.u8()? {
            0 => PayloadResult::Spun(d.f64()?),
            1 => PayloadResult::Norm(d.f64()?),
            _ => {
                let n = d.u32()? as usize;
                let mut v = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let w = d.str()?;
                    let c = d.u64()?;
                    v.push((w, c));
                }
                PayloadResult::Counts(v)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_spin_hits_duration() {
        let t0 = std::time::Instant::now();
        let r = Payload::BusySpin { seconds: 0.01 }.execute();
        let wall = t0.elapsed().as_secs_f64();
        assert!(wall >= 0.01 && wall < 0.02, "wall={wall}");
        matches!(r, PayloadResult::Spun(_));
    }

    #[test]
    fn matmul_deterministic() {
        let a = Payload::MatMul { n: 16, seed: 9 }.execute();
        let b = Payload::MatMul { n: 16, seed: 9 }.execute();
        assert_eq!(a, b);
        if let PayloadResult::Norm(x) = a {
            assert!(x > 0.0);
        } else {
            panic!("wrong result kind");
        }
    }

    #[test]
    fn wordcount_counts() {
        let r = Payload::WordCount {
            text: "a b a c a b".into(),
            top: 2,
        }
        .execute();
        assert_eq!(
            r,
            PayloadResult::Counts(vec![("a".into(), 3), ("b".into(), 2)])
        );
    }

    #[test]
    fn padded_holds_duration_and_computes() {
        let t0 = std::time::Instant::now();
        let r = Payload::Padded {
            inner: Box::new(Payload::WordCount { text: "a a b".into(), top: 1 }),
            seconds: 0.01,
        }
        .execute();
        assert!(t0.elapsed().as_secs_f64() >= 0.01);
        assert_eq!(r, PayloadResult::Counts(vec![("a".into(), 2)]));
    }

    #[test]
    fn payload_roundtrip_codec() {
        for p in [
            Payload::BusySpin { seconds: 1.5 },
            Payload::MatMul { n: 8, seed: 42 },
            Payload::WordCount { text: "x y z".into(), top: 3 },
            Payload::Padded {
                inner: Box::new(Payload::MatMul { n: 4, seed: 1 }),
                seconds: 0.5,
            },
        ] {
            let mut e = Encoder::new();
            p.encode(&mut e);
            let bytes = e.finish();
            let got = Payload::decode(&mut Decoder::new(&bytes)).unwrap();
            assert_eq!(got, p);
        }
    }

    #[test]
    fn result_roundtrip_codec() {
        for r in [
            PayloadResult::Spun(0.5),
            PayloadResult::Norm(12.25),
            PayloadResult::Counts(vec![("hi".into(), 2)]),
        ] {
            let mut e = Encoder::new();
            r.encode(&mut e);
            let bytes = e.finish();
            let got = PayloadResult::decode(&mut Decoder::new(&bytes)).unwrap();
            assert_eq!(got, r);
        }
    }
}
