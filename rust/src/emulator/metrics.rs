//! Metrics listener — sparklite's analog of the paper's extended Spark
//! listener ("we added a Spark listener which stores more detailed task
//! metrics than what is available by default", Sec. 2.3).
//!
//! All durations are **wall seconds**; the cluster converts to emulated
//! seconds (dividing by `time_scale`) when assembling results.

/// Per-task measurements, one per completed task (Fig. 7 taxonomy).
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskMetrics {
    /// Owning job.
    pub job_id: u64,
    /// Task index within the job.
    pub task_id: u32,
    /// Executor that ran it.
    pub executor_id: u32,
    /// Driver-side serialization time.
    pub driver_serialize: f64,
    /// Scheduler processing (dequeue → handed to the channel).
    pub scheduler_process: f64,
    /// Channel transit + queueing at the executor (send → dequeue).
    pub transmission: f64,
    /// Executor-side deserialization.
    pub deserialize: f64,
    /// Task-binary fetch (first task on the executor only).
    pub binary_fetch: f64,
    /// Pure payload execution time E_i.
    pub execution: f64,
    /// Result serialization on the executor.
    pub result_serialize: f64,
    /// Executor occupancy Q_i (dequeue → ready for the next task).
    pub occupancy: f64,
    /// Wall instant the completion reached the scheduler — the timestamp
    /// that anchors this task on the cluster timeline (trace capture
    /// derives `start ≈ finished − occupancy` from it).
    pub finished: f64,
}

impl TaskMetrics {
    /// Task overhead O_i = Q_i − E_i (Eq. 1, executor-blocking part).
    pub fn overhead(&self) -> f64 {
        (self.occupancy - self.execution).max(0.0)
    }

    /// Overhead fraction O_i / Q_i (the Fig. 9(a) statistic).
    pub fn overhead_fraction(&self) -> f64 {
        if self.occupancy <= 0.0 {
            0.0
        } else {
            self.overhead() / self.occupancy
        }
    }
}

/// Per-job measurements (emulated seconds where marked).
#[derive(Clone, Debug, Default)]
pub struct JobMetrics {
    /// Job index.
    pub job_id: u64,
    /// Emulated arrival time A(n).
    pub arrival: f64,
    /// Emulated time the driver submitted the job to the scheduler.
    pub submitted: f64,
    /// Emulated time the last task result arrived at the driver.
    pub last_result: f64,
    /// Emulated departure time D(n) (after merge + pre-departure work).
    pub departure: f64,
    /// Tasks in the job.
    pub tasks: u32,
    /// Σ E_i (emulated seconds).
    pub total_execution: f64,
    /// Σ O_i (emulated seconds).
    pub total_task_overhead: f64,
    /// Driver-side merge/aggregation time (emulated seconds) — the
    /// measured pre-departure overhead.
    pub merge_time: f64,
}

impl JobMetrics {
    /// Sojourn time T(n) = D(n) − A(n) in emulated seconds.
    pub fn sojourn(&self) -> f64 {
        self.departure - self.arrival
    }
}

/// Collects task and job metrics across the run.
#[derive(Clone, Debug, Default)]
pub struct MetricsListener {
    /// All task metrics in completion order.
    pub tasks: Vec<TaskMetrics>,
    /// All job metrics in departure order.
    pub jobs: Vec<JobMetrics>,
}

impl MetricsListener {
    /// Mean task-overhead fraction (Fig. 9(a) summary).
    pub fn mean_overhead_fraction(&self) -> f64 {
        if self.tasks.is_empty() {
            return 0.0;
        }
        self.tasks.iter().map(|t| t.overhead_fraction()).sum::<f64>() / self.tasks.len() as f64
    }

    /// Total overhead per job samples (Fig. 9(b)).
    pub fn job_overheads(&self) -> Vec<f64> {
        self.jobs.iter().map(|j| j.total_task_overhead).collect()
    }

    /// Project the listener into the engine-wide obs registry so
    /// `emulate --metrics` emits the same RUN_METRICS.json schema as the
    /// simulators: tasks → dispatches, jobs → completions, sojourns into
    /// the latency histogram.
    pub fn to_obs(&self) -> crate::obs::Metrics {
        let mut m = crate::obs::Metrics::enabled();
        m.add(crate::obs::Counter::TasksDispatched, self.tasks.len() as u64);
        m.add(crate::obs::Counter::JobsCompleted, self.jobs.len() as u64);
        for j in &self.jobs {
            m.observe_sojourn(j.sojourn());
            m.observe_waiting((j.submitted - j.arrival).max(0.0));
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_decomposition() {
        let t = TaskMetrics {
            occupancy: 1.2,
            execution: 1.0,
            ..Default::default()
        };
        assert!((t.overhead() - 0.2).abs() < 1e-12);
        assert!((t.overhead_fraction() - 0.2 / 1.2).abs() < 1e-12);
    }

    #[test]
    fn sojourn_sign() {
        let j = JobMetrics { arrival: 2.0, departure: 5.5, ..Default::default() };
        assert!((j.sojourn() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn listener_aggregates() {
        let mut l = MetricsListener::default();
        l.tasks.push(TaskMetrics { occupancy: 1.0, execution: 0.5, ..Default::default() });
        l.tasks.push(TaskMetrics { occupancy: 1.0, execution: 1.0, ..Default::default() });
        assert!((l.mean_overhead_fraction() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn to_obs_projects_counts_and_sojourns() {
        let mut l = MetricsListener::default();
        l.tasks.push(TaskMetrics::default());
        l.tasks.push(TaskMetrics::default());
        l.jobs.push(JobMetrics {
            arrival: 1.0,
            submitted: 1.5,
            departure: 3.0,
            ..Default::default()
        });
        let m = l.to_obs();
        assert!(m.is_enabled());
        assert_eq!(m.counter(crate::obs::Counter::TasksDispatched), 2);
        assert_eq!(m.counter(crate::obs::Counter::JobsCompleted), 1);
        assert_eq!(m.sojourn_hist.total(), 1);
        assert_eq!(m.waiting_hist.total(), 1);
    }
}
