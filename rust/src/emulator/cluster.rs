//! Cluster assembly: spawn executors + scheduler + collector, run the
//! driver, gather results.

use super::driver::{collector_main, driver_main, JobMeta, JobOutcome};
use super::executor::{executor_main, ExecutorConfig};
use super::metrics::MetricsListener;
use super::payload::Payload;
use super::scheduler::{scheduler_main, CompletionRecord, SchedMsg};
use crate::config::{EmulatorConfig, OverheadConfig};
use crate::dist::parse_spec;
use crate::rng::{Pcg64, Rng};
use crate::stats::QuantileSketch;
use std::sync::mpsc;
use std::time::Instant;

/// Everything a sparklite run produces.
pub struct EmulatorResult {
    /// Echo of the configuration.
    pub config: EmulatorConfig,
    /// All metrics (tasks + jobs), including warmup.
    pub listener: MetricsListener,
    /// Job outcomes (merge results) in departure order.
    pub outcomes: Vec<(u64, JobOutcome)>,
    /// Post-warmup sojourn times (emulated seconds).
    pub sojourn: QuantileSketch,
    /// Wall seconds the run took.
    pub wall_seconds: f64,
}

impl EmulatorResult {
    /// Post-warmup sojourn quantile (emulated seconds).
    pub fn sojourn_quantile(&mut self, q: f64) -> f64 {
        self.sojourn.quantile(q)
    }

    /// Post-warmup job metrics.
    pub fn measured_jobs(&self) -> impl Iterator<Item = &super::metrics::JobMetrics> {
        let warmup = self.config.warmup as u64;
        self.listener.jobs.iter().filter(move |j| j.job_id >= warmup)
    }

    /// Throughput over the measured window (jobs per emulated second).
    pub fn throughput(&self) -> f64 {
        let jobs: Vec<_> = self.measured_jobs().collect();
        if jobs.len() < 2 {
            return 0.0;
        }
        let t0 = jobs.iter().map(|j| j.arrival).fold(f64::INFINITY, f64::min);
        let t1 = jobs.iter().map(|j| j.departure).fold(0.0f64, f64::max);
        jobs.len() as f64 / (t1 - t0).max(1e-9)
    }
}

/// The assembled cluster (constructable for custom payload runs).
pub struct Cluster;

impl Cluster {
    /// Run `cfg` with the default BusySpin payloads whose durations are
    /// drawn from `cfg.execution` (the controlled statistical workload of
    /// Sec. 2.3).
    pub fn run_synthetic(cfg: &EmulatorConfig) -> Result<EmulatorResult, String> {
        cfg.validate()?;
        let exec_dist = parse_spec(&cfg.execution)?;
        let scale = cfg.time_scale;
        let mut rng = Pcg64::seed_from_u64(cfg.seed ^ 0x5EED_7A5C);
        let mut sampler = move || {
            let mut f = || rng.next_f64_open();
            exec_dist.sample(&mut f)
        };
        Self::run_with(cfg, move |_job, _task| Payload::BusySpin {
            seconds: sampler() * scale,
        })
    }

    /// Run `cfg` with custom payloads (`payloads(job, task)` — durations
    /// inside must already be wall-scaled).
    pub fn run_with<F: FnMut(u64, u32) -> Payload + Send>(
        cfg: &EmulatorConfig,
        payloads: F,
    ) -> Result<EmulatorResult, String> {
        cfg.validate()?;
        let t_start = Instant::now();
        let epoch = Instant::now();
        let scale = cfg.time_scale;

        // Arrival schedule (emulated seconds), generated up front for
        // reproducibility.
        let arr_dist = parse_spec(&cfg.interarrival)?;
        let mut rng = Pcg64::seed_from_u64(cfg.seed);
        let total_jobs = cfg.warmup + cfg.jobs;
        let mut arrivals = Vec::with_capacity(total_jobs);
        let mut t = 0.0;
        for _ in 0..total_jobs {
            let mut f = || rng.next_f64_open();
            t += arr_dist.sample(&mut f);
            arrivals.push(t);
        }

        // Channels.
        let (sched_tx, sched_rx) = mpsc::channel::<SchedMsg>();
        let (coll_tx, coll_rx) = mpsc::channel::<CompletionRecord>();
        let (meta_tx, meta_rx) = mpsc::channel::<JobMeta>();
        let (dep_tx, dep_rx) = mpsc::channel::<(u64, f64)>();

        // Executors: injected overhead is specified in emulated seconds;
        // scale to wall time for the busy-waits.
        let inject_wall = cfg.inject_overhead.map(|oh| OverheadConfig {
            c_task_ts: oh.c_task_ts * scale,
            mu_task_ts: if oh.mu_task_ts.is_finite() { oh.mu_task_ts / scale } else { oh.mu_task_ts },
            c_job_pd: oh.c_job_pd, // applied by the collector (emulated)
            c_task_pd: oh.c_task_pd,
        });
        let speeds = cfg.resolved_speeds()?;
        let mut exec_txs = Vec::with_capacity(cfg.executors);
        let mut exec_handles = Vec::with_capacity(cfg.executors);
        for id in 0..cfg.executors as u32 {
            let (tx, rx) = mpsc::channel::<(f64, Vec<u8>)>();
            exec_txs.push(tx);
            let results = sched_tx.clone();
            let ecfg = ExecutorConfig {
                id,
                // Task-binary fetch: 5 ms emulated, once per executor
                // (Fig. 7) — negligible steady-state, visible on task 1.
                binary_fetch: 0.005 * scale,
                inject: inject_wall,
                seed: cfg.seed ^ (0xE0 + id as u64),
                speed: speeds[id as usize],
            };
            exec_handles.push(
                std::thread::Builder::new()
                    .name(format!("sparklite-exec-{id}"))
                    .spawn(move || executor_main(ecfg, rx, results, epoch))
                    .map_err(|e| e.to_string())?,
            );
        }

        // Scheduler.
        let sched_handle = {
            let coll = coll_tx.clone();
            std::thread::Builder::new()
                .name("sparklite-scheduler".into())
                .spawn(move || scheduler_main(sched_rx, exec_txs, coll, epoch))
                .map_err(|e| e.to_string())?
        };
        drop(coll_tx);

        // Collector.
        let coll_cfg = cfg.clone();
        let coll_handle = std::thread::Builder::new()
            .name("sparklite-collector".into())
            .spawn(move || collector_main(coll_rx, meta_rx, dep_tx, coll_cfg, epoch))
            .map_err(|e| e.to_string())?;

        // Driver runs here.
        driver_main(cfg, payloads, &arrivals, &sched_tx, &meta_tx, &dep_rx, epoch);

        // Shutdown: scheduler stops, executor channels close, executors
        // exit, completion channel closes, collector returns.
        let _ = sched_tx.send(SchedMsg::Shutdown);
        drop(sched_tx);
        drop(meta_tx);
        sched_handle.join().map_err(|_| "scheduler panicked")?;
        for h in exec_handles {
            h.join().map_err(|_| "executor panicked")?;
        }
        let (listener, outcomes) = coll_handle.join().map_err(|_| "collector panicked")?;

        // Post-warmup sojourns.
        let mut sojourn = QuantileSketch::with_capacity(cfg.jobs);
        for j in &listener.jobs {
            if j.job_id >= cfg.warmup as u64 {
                sojourn.push(j.sojourn());
            }
        }

        Ok(EmulatorResult {
            config: cfg.clone(),
            listener,
            outcomes,
            sojourn,
            wall_seconds: t_start.elapsed().as_secs_f64(),
        })
    }
}

/// Convenience wrapper: synthetic run.
pub fn run(cfg: &EmulatorConfig) -> Result<EmulatorResult, String> {
    Cluster::run_synthetic(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;

    fn quick_cfg() -> EmulatorConfig {
        EmulatorConfig {
            executors: 4,
            tasks_per_job: 8,
            mode: ModelKind::ForkJoinSingleQueue,
            interarrival: "exp:2.0".into(),
            execution: "exp:2.0".into(),
            time_scale: 0.004,
            jobs: 40,
            warmup: 5,
            seed: 11,
            inject_overhead: None,
            workers: None,
        }
    }

    #[test]
    fn fj_run_completes_all_jobs() {
        let mut res = run(&quick_cfg()).unwrap();
        assert_eq!(res.listener.jobs.len(), 45);
        assert_eq!(res.sojourn.len(), 40);
        assert_eq!(res.listener.tasks.len(), 45 * 8);
        let p50 = res.sojourn_quantile(0.5);
        assert!(p50 > 0.0, "p50={p50}");
        // Every job's sojourn exceeds the parallel lower bound L/l is not
        // guaranteed per-job, but departure must follow arrival.
        for j in &res.listener.jobs {
            assert!(j.departure > j.arrival);
            assert!(j.total_execution > 0.0);
        }
    }

    #[test]
    fn sm_mode_departures_in_order_and_serial() {
        let cfg = EmulatorConfig {
            mode: ModelKind::SplitMerge,
            jobs: 20,
            warmup: 0,
            ..quick_cfg()
        };
        let res = run(&cfg).unwrap();
        assert_eq!(res.listener.jobs.len(), 20);
        let mut jobs = res.listener.jobs.clone();
        jobs.sort_by_key(|j| j.job_id);
        for w in jobs.windows(2) {
            // SM: job n+1 cannot be *submitted* before job n departs.
            assert!(
                w[1].submitted >= w[0].departure - 1e-6,
                "job {} submitted {} before job {} departed {}",
                w[1].job_id,
                w[1].submitted,
                w[0].job_id,
                w[0].departure
            );
        }
    }

    #[test]
    fn injected_overhead_shows_up_in_measurements() {
        let base = quick_cfg();
        let mut clean = run(&base).unwrap();
        let mut dirty_cfg = base.clone();
        // Exaggerated overhead so the effect dominates scheduling noise:
        // 0.2 emulated-second constant per task.
        dirty_cfg.inject_overhead = Some(OverheadConfig {
            c_task_ts: 0.2,
            mu_task_ts: f64::INFINITY,
            c_job_pd: 0.5,
            c_task_pd: 0.0,
        });
        dirty_cfg.seed = base.seed;
        let mut dirty = run(&dirty_cfg).unwrap();
        let c50 = clean.sojourn_quantile(0.5);
        let d50 = dirty.sojourn_quantile(0.5);
        assert!(d50 > c50 + 0.4, "overhead not visible: {c50} vs {d50}");
        assert!(
            dirty.listener.mean_overhead_fraction()
                > clean.listener.mean_overhead_fraction()
        );
    }

    /// Pinned slow executors (the ROADMAP scenario item): tasks landing
    /// on the slow half report dilated execution, and the dilation shows
    /// up as service, not overhead.
    #[test]
    fn pinned_slow_executors_dilate_their_tasks() {
        let cfg = EmulatorConfig {
            executors: 2,
            tasks_per_job: 8,
            execution: "det:2.0".into(), // 8 ms wall at scale 0.004
            jobs: 25,
            warmup: 0,
            workers: Some(crate::config::WorkersConfig::Speeds(vec![1.0, 0.5])),
            ..quick_cfg()
        };
        let res = run(&cfg).unwrap();
        let mean_exec = |srv: u32| {
            let ts: Vec<_> =
                res.listener.tasks.iter().filter(|t| t.executor_id == srv).collect();
            assert!(!ts.is_empty(), "executor {srv} never ran a task");
            ts.iter().map(|t| t.execution).sum::<f64>() / ts.len() as f64
        };
        let (fast, slow) = (mean_exec(0), mean_exec(1));
        assert!(
            slow > fast * 1.5,
            "slow executor not dilated: fast {fast} vs slow {slow}"
        );
        // Dilation is service, not overhead: the fraction stays modest.
        assert!(res.listener.mean_overhead_fraction() < 0.2);
    }

    #[test]
    fn intrinsic_overhead_is_measured_and_small() {
        let res = run(&quick_cfg()).unwrap();
        let f = res.listener.mean_overhead_fraction();
        // sparklite's own scheduling overhead exists but is far below the
        // task service times at this scale.
        assert!(f > 0.0, "no overhead measured");
        assert!(f < 0.2, "overhead implausibly large: {f}");
        let _ = res.throughput();
    }
}
