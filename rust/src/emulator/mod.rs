//! **sparklite** — a thread-based mini map-reduce engine standing in for
//! the paper's Apache Spark cluster (DESIGN.md §2 substitution table).
//!
//! Architecture mirrors Fig. 6: a **driver** submits jobs of `k` tasks to
//! a central **scheduler**, which serializes task descriptors and
//! dispatches them over channels to `l` single-core **executor** threads;
//! executors deserialize, run the task payload (real computation in real
//! time), serialize the result, and report back. The driver aggregates
//! results when all `k` tasks of a job complete (the merge/collect step —
//! the source of *pre-departure* overhead), then the job departs.
//!
//! Every Fig.-7 overhead component is measured per task:
//! driver serialization, scheduler processing, transmission (channel
//! transit), executor deserialization + housekeeping, task-binary fetch
//! (first task per executor), execution, and result round-trip. The
//! calibration pipeline (Sec. 2.6 methodology) fits the four-parameter
//! overhead model to these measurements plus PP-matching of sojourn
//! distributions.
//!
//! Submission modes (Sec. 1.1): `SplitMerge` — single-threaded driver
//! that blocks until the in-flight job departs; `ForkJoinSingleQueue` —
//! multi-threaded driver submitting jobs as they arrive. All service
//! times are scaled by `time_scale` so paper-scale workloads (1 s mean
//! tasks) run in ~1/100 wall time.

mod cluster;
mod codec;
mod driver;
mod executor;
mod metrics;
mod payload;
mod scheduler;
mod task;

pub use cluster::{run, Cluster, EmulatorResult};
pub use codec::{DecodeError, Decoder, Encoder};
pub use driver::JobOutcome;
pub use metrics::{JobMetrics, MetricsListener, TaskMetrics};
pub use payload::{Payload, PayloadResult};
pub use task::{TaskDescriptor, TaskResult};
