//! Binary codec for task descriptors and results.
//!
//! Hand-rolled (the offline registry has no serde) — which is a feature
//! here: Spark's task serialization cost is a first-class overhead
//! component (Fig. 7 "driver serialization time"), and an explicit codec
//! makes the measured cost honest rather than an artifact of a generic
//! framework.
//!
//! Wire format: little-endian fixed-width scalars, `u32`-length-prefixed
//! byte strings, `u32`-length-prefixed sequences. A leading `u8` tag
//! versions each message kind.

/// Serializer writing into a reusable buffer.
#[derive(Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    /// Fresh encoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear and return the reusable buffer for a new message.
    pub fn reset(&mut self) {
        self.buf.clear();
    }

    /// Finished bytes.
    pub fn finish(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }

    /// Append a u8.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    /// Append a u32 (LE).
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// Append a u64 (LE).
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// Append an f64 (LE bits).
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// Append length-prefixed bytes.
    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }
    /// Append a length-prefixed sequence of f64.
    pub fn f64_seq(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f64(x);
        }
    }
}

/// Deserializer over a byte slice.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// Decode error (truncated or malformed message).
#[derive(Debug)]
pub struct DecodeError {
    pos: usize,
    reason: &'static str,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "decode error at byte {}: {}", self.pos, self.reason)
    }
}

impl std::error::Error for DecodeError {}

impl<'a> Decoder<'a> {
    /// Decoder over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, reason: &'static str) -> Result<&'a [u8], DecodeError> {
        if self.pos + n > self.buf.len() {
            return Err(DecodeError { pos: self.pos, reason });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a u8.
    pub fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1, "u8")?[0])
    }
    /// Read a u32.
    pub fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4, "u32")?.try_into().unwrap()))
    }
    /// Read a u64.
    pub fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8, "u64")?.try_into().unwrap()))
    }
    /// Read an f64.
    pub fn f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_le_bytes(self.take(8, "f64")?.try_into().unwrap()))
    }
    /// Read length-prefixed bytes.
    pub fn bytes(&mut self) -> Result<&'a [u8], DecodeError> {
        let n = self.u32()? as usize;
        self.take(n, "bytes body")
    }
    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String, DecodeError> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| DecodeError {
            pos: self.pos,
            reason: "invalid utf-8",
        })
    }
    /// Read a length-prefixed f64 sequence.
    pub fn f64_seq(&mut self) -> Result<Vec<f64>, DecodeError> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }
    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars_and_strings() {
        let mut e = Encoder::new();
        e.u8(7);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX);
        e.f64(std::f64::consts::PI);
        e.str("tiny tasks");
        e.f64_seq(&[1.0, -2.5, 3.25]);
        let bytes = e.finish();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX);
        assert_eq!(d.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(d.str().unwrap(), "tiny tasks");
        assert_eq!(d.f64_seq().unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!(d.remaining(), 0);
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Encoder::new();
        e.u64(42);
        let bytes = e.finish();
        let mut d = Decoder::new(&bytes[..5]);
        assert!(d.u64().is_err());
    }

    #[test]
    fn bad_utf8_rejected() {
        let mut e = Encoder::new();
        e.bytes(&[0xFF, 0xFE]);
        let bytes = e.finish();
        let mut d = Decoder::new(&bytes);
        assert!(d.str().is_err());
    }

    #[test]
    fn encoder_reuse() {
        let mut e = Encoder::new();
        e.u32(1);
        let a = e.finish();
        e.reset();
        e.u32(2);
        let b = e.finish();
        assert_ne!(a, b);
        assert_eq!(a.len(), b.len());
    }
}
