//! Executor threads — sparklite's single-core workers.
//!
//! Each executor owns a task channel; its loop mirrors a Spark executor
//! core (Sec. 2.2): receive → deserialize → (first time) fetch the task
//! binary → run → serialize the result → report. Everything except the
//! payload execution is the task-service overhead the paper measures.

use super::codec::{Decoder, Encoder};
use super::scheduler::SchedMsg;
use super::task::{TaskDescriptor, TaskResult};
use crate::config::OverheadConfig;
use crate::rng::Pcg64;
use crate::sim::OverheadModel;
use std::sync::mpsc::{Receiver, Sender};
use std::time::{Duration, Instant};

/// Configuration handed to each executor thread.
pub struct ExecutorConfig {
    /// This executor's id.
    pub id: u32,
    /// Simulated task-binary fetch duration (wall seconds) for the first
    /// task on this executor (Fig. 7 "task binary fetching time").
    pub binary_fetch: f64,
    /// Injected per-task overhead (paper Eq. 2, pre-scaled to wall time),
    /// if reproducing paper-scale overhead in scaled time.
    pub inject: Option<OverheadConfig>,
    /// RNG seed for the injected overhead sampling.
    pub seed: u64,
    /// Speed factor in `(0, 1]`: a slow executor (`speed < 1`) dilates
    /// each task's execution to `E_i / speed` with extra busy work —
    /// the sparklite analog of the DES heterogeneous-worker scenario
    /// (slowdown only; real payloads cannot be sped up).
    pub speed: f64,
}

/// Body of one executor thread. `tasks` delivers `(sent_wall, bytes)`
/// pairs so transmission time can be measured at dequeue.
pub fn executor_main(
    cfg: ExecutorConfig,
    tasks: Receiver<(f64, Vec<u8>)>,
    results: Sender<SchedMsg>,
    epoch: Instant,
) {
    let mut first_task = true;
    let mut rng = Pcg64::seed_from_u64(cfg.seed);
    let inject = OverheadModel::from_option(cfg.inject);
    let mut encoder = Encoder::new();
    let now = |epoch: Instant| epoch.elapsed().as_secs_f64();

    while let Ok((sent_wall, bytes)) = tasks.recv() {
        let t_dequeue = now(epoch);
        let transmission = (t_dequeue - sent_wall).max(0.0);

        // Deserialize the task description (timed).
        let t0 = Instant::now();
        let desc = match TaskDescriptor::decode(&mut Decoder::new(&bytes)) {
            Ok(d) => d,
            Err(e) => {
                log::error!("executor {}: bad task message: {e}", cfg.id);
                continue;
            }
        };
        let deserialize = t0.elapsed().as_secs_f64();

        // One-time task-binary fetch (remote broadcast variable).
        let binary_fetch = if first_task && cfg.binary_fetch > 0.0 {
            first_task = false;
            busy_wait(cfg.binary_fetch);
            cfg.binary_fetch
        } else {
            first_task = false;
            0.0
        };

        // Injected task-service overhead (Eq. 2), blocking the core.
        let injected = inject.sample_task(&mut rng);
        if injected > 0.0 {
            busy_wait(injected);
        }

        // Run the payload (timed) — the task execution time E_i.
        let t1 = Instant::now();
        let result = desc.payload.execute();
        let mut execution = t1.elapsed().as_secs_f64();

        // Slow executor: stretch the service to E_i / speed. The padding
        // counts as *execution* (service dilation), not overhead — a slow
        // core runs the same work for longer, it does not scheduler-chat
        // more.
        if cfg.speed < 1.0 {
            let extra = execution * (1.0 / cfg.speed - 1.0);
            busy_wait(extra);
            execution += extra;
        }

        // Serialize the result (timed).
        let t2 = Instant::now();
        encoder.reset();
        let mut tr = TaskResult {
            job_id: desc.job_id,
            task_id: desc.task_id,
            executor_id: cfg.id,
            result,
            occupancy: 0.0,
            execution,
            deserialize,
            binary_fetch,
            result_serialize: 0.0,
        };
        tr.encode(&mut encoder);
        let result_serialize = t2.elapsed().as_secs_f64();

        // Occupancy: dequeue → now (the server-blocking Q_i of Eq. 1).
        let occupancy = now(epoch) - t_dequeue;
        // Re-encode with the final timings (cheap second pass).
        tr.occupancy = occupancy;
        tr.result_serialize = result_serialize;
        encoder.reset();
        tr.encode(&mut encoder);
        let payload_bytes = encoder.finish();

        if results
            .send(SchedMsg::Completion {
                executor_id: cfg.id,
                sent_wall: now(epoch),
                transmission,
                bytes: payload_bytes,
            })
            .is_err()
        {
            break; // scheduler gone: shutting down
        }
    }
}

/// Sleep-then-spin to occupy the core for `seconds` without gross
/// oversubscription (executors may outnumber physical cores).
fn busy_wait(seconds: f64) {
    let target = Duration::from_secs_f64(seconds);
    let start = Instant::now();
    if target > Duration::from_micros(300) {
        std::thread::sleep(target - Duration::from_micros(200));
    }
    while start.elapsed() < target {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::payload::{Payload, PayloadResult};
    use std::sync::mpsc;

    #[test]
    fn executor_runs_tasks_and_reports() {
        let epoch = Instant::now();
        let (task_tx, task_rx) = mpsc::channel();
        let (res_tx, res_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            executor_main(
                ExecutorConfig { id: 3, binary_fetch: 0.002, inject: None, seed: 1, speed: 1.0 },
                task_rx,
                res_tx,
                epoch,
            )
        });
        for i in 0..3u32 {
            let desc = TaskDescriptor {
                job_id: 1,
                task_id: i,
                stage_id: 0,
                executor_id: 3,
                attempt: 0,
                payload: Payload::BusySpin { seconds: 0.003 },
                job_arrival: 0.0,
            };
            let mut e = Encoder::new();
            desc.encode(&mut e);
            task_tx.send((epoch.elapsed().as_secs_f64(), e.finish())).unwrap();
        }
        drop(task_tx);
        let mut fetches = 0;
        for _ in 0..3 {
            match res_rx.recv().unwrap() {
                SchedMsg::Completion { executor_id, bytes, .. } => {
                    assert_eq!(executor_id, 3);
                    let tr = TaskResult::decode(&mut Decoder::new(&bytes)).unwrap();
                    assert!(matches!(tr.result, PayloadResult::Spun(_)));
                    assert!(tr.execution >= 0.003);
                    assert!(tr.occupancy >= tr.execution);
                    if tr.binary_fetch > 0.0 {
                        fetches += 1;
                    }
                }
                other => panic!("unexpected msg {other:?}"),
            }
        }
        // Binary fetch happens exactly once (first task on the executor).
        assert_eq!(fetches, 1);
        handle.join().unwrap();
    }

    /// A speed-0.5 executor reports roughly doubled execution times (the
    /// dilation is busy work counted as service, not overhead).
    #[test]
    fn slow_executor_dilates_execution() {
        let epoch = Instant::now();
        let (task_tx, task_rx) = mpsc::channel();
        let (res_tx, res_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            executor_main(
                ExecutorConfig { id: 0, binary_fetch: 0.0, inject: None, seed: 1, speed: 0.5 },
                task_rx,
                res_tx,
                epoch,
            )
        });
        let desc = TaskDescriptor {
            job_id: 0,
            task_id: 0,
            stage_id: 0,
            executor_id: 0,
            attempt: 0,
            payload: Payload::BusySpin { seconds: 0.01 },
            job_arrival: 0.0,
        };
        let mut e = Encoder::new();
        desc.encode(&mut e);
        task_tx.send((epoch.elapsed().as_secs_f64(), e.finish())).unwrap();
        drop(task_tx);
        match res_rx.recv().unwrap() {
            SchedMsg::Completion { bytes, .. } => {
                let tr = TaskResult::decode(&mut Decoder::new(&bytes)).unwrap();
                // 10 ms of payload stretched towards 20 ms of service.
                assert!(tr.execution >= 0.018, "no dilation: {}", tr.execution);
                assert!(tr.occupancy >= tr.execution);
            }
            other => panic!("unexpected msg {other:?}"),
        }
        handle.join().unwrap();
    }
}
