//! Fig. 13: sojourn-time bounds vs. tasks per job at ε = 10⁻⁶ for the
//! single-queue fork-join and split-merge models with l = 50 servers,
//! against the ideal-partition reference. λ = 0.5, μ = k/l.

use super::{FigureCtx, Scale};
use crate::runtime::BoundQuery;
use crate::util::csv::Csv;
use anyhow::Result;

pub fn fig13(ctx: &FigureCtx) -> Result<()> {
    let l = 50usize;
    let lambda = 0.5;
    let eps = 1e-6;
    let ks: Vec<usize> = match ctx.scale {
        Scale::Quick => vec![50, 100, 200, 400, 800, 1600, 3200],
        Scale::Paper => {
            // Dense log grid 50 … 5000.
            let mut v = Vec::new();
            let mut k = 50.0f64;
            while k <= 5000.0 {
                v.push(k.round() as usize);
                k *= 1.15;
            }
            v
        }
    };

    let rows = ctx.engine.bounds(
        &ks.iter()
            .map(|&k| BoundQuery {
                k,
                l,
                lambda,
                mu: k as f64 / l as f64,
                epsilon: eps,
                overhead: None,
            })
            .collect::<Vec<_>>(),
    )?;

    let mut csv = Csv::new(vec!["k", "fork_join", "split_merge", "ideal"]);
    for (i, &k) in ks.iter().enumerate() {
        csv.push(&[
            k as f64,
            rows[i].fork_join.unwrap_or(f64::NAN),
            rows[i].split_merge.unwrap_or(f64::NAN),
            rows[i].ideal.unwrap_or(f64::NAN),
        ]);
    }
    let path = ctx.out_dir.join("fig13_bounds.csv");
    csv.write_file(&path)?;
    println!("fig13: {} rows -> {}", ks.len(), path.display());
    Ok(())
}
