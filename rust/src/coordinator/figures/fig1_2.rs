//! Figs. 1–2: executor activity diagrams. Four jobs on 50 executors,
//! split-merge submission, k = 400 vs k = 1500 tasks per job with the
//! same expected workload E[L] = 50 s — the coarse case leaves executors
//! idling at every merge barrier, the fine case keeps them busy.

use super::FigureCtx;
use crate::config::{ModelKind, SimulationConfig};
use crate::sim::{self, RunOptions};
use anyhow::Result;

pub fn fig1_2(ctx: &FigureCtx) -> Result<()> {
    for (fig, k) in [("fig1", 400usize), ("fig2", 1500usize)] {
        let cfg = SimulationConfig {
            model: ModelKind::SplitMerge,
            servers: 50,
            tasks_per_job: k,
            // Saturated driver: jobs queued back-to-back as from a
            // single-threaded driver replaying a backlog.
            arrival: crate::config::ArrivalConfig { interarrival: "det:0.001".into() },
            service: crate::config::ServiceConfig {
                // E[L] = 50 s → mean task 50/k s.
                execution: format!("exp:{}", k as f64 / 50.0),
            },
            jobs: 4,
            warmup: 0,
            seed: ctx.seed,
            overhead: Some(crate::config::OverheadConfig::paper()),
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let res = sim::run(&cfg, RunOptions { trace: true, record_jobs: true, ..Default::default() })
            .map_err(anyhow::Error::msg)?;
        let csv = res.trace.to_csv();
        let path = ctx.out_dir.join(format!("{fig}_gantt.csv"));
        csv.write_file(&path)?;

        // Headline statistic: mean executor utilization over the first
        // five seconds (the paper's visual contrast).
        let horizon = 5.0;
        let util = res.trace.utilization(50, 0.0, horizon);
        let mean_util = util.iter().sum::<f64>() / util.len() as f64;
        let d4 = res.jobs.last().map(|j| j.departure).unwrap_or(f64::NAN);
        println!(
            "{fig}: k={k}, mean executor utilization over first {horizon}s = {mean_util:.3}, \
             4th job departs at {d4:.2}s -> {}",
            path.display()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BoundsEngine;
    use crate::util::threadpool::ThreadPool;

    /// The Fig. 1 vs Fig. 2 contrast: finer tasks → higher utilization
    /// and earlier completion of the 4th job.
    #[test]
    fn finer_tasks_better_utilization() {
        let run_k = |k: usize| {
            let cfg = SimulationConfig {
                model: ModelKind::SplitMerge,
                servers: 50,
                tasks_per_job: k,
                arrival: crate::config::ArrivalConfig { interarrival: "det:0.001".into() },
                service: crate::config::ServiceConfig {
                    execution: format!("exp:{}", k as f64 / 50.0),
                },
                jobs: 4,
                warmup: 0,
                seed: 1,
                overhead: None,
                workers: None,
                redundancy: None,
                faults: None,
                policy: None,
            };
            let res = sim::run(&cfg, RunOptions { trace: true, record_jobs: true, ..Default::default() })
                .unwrap();
            let util = res.trace.utilization(50, 0.0, 5.0);
            let mean: f64 = util.iter().sum::<f64>() / 50.0;
            (mean, res.jobs.last().unwrap().departure)
        };
        let (u_coarse, d_coarse) = run_k(400);
        let (u_fine, d_fine) = run_k(1500);
        assert!(u_fine > u_coarse, "{u_fine} !> {u_coarse}");
        assert!(d_fine < d_coarse, "{d_fine} !< {d_coarse}");
        let _ = (BoundsEngine::native(), ThreadPool::new(1)); // silence unused-dev-deps lints
    }
}
