//! Fig. 10: PP plots validating the overhead model — simulated
//! single-queue fork-join sojourn distributions (no overhead / task
//! overhead only / task + pre-departure overhead) against the emulated
//! cluster at k = 2500 tasks per job.

use super::{FigureCtx, Scale};
use crate::config::{EmulatorConfig, ModelKind, OverheadConfig, SimulationConfig};
use crate::emulator;
use crate::sim::{self, RunOptions};
use crate::stats::{pp_distance, pp_points, Ecdf};
use crate::util::csv::Csv;
use anyhow::Result;

pub fn fig10(ctx: &FigureCtx) -> Result<()> {
    let l = 50usize;
    let lambda = 0.5;
    // k = 2500 at the rate-limited wall scale (see fig8.rs) runs ~0.63 s
    // of wall time per job; quick scale trims the job count accordingly.
    let (k, emu_jobs, sim_jobs) = match ctx.scale {
        Scale::Quick => (2500usize, 250usize, 30_000usize),
        Scale::Paper => (2500, 30_000, 300_000),
    };
    let time_scale = (k as f64 * 2.5e-4).max(0.002);
    let mu = k as f64 / l as f64;
    let oh = OverheadConfig::paper();

    // The "Spark" measurement: sparklite with injected paper overhead.
    let emu_cfg = EmulatorConfig {
        executors: l,
        tasks_per_job: k,
        mode: ModelKind::ForkJoinSingleQueue,
        interarrival: format!("exp:{lambda}"),
        execution: format!("exp:{mu}"),
        time_scale,
        jobs: emu_jobs,
        warmup: emu_jobs / 10,
        seed: ctx.seed,
        inject_overhead: Some(oh),
        workers: None,
    };
    let emu_res = emulator::run(&emu_cfg).map_err(anyhow::Error::msg)?;
    let emu_ecdf = Ecdf::new(emu_res.measured_jobs().map(|j| j.sojourn()).collect());

    // Three simulation variants (the paper's blue / green / magenta).
    let sim_ecdf = |overhead: Option<OverheadConfig>| -> Result<Ecdf> {
        let cfg = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: l,
            tasks_per_job: k,
            arrival: crate::config::ArrivalConfig { interarrival: format!("exp:{lambda}") },
            service: crate::config::ServiceConfig { execution: format!("exp:{mu}") },
            jobs: sim_jobs,
            warmup: sim_jobs / 10,
            seed: ctx.seed ^ 0xF16,
            overhead,
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let res = sim::run(&cfg, RunOptions { record_jobs: true, ..Default::default() })
            .map_err(anyhow::Error::msg)?;
        Ok(Ecdf::new(res.jobs.iter().map(|j| j.sojourn()).collect()))
    };
    let none = sim_ecdf(None)?;
    let task_only = sim_ecdf(Some(OverheadConfig { c_job_pd: 0.0, c_task_pd: 0.0, ..oh }))?;
    let full = sim_ecdf(Some(oh))?;

    let n = 201;
    let mut csv = Csv::new(vec![
        "p_sim_no_overhead",
        "p_emulator_0",
        "p_sim_task_overhead",
        "p_emulator_1",
        "p_sim_full_overhead",
        "p_emulator_2",
    ]);
    let a = pp_points(&none, &emu_ecdf, n);
    let b = pp_points(&task_only, &emu_ecdf, n);
    let c = pp_points(&full, &emu_ecdf, n);
    for i in 0..n {
        csv.push(&[
            a[i].p_first, a[i].p_second, b[i].p_first, b[i].p_second, c[i].p_first,
            c[i].p_second,
        ]);
    }
    let path = ctx.out_dir.join("fig10_ppplot.csv");
    csv.write_file(&path)?;

    let d_none = pp_distance(&none, &emu_ecdf, n);
    let d_task = pp_distance(&task_only, &emu_ecdf, n);
    let d_full = pp_distance(&full, &emu_ecdf, n);
    println!(
        "fig10: PP distance no-overhead={d_none:.4} task-only={d_task:.4} full={d_full:.4} \
         -> {}",
        path.display()
    );
    Ok(())
}
