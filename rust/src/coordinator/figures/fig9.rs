//! Fig. 9: overhead statistics from the (emulated) cluster — (a) box
//! plots of the per-task overhead fraction O_i/Q_i vs. k, (b) box plots
//! of the total overhead per job Σ O_i vs. k. Both grow ~linearly in k,
//! the mechanism behind the Fig. 8 upturn.

use super::{FigureCtx, Scale};
use crate::config::{EmulatorConfig, ModelKind, OverheadConfig};
use crate::emulator;
use crate::stats::BoxStats;
use crate::util::csv::Csv;
use anyhow::Result;

pub fn fig9(ctx: &FigureCtx) -> Result<()> {
    let l = 50usize;
    let lambda = 0.5;
    let (ks, jobs): (Vec<usize>, usize) = match ctx.scale {
        Scale::Quick => (vec![100, 400, 1000], 120),
        Scale::Paper => (vec![50, 100, 200, 400, 600, 1000, 1500, 2000, 2500], 5_000),
    };
    // Rate-limited wall scale (see fig8.rs: 1-core testbed).
    let scale_for = |k: usize| (k as f64 * 2.5e-4).max(0.002);

    let mut frac_csv = Csv::new(vec![
        "k", "mean", "q1", "median", "q3", "whisker_lo", "whisker_hi", "outliers", "n",
    ]);
    let mut total_csv = Csv::new(vec![
        "k", "mean", "q1", "median", "q3", "whisker_lo", "whisker_hi", "outliers", "n",
    ]);

    for &k in &ks {
        let cfg = EmulatorConfig {
            executors: l,
            tasks_per_job: k,
            // The paper's Fig. 9 uses the fork-join experiments.
            mode: ModelKind::ForkJoinSingleQueue,
            interarrival: format!("exp:{lambda}"),
            execution: format!("exp:{}", k as f64 / l as f64),
            time_scale: scale_for(k),
            jobs,
            warmup: jobs / 10,
            seed: ctx.seed ^ (k as u64) << 1,
            inject_overhead: Some(OverheadConfig::paper()),
            workers: None,
        };
        let res = emulator::run(&cfg).map_err(anyhow::Error::msg)?;

        let fracs: Vec<f64> = res
            .listener
            .tasks
            .iter()
            .map(|t| t.overhead_fraction())
            .collect();
        let totals: Vec<f64> = res
            .measured_jobs()
            .map(|j| j.total_task_overhead)
            .collect();
        push_box(&mut frac_csv, k, &BoxStats::from_samples(&fracs));
        push_box(&mut total_csv, k, &BoxStats::from_samples(&totals));
    }

    let fp = ctx.out_dir.join("fig9a_overhead_fraction.csv");
    frac_csv.write_file(&fp)?;
    let tp = ctx.out_dir.join("fig9b_job_overhead.csv");
    total_csv.write_file(&tp)?;
    println!("fig9: {} k-points -> {} / {}", ks.len(), fp.display(), tp.display());
    Ok(())
}

fn push_box(csv: &mut Csv, k: usize, b: &BoxStats) {
    csv.push(&[
        k as f64,
        b.mean,
        b.q1,
        b.median,
        b.q3,
        b.whisker_lo,
        b.whisker_hi,
        b.outliers as f64,
        b.n as f64,
    ]);
}
