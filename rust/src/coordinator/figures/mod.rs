//! Figure pipelines: one module per paper figure, each regenerating the
//! figure's data as CSV under `reports/` (see DESIGN.md §4 for the
//! experiment index).

mod fig1_2;
mod fig3;
mod fig8;
mod fig9;
mod fig10;
mod fig11;
mod fig12;
mod fig13;
mod fig_faults;
mod fig_hetero;
mod fig_hetero_approx;
mod fig_policy;

pub use fig1_2::fig1_2;
pub use fig3::fig3;
pub use fig8::fig8;
pub use fig9::fig9;
pub use fig10::fig10;
pub use fig11::fig11;
pub use fig12::{fig12a, fig12b};
pub use fig13::fig13;
pub use fig_faults::{fig_faults, panel_faults};
pub use fig_hetero::{fig_hetero, two_class_speeds};
pub use fig_hetero_approx::fig_hetero_approx;
pub use fig_policy::fig_policy;

use anyhow::Result;
use std::path::Path;

/// How heavy to run a pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Minutes-scale: reduced job counts / coarser grids; same shapes.
    Quick,
    /// Paper-scale parameters (hours on a laptop for some figures).
    Paper,
}

impl Scale {
    /// Parse from the CLI flag.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "quick" => Ok(Self::Quick),
            "paper" => Ok(Self::Paper),
            _ => Err(format!("unknown scale {s:?} (quick|paper)")),
        }
    }
}

/// Common context handed to each pipeline.
pub struct FigureCtx<'a> {
    /// Output directory for CSVs.
    pub out_dir: &'a Path,
    /// Quick or paper scale.
    pub scale: Scale,
    /// Master seed.
    pub seed: u64,
    /// Bounds engine (artifact-backed when available).
    pub engine: &'a crate::runtime::BoundsEngine,
    /// Thread pool for simulation sweeps.
    pub pool: &'a crate::util::threadpool::ThreadPool,
}

/// All figure ids: the paper's figures in paper order, then the
/// beyond-the-paper scenario panels.
pub const ALL: &[&str] = &[
    "fig1-2", "fig3", "fig8", "fig9", "fig10", "fig11", "fig12a", "fig12b", "fig13",
    "hetero", "hetero-approx", "faults", "policy",
];

/// Run one figure by id.
pub fn run(id: &str, ctx: &FigureCtx) -> Result<()> {
    match id {
        "fig1-2" => fig1_2(ctx),
        "fig3" => fig3(ctx),
        "fig8" => fig8(ctx),
        "fig9" => fig9(ctx),
        "fig10" => fig10(ctx),
        "fig11" => fig11(ctx),
        "fig12a" => fig12a(ctx),
        "fig12b" => fig12b(ctx),
        "fig13" => fig13(ctx),
        "hetero" => fig_hetero(ctx),
        "hetero-approx" => fig_hetero_approx(ctx),
        "faults" => fig_faults(ctx),
        "policy" => fig_policy(ctx),
        "all" => {
            for id in ALL {
                println!("== {id} ==");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown figure {other:?}; known: {ALL:?} or 'all'"),
    }
}
