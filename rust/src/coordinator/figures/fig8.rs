//! Fig. 8: the headline figure — 0.99 sojourn-time quantile vs. tasks per
//! job for split-merge (a) and single-queue fork-join (b), l = 50,
//! λ = 0.5 s⁻¹, μ = k/l (constant E[L] = 50 s). Five series per panel:
//! sparklite ("Spark experiment"), simulation without/with overhead,
//! the clean analytic bound, and the Sec.-6 analytic approximation with
//! overhead.

use super::{FigureCtx, Scale};
use crate::config::{EmulatorConfig, ModelKind, OverheadConfig, SimulationConfig};
use crate::coordinator::sweep::{run_sweep, SweepPoint};
use crate::emulator;
use crate::runtime::BoundQuery;
use crate::util::csv::Csv;
use anyhow::Result;

pub fn fig8(ctx: &FigureCtx) -> Result<()> {
    let (l, lambda) = (50usize, 0.5);
    let eps = 0.01; // the paper's 0.99 quantile
    let oh = OverheadConfig::paper();

    let (ks, sim_jobs, emu_jobs, emu_ks): (Vec<usize>, usize, usize, Vec<usize>) =
        match ctx.scale {
            Scale::Quick => (
                vec![50, 100, 200, 400, 600, 1000, 1500, 2500],
                30_000,
                300,
                vec![400, 1000],
            ),
            Scale::Paper => (
                vec![50, 100, 150, 200, 300, 400, 600, 800, 1000, 1500, 2000, 2500, 3000],
                200_000,
                10_000,
                vec![100, 200, 400, 600, 1000, 1500, 2500],
            ),
        };
    // Per-k wall-time scale. The testbed has far fewer physical cores
    // than the paper's 50 single-core executors, so the emulator must be
    // sleep-dominated AND rate-limited: the wall task rate λ·k/scale is
    // capped at ~2000/s (each task costs ~20-50 µs of real scheduler/
    // serialization work) and mean task wall time stays ≥ 6 ms. See the
    // DESIGN.md §2 substitution note.
    let scale_for = |k: usize| (k as f64 * 2.5e-4).max(0.002);

    for (panel, model) in [("a_split_merge", ModelKind::SplitMerge), ("b_fork_join", ModelKind::ForkJoinSingleQueue)]
    {
        // --- analytic series via the engine (artifact hot path) ---
        let mk_query = |k: usize, overhead: Option<OverheadConfig>| BoundQuery {
            k,
            l,
            lambda,
            mu: k as f64 / l as f64,
            epsilon: eps,
            overhead,
        };
        let clean_rows = ctx
            .engine
            .bounds(&ks.iter().map(|&k| mk_query(k, None)).collect::<Vec<_>>())?;
        let oh_rows = ctx
            .engine
            .bounds(&ks.iter().map(|&k| mk_query(k, Some(oh))).collect::<Vec<_>>())?;

        // --- simulation series ---
        let mk_sim = |k: usize, overhead: Option<OverheadConfig>| SweepPoint {
            label: k as f64,
            config: SimulationConfig {
                model,
                servers: l,
                tasks_per_job: k,
                arrival: crate::config::ArrivalConfig {
                    interarrival: format!("exp:{lambda}"),
                },
                service: crate::config::ServiceConfig {
                    execution: format!("exp:{}", k as f64 / l as f64),
                },
                jobs: sim_jobs,
                warmup: sim_jobs / 10,
                seed: 0,
                overhead,
                workers: None,
                redundancy: None,
                faults: None,
                policy: None,
            },
        };
        let q = 1.0 - eps;
        let sim_clean = run_sweep(
            ctx.pool,
            ks.iter().map(|&k| mk_sim(k, None)).collect(),
            q,
            ctx.seed ^ 0x8a,
        )
        .map_err(anyhow::Error::msg)?;
        let sim_oh = run_sweep(
            ctx.pool,
            ks.iter().map(|&k| mk_sim(k, Some(oh))).collect(),
            q,
            ctx.seed ^ 0x8b,
        )
        .map_err(anyhow::Error::msg)?;

        // --- sparklite ("Spark experiment") series at selected k ---
        let mut emu_q: Vec<(usize, f64)> = Vec::new();
        for &k in &emu_ks {
            // Skip configurations that are unstable (quick scale would
            // just measure the transient backlog).
            let stable = crate::analysis::stability::sm_tiny_tasks(l, k) > 0.5
                || model == ModelKind::ForkJoinSingleQueue;
            if !stable {
                emu_q.push((k, f64::NAN));
                continue;
            }
            let cfg = EmulatorConfig {
                executors: l,
                tasks_per_job: k,
                mode: model,
                interarrival: format!("exp:{lambda}"),
                execution: format!("exp:{}", k as f64 / l as f64),
                time_scale: scale_for(k),
                jobs: emu_jobs,
                warmup: emu_jobs / 10,
                seed: ctx.seed ^ k as u64,
                inject_overhead: Some(oh),
                workers: None,
            };
            let mut res = emulator::run(&cfg).map_err(anyhow::Error::msg)?;
            emu_q.push((k, res.sojourn_quantile(q)));
        }

        let mut csv = Csv::new(vec![
            "k",
            "spark_emulator",
            "sim_no_overhead",
            "sim_overhead",
            "bound",
            "approx_overhead",
        ]);
        for (i, &k) in ks.iter().enumerate() {
            let (clean_b, oh_b) = match model {
                ModelKind::SplitMerge => (clean_rows[i].split_merge, oh_rows[i].split_merge),
                _ => (clean_rows[i].fork_join, oh_rows[i].fork_join),
            };
            let emu = emu_q
                .iter()
                .find(|&&(ek, _)| ek == k)
                .map(|&(_, v)| v)
                .unwrap_or(f64::NAN);
            // Mask simulated quantiles for unstable SM configurations.
            let stable_clean = model != ModelKind::SplitMerge
                || crate::analysis::stability::sm_tiny_tasks(l, k) > 0.5;
            csv.push(&[
                k as f64,
                emu,
                if stable_clean { sim_clean[i].sojourn_q } else { f64::NAN },
                if stable_clean { sim_oh[i].sojourn_q } else { f64::NAN },
                clean_b.unwrap_or(f64::NAN),
                oh_b.unwrap_or(f64::NAN),
            ]);
        }
        let path = ctx.out_dir.join(format!("fig8{panel}.csv"));
        csv.write_file(&path)?;
        println!("fig8{panel}: {} rows -> {}", ks.len(), path.display());
    }
    Ok(())
}
