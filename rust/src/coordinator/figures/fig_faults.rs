//! Fault-injection panel (beyond the paper): how task granularity
//! interacts with worker crashes, task failures, and retries.
//!
//! The tiny-tasks argument extends to fault tolerance: a failure (crash
//! or failed attempt) wastes at most one task's worth of service, so at
//! constant mean job workload the *work lost per failure event* shrinks
//! as ~1/k. The panel sweeps tasks-per-job k at constant workload
//! (μ = k/l) twice — a fault-free baseline and a faulty configuration
//! with Markov worker crashes plus per-attempt task failures — and
//! emits one CSV row per (config, k):
//!
//! `config,k,sojourn_q,sojourn_mean,overhead_mean,lost_mean,retries_mean,lost_per_retry`
//!
//! where `lost_mean` is the mean crashed-plus-failed-attempt service
//! time per job, `retries_mean` the mean retry count per job, and
//! `lost_per_retry` their ratio — the work lost per failure event,
//! which must decrease in k (test-enforced in
//! `rust/tests/fault_injection.rs` and asserted by the CI smoke job).

use super::{FigureCtx, Scale};
use crate::config::{FaultsConfig, ModelKind, OverheadConfig};
use crate::coordinator::sweep::{constant_workload_points, run_sweep};
use crate::util::csv::Csv;
use anyhow::Result;

/// The faulty configuration swept against the baseline: worker crashes
/// every 50 s of up-time (1 s repair) plus a 2% per-attempt failure
/// probability with three bounded retries.
pub fn panel_faults() -> FaultsConfig {
    FaultsConfig {
        mtbf: 50.0,
        mttr: 1.0,
        task_fail_p: 0.02,
        max_retries: 3,
        backoff_base: 0.01,
        ..Default::default()
    }
}

pub fn fig_faults(ctx: &FigureCtx) -> Result<()> {
    let l = 10usize;
    let lambda = 0.4;
    let eps = 0.01;
    let oh = OverheadConfig::paper();
    let (ks, jobs): (Vec<usize>, usize) = match ctx.scale {
        Scale::Quick => (vec![10, 20, 40, 80, 160], 6_000),
        Scale::Paper => (vec![10, 20, 40, 80, 160, 320, 640], 40_000),
    };
    let configs: [(&str, Option<FaultsConfig>); 2] =
        [("baseline", None), ("faults", Some(panel_faults()))];

    let mut csv = Csv::new(vec![
        "config",
        "k",
        "sojourn_q",
        "sojourn_mean",
        "overhead_mean",
        "lost_mean",
        "retries_mean",
        "lost_per_retry",
    ]);
    for (cfg_i, (label, faults)) in configs.iter().enumerate() {
        let points = constant_workload_points(
            ModelKind::ForkJoinSingleQueue,
            l,
            lambda,
            l as f64,
            jobs,
            Some(oh),
            None,
            None,
            *faults,
            None,
            &ks,
        )
        .map_err(anyhow::Error::msg)?;
        let sims = run_sweep(ctx.pool, points, 1.0 - eps, ctx.seed ^ (0xFA17 + cfg_i as u64))
            .map_err(anyhow::Error::msg)?;
        for sim in &sims {
            let lost_per_retry =
                if sim.retry_mean > 0.0 { sim.lost_mean / sim.retry_mean } else { 0.0 };
            csv.push_raw(vec![
                label.to_string(),
                sim.label.to_string(),
                sim.sojourn_q.to_string(),
                sim.sojourn_mean.to_string(),
                sim.overhead_mean.to_string(),
                sim.lost_mean.to_string(),
                sim.retry_mean.to_string(),
                lost_per_retry.to_string(),
            ]);
        }
    }
    let path = ctx.out_dir.join("faults_panel.csv");
    csv.write_file(&path)?;
    println!(
        "faults: {} rows ({} configs x {} ks) -> {}",
        csv.len(),
        configs.len(),
        ks.len(),
        path.display()
    );
    Ok(())
}
