//! Heterogeneous-approximation panel (beyond the paper): how well does
//! the `approx` subsystem's analytic sojourn quantile track the
//! simulated quantile across skewed-speed and redundancy scenarios?
//!
//! Three configurations — two skew levels of the capacity-preserving
//! two-class cluster (r = 1) and one redundant variant (r = 2) — are
//! swept over tasks-per-job k at constant mean workload (μ = k/l) with
//! the paper overhead model. One CSV row per (config, k):
//!
//! `config,skew,replicas,k,analytic_q,sim_q`
//!
//! where `analytic_q` is the [`crate::approx`] sojourn ε-quantile (NaN
//! when the approximation's stability condition fails) and `sim_q` the
//! simulated (1−ε)-quantile of the same scenario.

use super::{two_class_speeds, FigureCtx, Scale};
use crate::approx::{self, ApproxModel, ClusterSpec};
use crate::config::{ModelKind, OverheadConfig, RedundancyConfig, WorkersConfig};
use crate::coordinator::sweep::{constant_workload_points, run_sweep};
use crate::util::csv::Csv;
use anyhow::Result;

pub fn fig_hetero_approx(ctx: &FigureCtx) -> Result<()> {
    let l = 10usize;
    let lambda = 0.4;
    let eps = 0.01;
    let oh = OverheadConfig::paper();
    let (ks, jobs): (Vec<usize>, usize) = match ctx.scale {
        Scale::Quick => (vec![10, 20, 40, 80, 160], 8_000),
        Scale::Paper => (vec![10, 20, 40, 80, 160, 320, 640, 1280], 60_000),
    };
    // (label, skew, replicas): two skewed-speed panels + one redundancy
    // panel, the acceptance set of the hetero-approx pipeline.
    let configs: [(&str, f64, usize); 3] =
        [("skew25", 0.25, 1), ("skew50", 0.5, 1), ("skew50-r2", 0.5, 2)];

    let mut csv = Csv::new(vec!["config", "skew", "replicas", "k", "analytic_q", "sim_q"]);
    for (cfg_i, &(label, skew, replicas)) in configs.iter().enumerate() {
        let speeds = two_class_speeds(l, skew);
        let spec = ClusterSpec::new(speeds.clone(), replicas, 0.0)
            .map_err(anyhow::Error::msg)?;
        let analytic = approx::sojourn_curve(
            ApproxModel::ForkJoin,
            &spec,
            lambda,
            l as f64,
            eps,
            Some(oh),
            &ks,
        );
        let points = constant_workload_points(
            ModelKind::ForkJoinSingleQueue,
            l,
            lambda,
            l as f64,
            jobs,
            Some(oh),
            Some(WorkersConfig::Speeds(speeds.clone())),
            if replicas > 1 {
                Some(RedundancyConfig::new(replicas))
            } else {
                None
            },
            None,
            None,
            &ks,
        )
        .map_err(anyhow::Error::msg)?;
        let sims = run_sweep(ctx.pool, points, 1.0 - eps, ctx.seed ^ (0xa99 + cfg_i as u64))
            .map_err(anyhow::Error::msg)?;
        for (pt, sim) in analytic.iter().zip(&sims) {
            let analytic_txt = pt
                .sojourn
                .map(|t| t.to_string())
                .unwrap_or_else(|| "nan".into());
            csv.push_raw(vec![
                label.to_string(),
                skew.to_string(),
                replicas.to_string(),
                pt.k.to_string(),
                analytic_txt,
                sim.sojourn_q.to_string(),
            ]);
        }
    }
    let path = ctx.out_dir.join("hetero_approx_panel.csv");
    csv.write_file(&path)?;
    println!(
        "hetero-approx: {} rows ({} configs x {} ks) -> {}",
        csv.len(),
        configs.len(),
        ks.len(),
        path.display()
    );
    Ok(())
}
