//! Heterogeneous-worker panel (beyond the paper): where does the
//! tiny-tasks sweet spot move when worker speeds are skewed, and how much
//! of the skew penalty does first-finish-wins redundancy buy back?
//!
//! Sweeps speed skew σ × tasks-per-job k for the single-queue fork-join
//! model at constant mean workload (μ = k/l) and paper overhead. Workers
//! split into a fast half (speed 1 + σ) and a slow half (speed 1 − σ), so
//! aggregate capacity Σ speeds = l is held fixed across σ — any quantile
//! shift is pure skew, not capacity. One CSV row per (σ, k):
//!
//! `skew,k,q_r1,q_r2,mean_r1,mean_r2,redundant_r2`
//!
//! where `q_*` is the 0.99 sojourn quantile without (r = 1) and with
//! (r = 2) redundancy and `redundant_r2` is the mean cancelled-replica
//! server time per job.

use super::{FigureCtx, Scale};
use crate::config::{ModelKind, OverheadConfig, RedundancyConfig, SimulationConfig, WorkersConfig};
use crate::coordinator::sweep::{run_sweep, SweepPoint};
use crate::util::csv::Csv;
use anyhow::Result;

/// Two-class speed vector: half the workers at `1 + skew`, half at
/// `1 − skew` (capacity-preserving for even l).
pub fn two_class_speeds(l: usize, skew: f64) -> Vec<f64> {
    assert!(l % 2 == 0, "two-class skew needs an even worker count");
    assert!((0.0..1.0).contains(&skew), "skew must be in [0, 1)");
    let mut speeds = vec![1.0 + skew; l / 2];
    speeds.resize(l, 1.0 - skew);
    speeds
}

pub fn fig_hetero(ctx: &FigureCtx) -> Result<()> {
    let l = 10usize;
    let lambda = 0.4;
    let eps = 0.01;
    let (ks, jobs): (Vec<usize>, usize) = match ctx.scale {
        Scale::Quick => (vec![10, 20, 40, 80, 160], 8_000),
        Scale::Paper => (vec![10, 20, 40, 80, 160, 320, 640, 1280], 60_000),
    };
    let skews = [0.0, 0.25, 0.5, 0.75];

    let mk = |k: usize, skew: f64, replicas: usize| SweepPoint {
        label: k as f64,
        config: SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: l,
            tasks_per_job: k,
            arrival: crate::config::ArrivalConfig { interarrival: format!("exp:{lambda}") },
            service: crate::config::ServiceConfig {
                execution: format!("exp:{}", k as f64 / l as f64),
            },
            jobs,
            warmup: jobs / 10,
            seed: 0, // reseeded per point by run_sweep
            overhead: Some(OverheadConfig::paper()),
            workers: if skew > 0.0 {
                Some(WorkersConfig::Speeds(two_class_speeds(l, skew)))
            } else {
                None
            },
            redundancy: if replicas > 1 {
                Some(RedundancyConfig::new(replicas))
            } else {
                None
            },
            faults: None,
            policy: None,
        },
    };

    let mut csv = Csv::new(vec![
        "skew",
        "k",
        "q_r1",
        "q_r2",
        "mean_r1",
        "mean_r2",
        "redundant_r2",
    ]);
    for &skew in &skews {
        let r1 = run_sweep(
            ctx.pool,
            ks.iter().map(|&k| mk(k, skew, 1)).collect(),
            1.0 - eps,
            ctx.seed ^ 0x4e7e,
        )
        .map_err(anyhow::Error::msg)?;
        let r2 = run_sweep(
            ctx.pool,
            ks.iter().map(|&k| mk(k, skew, 2)).collect(),
            1.0 - eps,
            ctx.seed ^ 0x4e7f,
        )
        .map_err(anyhow::Error::msg)?;
        for ((&k, a), b) in ks.iter().zip(&r1).zip(&r2) {
            csv.push(&[
                skew,
                k as f64,
                a.sojourn_q,
                b.sojourn_q,
                a.sojourn_mean,
                b.sojourn_mean,
                b.redundant_mean,
            ]);
        }
    }
    let path = ctx.out_dir.join("hetero_panel.csv");
    csv.write_file(&path)?;
    println!(
        "hetero: {} rows ({} skews x {} ks) -> {}",
        csv.len(),
        skews.len(),
        ks.len(),
        path.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_class_speeds_preserve_capacity() {
        for skew in [0.0, 0.25, 0.5, 0.75] {
            let speeds = two_class_speeds(10, skew);
            assert_eq!(speeds.len(), 10);
            let sum: f64 = speeds.iter().sum();
            assert!((sum - 10.0).abs() < 1e-12, "skew {skew}: Σ={sum}");
        }
    }

    #[test]
    #[should_panic(expected = "even worker count")]
    fn odd_worker_count_rejected() {
        two_class_speeds(7, 0.5);
    }
}
