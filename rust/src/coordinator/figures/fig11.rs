//! Fig. 11: stability regions vs. tasks per job for split-merge and
//! fork-join, with and without the overhead model, l = 50. Split-merge's
//! region climbs toward 1 with tinyfication, then falls past k ≈ 2000 as
//! overhead dominates; fork-join starts at 1 and degrades gradually.

use super::{FigureCtx, Scale};
use crate::config::OverheadConfig;
use crate::dist::{Distribution, Exponential};
use crate::sim::stability::{fj_max_utilization, sm_max_utilization};
use crate::sim::OverheadModel;
use crate::util::csv::Csv;
use anyhow::Result;

pub fn fig11(ctx: &FigureCtx) -> Result<()> {
    let l = 50usize;
    let (ks, samples): (Vec<usize>, usize) = match ctx.scale {
        Scale::Quick => (
            vec![50, 100, 200, 400, 700, 1000, 1500, 2000, 3000, 4000, 6000],
            4_000,
        ),
        Scale::Paper => (
            vec![
                50, 75, 100, 150, 200, 300, 400, 500, 700, 1000, 1300, 1600, 2000, 2500,
                3000, 4000, 5000, 6000, 8000,
            ],
            40_000,
        ),
    };

    let mut csv = Csv::new(vec![
        "k",
        "sm_no_overhead",
        "sm_overhead",
        "fj_no_overhead",
        "fj_overhead",
        "sm_eq20_closed_form",
    ]);
    // Closed-form Eq. 20 series through the engine (artifact hot path).
    let eq20 = ctx
        .engine
        .stability(&ks.iter().map(|&k| (k, l)).collect::<Vec<_>>())?;

    for (i, &k) in ks.iter().enumerate() {
        // μ = k/l keeps E[L] = l·1s constant, as everywhere in the paper.
        let mu = k as f64 / l as f64;
        let exec = Exponential::new(mu);
        let clean = OverheadModel::none();
        let paper = OverheadModel::new(OverheadConfig::paper());
        let sm_clean = sm_max_utilization(l, k, &exec, &clean, samples, ctx.seed ^ k as u64);
        let sm_oh = sm_max_utilization(l, k, &exec, &paper, samples, ctx.seed ^ k as u64);
        let fj_clean = fj_max_utilization(exec.mean(), &clean);
        let fj_oh = fj_max_utilization(exec.mean(), &paper);
        csv.push(&[k as f64, sm_clean, sm_oh, fj_clean, fj_oh, eq20[i]]);
    }
    let path = ctx.out_dir.join("fig11_stability.csv");
    csv.write_file(&path)?;
    println!("fig11: {} rows -> {}", ks.len(), path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Fig.-11 shape: SM-with-overhead peaks and then declines; FJ
    /// with overhead declines monotonically from ~1.
    #[test]
    fn stability_shapes() {
        let l = 50;
        let paper = OverheadModel::new(OverheadConfig::paper());
        let clean = OverheadModel::none();
        let rho = |k: usize, oh: &OverheadModel| {
            let mu = k as f64 / l as f64;
            sm_max_utilization(l, k, &Exponential::new(mu), oh, 4_000, 9)
        };
        // Clean: monotone increasing in k.
        assert!(rho(200, &clean) < rho(2000, &clean));
        // With overhead: k=2000 is past the peak vs k=8000 declining.
        let peak_region = rho(2000, &paper);
        let tail = rho(8000, &paper);
        assert!(tail < peak_region, "{tail} !< {peak_region}");
        // FJ: overhead pushes below 1, worse at larger k.
        let fj_2000 = fj_max_utilization(50.0 / 2000.0, &paper);
        let fj_200 = fj_max_utilization(50.0 / 200.0, &paper);
        assert!(fj_2000 < fj_200 && fj_200 < 1.0);
    }
}
