//! Fig. 3: sojourn-time quantile scaling vs. number of servers for the
//! conventional (k = l) models — split-merge, per-server fork-join,
//! single-queue fork-join, and the ideal partition. Bounds from the
//! analysis/artifact engine; simulation of each model alongside.
//! λ = 0.2, μ = 1.0 as in the paper.

use super::{FigureCtx, Scale};
use crate::analysis::{self, BoundModel, BoundParams};
use crate::config::{ModelKind, SimulationConfig};
use crate::coordinator::sweep::{run_sweep, SweepPoint};
use crate::runtime::BoundQuery;
use crate::util::csv::Csv;
use anyhow::Result;

pub fn fig3(ctx: &FigureCtx) -> Result<()> {
    let (lambda, mu) = (0.2, 1.0);
    let (eps, jobs) = match ctx.scale {
        // The paper evaluates bounds at ε = 1e-6; simulating that tail
        // needs ~1e7 jobs/point, so quick scale uses the 0.99 quantile.
        Scale::Quick => (1e-2, 30_000usize),
        Scale::Paper => (1e-3, 2_000_000usize),
    };
    let ls: Vec<usize> = match ctx.scale {
        Scale::Quick => vec![1, 2, 4, 8, 16, 32, 64, 128],
        Scale::Paper => vec![1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256],
    };

    // Bounds: SQ-FJ + ideal via the engine (artifact path); SM (k=l) and
    // per-server FJ via the analysis module (conventional models).
    let queries: Vec<BoundQuery> = ls
        .iter()
        .map(|&l| BoundQuery { k: l, l, lambda, mu, epsilon: eps, overhead: None })
        .collect();
    let engine_rows = ctx.engine.bounds(&queries)?;

    let mut csv = Csv::new(vec![
        "l",
        "bound_split_merge",
        "bound_fork_join_ps",
        "bound_sq_fork_join",
        "bound_ideal",
        "sim_split_merge",
        "sim_fork_join_ps",
        "sim_sq_fork_join",
        "sim_ideal",
    ]);

    // Simulations for all four models at each l.
    let mk = |model: ModelKind, l: usize| SweepPoint {
        label: l as f64,
        config: SimulationConfig {
            model,
            servers: l,
            tasks_per_job: l,
            arrival: crate::config::ArrivalConfig {
                interarrival: format!("exp:{lambda}"),
            },
            service: crate::config::ServiceConfig { execution: format!("exp:{mu}") },
            jobs,
            warmup: jobs / 10,
            seed: 0,
            overhead: None,
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        },
    };
    let q = 1.0 - eps;
    let sim_sm = run_sweep(
        ctx.pool,
        ls.iter().map(|&l| mk(ModelKind::SplitMerge, l)).collect(),
        q,
        ctx.seed ^ 1,
    )
    .map_err(anyhow::Error::msg)?;
    let sim_fjps = run_sweep(
        ctx.pool,
        ls.iter().map(|&l| mk(ModelKind::ForkJoinPerServer, l)).collect(),
        q,
        ctx.seed ^ 2,
    )
    .map_err(anyhow::Error::msg)?;
    let sim_sqfj = run_sweep(
        ctx.pool,
        ls.iter().map(|&l| mk(ModelKind::ForkJoinSingleQueue, l)).collect(),
        q,
        ctx.seed ^ 3,
    )
    .map_err(anyhow::Error::msg)?;
    let sim_ideal = run_sweep(
        ctx.pool,
        ls.iter().map(|&l| mk(ModelKind::Ideal, l)).collect(),
        q,
        ctx.seed ^ 4,
    )
    .map_err(anyhow::Error::msg)?;

    for (i, &l) in ls.iter().enumerate() {
        let p = BoundParams { l, k: l, lambda, mu, epsilon: eps, overhead: None };
        let bound_sm = analysis::sojourn_bound(BoundModel::SplitMergeTiny, &p);
        let bound_fjps = analysis::sojourn_bound(BoundModel::ForkJoinPerServer, &p);
        csv.push(&[
            l as f64,
            bound_sm.unwrap_or(f64::NAN),
            bound_fjps.unwrap_or(f64::NAN),
            engine_rows[i].fork_join.unwrap_or(f64::NAN),
            engine_rows[i].ideal.unwrap_or(f64::NAN),
            // SM is unstable for larger l at ρ=0.2·H_l>1… report the
            // simulated quantile regardless; NaN when λE[Δ] ≥ 1.
            sim_or_nan(&sim_sm[i], l, lambda, mu),
            sim_fjps[i].sojourn_q,
            sim_sqfj[i].sojourn_q,
            sim_ideal[i].sojourn_q,
        ]);
    }
    let path = ctx.out_dir.join("fig3_scaling.csv");
    csv.write_file(&path)?;
    println!("fig3: {} rows -> {}", ls.len(), path.display());
    Ok(())
}

/// Split-merge diverges once λ·E[Δ] ≥ 1; mask the meaningless quantile.
fn sim_or_nan(out: &crate::coordinator::sweep::SweepOutcome, l: usize, lambda: f64, mu: f64) -> f64 {
    let stable = lambda * crate::analysis::lemma1::mean_service(l, l, mu) < 1.0;
    if stable {
        out.sojourn_q
    } else {
        f64::NAN
    }
}
