//! Fig. 12: direct refinement of big tasks into tiny tasks (Sec. 4.1),
//! μ = κ = 20 so utilization = λ. (a) stability regions vs. l — tiny
//! (Eq. 20) vs. big (Eq. 23, Erlang-max integration); (b) sojourn-time
//! bounds vs. l at utilizations 0.5/0.6/0.7.

use super::{FigureCtx, Scale};
use crate::runtime::{BoundQuery, ErlangQuery};
use crate::util::csv::Csv;
use anyhow::Result;

const KAPPA: u32 = 20;
const MU: f64 = 20.0;

fn ls(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![1, 2, 4, 8, 16, 32, 64],
        Scale::Paper => vec![1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128],
    }
}

pub fn fig12a(ctx: &FigureCtx) -> Result<()> {
    let ls = ls(ctx.scale);
    // Tiny: Eq. 20 closed form (stability artifact); big: Erlang artifact.
    let tiny = ctx
        .engine
        .stability(&ls.iter().map(|&l| (KAPPA as usize * l, l)).collect::<Vec<_>>())?;
    let big_rows = ctx.engine.erlang(
        &ls.iter()
            .map(|&l| ErlangQuery { l, kappa: KAPPA, lambda: 0.5, mu: MU, epsilon: 1e-6 })
            .collect::<Vec<_>>(),
    )?;

    let mut csv = Csv::new(vec!["l", "tiny_tasks_eq20", "big_tasks_eq23"]);
    for (i, &l) in ls.iter().enumerate() {
        csv.push(&[l as f64, tiny[i], big_rows[i].max_utilization]);
    }
    let path = ctx.out_dir.join("fig12a_stability.csv");
    csv.write_file(&path)?;
    println!("fig12a: {} rows -> {}", ls.len(), path.display());
    Ok(())
}

pub fn fig12b(ctx: &FigureCtx) -> Result<()> {
    let ls = ls(ctx.scale);
    let eps = 1e-6;
    let utils = [0.5, 0.6, 0.7];

    let mut csv = Csv::new(vec![
        "l",
        "tiny_rho_0.5",
        "big_rho_0.5",
        "tiny_rho_0.6",
        "big_rho_0.6",
        "tiny_rho_0.7",
        "big_rho_0.7",
    ]);

    // Tiny bounds via the bounds artifact; big via the Erlang artifact.
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); 6];
    for (ui, &rho) in utils.iter().enumerate() {
        let lambda = rho; // utilization = λκ/μ = λ at μ = κ = 20
        let tiny_rows = ctx.engine.bounds(
            &ls.iter()
                .map(|&l| BoundQuery {
                    k: KAPPA as usize * l,
                    l,
                    lambda,
                    mu: MU,
                    epsilon: eps,
                    overhead: None,
                })
                .collect::<Vec<_>>(),
        )?;
        let big_rows = ctx.engine.erlang(
            &ls.iter()
                .map(|&l| ErlangQuery { l, kappa: KAPPA, lambda, mu: MU, epsilon: eps })
                .collect::<Vec<_>>(),
        )?;
        for i in 0..ls.len() {
            cols[2 * ui].push(tiny_rows[i].split_merge.unwrap_or(f64::NAN));
            cols[2 * ui + 1].push(big_rows[i].sojourn.unwrap_or(f64::NAN));
        }
    }
    for (i, &l) in ls.iter().enumerate() {
        csv.push(&[
            l as f64, cols[0][i], cols[1][i], cols[2][i], cols[3][i], cols[4][i], cols[5][i],
        ]);
    }
    let path = ctx.out_dir.join("fig12b_bounds.csv");
    csv.write_file(&path)?;
    println!("fig12b: {} rows -> {}", ls.len(), path.display());
    Ok(())
}
