//! Scheduling-policy panel (beyond the paper): how task granularity
//! interacts with the dispatch discipline.
//!
//! The paper's dispatch rule is FCFS to the earliest-free server; this
//! panel sweeps tasks-per-job k at constant mean job workload (μ = k/l)
//! once per policy — FCFS, degenerate single-interval SITA, SITA with a
//! boundary at the mean task size, two-class priority, and work
//! stealing — and emits one CSV row per (policy, k):
//!
//! `config,k,sojourn_q,sojourn_mean,overhead_mean,class0_mean,class1_mean`
//!
//! Every policy runs on the SAME master seed, so the `fcfs` and `sita1`
//! rows must agree bitwise at every k: a single size interval routes
//! every task to the one all-server partition, which is exactly the
//! FCFS earliest-free dispatch (test-enforced in
//! `rust/tests/policy_equivalence.rs` and asserted by the CI policy
//! smoke job against this CSV). `class0_mean`/`class1_mean` are the
//! per-class mean sojourns (priority rows only; `nan` elsewhere).
//!
//! The size-dependent knobs scale with k: the SITA boundary and the
//! steal threshold both sit at the mean task size l/k, so every k sees
//! the same *relative* policy shape.

use super::{FigureCtx, Scale};
use crate::config::{ModelKind, OverheadConfig, PolicyConfig, PolicyKind};
use crate::coordinator::sweep::{constant_workload_points, run_sweep, SweepPoint};
use crate::util::csv::Csv;
use anyhow::Result;

/// The swept policies, with knobs scaled to the mean task size at k.
fn panel_policy(label: &str, mean_task: f64) -> Option<PolicyConfig> {
    match label {
        "fcfs" => None,
        // Single size interval: active policy state, degenerate routing.
        "sita1" => Some(PolicyConfig { kind: PolicyKind::Sita, ..Default::default() }),
        "sita" => Some(PolicyConfig {
            kind: PolicyKind::Sita,
            sita_boundaries: vec![mean_task],
            ..Default::default()
        }),
        "priority" => Some(PolicyConfig {
            kind: PolicyKind::Priority,
            classes: 2,
            weights: vec![2.0, 1.0],
            ..Default::default()
        }),
        "worksteal" => Some(PolicyConfig {
            kind: PolicyKind::WorkSteal,
            steal_threshold: mean_task,
            ..Default::default()
        }),
        other => unreachable!("unknown panel policy {other:?}"),
    }
}

pub fn fig_policy(ctx: &FigureCtx) -> Result<()> {
    let l = 10usize;
    let lambda = 0.4;
    let eps = 0.01;
    let oh = OverheadConfig::paper();
    let (ks, jobs): (Vec<usize>, usize) = match ctx.scale {
        Scale::Quick => (vec![10, 20, 40, 80, 160], 6_000),
        Scale::Paper => (vec![10, 20, 40, 80, 160, 320, 640], 40_000),
    };
    let configs = ["fcfs", "sita1", "sita", "priority", "worksteal"];

    let mut csv = Csv::new(vec![
        "config",
        "k",
        "sojourn_q",
        "sojourn_mean",
        "overhead_mean",
        "class0_mean",
        "class1_mean",
    ]);
    for label in configs {
        // One point per k so the size-dependent knobs can track l/k;
        // points stay in k order, so run_sweep's per-index reseeding
        // gives every policy the identical seed at the same k — that is
        // what makes the fcfs and sita1 rows comparable bitwise.
        let mut points: Vec<SweepPoint> = Vec::with_capacity(ks.len());
        for &k in &ks {
            points.extend(
                constant_workload_points(
                    ModelKind::ForkJoinSingleQueue,
                    l,
                    lambda,
                    l as f64,
                    jobs,
                    Some(oh),
                    None,
                    None,
                    None,
                    panel_policy(label, l as f64 / k as f64),
                    &[k],
                )
                .map_err(anyhow::Error::msg)?,
            );
        }
        // Same master seed for every policy (see above).
        let sims = run_sweep(ctx.pool, points, 1.0 - eps, ctx.seed ^ 0x701C)
            .map_err(anyhow::Error::msg)?;
        for sim in &sims {
            let class = |c: usize| {
                sim.class_sojourn_mean
                    .get(c)
                    .map(|m| m.to_string())
                    .unwrap_or_else(|| "nan".into())
            };
            csv.push_raw(vec![
                label.to_string(),
                sim.label.to_string(),
                sim.sojourn_q.to_string(),
                sim.sojourn_mean.to_string(),
                sim.overhead_mean.to_string(),
                class(0),
                class(1),
            ]);
        }
    }
    let path = ctx.out_dir.join("policy_panel.csv");
    csv.write_file(&path)?;
    println!(
        "policy: {} rows ({} policies x {} ks) -> {}",
        csv.len(),
        configs.len(),
        ks.len(),
        path.display()
    );
    Ok(())
}
