//! Task-granularity advisor — the paper's concluding application: "our
//! analytical approximation model which includes scheduling overhead can
//! also be used to optimize task granularity on real systems" (Sec. 7).
//!
//! Given a cluster (l workers), an arrival rate, a mean job workload and
//! an overhead model, sweep k over a log grid through the Sec.-6
//! approximation and return the k minimizing the sojourn ε-quantile.
//!
//! For scenarios the analytic layer does not cover — heterogeneous worker
//! speeds and task redundancy — [`recommend_simulated`] answers the same
//! question by sweeping k through the simulator on the thread pool.

use crate::config::{ModelKind, OverheadConfig, SimulationConfig};
use crate::coordinator::sweep::{run_sweep, SweepPoint};
use crate::runtime::{BoundQuery, BoundsEngine};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;

/// Advisor output: the recommended k and the full curve for context.
#[derive(Clone, Debug)]
pub struct Recommendation {
    /// `(k, τ_ε)` of the best stable point, if any.
    pub best: Option<(usize, f64)>,
    /// The evaluated `(k, τ_ε)` curve (None = unstable at that k).
    pub curve: Vec<(usize, Option<f64>)>,
}

/// Sweep k ∈ {l, 2l, … } (log-ish grid) and pick the minimizer.
pub fn recommend(
    engine: &BoundsEngine,
    model: ModelKind,
    l: usize,
    lambda: f64,
    mean_workload: f64,
    epsilon: f64,
    overhead: OverheadConfig,
) -> Result<Recommendation> {
    // κ grid: 1..~200 in multiplicative steps.
    let ks = k_grid(l, 200.0);

    let queries: Vec<BoundQuery> = ks
        .iter()
        .map(|&k| BoundQuery {
            k,
            l,
            lambda,
            // Tasks sized so k·E[Q_exec] = mean workload.
            mu: k as f64 / mean_workload,
            epsilon,
            overhead: Some(overhead),
        })
        .collect();
    let rows = engine.bounds(&queries)?;
    let mut curve = Vec::with_capacity(ks.len());
    let mut best: Option<(usize, f64)> = None;
    for (&k, row) in ks.iter().zip(&rows) {
        let tau = match model {
            ModelKind::SplitMerge => row.split_merge,
            _ => row.fork_join,
        };
        if let Some(t) = tau {
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((k, t));
            }
        }
        curve.push((k, tau));
    }
    Ok(Recommendation { best, curve })
}

/// The advisor's κ grid: k ∈ {l, 1.3·l, …} up to `kappa_max`·l.
pub fn k_grid(l: usize, kappa_max: f64) -> Vec<usize> {
    let mut ks = Vec::new();
    let mut kappa = 1.0f64;
    while kappa <= kappa_max {
        let k = (kappa * l as f64).round() as usize;
        if ks.last() != Some(&k) {
            ks.push(k);
        }
        kappa *= 1.3;
    }
    ks
}

/// Simulation-backed recommendation for scenarios outside the analytic
/// models' reach: heterogeneous worker speeds and task redundancy (the
/// `base` config's `[workers]` / `[redundancy]` sections are honoured).
///
/// For each k in `ks`, tasks are sized so the mean job workload
/// `k · E[exec]` equals `mean_workload`, the sweep runs on `pool` with
/// per-point seeds derived from `base.seed`, and the k minimizing the
/// simulated sojourn (1 − ε)-quantile wins.
pub fn recommend_simulated(
    pool: &ThreadPool,
    base: &SimulationConfig,
    mean_workload: f64,
    epsilon: f64,
    ks: &[usize],
) -> Result<Recommendation, String> {
    if !(mean_workload > 0.0 && mean_workload.is_finite()) {
        return Err(format!("mean workload must be positive, got {mean_workload}"));
    }
    if base.model == ModelKind::ForkJoinPerServer {
        return Err(
            "the simulated advisor sweeps tasks-per-job, which the per-server \
             fork-join model pins to k = l; use sm, fj, or ideal"
                .into(),
        );
    }
    let points: Vec<SweepPoint> = ks
        .iter()
        .map(|&k| SweepPoint {
            label: k as f64,
            config: SimulationConfig {
                tasks_per_job: k,
                service: crate::config::ServiceConfig {
                    execution: format!("exp:{}", k as f64 / mean_workload),
                },
                ..base.clone()
            },
        })
        .collect();
    let outcomes = run_sweep(pool, points, 1.0 - epsilon, base.seed)?;
    let mut curve = Vec::with_capacity(outcomes.len());
    let mut best: Option<(usize, f64)> = None;
    for o in &outcomes {
        let k = o.label as usize;
        let tau = o.sojourn_q;
        if tau.is_finite() {
            if best.map(|(_, bt)| tau < bt).unwrap_or(true) {
                best = Some((k, tau));
            }
            curve.push((k, Some(tau)));
        } else {
            curve.push((k, None));
        }
    }
    Ok(Recommendation { best, curve })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// With paper overhead the advisor picks an interior k: larger than
    /// l (tinyfication helps) but far from the maximum (overhead hurts)
    /// — the existence of the trade-off optimum is the paper's thesis.
    #[test]
    fn recommends_interior_optimum() {
        let engine = BoundsEngine::native();
        let rec = recommend(
            &engine,
            ModelKind::ForkJoinSingleQueue,
            50,
            0.5,
            50.0,
            0.01,
            OverheadConfig::paper(),
        )
        .unwrap();
        let (k, _tau) = rec.best.expect("stable configuration exists");
        assert!(k > 50, "tinyfication should help: k={k}");
        let k_max = rec.curve.last().unwrap().0;
        assert!(k < k_max / 2, "overhead should cap k: k={k} of {k_max}");
        // Sanity: the curve is not monotone (has an interior minimum).
        let feasible: Vec<f64> = rec.curve.iter().filter_map(|&(_, t)| t).collect();
        let min = feasible.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(*feasible.last().unwrap() > min, "tail should rise");
    }

    /// Simulated advisor on a skewed cluster: it returns a stable
    /// recommendation, and with redundancy the recommended quantile at
    /// the same k-grid stays finite. End-to-end sanity of the
    /// heterogeneous path ("what k, given skewed workers?").
    #[test]
    fn simulated_advisor_handles_skewed_workers() {
        use crate::config::{RedundancyConfig, WorkersConfig};
        let l = 8usize;
        let base = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: l,
            tasks_per_job: l, // overridden per sweep point
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.4".into() },
            service: crate::config::ServiceConfig { execution: "exp:1.0".into() },
            jobs: 4_000,
            warmup: 400,
            seed: 11,
            overhead: Some(OverheadConfig::paper()),
            workers: Some(WorkersConfig::Speeds(vec![
                1.5, 1.5, 1.5, 1.5, 0.5, 0.5, 0.5, 0.5,
            ])),
            redundancy: Some(RedundancyConfig { replicas: 2 }),
        };
        let pool = ThreadPool::new(4);
        let ks = k_grid(l, 16.0);
        let rec = recommend_simulated(&pool, &base, l as f64, 0.05, &ks).unwrap();
        let (k, tau) = rec.best.expect("stable recommendation");
        assert!(ks.contains(&k));
        assert!(tau.is_finite() && tau > 0.0);
        assert_eq!(rec.curve.len(), ks.len());
    }

    #[test]
    fn k_grid_is_increasing_and_deduped() {
        let ks = k_grid(10, 200.0);
        assert_eq!(ks[0], 10);
        for w in ks.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(*ks.last().unwrap() >= 1500);
    }

    /// Without overhead, more tinyfication is always better (the curve
    /// is non-increasing), so the advisor picks the largest k.
    #[test]
    fn no_overhead_prefers_maximum_k() {
        let engine = BoundsEngine::native();
        let rec = recommend(
            &engine,
            ModelKind::ForkJoinSingleQueue,
            20,
            0.5,
            20.0,
            0.01,
            OverheadConfig::zero(),
        )
        .unwrap();
        let (k, _) = rec.best.unwrap();
        let k_max = rec.curve.last().unwrap().0;
        assert!(k as f64 > 0.5 * k_max as f64, "k={k} vs max {k_max}");
    }
}
