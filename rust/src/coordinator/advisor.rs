//! Task-granularity advisor — the paper's concluding application: "our
//! analytical approximation model which includes scheduling overhead can
//! also be used to optimize task granularity on real systems" (Sec. 7).
//!
//! Given a cluster (l workers), an arrival rate, a mean job workload and
//! an overhead model, sweep k over a log grid through the Sec.-6
//! approximation and return the k minimizing the sojourn ε-quantile.
//!
//! Heterogeneous / redundant clusters are answered analytically by
//! [`recommend_approx`] through the [`crate::approx`] subsystem
//! (microseconds per query; bit-for-bit the homogeneous answer in the
//! degenerate scenario), and by [`recommend_simulated`], which sweeps k
//! through the simulator on the thread pool — kept as the ground-truth
//! fallback (`advisor --simulate`).

use crate::approx::{self, ApproxModel, ClusterSpec};
use crate::config::{ModelKind, OverheadConfig, SimulationConfig};
use crate::coordinator::sweep::{run_sweep, SweepPoint};
use crate::runtime::{BoundQuery, BoundsEngine};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;

/// Advisor output: the recommended k and the full curve for context.
#[derive(Clone, Debug)]
pub struct Recommendation {
    /// `(k, τ_ε)` of the best stable point, if any.
    pub best: Option<(usize, f64)>,
    /// The evaluated `(k, τ_ε)` curve (None = unstable at that k).
    pub curve: Vec<(usize, Option<f64>)>,
}

/// Sweep k ∈ {l, 2l, … } (log-ish grid) and pick the minimizer.
pub fn recommend(
    engine: &BoundsEngine,
    model: ModelKind,
    l: usize,
    lambda: f64,
    mean_workload: f64,
    epsilon: f64,
    overhead: OverheadConfig,
) -> Result<Recommendation> {
    // κ grid: 1..~200 in multiplicative steps.
    let ks = k_grid(l, 200.0);

    let queries: Vec<BoundQuery> = ks
        .iter()
        .map(|&k| BoundQuery {
            k,
            l,
            lambda,
            // Tasks sized so k·E[Q_exec] = mean workload.
            mu: k as f64 / mean_workload,
            epsilon,
            overhead: Some(overhead),
        })
        .collect();
    let rows = engine.bounds(&queries)?;
    let mut curve = Vec::with_capacity(ks.len());
    let mut best: Option<(usize, f64)> = None;
    for (&k, row) in ks.iter().zip(&rows) {
        let tau = match model {
            ModelKind::SplitMerge => row.split_merge,
            _ => row.fork_join,
        };
        if let Some(t) = tau {
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((k, t));
            }
        }
        curve.push((k, tau));
    }
    Ok(Recommendation { best, curve })
}

/// Analytic recommendation for a heterogeneous / redundant cluster: the
/// κ grid (up to `kappa_max`, [`recommend`] uses 200) and task sizing of
/// [`recommend`], evaluated through the [`crate::approx`] sojourn
/// approximation instead of the homogeneous bounds engine. In the
/// degenerate scenario (all speeds 1.0, r = 1, `kappa_max` 200) the
/// curve — and therefore the pick — equals [`recommend`] on the native
/// engine bit-for-bit.
pub fn recommend_approx(
    model: ModelKind,
    spec: &ClusterSpec,
    lambda: f64,
    mean_workload: f64,
    epsilon: f64,
    overhead: OverheadConfig,
    kappa_max: f64,
) -> Result<Recommendation, String> {
    if !(mean_workload > 0.0 && mean_workload.is_finite()) {
        return Err(format!("mean workload must be positive, got {mean_workload}"));
    }
    if !(kappa_max >= 1.0 && kappa_max.is_finite()) {
        return Err(format!("kappa_max must be >= 1, got {kappa_max}"));
    }
    let am = ApproxModel::from_model_kind(model)?;
    let ks = k_grid(spec.len(), kappa_max);
    let points = approx::sojourn_curve(
        am,
        spec,
        lambda,
        mean_workload,
        epsilon,
        Some(overhead),
        &ks,
    );
    let mut curve = Vec::with_capacity(points.len());
    let mut best: Option<(usize, f64)> = None;
    for p in &points {
        if let Some(t) = p.sojourn {
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((p.k, t));
            }
        }
        curve.push((p.k, p.sojourn));
    }
    Ok(Recommendation { best, curve })
}

/// The advisor's κ grid: k ∈ {l, 1.3·l, …} up to `kappa_max`·l.
pub fn k_grid(l: usize, kappa_max: f64) -> Vec<usize> {
    let mut ks = Vec::new();
    let mut kappa = 1.0f64;
    while kappa <= kappa_max {
        let k = (kappa * l as f64).round() as usize;
        if ks.last() != Some(&k) {
            ks.push(k);
        }
        kappa *= 1.3;
    }
    ks
}

/// Simulation-backed recommendation for scenarios outside the analytic
/// models' reach: heterogeneous worker speeds and task redundancy (the
/// `base` config's `[workers]` / `[redundancy]` sections are honoured).
///
/// For each k in `ks`, tasks are sized so the mean job workload
/// `k · E[exec]` equals `mean_workload`, the sweep runs on `pool` with
/// per-point seeds derived from `base.seed`, and the k minimizing the
/// simulated sojourn (1 − ε)-quantile wins.
pub fn recommend_simulated(
    pool: &ThreadPool,
    base: &SimulationConfig,
    mean_workload: f64,
    epsilon: f64,
    ks: &[usize],
) -> Result<Recommendation, String> {
    if !(mean_workload > 0.0 && mean_workload.is_finite()) {
        return Err(format!("mean workload must be positive, got {mean_workload}"));
    }
    if base.model == ModelKind::ForkJoinPerServer {
        return Err(
            "the simulated advisor sweeps tasks-per-job, which the per-server \
             fork-join model pins to k = l; use sm, fj, or ideal"
                .into(),
        );
    }
    let points: Vec<SweepPoint> = ks
        .iter()
        .map(|&k| SweepPoint {
            label: k as f64,
            config: SimulationConfig {
                tasks_per_job: k,
                service: crate::config::ServiceConfig {
                    execution: format!("exp:{}", k as f64 / mean_workload),
                },
                ..base.clone()
            },
        })
        .collect();
    let outcomes = run_sweep(pool, points, 1.0 - epsilon, base.seed)?;
    let mut curve = Vec::with_capacity(outcomes.len());
    let mut best: Option<(usize, f64)> = None;
    for o in &outcomes {
        let k = o.label as usize;
        let tau = o.sojourn_q;
        if tau.is_finite() {
            if best.map(|(_, bt)| tau < bt).unwrap_or(true) {
                best = Some((k, tau));
            }
            curve.push((k, Some(tau)));
        } else {
            curve.push((k, None));
        }
    }
    Ok(Recommendation { best, curve })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// With paper overhead the advisor picks an interior k: larger than
    /// l (tinyfication helps) but far from the maximum (overhead hurts)
    /// — the existence of the trade-off optimum is the paper's thesis.
    #[test]
    fn recommends_interior_optimum() {
        let engine = BoundsEngine::native();
        let rec = recommend(
            &engine,
            ModelKind::ForkJoinSingleQueue,
            50,
            0.5,
            50.0,
            0.01,
            OverheadConfig::paper(),
        )
        .unwrap();
        let (k, _tau) = rec.best.expect("stable configuration exists");
        assert!(k > 50, "tinyfication should help: k={k}");
        let k_max = rec.curve.last().unwrap().0;
        assert!(k < k_max / 2, "overhead should cap k: k={k} of {k_max}");
        // Sanity: the curve is not monotone (has an interior minimum).
        let feasible: Vec<f64> = rec.curve.iter().filter_map(|&(_, t)| t).collect();
        let min = feasible.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(*feasible.last().unwrap() > min, "tail should rise");
    }

    /// Simulated advisor on a skewed cluster: it returns a stable
    /// recommendation, and with redundancy the recommended quantile at
    /// the same k-grid stays finite. End-to-end sanity of the
    /// heterogeneous path ("what k, given skewed workers?").
    #[test]
    fn simulated_advisor_handles_skewed_workers() {
        use crate::config::{RedundancyConfig, WorkersConfig};
        let l = 8usize;
        let base = SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: l,
            tasks_per_job: l, // overridden per sweep point
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.4".into() },
            service: crate::config::ServiceConfig { execution: "exp:1.0".into() },
            jobs: 4_000,
            warmup: 400,
            seed: 11,
            overhead: Some(OverheadConfig::paper()),
            workers: Some(WorkersConfig::Speeds(vec![
                1.5, 1.5, 1.5, 1.5, 0.5, 0.5, 0.5, 0.5,
            ])),
            redundancy: Some(RedundancyConfig::new(2)),
            faults: None,
            policy: None,
        };
        let pool = ThreadPool::new(4);
        let ks = k_grid(l, 16.0);
        let rec = recommend_simulated(&pool, &base, l as f64, 0.05, &ks).unwrap();
        let (k, tau) = rec.best.expect("stable recommendation");
        assert!(ks.contains(&k));
        assert!(tau.is_finite() && tau > 0.0);
        assert_eq!(rec.curve.len(), ks.len());
    }

    /// Degenerate-scenario delegation: the analytic scenario advisor is
    /// bitwise the homogeneous advisor on the native engine — same
    /// curve, same pick.
    #[test]
    fn approx_advisor_degenerates_to_homogeneous() {
        let l = 20usize;
        let engine = BoundsEngine::native();
        for model in [ModelKind::ForkJoinSingleQueue, ModelKind::SplitMerge] {
            let reference =
                recommend(&engine, model, l, 0.5, l as f64, 0.01, OverheadConfig::paper())
                    .unwrap();
            let approx = recommend_approx(
                model,
                &ClusterSpec::homogeneous(l),
                0.5,
                l as f64,
                0.01,
                OverheadConfig::paper(),
                200.0,
            )
            .unwrap();
            assert_eq!(reference.curve.len(), approx.curve.len());
            for ((ka, ta), (kb, tb)) in reference.curve.iter().zip(&approx.curve) {
                assert_eq!(ka, kb);
                assert_eq!(ta.map(f64::to_bits), tb.map(f64::to_bits), "{model} k={ka}");
            }
            assert_eq!(
                reference.best.map(|(k, t)| (k, t.to_bits())),
                approx.best.map(|(k, t)| (k, t.to_bits())),
                "{model}"
            );
        }
    }

    /// The analytic scenario advisor handles a skewed redundant cluster
    /// and still finds the interior optimum.
    #[test]
    fn approx_advisor_handles_skewed_cluster() {
        let l = 10usize;
        let mut speeds = vec![1.5; l / 2];
        speeds.extend(vec![0.5; l / 2]);
        let spec = ClusterSpec::new(speeds, 2, 1e-3).unwrap();
        let rec = recommend_approx(
            ModelKind::ForkJoinSingleQueue,
            &spec,
            0.4,
            l as f64,
            0.01,
            OverheadConfig::paper(),
            200.0,
        )
        .unwrap();
        // --kappa-max reaches the analytic grid (the simulated advisor's
        // contract, honored here too).
        let capped = recommend_approx(
            ModelKind::ForkJoinSingleQueue,
            &spec,
            0.4,
            l as f64,
            0.01,
            OverheadConfig::paper(),
            8.0,
        )
        .unwrap();
        assert!(capped.curve.last().unwrap().0 <= 8 * l);
        let (k, tau) = rec.best.expect("stable recommendation");
        assert!(k > l, "tinyfication should help: k={k}");
        assert!(tau.is_finite() && tau > 0.0);
        let k_max = rec.curve.last().unwrap().0;
        assert!(k < k_max, "overhead should cap k");
    }

    #[test]
    fn k_grid_is_increasing_and_deduped() {
        let ks = k_grid(10, 200.0);
        assert_eq!(ks[0], 10);
        for w in ks.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(*ks.last().unwrap() >= 1500);
    }

    /// Without overhead, more tinyfication is always better (the curve
    /// is non-increasing), so the advisor picks the largest k.
    #[test]
    fn no_overhead_prefers_maximum_k() {
        let engine = BoundsEngine::native();
        let rec = recommend(
            &engine,
            ModelKind::ForkJoinSingleQueue,
            20,
            0.5,
            20.0,
            0.01,
            OverheadConfig::zero(),
        )
        .unwrap();
        let (k, _) = rec.best.unwrap();
        let k_max = rec.curve.last().unwrap().0;
        assert!(k as f64 > 0.5 * k_max as f64, "k={k} vs max {k_max}");
    }
}
