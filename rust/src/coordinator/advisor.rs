//! Task-granularity advisor — the paper's concluding application: "our
//! analytical approximation model which includes scheduling overhead can
//! also be used to optimize task granularity on real systems" (Sec. 7).
//!
//! Given a cluster (l workers), an arrival rate, a mean job workload and
//! an overhead model, sweep k over a log grid through the Sec.-6
//! approximation and return the k minimizing the sojourn ε-quantile.

use crate::config::{ModelKind, OverheadConfig};
use crate::runtime::{BoundQuery, BoundsEngine};
use anyhow::Result;

/// Advisor output: the recommended k and the full curve for context.
#[derive(Clone, Debug)]
pub struct Recommendation {
    /// `(k, τ_ε)` of the best stable point, if any.
    pub best: Option<(usize, f64)>,
    /// The evaluated `(k, τ_ε)` curve (None = unstable at that k).
    pub curve: Vec<(usize, Option<f64>)>,
}

/// Sweep k ∈ {l, 2l, … } (log-ish grid) and pick the minimizer.
pub fn recommend(
    engine: &BoundsEngine,
    model: ModelKind,
    l: usize,
    lambda: f64,
    mean_workload: f64,
    epsilon: f64,
    overhead: OverheadConfig,
) -> Result<Recommendation> {
    // κ grid: 1..~200 in multiplicative steps.
    let mut kappas: Vec<f64> = Vec::new();
    let mut kappa = 1.0f64;
    while kappa <= 200.0 {
        kappas.push(kappa);
        kappa *= 1.3;
    }
    let ks: Vec<usize> = kappas.iter().map(|&x| (x * l as f64).round() as usize).collect();

    let queries: Vec<BoundQuery> = ks
        .iter()
        .map(|&k| BoundQuery {
            k,
            l,
            lambda,
            // Tasks sized so k·E[Q_exec] = mean workload.
            mu: k as f64 / mean_workload,
            epsilon,
            overhead: Some(overhead),
        })
        .collect();
    let rows = engine.bounds(&queries)?;
    let mut curve = Vec::with_capacity(ks.len());
    let mut best: Option<(usize, f64)> = None;
    for (&k, row) in ks.iter().zip(&rows) {
        let tau = match model {
            ModelKind::SplitMerge => row.split_merge,
            _ => row.fork_join,
        };
        if let Some(t) = tau {
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((k, t));
            }
        }
        curve.push((k, tau));
    }
    Ok(Recommendation { best, curve })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// With paper overhead the advisor picks an interior k: larger than
    /// l (tinyfication helps) but far from the maximum (overhead hurts)
    /// — the existence of the trade-off optimum is the paper's thesis.
    #[test]
    fn recommends_interior_optimum() {
        let engine = BoundsEngine::native();
        let rec = recommend(
            &engine,
            ModelKind::ForkJoinSingleQueue,
            50,
            0.5,
            50.0,
            0.01,
            OverheadConfig::paper(),
        )
        .unwrap();
        let (k, _tau) = rec.best.expect("stable configuration exists");
        assert!(k > 50, "tinyfication should help: k={k}");
        let k_max = rec.curve.last().unwrap().0;
        assert!(k < k_max / 2, "overhead should cap k: k={k} of {k_max}");
        // Sanity: the curve is not monotone (has an interior minimum).
        let feasible: Vec<f64> = rec.curve.iter().filter_map(|&(_, t)| t).collect();
        let min = feasible.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(*feasible.last().unwrap() > min, "tail should rise");
    }

    /// Without overhead, more tinyfication is always better (the curve
    /// is non-increasing), so the advisor picks the largest k.
    #[test]
    fn no_overhead_prefers_maximum_k() {
        let engine = BoundsEngine::native();
        let rec = recommend(
            &engine,
            ModelKind::ForkJoinSingleQueue,
            20,
            0.5,
            20.0,
            0.01,
            OverheadConfig::zero(),
        )
        .unwrap();
        let (k, _) = rec.best.unwrap();
        let k_max = rec.curve.last().unwrap().0;
        assert!(k as f64 > 0.5 * k_max as f64, "k={k} vs max {k_max}");
    }
}
