//! Overhead-model calibration — the Sec. 2.6 methodology.
//!
//! The paper fit its four-parameter model by (1) observing the linear
//! growth of per-job overhead, (2) adding a constant + exponential
//! task-service overhead, and (3) adding linear pre-departure overhead,
//! iterating until the simulated sojourn distribution PP-matched the
//! Spark measurements. We reproduce that pipeline against sparklite:
//!
//! 1. run sparklite, collect per-task overheads `O_i` and per-job
//!    post-completion delays;
//! 2. moment-fit: `c_task_ts` = a low quantile of O_i, `mu_task_ts` from
//!    the mean residual; regress departure−last-result on k for the
//!    pre-departure line;
//! 3. validate + refine: simulate with the fitted model and minimize the
//!    PP distance of the sojourn distributions over a small grid around
//!    the moment fit.
//!
//! [`calibrate_from_trace`] runs steps 2–3 against a *recorded* trace
//! file instead of a live emulator (the paper worked from persisted
//! Spark task traces, not a tethered cluster) — record once with
//! `tiny-tasks trace record`, fit offline any number of times.

use crate::config::{EmulatorConfig, OverheadConfig, SimulationConfig};
use crate::emulator;
use crate::sim::{self, RunOptions};
use crate::stats::{pp_distance, quantile_of_sorted, Ecdf};
use crate::trace::Trace;

/// Result of a calibration run.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// The fitted four-parameter model.
    pub fitted: OverheadConfig,
    /// PP distance (sim vs reference sojourns) with the fitted model.
    pub pp_with_overhead: f64,
    /// PP distance with *no* overhead model (the Fig.-10 blue line).
    pub pp_without_overhead: f64,
    /// Number of tasks measured.
    pub tasks_measured: usize,
    /// Number of jobs measured.
    pub jobs_measured: usize,
}

/// Moment-fit the task-service overhead from measured `O_i` samples.
///
/// `c_task_ts` is taken as the 10th percentile (the deterministic floor;
/// robust to the exponential outliers), and `mu_task_ts` from the mean
/// excess above it (exponential MLE). Errors on an empty sample set (a
/// truncated or task-less trace) instead of panicking.
pub fn fit_task_overhead(mut overheads: Vec<f64>) -> Result<(f64, f64), String> {
    if overheads.is_empty() {
        return Err("cannot fit task overhead: no O_i samples (empty trace?)".into());
    }
    overheads.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let c = quantile_of_sorted(&overheads, 0.10);
    let mean_excess = overheads.iter().map(|o| (o - c).max(0.0)).sum::<f64>()
        / overheads.len() as f64;
    let mu = if mean_excess > 1e-12 { 1.0 / mean_excess } else { f64::INFINITY };
    Ok((c, mu))
}

/// Least-squares fit of `pd = a + b*k` from (k, pre-departure) samples.
/// Errors on an empty sample set instead of panicking.
pub fn fit_pre_departure(samples: &[(f64, f64)]) -> Result<(f64, f64), String> {
    if samples.is_empty() {
        return Err("cannot fit pre-departure overhead: no job samples".into());
    }
    let n = samples.len() as f64;
    let sx: f64 = samples.iter().map(|s| s.0).sum();
    let sy: f64 = samples.iter().map(|s| s.1).sum();
    let sxx: f64 = samples.iter().map(|s| s.0 * s.0).sum();
    let sxy: f64 = samples.iter().map(|s| s.0 * s.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        // Single k: attribute everything to the per-job constant.
        return Ok((sy / n, 0.0));
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    Ok((a.max(0.0), b.max(0.0)))
}

/// Steps 2–3 of the pipeline: moment-fit from the collected samples,
/// then refine `c_task_ts` by PP-distance minimization of simulated
/// sojourns (`sim_base` with a candidate overhead model) against the
/// reference sojourn ECDF.
fn fit_and_refine(
    task_overheads: Vec<f64>,
    pd_samples: Vec<(f64, f64)>,
    sim_base: &SimulationConfig,
    reference: &Ecdf,
) -> Result<Calibration, String> {
    let tasks_measured = task_overheads.len();
    let jobs_measured = pd_samples.len();
    let (c_ts0, mu_ts0) = fit_task_overhead(task_overheads)?;
    let (c_pd_job, c_pd_task) = fit_pre_departure(&pd_samples)?;

    // Simulated sojourns under a candidate overhead model.
    let sim_ecdf = |oh: Option<OverheadConfig>| -> Result<Ecdf, String> {
        let cfg = SimulationConfig { overhead: oh, ..sim_base.clone() };
        let res = sim::run(&cfg, RunOptions { record_jobs: true, ..Default::default() })?;
        Ok(Ecdf::new(res.jobs.iter().map(|j| j.sojourn()).collect()))
    };

    let pp_without = pp_distance(&sim_ecdf(None)?, reference, 256);

    // PP refinement of c_task_ts around the moment fit (paper: iterate
    // the constant until the distributions align).
    let mut best = OverheadConfig {
        c_task_ts: c_ts0,
        mu_task_ts: mu_ts0,
        c_job_pd: c_pd_job,
        c_task_pd: c_pd_task,
    };
    let mut best_pp = pp_distance(&sim_ecdf(Some(best))?, reference, 256);
    for mult in [0.5, 0.75, 1.25, 1.5, 2.0] {
        let cand = OverheadConfig { c_task_ts: c_ts0 * mult, ..best };
        let pp = pp_distance(&sim_ecdf(Some(cand))?, reference, 256);
        if pp < best_pp {
            best_pp = pp;
            best = cand;
        }
    }

    Ok(Calibration {
        fitted: best,
        pp_with_overhead: best_pp,
        pp_without_overhead: pp_without,
        tasks_measured,
        jobs_measured,
    })
}

/// Run the full calibration pipeline against sparklite.
///
/// Runs the emulator at (possibly several) task counts, moment-fits the
/// model, then refines `c_task_ts` by PP-distance minimization as the
/// paper did.
pub fn calibrate(base: &EmulatorConfig, ks: &[usize]) -> Result<Calibration, String> {
    assert!(!ks.is_empty());
    let mut all_task_overheads: Vec<f64> = Vec::new();
    let mut pd_samples: Vec<(f64, f64)> = Vec::new();
    let mut reference: Option<(EmulatorConfig, emulator::EmulatorResult)> = None;

    for (i, &k) in ks.iter().enumerate() {
        let cfg = EmulatorConfig { tasks_per_job: k, ..base.clone() };
        let res = emulator::run(&cfg)?;
        let scale = cfg.time_scale;
        for t in &res.listener.tasks {
            // Wall → emulated seconds.
            all_task_overheads.push(t.overhead() / scale);
        }
        for j in res.listener.jobs.iter().filter(|j| j.job_id >= cfg.warmup as u64) {
            // Pre-departure: last result → departure (merge + bookkeeping).
            pd_samples.push((j.tasks as f64, (j.departure - j.last_result).max(0.0)));
        }
        if i == ks.len() / 2 {
            reference = Some((cfg, res));
        }
    }
    let (ref_cfg, ref_res) = reference.expect("at least one k");

    // Reference ECDF of emulator sojourns (post-warmup).
    let emu_sojourns: Vec<f64> = ref_res.measured_jobs().map(|j| j.sojourn()).collect();
    if emu_sojourns.is_empty() {
        return Err("emulator run produced no measured jobs to calibrate against".into());
    }
    let emu_ecdf = Ecdf::new(emu_sojourns);
    let sim_base = sim_base_for(
        ref_cfg.mode,
        ref_cfg.executors,
        ref_cfg.tasks_per_job,
        &ref_cfg.interarrival,
        &ref_cfg.execution,
        ref_res.measured_jobs().count(),
        ref_cfg.warmup,
        ref_cfg.seed,
        ref_cfg.workers.clone(),
        None,
    );
    fit_and_refine(all_task_overheads, pd_samples, &sim_base, &emu_ecdf)
}

/// Run the fit + PP-refine pipeline against a recorded trace file —
/// `tiny-tasks calibrate --from-trace <file>` (Sec. 2.6 offline).
pub fn calibrate_from_trace(trace: &Trace) -> Result<Calibration, String> {
    trace.validate()?;
    let sojourns = trace.sojourns();
    if sojourns.is_empty() {
        return Err("trace has no measured jobs to calibrate against".into());
    }
    let reference = Ecdf::new(sojourns);
    let meta = &trace.meta;
    // Schema-v2 traces carry the scenario shape: the candidate
    // simulations refine against the same skewed/redundant cluster the
    // reference sojourns were measured on.
    let workers = meta.speeds.clone().map(crate::config::WorkersConfig::Speeds);
    let redundancy = (meta.replicas > 1).then(|| crate::config::RedundancyConfig {
        replicas: meta.replicas as usize,
        launch_overhead: meta.launch_overhead,
    });
    let sim_base = sim_base_for(
        trace.model()?,
        meta.servers as usize,
        meta.tasks_per_job as usize,
        &meta.interarrival,
        &meta.execution,
        trace.measured_jobs().count(),
        meta.warmup as usize,
        meta.seed,
        workers,
        redundancy,
    );
    fit_and_refine(
        trace.task_overheads(),
        trace.pre_departure_samples(),
        &sim_base,
        &reference,
    )
}

/// The candidate-simulation config shared by the live and from-trace
/// paths: same shape as the reference run (including any recorded
/// scenario), 10× the jobs for a smooth ECDF, a decorrelated seed.
#[allow(clippy::too_many_arguments)]
fn sim_base_for(
    model: crate::config::ModelKind,
    servers: usize,
    tasks_per_job: usize,
    interarrival: &str,
    execution: &str,
    measured_jobs: usize,
    warmup: usize,
    seed: u64,
    workers: Option<crate::config::WorkersConfig>,
    redundancy: Option<crate::config::RedundancyConfig>,
) -> SimulationConfig {
    SimulationConfig {
        model,
        servers,
        tasks_per_job,
        arrival: crate::config::ArrivalConfig { interarrival: interarrival.to_string() },
        service: crate::config::ServiceConfig { execution: execution.to_string() },
        jobs: (measured_jobs * 10).max(5_000),
        warmup: warmup * 10,
        seed: seed ^ 0xCA11B,
        overhead: None,
        workers,
        redundancy,
        faults: None,
        policy: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;

    #[test]
    fn task_overhead_moment_fit_recovers_parameters() {
        use crate::rng::{Pcg64, Rng};
        // Synthesize O_i = 2.6ms + Exp(2000): the paper's model.
        let mut rng = Pcg64::seed_from_u64(3);
        let samples: Vec<f64> = (0..50_000)
            .map(|_| 2.6e-3 - rng.next_f64_open().ln() / 2000.0)
            .collect();
        let (c, mu) = fit_task_overhead(samples).unwrap();
        // The 10th percentile of the model sits slightly above c; accept
        // a small bias.
        assert!((c - 2.6e-3).abs() < 3e-4, "c={c}");
        assert!((mu - 2000.0).abs() / 2000.0 < 0.25, "mu={mu}");
    }

    #[test]
    fn pre_departure_regression() {
        // pd = 0.02 + 7.4e-6 * k with noise.
        use crate::rng::{Pcg64, Rng};
        let mut rng = Pcg64::seed_from_u64(4);
        let samples: Vec<(f64, f64)> = (0..2000)
            .map(|i| {
                let k = 50.0 + (i % 5) as f64 * 500.0;
                let noise = (rng.next_f64() - 0.5) * 1e-3;
                (k, 0.02 + 7.4e-6 * k + noise)
            })
            .collect();
        let (a, b) = fit_pre_departure(&samples).unwrap();
        assert!((a - 0.02).abs() < 2e-3, "a={a}");
        assert!((b - 7.4e-6).abs() < 2e-6, "b={b}");
    }

    #[test]
    fn single_k_regression_degenerates_to_constant() {
        let (a, b) = fit_pre_departure(&[(100.0, 0.05), (100.0, 0.07)]).unwrap();
        assert!((a - 0.06).abs() < 1e-12);
        assert_eq!(b, 0.0);
    }

    /// The robustness fix: empty inputs are clean errors, not panics.
    #[test]
    fn empty_samples_are_errors_not_panics() {
        assert!(fit_task_overhead(Vec::new()).is_err());
        assert!(fit_pre_departure(&[]).is_err());
    }

    /// End-to-end: calibrate against a sparklite run with *injected*
    /// paper-scale overhead; the fitted parameters must land near the
    /// injected truth, and the with-overhead PP distance must beat the
    /// without-overhead one (the Fig. 10 conclusion).
    #[test]
    fn recovers_injected_overhead() {
        let base = EmulatorConfig {
            executors: 4,
            tasks_per_job: 32,
            mode: ModelKind::ForkJoinSingleQueue,
            interarrival: "exp:0.4".into(),
            execution: "exp:8.0".into(), // mean 125 ms emulated
            time_scale: 0.02,
            jobs: 150,
            warmup: 15,
            seed: 5,
            // Exaggerated so it dominates sparklite's intrinsic noise.
            inject_overhead: Some(OverheadConfig {
                c_task_ts: 30e-3,
                mu_task_ts: 100.0,
                c_job_pd: 0.2,
                c_task_pd: 0.0,
            }),
            workers: None,
        };
        let cal = calibrate(&base, &[32, 64]).unwrap();
        assert!(
            (cal.fitted.c_task_ts - 30e-3).abs() < 15e-3,
            "c_ts={}",
            cal.fitted.c_task_ts
        );
        assert!(cal.fitted.c_job_pd > 0.02, "c_pd_job={}", cal.fitted.c_job_pd);
        assert!(
            cal.pp_with_overhead < cal.pp_without_overhead,
            "PP: with={} without={}",
            cal.pp_with_overhead,
            cal.pp_without_overhead
        );
    }

    /// From-trace calibration against a *simulator*-recorded trace with
    /// the paper's overhead injected: the fit recovers the injected
    /// parameters and the refined model PP-beats no-overhead — the same
    /// acceptance as the live pipeline, no emulator in the loop.
    #[test]
    fn calibrate_from_trace_recovers_sim_injected_overhead() {
        let injected = OverheadConfig {
            c_task_ts: 50e-3,
            mu_task_ts: 200.0,
            c_job_pd: 0.2,
            c_task_pd: 0.0,
        };
        let cfg = crate::config::SimulationConfig {
            model: ModelKind::ForkJoinSingleQueue,
            servers: 4,
            tasks_per_job: 32,
            arrival: crate::config::ArrivalConfig { interarrival: "exp:0.4".into() },
            service: crate::config::ServiceConfig { execution: "exp:8.0".into() },
            jobs: 800,
            warmup: 80,
            seed: 5,
            overhead: Some(injected),
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let res = crate::sim::run(
            &cfg,
            RunOptions { record_jobs: true, trace: true, ..Default::default() },
        )
        .unwrap();
        let trace = Trace::from_sim(&res).unwrap();
        let cal = calibrate_from_trace(&trace).unwrap();
        assert!(
            (cal.fitted.c_task_ts - 50e-3).abs() < 15e-3,
            "c_ts={}",
            cal.fitted.c_task_ts
        );
        assert!(
            (cal.fitted.c_job_pd - 0.2).abs() < 0.05,
            "c_pd_job={}",
            cal.fitted.c_job_pd
        );
        assert!(
            cal.pp_with_overhead < cal.pp_without_overhead,
            "PP: with={} without={}",
            cal.pp_with_overhead,
            cal.pp_without_overhead
        );
        assert_eq!(cal.jobs_measured, 800);
    }
}
