//! CLI command implementations.

use super::figures::{self, FigureCtx, Scale};
use super::{advisor, calibrate};
use crate::cli::Args;
use crate::config::{
    BackoffKind, EmulatorConfig, FaultsConfig, ModelKind, OverheadConfig, PolicyConfig,
    PolicyKind, RedundancyConfig, SimulationConfig, WorkersConfig,
};
use crate::obs::{self, Counter, Metrics, Phase};
use crate::runtime::{BoundQuery, BoundsEngine, ErlangQuery};
use crate::sim::{self, RunOptions};
use crate::util::threadpool::ThreadPool;
use crate::{analysis, emulator};
use anyhow::{bail, Result};
use std::path::PathBuf;

fn overhead_from_args(args: &Args) -> Result<Option<OverheadConfig>> {
    if !args.get_bool("overhead") && args.get("c-task-ts").is_none() {
        return Ok(None);
    }
    let paper = OverheadConfig::paper();
    Ok(Some(OverheadConfig {
        c_task_ts: args.get_f64("c-task-ts", paper.c_task_ts).map_err(anyhow::Error::msg)?,
        mu_task_ts: args.get_f64("mu-task-ts", paper.mu_task_ts).map_err(anyhow::Error::msg)?,
        c_job_pd: args.get_f64("c-job-pd", paper.c_job_pd).map_err(anyhow::Error::msg)?,
        c_task_pd: args.get_f64("c-task-pd", paper.c_task_pd).map_err(anyhow::Error::msg)?,
    }))
}

fn e(s: String) -> anyhow::Error {
    anyhow::Error::msg(s)
}

/// Parse the heterogeneous-worker / redundancy scenario flags:
/// `--speeds 1.0,0.5,...` or `--speed-dist uniform:0.5:1.5`
/// (with `--speed-seed N`), plus `--redundancy R [--replica-launch S]`.
fn scenario_from_args(
    args: &Args,
) -> Result<(Option<WorkersConfig>, Option<RedundancyConfig>)> {
    let workers = match (args.get_list_f64("speeds").map_err(e)?, args.get("speed-dist")) {
        (Some(_), Some(_)) => bail!("give either --speeds or --speed-dist, not both"),
        (Some(speeds), None) => Some(WorkersConfig::Speeds(speeds)),
        (None, Some(spec)) => {
            crate::dist::parse_spec(spec).map_err(e)?;
            Some(WorkersConfig::Distribution {
                spec: spec.to_string(),
                seed: args.get_u64("speed-seed", 1).map_err(e)?,
            })
        }
        (None, None) => None,
    };
    let launch_overhead = args.get_f64("replica-launch", 0.0).map_err(e)?;
    if !(launch_overhead >= 0.0 && launch_overhead.is_finite()) {
        bail!("--replica-launch must be finite and >= 0");
    }
    let redundancy = match args.get_usize("redundancy", 1).map_err(e)? {
        0 => bail!("--redundancy must be >= 1"),
        1 => {
            if launch_overhead > 0.0 {
                bail!("--replica-launch needs --redundancy >= 2");
            }
            None
        }
        replicas => Some(RedundancyConfig { replicas, launch_overhead }),
    };
    Ok((workers, redundancy))
}

/// Parse the fault-injection flags: `--mtbf S --mttr S` (Markov worker
/// crashes), `--task-fail-p P --max-retries N --fault-backoff fixed|exp
/// --fault-backoff-base S` (per-task failures with bounded retries), and
/// `--spec-timeout F` (speculative re-execution after F × E[task]).
/// Returns `None` when no fault mechanism is enabled, so fault-free runs
/// stay on the untouched (bit-for-bit identical) code paths.
fn faults_from_args(args: &Args) -> Result<Option<FaultsConfig>> {
    let d = FaultsConfig::default();
    let max_retries = args.get_u64("max-retries", u64::from(d.max_retries)).map_err(e)?;
    let cfg = FaultsConfig {
        mtbf: args.get_f64("mtbf", d.mtbf).map_err(e)?,
        mttr: args.get_f64("mttr", d.mttr).map_err(e)?,
        task_fail_p: args.get_f64("task-fail-p", d.task_fail_p).map_err(e)?,
        max_retries: u32::try_from(max_retries)
            .map_err(|_| anyhow::anyhow!("--max-retries {max_retries} is out of range"))?,
        backoff: BackoffKind::parse(&args.get_or("fault-backoff", "fixed")).map_err(e)?,
        backoff_base: args.get_f64("fault-backoff-base", d.backoff_base).map_err(e)?,
        spec_timeout: args.get_f64("spec-timeout", d.spec_timeout).map_err(e)?,
        seed: args.get_u64("fault-seed", d.seed).map_err(e)?,
    };
    Ok(cfg.is_active().then_some(cfg))
}

/// Parse the dispatch-policy flags: `--policy fcfs|sita|priority|worksteal`
/// plus the per-policy knobs `--sita-boundaries 0.5,2.0` (ascending
/// seconds), `--classes N --class-weights 2,1` (priority partitions) and
/// `--steal-threshold S` (work stealing). Returns `None` for an absent or
/// `fcfs` policy so default runs stay on the untouched (bit-for-bit
/// identical) dispatch paths; cross-field validation (partition
/// arithmetic, model/scenario compatibility) happens in
/// `SimulationConfig::validate` when the run starts.
fn policy_from_args(args: &Args) -> Result<Option<PolicyConfig>> {
    let kind = match args.get("policy") {
        Some(tok) => PolicyKind::parse(tok).map_err(e)?,
        None => {
            for flag in ["sita-boundaries", "classes", "class-weights", "steal-threshold"] {
                if args.get(flag).is_some() {
                    bail!("--{flag} needs --policy sita|priority|worksteal");
                }
            }
            return Ok(None);
        }
    };
    let d = PolicyConfig::default();
    let cfg = PolicyConfig {
        kind,
        sita_boundaries: args
            .get_list_f64("sita-boundaries")
            .map_err(e)?
            .unwrap_or_default(),
        classes: args.get_usize("classes", d.classes).map_err(e)?,
        weights: args.get_list_f64("class-weights").map_err(e)?.unwrap_or_default(),
        steal_threshold: args.get_f64("steal-threshold", d.steal_threshold).map_err(e)?,
    };
    Ok(cfg.is_active().then_some(cfg))
}

/// One-line policy description for command banners.
fn policy_banner(p: &PolicyConfig, servers: usize) -> String {
    match p.kind {
        PolicyKind::Sita => format!(
            "sita (boundaries {:?} -> partitions {:?})",
            p.sita_boundaries,
            p.partition_sizes(servers)
        ),
        PolicyKind::Priority => format!(
            "priority ({} classes -> partitions {:?})",
            p.classes,
            p.partition_sizes(servers)
        ),
        PolicyKind::WorkSteal => {
            format!("worksteal (steal threshold {} s)", p.steal_threshold)
        }
        PolicyKind::Fcfs => "fcfs".into(),
    }
}

/// Parse a `--k-list 50,100,...` flag into task counts, rejecting
/// non-integer or non-positive entries (a negative value used to
/// saturate to k = 0 and panic deep inside the sweep).
fn k_list_from_args(args: &Args, key: &str) -> Result<Option<Vec<usize>>> {
    let Some(list) = args.get_list_f64(key).map_err(e)? else {
        return Ok(None);
    };
    let mut ks = Vec::with_capacity(list.len());
    for x in list {
        if !(x.is_finite() && x >= 1.0 && x.fract() == 0.0) {
            bail!("--{key}: entries must be positive integers, got {x}");
        }
        ks.push(x as usize);
    }
    if ks.is_empty() {
        bail!("--{key}: needs at least one entry");
    }
    Ok(Some(ks))
}

/// Write the RUN_METRICS.json report when the command got
/// `--metrics FILE` (the schema-v1 surface shared by every command).
fn write_metrics_report(
    args: &Args,
    source: &str,
    m: &Metrics,
    jobs: u64,
    wall_seconds: f64,
) -> Result<()> {
    if let Some(path) = args.get("metrics") {
        obs::report::write_file(path, source, m, jobs, wall_seconds).map_err(e)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Sweep pool sized by `--threads` (absent or 0 = machine default).
fn pool_from_args(args: &Args) -> Result<ThreadPool> {
    Ok(match args.get_usize("threads", 0).map_err(e)? {
        0 => ThreadPool::with_default_size(),
        n => ThreadPool::new(n),
    })
}

/// `tiny-tasks simulate` — one DES run, statistics to stdout.
pub fn cmd_simulate(args: &Args) -> Result<i32> {
    // `--config file.toml` loads the [simulation] section; flags override
    // nothing in that case (file is authoritative, as with sparkbench).
    if let Some(path) = args.get("config") {
        let exp = crate::config::ExperimentConfig::load(path).map_err(e)?;
        let cfg = exp
            .simulation
            .ok_or_else(|| anyhow::anyhow!("{path}: no [simulation] section"))?;
        let opts = RunOptions {
            metrics: args.get("metrics").is_some(),
            progress: args.get_bool("progress"),
            ..Default::default()
        };
        let mut res = sim::run(&cfg, opts).map_err(e)?;
        println!("experiment       {}", exp.name);
        println!("model            {}", cfg.model);
        println!("mean sojourn     {:.4} s", res.sojourn_summary.mean());
        for q in [0.5, 0.9, 0.99] {
            println!("sojourn p{:<6} {:.4} s", q * 100.0, res.sojourn_quantile(q));
        }
        write_metrics_report(args, "simulate", &res.metrics, cfg.jobs as u64, res.wall_seconds)?;
        return Ok(0);
    }
    let cfg = sim_cfg_from_args(args)?;
    let (l, k) = (cfg.servers, cfg.tasks_per_job);
    let opts = RunOptions {
        in_order_departures: args.get_bool("in-order"),
        // O(1)-memory mode for huge --jobs: P² quantiles on the default
        // grid (covers every quantile printed below).
        streaming: args.get_bool("streaming"),
        // Replication sharding: `--threads N` splits the run into N
        // shards on N workers; `--shards M` decouples the shard count
        // (the sample stream) from the worker count (never observable).
        threads: args.get_usize("threads", 1).map_err(e)?,
        shards: args.get_usize("shards", 0).map_err(e)?,
        metrics: args.get("metrics").is_some(),
        progress: args.get_bool("progress"),
        ..Default::default()
    };
    let mut res = sim::run(&cfg, opts).map_err(e)?;
    println!("model            {}", cfg.model);
    println!("servers l        {l}");
    println!("tasks/job k      {k}  (kappa = {:.2})", cfg.kappa());
    if cfg.workers.is_some() || cfg.redundancy.is_some() {
        let speeds = cfg.resolved_speeds().map_err(e)?;
        let min = speeds.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = speeds.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "scenario         speeds in [{min:.3}, {max:.3}] (Σ = {:.3}), replicas r = {}",
            speeds.iter().sum::<f64>(),
            cfg.replicas()
        );
    }
    if let Some(p) = &cfg.policy {
        println!("policy           {}", policy_banner(p, l));
    }
    println!("jobs             {} (+{} warmup)", cfg.jobs, cfg.warmup);
    if opts.shards > 1 || opts.threads > 1 {
        let shards = if opts.shards == 0 { opts.threads.max(1) } else { opts.shards };
        println!(
            "shards           {} on {} thread(s) (per-shard seeds + warmup)",
            shards.min(cfg.jobs),
            opts.threads.max(1)
        );
    }
    println!("mean sojourn     {:.4} s", res.sojourn_summary.mean());
    for q in [0.5, 0.9, 0.99, 0.999] {
        println!("sojourn p{:<6} {:.4} s", q * 100.0, res.sojourn_quantile(q));
    }
    println!("mean waiting     {:.4} s", res.waiting_quantile(0.5));
    println!("mean overhead/job {:.6} s", res.overhead_summary.mean());
    for (c, s) in res.class_sojourn.iter().enumerate() {
        println!(
            "class {c} sojourn  {:.4} s mean over {} jobs",
            s.mean(),
            s.count()
        );
    }
    if cfg.replicas() > 1 {
        println!("mean redundant/job {:.6} s", res.redundant_summary.mean());
    }
    if let Some(f) = &cfg.faults {
        println!(
            "faults           mtbf {}, mttr {}, task_fail_p {}, max_retries {}, \
             spec_timeout {}",
            f.mtbf, f.mttr, f.task_fail_p, f.max_retries, f.spec_timeout
        );
        println!("mean lost/job    {:.6} s (crashed + failed-attempt work)", res.lost_summary.mean());
        println!("mean retries/job {:.4}", res.retry_summary.mean());
        if f.speculation_enabled() {
            println!("mean redundant/job {:.6} s (speculative copies)", res.redundant_summary.mean());
        }
    }
    println!("throughput       {:.0} jobs/s wall", res.jobs_per_second());
    write_metrics_report(args, "simulate", &res.metrics, cfg.jobs as u64, res.wall_seconds)?;
    Ok(0)
}

/// Build a [`SimulationConfig`] from `simulate`-style flags (shared with
/// `tiny-tasks profile`).
fn sim_cfg_from_args(args: &Args) -> Result<SimulationConfig> {
    let l = args.get_usize("servers", 50).map_err(e)?;
    let k = args.get_usize("k", l).map_err(e)?;
    let lambda = args.get_f64("lambda", 0.5).map_err(e)?;
    let mu = args.get_f64("mu", k as f64 / l as f64).map_err(e)?;
    let (workers, redundancy) = scenario_from_args(args)?;
    Ok(SimulationConfig {
        model: ModelKind::parse(&args.get_or("model", "fj")).map_err(e)?,
        servers: l,
        tasks_per_job: k,
        arrival: crate::config::ArrivalConfig {
            interarrival: args.get_or("interarrival", &format!("exp:{lambda}")),
        },
        service: crate::config::ServiceConfig {
            execution: args.get_or("execution", &format!("exp:{mu}")),
        },
        jobs: args.get_usize("jobs", 30_000).map_err(e)?,
        warmup: args.get_usize("warmup", 3_000).map_err(e)?,
        seed: args.get_u64("seed", 1).map_err(e)?,
        overhead: overhead_from_args(args)?,
        workers,
        redundancy,
        faults: faults_from_args(args)?,
        policy: policy_from_args(args)?,
    })
}

/// `tiny-tasks profile` — run one configuration with the obs registry on
/// and print the phase/counter table. The profiled run is bitwise
/// identical to `simulate` with the same flags: metrics consume no RNG
/// draws. `--engine recursion` (default) profiles `sim::run`;
/// `--engine calendar` drives the event-calendar engine with its
/// sampling-phase hook. `--csv FILE` dumps the table as metric,value
/// rows; `--metrics FILE` writes the RUN_METRICS.json report.
/// `profile --diff BASE.json NEW.json [--gate name:ratio,...]` — align
/// two RUN_METRICS reports into one table of absolute and ratio deltas,
/// then evaluate the gates (exit 1 on any regression past its ratio).
fn profile_diff(args: &Args, base_path: &str) -> Result<i32> {
    let new_path = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("--diff BASE.json needs the new report's path as a positional arg")
    })?;
    let read = |p: &str| -> Result<obs::report::ParsedReport> {
        let text = std::fs::read_to_string(p).map_err(|err| anyhow::anyhow!("{p}: {err}"))?;
        obs::report::parse(&text).map_err(e)
    };
    let base = read(base_path)?;
    let new = read(new_path)?;
    let rows = obs::report::diff_rows(&base, &new);
    println!("profile diff     {base_path} (base) vs {new_path} (new)");
    println!("\n{:>28} {:>16} {:>16} {:>16} {:>9}", "row", "base", "new", "delta", "ratio");
    for r in &rows {
        let ratio = r.ratio().map_or_else(|| "-".into(), |x| format!("{x:.4}"));
        println!(
            "{:>28} {:>16.6} {:>16.6} {:>+16.6} {ratio:>9}",
            r.name,
            r.base,
            r.new,
            r.new - r.base
        );
    }
    if let Some(spec) = args.get("gate") {
        let gates = obs::report::parse_gates(spec).map_err(e)?;
        let failures = obs::report::check_gates(&rows, &gates);
        if !failures.is_empty() {
            println!("\ngates: FAIL");
            for f in &failures {
                println!("  {f}");
            }
            return Ok(1);
        }
        println!("\ngates: OK ({} checked)", gates.len());
    }
    Ok(0)
}

pub fn cmd_profile(args: &Args) -> Result<i32> {
    if let Some(base_path) = args.get("diff") {
        return profile_diff(args, base_path);
    }
    let cfg = sim_cfg_from_args(args)?;
    cfg.validate().map_err(e)?;
    let engine = args.get_or("engine", "recursion");
    let (metrics, jobs, wall) = match engine.as_str() {
        "recursion" | "sim" => {
            let opts = RunOptions {
                streaming: args.get_bool("streaming"),
                threads: args.get_usize("threads", 1).map_err(e)?,
                shards: args.get_usize("shards", 0).map_err(e)?,
                metrics: true,
                progress: args.get_bool("progress"),
                ..Default::default()
            };
            let res = sim::run(&cfg, opts).map_err(e)?;
            (res.metrics, cfg.jobs as u64, res.wall_seconds)
        }
        "calendar" | "cal" => {
            use crate::sim::{
                Calendar, Discipline, FaultInjector, OverheadModel, TraceLog, Workload,
            };
            if cfg.workers.is_some() || cfg.redundancy.is_some() {
                bail!("the calendar engine has no scenario support; drop --workers/--redundancy");
            }
            if cfg.faults.is_some() && cfg.policy.is_some() {
                bail!("the calendar engine composes faults with FCFS only; drop one flag set");
            }
            let disc = match cfg.model {
                ModelKind::SplitMerge => Discipline::SplitMerge,
                ModelKind::ForkJoinSingleQueue => Discipline::SingleQueueForkJoin,
                other => bail!("--engine calendar profiles sm/fj models, not {other}"),
            };
            let mut workload = Workload::from_config(&cfg).map_err(e)?;
            let overhead = OverheadModel::from_option(cfg.overhead);
            let expected_task = workload.mean_execution() + overhead.mean_task();
            let faults = FaultInjector::from_config(&cfg, expected_task);
            let mut cal = Calendar::new(disc, cfg.servers, vec![cfg.tasks_per_job as u32])
                .with_faults(faults)
                .with_policy(cfg.policy.as_ref())
                .with_profile(true);
            let mut tr = TraceLog::disabled();
            let t0 = std::time::Instant::now();
            let recs = cal.run(cfg.jobs, &mut workload, &overhead, &mut tr);
            let wall = t0.elapsed().as_secs_f64();
            let mut m = Metrics::enabled();
            m.absorb_tallies(&cal.tallies());
            let (arrivals, executions, batches) = workload.draw_counts();
            m.add(Counter::ArrivalDraws, arrivals);
            m.add(Counter::ExecutionDraws, executions);
            m.add(Counter::BatchDraws, batches);
            let sampling = cal.sampling_seconds();
            m.phase_add_secs(Phase::Sampling, sampling);
            m.phase_add_secs(Phase::Dispatch, (wall - sampling).max(0.0));
            m.absorb_spans(cal.spans());
            for r in &recs {
                m.observe_sojourn(r.sojourn());
                m.observe_waiting(r.waiting());
            }
            (m, recs.len() as u64, wall)
        }
        other => bail!("unknown --engine {other:?} (recursion|calendar)"),
    };

    println!(
        "profile          {} on the {engine} engine (l={}, k={}, jobs={jobs})",
        cfg.model, cfg.servers, cfg.tasks_per_job
    );
    println!("\n{:>24} {:>16}", "phase", "seconds");
    for p in Phase::ALL {
        println!("{:>24} {:>16.6}", p.key(), metrics.phase_seconds(p));
    }
    println!("\n{:>24} {:>16}", "counter", "value");
    for c in Counter::ALL {
        println!("{:>24} {:>16}", c.key(), metrics.counter(c));
    }
    println!("\n{:>24} {:>16} {:>16}", "percentile", "sojourn s", "waiting s");
    for (q, name) in obs::report::PERCENTILES {
        println!(
            "{name:>24} {:>16.6} {:>16.6}",
            metrics.sojourn_hist.percentile(q).unwrap_or(0.0),
            metrics.waiting_hist.percentile(q).unwrap_or(0.0),
        );
    }
    if !metrics.spans.is_empty() {
        println!("\nevent-loop spans (total / self wall seconds, enter counts):");
        print!("{}", metrics.spans.render_tree());
    }
    println!(
        "\nwall             {:.3} s ({:.0} jobs/s), peak rss {} bytes",
        wall,
        jobs as f64 / wall.max(1e-12),
        obs::report::peak_rss_bytes()
    );
    if let Some(path) = args.get("folded") {
        if metrics.spans.is_empty() {
            bail!("--folded needs the calendar engine's span profile; use --engine calendar");
        }
        std::fs::write(path, metrics.spans.render_folded())?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("csv") {
        let mut s = String::from("metric,value\n");
        for p in Phase::ALL {
            s.push_str(&format!("phase_{},{}\n", p.key(), metrics.phase_seconds(p)));
        }
        for c in Counter::ALL {
            s.push_str(&format!("{},{}\n", c.key(), metrics.counter(c)));
        }
        std::fs::write(path, s)?;
        println!("wrote {path}");
    }
    write_metrics_report(args, "profile", &metrics, jobs, wall)?;
    Ok(0)
}

/// Build an [`EmulatorConfig`] from `emulate`-style flags (shared with
/// `trace record --source emulator`).
fn emulator_cfg_from_args(args: &Args) -> Result<EmulatorConfig> {
    let l = args.get_usize("executors", 8).map_err(e)?;
    let k = args.get_usize("k", 4 * l).map_err(e)?;
    let lambda = args.get_f64("lambda", 0.5).map_err(e)?;
    let mu = args.get_f64("mu", k as f64 / l as f64).map_err(e)?;
    let (workers, redundancy) = scenario_from_args(args)?;
    if redundancy.is_some() {
        bail!("sparklite does not emulate task redundancy; drop --redundancy");
    }
    Ok(EmulatorConfig {
        executors: l,
        tasks_per_job: k,
        mode: ModelKind::parse(&args.get_or("mode", "fj")).map_err(e)?,
        interarrival: args.get_or("interarrival", &format!("exp:{lambda}")),
        execution: args.get_or("execution", &format!("exp:{mu}")),
        time_scale: args.get_f64("time-scale", 0.005).map_err(e)?,
        jobs: args.get_usize("jobs", 300).map_err(e)?,
        warmup: args.get_usize("warmup", 30).map_err(e)?,
        seed: args.get_u64("seed", 1).map_err(e)?,
        inject_overhead: if args.get_bool("inject-overhead") {
            Some(OverheadConfig::paper())
        } else {
            None
        },
        workers,
    })
}

/// `tiny-tasks emulate` — one sparklite run.
pub fn cmd_emulate(args: &Args) -> Result<i32> {
    let cfg = emulator_cfg_from_args(args)?;
    cfg.validate().map_err(e)?;
    let (l, k) = (cfg.executors, cfg.tasks_per_job);
    let mut res = emulator::run(&cfg).map_err(e)?;
    println!("mode             {}", cfg.mode);
    println!("executors        {l}, tasks/job {k}");
    if cfg.workers.is_some() {
        let speeds = cfg.resolved_speeds().map_err(e)?;
        let min = speeds.iter().cloned().fold(f64::INFINITY, f64::min);
        println!(
            "pinned speeds    in [{min:.3}, 1.000] (Σ = {:.3})",
            speeds.iter().sum::<f64>()
        );
    }
    println!(
        "jobs             {} (+{} warmup), time_scale {}",
        cfg.jobs, cfg.warmup, cfg.time_scale
    );
    for q in [0.5, 0.9, 0.99] {
        println!("sojourn p{:<6} {:.4} s (emulated)", q * 100.0, res.sojourn_quantile(q));
    }
    println!("throughput       {:.3} jobs/s (emulated)", res.throughput());
    println!(
        "mean task overhead fraction {:.4}",
        res.listener.mean_overhead_fraction()
    );
    println!("wall time        {:.1} s", res.wall_seconds);
    if args.get("metrics").is_some() {
        // Project the Spark-style listener into the engine-wide schema so
        // emulate emits the same RUN_METRICS.json as the simulators.
        let m = res.listener.to_obs();
        let jobs = res.listener.jobs.len() as u64;
        write_metrics_report(args, "emulate", &m, jobs, res.wall_seconds)?;
    }
    Ok(0)
}

/// `tiny-tasks bounds` — analytic bounds/approximations for one config.
pub fn cmd_bounds(args: &Args) -> Result<i32> {
    let l = args.get_usize("servers", 50).map_err(e)?;
    let k = args.get_usize("k", l).map_err(e)?;
    let lambda = args.get_f64("lambda", 0.5).map_err(e)?;
    let mu = args.get_f64("mu", k as f64 / l as f64).map_err(e)?;
    let epsilon = args.get_f64("epsilon", 1e-6).map_err(e)?;
    let overhead = overhead_from_args(args)?;
    let engine = match args.get_or("engine", "auto").as_str() {
        "artifact" => BoundsEngine::artifact()?,
        "rust" | "native" => BoundsEngine::native(),
        _ => BoundsEngine::auto(),
    };
    println!("engine: {:?}", engine.kind());

    match args.get_or("model", "all").as_str() {
        "sm-big" => {
            let kappa = args.get_usize("kappa", 20).map_err(e)? as u32;
            let rows = engine.erlang(&[ErlangQuery { l, kappa, lambda, mu, epsilon }])?;
            let r = rows[0];
            println!("big-tasks SM: E[Δ]={:.4}s  ρ*={:.4}", r.mean_service, r.max_utilization);
            match r.sojourn {
                Some(t) => println!("sojourn ε-quantile bound: {t:.4} s"),
                None => println!("sojourn bound: INFEASIBLE (unstable)"),
            }
        }
        _ => {
            let rows =
                engine.bounds(&[BoundQuery { k, l, lambda, mu, epsilon, overhead }])?;
            let r = rows[0];
            let show = |name: &str, v: Option<f64>| match v {
                Some(t) => println!("{name:<22} {t:.4} s"),
                None => println!("{name:<22} INFEASIBLE (unstable)"),
            };
            println!(
                "l={l} k={k} lambda={lambda} mu={mu} eps={epsilon} overhead={}",
                overhead.is_some()
            );
            show("split-merge", r.split_merge);
            show("single-queue fork-join", r.fork_join);
            show("ideal partition", r.ideal);
        }
    }
    Ok(0)
}

/// `tiny-tasks stability` — stability scans.
pub fn cmd_stability(args: &Args) -> Result<i32> {
    let l = args.get_usize("servers", 50).map_err(e)?;
    let ks: Vec<usize> = k_list_from_args(args, "k-list")?
        .unwrap_or_else(|| vec![50, 100, 200, 400, 1000, 2000, 4000]);
    let overhead = overhead_from_args(args)?;
    println!("{:>8} {:>14} {:>14} {:>14}", "k", "sm_eq20", "sm_mc", "fj");
    for k in ks {
        let mu = k as f64 / l as f64;
        let eq20 = analysis::stability::sm_tiny_tasks(l, k);
        let mc = sim::stability::max_utilization(
            ModelKind::SplitMerge,
            l,
            k,
            mu,
            overhead,
            10_000,
            args.get_u64("seed", 1).map_err(e)?,
        );
        let fj = sim::stability::max_utilization(
            ModelKind::ForkJoinSingleQueue,
            l,
            k,
            mu,
            overhead,
            10_000,
            1,
        );
        println!("{k:>8} {eq20:>14.4} {mc:>14.4} {fj:>14.4}");
    }
    Ok(0)
}

/// `tiny-tasks figure` — regenerate a paper figure's data.
pub fn cmd_figure(args: &Args) -> Result<i32> {
    let Some(id) = args.positional.first() else {
        bail!("usage: tiny-tasks figure <id>|all [--out DIR] [--scale quick|paper]");
    };
    let out_dir = PathBuf::from(args.get_or("out", "reports"));
    std::fs::create_dir_all(&out_dir)?;
    let scale = Scale::parse(&args.get_or("scale", "quick")).map_err(e)?;
    let engine = BoundsEngine::auto();
    let pool = pool_from_args(args)?;
    let ctx = FigureCtx {
        out_dir: &out_dir,
        scale,
        seed: args.get_u64("seed", 1).map_err(e)?,
        engine: &engine,
        pool: &pool,
    };
    let t0 = std::time::Instant::now();
    figures::run(id, &ctx)?;
    println!("figure {id} done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(0)
}

fn print_calibration(cal: &calibrate::Calibration) {
    println!("measured {} tasks / {} jobs", cal.tasks_measured, cal.jobs_measured);
    println!("fitted overhead model (paper §2.6 table analog, emulated seconds):");
    println!("  c_task_ts  = {:.6} s ({:.3} ms)", cal.fitted.c_task_ts, cal.fitted.c_task_ts * 1e3);
    println!("  mu_task_ts = {:.1} 1/s", cal.fitted.mu_task_ts);
    println!("  c_job_pd   = {:.6} s ({:.3} ms)", cal.fitted.c_job_pd, cal.fitted.c_job_pd * 1e3);
    println!("  c_task_pd  = {:.9} s ({:.6} ms)", cal.fitted.c_task_pd, cal.fitted.c_task_pd * 1e3);
    println!(
        "PP distance: without overhead {:.4} -> with fitted overhead {:.4}",
        cal.pp_without_overhead, cal.pp_with_overhead
    );
}

/// `tiny-tasks calibrate` — fit the 4-parameter overhead model, against
/// a live sparklite run or (`--from-trace FILE`) a recorded trace.
pub fn cmd_calibrate(args: &Args) -> Result<i32> {
    if let Some(path) = args.get("from-trace") {
        let trace = crate::trace::Trace::read_file(path).map_err(e)?;
        println!(
            "trace            {path} ({} source, {} jobs / {} task rows)",
            trace.meta.source,
            trace.jobs.len(),
            trace.tasks.len()
        );
        let cal = calibrate::calibrate_from_trace(&trace).map_err(e)?;
        print_calibration(&cal);
        return Ok(0);
    }
    let base = EmulatorConfig {
        executors: args.get_usize("executors", 8).map_err(e)?,
        tasks_per_job: 0, // overridden per k
        mode: ModelKind::ForkJoinSingleQueue,
        interarrival: args.get_or("interarrival", "exp:0.4"),
        execution: String::new(), // set per k below
        // Default respects the 1-core wall task-rate cap (DESIGN.md §2).
        time_scale: args.get_f64("time-scale", 0.05).map_err(e)?,
        jobs: args.get_usize("jobs", 200).map_err(e)?,
        warmup: args.get_usize("warmup", 20).map_err(e)?,
        seed: args.get_u64("seed", 1).map_err(e)?,
        inject_overhead: if args.get_bool("inject-overhead") {
            Some(OverheadConfig::paper())
        } else {
            None
        },
        workers: None,
    };
    let l = base.executors;
    let ks: Vec<usize> =
        k_list_from_args(args, "k-list")?.unwrap_or_else(|| vec![4 * l, 16 * l]);
    // μ = k/l per point, constant E[L].
    let mut cals = Vec::new();
    for &k in &ks {
        let cfg = EmulatorConfig {
            tasks_per_job: k,
            execution: format!("exp:{}", k as f64 / l as f64),
            ..base.clone()
        };
        cals.push(cfg);
    }
    // Calibrate with the middle config's execution spec applied to all ks
    // (the calibration runs one emulator per k internally).
    let mid = cals[cals.len() / 2].clone();
    let cal = calibrate::calibrate(&mid, &ks).map_err(e)?;
    print_calibration(&cal);
    Ok(0)
}

/// `tiny-tasks advisor` — recommend k for a cluster (the paper's
/// concluding use-case). With `--speeds`/`--speed-dist`/`--redundancy`
/// the recommendation comes from the `approx` analytic engine
/// (microseconds instead of sweep-minutes); `--simulate` falls back to
/// simulation sweeps. Homogeneous clusters use the bounds engine.
pub fn cmd_advisor(args: &Args) -> Result<i32> {
    let l = args.get_usize("servers", 50).map_err(e)?;
    let lambda = args.get_f64("lambda", 0.5).map_err(e)?;
    let workload = args.get_f64("workload", l as f64).map_err(e)?;
    let epsilon = args.get_f64("epsilon", 0.01).map_err(e)?;
    let model = ModelKind::parse(&args.get_or("model", "fj")).map_err(e)?;
    let oh = overhead_from_args(args)?.unwrap_or_else(OverheadConfig::paper);
    let (workers, redundancy) = scenario_from_args(args)?;
    let faults = faults_from_args(args)?;
    let policy = policy_from_args(args)?;
    let rec = if workers.is_some()
        || redundancy.is_some()
        || faults.is_some()
        || policy.is_some()
    {
        if model == ModelKind::ForkJoinPerServer {
            bail!(
                "the scenario advisor sweeps tasks-per-job and needs a \
                 tiny-tasks model (sm/fj); fjps is fixed at k = l"
            );
        }
        // The analytic approximation knows nothing about faults or
        // non-FCFS dispatch, so fault-injected and policy advice always
        // comes from a simulation sweep.
        if args.get_bool("simulate") || faults.is_some() || policy.is_some() {
            let jobs = args.get_usize("jobs", 8_000).map_err(e)?;
            let kappa_max = args.get_f64("kappa-max", 32.0).map_err(e)?;
            let base = SimulationConfig {
                model,
                servers: l,
                tasks_per_job: l, // overridden per sweep point
                arrival: crate::config::ArrivalConfig {
                    interarrival: format!("exp:{lambda}"),
                },
                service: crate::config::ServiceConfig { execution: "exp:1.0".into() },
                jobs,
                warmup: jobs / 10,
                seed: args.get_u64("seed", 1).map_err(e)?,
                overhead: Some(oh),
                workers,
                redundancy,
                faults,
                policy,
            };
            let pool = pool_from_args(args)?;
            let ks = advisor::k_grid(l, kappa_max);
            if let Some(p) = &base.policy {
                println!("engine: simulation sweep (policy: {})", policy_banner(p, l));
            } else if faults.is_some() {
                println!("engine: simulation sweep (fault-injected scenario)");
            } else {
                println!("engine: simulation sweep (heterogeneous/redundant scenario)");
            }
            advisor::recommend_simulated(&pool, &base, workload, epsilon, &ks).map_err(e)?
        } else {
            let spec = crate::approx::ClusterSpec::from_scenario(l, workers.as_ref(), redundancy)
                .map_err(e)?;
            let kappa_max = args.get_f64("kappa-max", 200.0).map_err(e)?;
            println!("engine: analytic approximation (heterogeneous/redundant scenario)");
            advisor::recommend_approx(model, &spec, lambda, workload, epsilon, oh, kappa_max)
                .map_err(e)?
        }
    } else {
        let engine = BoundsEngine::auto();
        advisor::recommend(&engine, model, l, lambda, workload, epsilon, oh)?
    };
    println!(
        "cluster: l={l}, lambda={lambda}/s, E[workload]={workload}s, model={model}, eps={epsilon}"
    );
    match rec.best {
        Some((k, tau)) => {
            println!("recommended tasks/job k = {k} (kappa = {:.1})", k as f64 / l as f64);
            println!("predicted sojourn ε-quantile = {tau:.3} s");
        }
        None => println!("no stable k found — reduce load or add workers"),
    }
    println!("\n{:>8} {:>14}", "k", "tau_eps(s)");
    for (k, tau) in &rec.curve {
        match tau {
            Some(t) => println!("{k:>8} {t:>14.3}"),
            None => println!("{k:>8} {:>14}", "unstable"),
        }
    }
    Ok(0)
}

/// `tiny-tasks approx` — the analytic approximation for heterogeneous /
/// redundant clusters, cross-validated against a simulation sweep: one
/// row per k with the analytic sojourn ε-quantile next to the simulated
/// (1−ε)-quantile. `--no-sim` skips the sweep (pure analytics,
/// microseconds); `--check` turns the comparison into a pass/fail gate
/// (the CI smoke check): every comparable point's `analytic / simulated`
/// ratio must land in `[--floor, --tolerance]` (defaults 0.75 and 12).
/// The approximation is a genuine upper bound for pure skew; replica
/// grouping idealizes the dynamic first-finish-wins dispatch, so under
/// redundancy it may undershoot slightly — hence a tracking window, not
/// a one-sided dominance test.
pub fn cmd_approx(args: &Args) -> Result<i32> {
    use crate::approx::{self, ApproxModel, ClusterSpec};
    use crate::coordinator::sweep::{constant_workload_points, run_sweep_with, SweepOptions};
    use crate::util::csv::Csv;

    let l = args.get_usize("servers", 8).map_err(e)?;
    let lambda = args.get_f64("lambda", 0.4).map_err(e)?;
    let workload = args.get_f64("workload", l as f64).map_err(e)?;
    let epsilon = args.get_f64("epsilon", 0.01).map_err(e)?;
    let model = ModelKind::parse(&args.get_or("model", "fj")).map_err(e)?;
    let am = ApproxModel::from_model_kind(model).map_err(e)?;
    let oh = overhead_from_args(args)?.unwrap_or_else(OverheadConfig::paper);
    let (workers, redundancy) = scenario_from_args(args)?;
    let faults = faults_from_args(args)?;
    if policy_from_args(args)?.is_some() {
        bail!(
            "the analytic approximation models FCFS dispatch only; drop --policy \
             (policy sweeps: `tiny-tasks advisor --policy ...` or `figure policy`)"
        );
    }
    if faults.is_some() && args.get_bool("check") {
        bail!(
            "--check compares the analytic curve against a fault-free sweep; \
             the approximation does not model faults — drop the fault flags"
        );
    }
    let spec = ClusterSpec::from_scenario(l, workers.as_ref(), redundancy).map_err(e)?;
    let ks: Vec<usize> = match k_list_from_args(args, "k-list")? {
        Some(list) => list,
        None => advisor::k_grid(l, args.get_f64("kappa-max", 16.0).map_err(e)?),
    };
    if ks.is_empty() {
        bail!("no k values to evaluate; give --k-list or a larger --kappa-max");
    }
    if ks.iter().any(|&k| k < l) {
        bail!("tiny-tasks approximation needs k >= l for every k");
    }

    let curve = approx::sojourn_curve(am, &spec, lambda, workload, epsilon, Some(oh), &ks);
    let sims = if args.get_bool("no-sim") {
        None
    } else {
        let jobs = args.get_usize("jobs", 6_000).map_err(e)?;
        let points = constant_workload_points(
            model,
            l,
            lambda,
            workload,
            jobs,
            Some(oh),
            workers,
            redundancy,
            faults,
            None,
            &ks,
        )
        .map_err(e)?;
        if faults.is_some() {
            println!(
                "note: faults are injected into the simulated column only; \
                 the analytic curve is fault-free"
            );
        }
        let pool = pool_from_args(args)?;
        let want_metrics = args.get("metrics").is_some();
        let n_points = points.len();
        let t_sweep = std::time::Instant::now();
        let outcomes = run_sweep_with(
            &pool,
            points,
            SweepOptions { q: 1.0 - epsilon, streaming: false, metrics: want_metrics },
            args.get_u64("seed", 1).map_err(e)?,
        )
        .map_err(e)?;
        if want_metrics {
            // Merge per-point registries in point order (deterministic),
            // keeping a per-k row for the report's `sweep_points` array.
            let mut m = Metrics::enabled();
            let mut rows = Vec::with_capacity(outcomes.len());
            for o in &outcomes {
                m.merge(&o.metrics);
                rows.push(obs::report::SweepPointRecord::from_metrics(
                    o.label,
                    jobs as u64,
                    o.jobs_per_sec,
                    &o.metrics,
                ));
            }
            if let Some(path) = args.get("metrics") {
                obs::report::write_file_with_points(
                    path,
                    "sweep",
                    &m,
                    (jobs * n_points) as u64,
                    t_sweep.elapsed().as_secs_f64(),
                    &rows,
                )
                .map_err(e)?;
                println!("wrote {path}");
            }
        }
        Some(outcomes)
    };

    println!(
        "cluster: l={l}, lambda={lambda}/s, E[workload]={workload}s, model={model}, \
         eps={epsilon}"
    );
    println!(
        "scenario: speeds in [{:.3}, {:.3}] (Σ = {:.3}), replicas r = {}, launch = {}s",
        spec.speeds.iter().cloned().fold(f64::INFINITY, f64::min),
        spec.speeds.iter().cloned().fold(0.0f64, f64::max),
        spec.total_speed(),
        spec.replicas,
        spec.replica_launch,
    );
    println!(
        "stability: sm rho* = {:.4} (at largest k), fj rho* = {:.4}",
        approx::sm_max_utilization(&spec, *ks.last().unwrap()),
        approx::fork_join_max_utilization(&spec),
    );
    let mut csv = Csv::new(vec!["k", "mu", "analytic_q", "sim_q"]);
    println!("\n{:>8} {:>14} {:>14} {:>8}", "k", "analytic(s)", "sim(s)", "ratio");
    for (i, pt) in curve.iter().enumerate() {
        let sim_q = sims.as_ref().map(|s| s[i].sojourn_q);
        let a_txt = pt
            .sojourn
            .map(|t| format!("{t:.3}"))
            .unwrap_or_else(|| "unstable".into());
        let s_txt = sim_q.map(|q| format!("{q:.3}")).unwrap_or_else(|| "-".into());
        let ratio = match (pt.sojourn, sim_q) {
            (Some(a), Some(s)) if s > 0.0 => format!("{:.2}", a / s),
            _ => "-".into(),
        };
        println!("{:>8} {a_txt:>14} {s_txt:>14} {ratio:>8}", pt.k);
        csv.push(&[
            pt.k as f64,
            pt.mu,
            pt.sojourn.unwrap_or(f64::NAN),
            sim_q.unwrap_or(f64::NAN),
        ]);
    }
    if let Some(out) = args.get("out") {
        csv.write_file(out)?;
        println!("wrote {out}");
    }

    if args.get_bool("check") {
        let Some(sims) = &sims else {
            bail!("--check needs the simulation sweep; drop --no-sim");
        };
        let tolerance = args.get_f64("tolerance", 12.0).map_err(e)?;
        let floor = args.get_f64("floor", 0.75).map_err(e)?;
        let mut compared = 0usize;
        let mut failures = Vec::new();
        for (pt, sim) in curve.iter().zip(sims) {
            let (Some(a), s) = (pt.sojourn, sim.sojourn_q) else { continue };
            if !s.is_finite() || s <= 0.0 {
                continue;
            }
            compared += 1;
            let ratio = a / s;
            if ratio < floor {
                failures.push(format!(
                    "k={}: analytic {a:.3}s undershoots simulated {s:.3}s \
                     (ratio {ratio:.2} < {floor})",
                    pt.k
                ));
            }
            if ratio > tolerance {
                failures.push(format!(
                    "k={}: analytic {a:.3}s vacuous vs simulated {s:.3}s \
                     (ratio {ratio:.2} > {tolerance})",
                    pt.k
                ));
            }
        }
        if compared == 0 {
            failures.push("no stable point to compare".into());
        }
        if failures.is_empty() {
            println!(
                "\napprox check: OK ({compared} points, analytic/sim within \
                 [{floor}, {tolerance}])"
            );
        } else {
            println!("\napprox check: FAIL");
            for f in &failures {
                println!("  {f}");
            }
            return Ok(1);
        }
    }
    Ok(0)
}

/// One measured row of the `bench` suite (serialized into BENCH.json).
struct BenchRow {
    name: String,
    engine: &'static str,
    model: String,
    servers: usize,
    k: usize,
    jobs_per_iter: usize,
    iters: u64,
    mean_seconds: f64,
    jobs_per_sec: f64,
    tasks_per_sec: f64,
    /// Phase-profile breakdown of one profiled (non-timed) run of the
    /// same workload, as (phase key, wall seconds); empty for rows that
    /// aren't profiled. Serialized last in each entry so schema-v1
    /// readers that scan up to the first close brace keep working.
    phases: Vec<(String, f64)>,
}

impl BenchRow {
    fn new(
        name: &str,
        engine: &'static str,
        model: &str,
        servers: usize,
        k: usize,
        jobs_per_iter: usize,
        result: &crate::util::bench::BenchResult,
    ) -> Self {
        let mean_seconds = result.mean.as_secs_f64().max(1e-12);
        Self {
            name: name.to_string(),
            engine,
            model: model.to_string(),
            servers,
            k,
            jobs_per_iter,
            iters: result.iters,
            mean_seconds,
            jobs_per_sec: jobs_per_iter as f64 / mean_seconds,
            tasks_per_sec: (jobs_per_iter * k) as f64 / mean_seconds,
            phases: Vec::new(),
        }
    }

    fn with_phases(mut self, phases: Vec<(String, f64)>) -> Self {
        self.phases = phases;
        self
    }
}

/// The obs phases of one profiled run as (key, seconds) pairs.
fn phase_pairs(m: &Metrics) -> Vec<(String, f64)> {
    Phase::ALL
        .iter()
        .map(|p| (p.key().to_string(), m.phase_seconds(*p)))
        .collect()
}

/// One profiled (untimed) recursion run for a bench row; folds the
/// registry into the bench-wide aggregate and returns the row's phases.
fn profile_sim_row(
    cfg: &SimulationConfig,
    streaming: bool,
    agg: &mut Metrics,
) -> Result<Vec<(String, f64)>> {
    let prof = sim::run(cfg, RunOptions { streaming, metrics: true, ..Default::default() })
        .map_err(e)?;
    agg.merge(&prof.metrics);
    Ok(phase_pairs(&prof.metrics))
}

/// One profiled calendar run for a bench row: times the run, splits the
/// wall clock into sampling vs dispatch via the engine's profile hook,
/// and folds tallies + RNG draw counts into the bench-wide aggregate.
fn profile_calendar_row(
    disc: crate::sim::Discipline,
    l: usize,
    k: usize,
    jobs: usize,
    mu: f64,
    seed: u64,
    agg: &mut Metrics,
) -> Vec<(String, f64)> {
    use crate::dist::Exponential;
    use crate::sim::{Calendar, OverheadModel, TraceLog, Workload};
    let mut cal = Calendar::new(disc, l, vec![k as u32]).with_profile(true);
    let oh = OverheadModel::none();
    let mut w = Workload::new(Exponential::new(0.5).into(), Exponential::new(mu).into(), seed);
    let mut tr = TraceLog::disabled();
    let t0 = std::time::Instant::now();
    cal.run(jobs, &mut w, &oh, &mut tr);
    let total = t0.elapsed().as_secs_f64();
    let sampling = cal.sampling_seconds();
    let dispatch = (total - sampling).max(0.0);
    agg.absorb_tallies(&cal.tallies());
    let (arrivals, executions, batches) = w.draw_counts();
    agg.add(Counter::ArrivalDraws, arrivals);
    agg.add(Counter::ExecutionDraws, executions);
    agg.add(Counter::BatchDraws, batches);
    agg.phase_add_secs(Phase::Sampling, sampling);
    agg.phase_add_secs(Phase::Dispatch, dispatch);
    agg.absorb_spans(cal.spans());
    vec![("sampling".to_string(), sampling), ("dispatch".to_string(), dispatch)]
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialize the suite to the BENCH.json schema (documented in the
/// README's Performance section). Hand-rolled: the offline registry has
/// no serde.
fn bench_json(fast: bool, seed: u64, rows: &[BenchRow]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    // v2: entries may carry a trailing "phases" object (profiled wall
    // seconds per obs phase). v1 readers that ignore unknown keys — and
    // the gate's innermost-brace scanner — stay compatible.
    s.push_str("  \"schema_version\": 2,\n");
    s.push_str(&format!("  \"fast\": {fast},\n"));
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str("  \"entries\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let phases = if r.phases.is_empty() {
            String::new()
        } else {
            let body: Vec<String> =
                r.phases.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
            format!(", \"phases\": {{{}}}", body.join(", "))
        };
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"engine\": \"{}\", \"model\": \"{}\", \
             \"servers\": {}, \"k\": {}, \"jobs_per_iter\": {}, \"iters\": {}, \
             \"mean_seconds\": {}, \"jobs_per_sec\": {}, \"tasks_per_sec\": {}{}}}{}\n",
            json_escape(&r.name),
            r.engine,
            json_escape(&r.model),
            r.servers,
            r.k,
            r.jobs_per_iter,
            r.iters,
            r.mean_seconds,
            r.jobs_per_sec,
            r.tasks_per_sec,
            phases,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

fn bench_sim_cfg(model: ModelKind, l: usize, k: usize, jobs: usize, seed: u64) -> SimulationConfig {
    SimulationConfig {
        model,
        servers: l,
        tasks_per_job: k,
        arrival: crate::config::ArrivalConfig { interarrival: "exp:0.5".into() },
        service: crate::config::ServiceConfig {
            execution: format!("exp:{}", k as f64 / l as f64),
        },
        jobs,
        warmup: 0,
        seed,
        overhead: None,
        workers: None,
        redundancy: None,
        faults: None,
        policy: None,
    }
}

/// `tiny-tasks bench` — run the deterministic perf suite (jobs/sec and
/// tasks/sec per model × k, both DES engines) and write BENCH.json, the
/// repo's perf-trajectory artifact (every PR gets a comparable number).
pub fn cmd_bench(args: &Args) -> Result<i32> {
    use crate::dist::Exponential;
    use crate::sim::{Calendar, Discipline, OverheadModel, TraceLog, Workload};
    use crate::util::bench::Bencher;
    use std::time::Duration;

    let out_path = PathBuf::from(args.get_or("out", "BENCH.json"));
    let fast = args.get_bool("fast");
    let seed = args.get_u64("seed", 1).map_err(e)?;
    let mut bencher = if fast {
        // Smoke budgets for CI: enough iterations for a stable order of
        // magnitude, small enough to keep the job cheap.
        Bencher::new(Duration::from_millis(30), Duration::from_millis(120))
    } else {
        Bencher::default()
    };
    let mut rows: Vec<BenchRow> = Vec::new();
    let t_bench = std::time::Instant::now();
    // Bench-wide profiled registry: each row gets one extra untimed run
    // with metrics on; its phase breakdown lands in the row's "phases"
    // object (BENCH.json schema v2) and the merged registry backs
    // `bench --metrics`.
    let mut profiled = Metrics::enabled();

    // Recursion engines: the four models on the Fig.-8 sweep shapes.
    let suite: &[(&str, ModelKind, usize, usize, usize)] = &[
        ("sim/sm/l50/k400", ModelKind::SplitMerge, 50, 400, 200),
        ("sim/fj/l50/k400", ModelKind::ForkJoinSingleQueue, 50, 400, 200),
        ("sim/fj/l50/k2500", ModelKind::ForkJoinSingleQueue, 50, 2500, 40),
        ("sim/fjps/l50", ModelKind::ForkJoinPerServer, 50, 50, 2000),
        ("sim/ideal/l50/k400", ModelKind::Ideal, 50, 400, 500),
    ];
    for &(name, model, l, k, jobs) in suite {
        let cfg = bench_sim_cfg(model, l, k, jobs, seed);
        let r = bencher.bench(name, || {
            sim::run(&cfg, RunOptions::default()).unwrap().sojourn_summary.count()
        });
        let phases = profile_sim_row(&cfg, false, &mut profiled)?;
        rows.push(
            BenchRow::new(name, "recursion", &model.to_string(), l, k, jobs, r)
                .with_phases(phases),
        );
    }

    // Variants on the fork-join shape: overhead model, heterogeneous +
    // redundant scenario, and the O(1)-memory streaming-stats mode.
    {
        let (l, k, jobs) = (50usize, 400usize, 200usize);
        let cfg = SimulationConfig {
            overhead: Some(OverheadConfig::paper()),
            ..bench_sim_cfg(ModelKind::ForkJoinSingleQueue, l, k, jobs, seed)
        };
        let name = "sim/fj/l50/k400/overhead";
        let r = bencher.bench(name, || {
            sim::run(&cfg, RunOptions::default()).unwrap().sojourn_summary.count()
        });
        let phases = profile_sim_row(&cfg, false, &mut profiled)?;
        rows.push(
            BenchRow::new(name, "recursion", "fj+overhead", l, k, jobs, r).with_phases(phases),
        );

        let mut speeds = vec![1.5; l / 2];
        speeds.extend(vec![0.5; l - l / 2]);
        let cfg = SimulationConfig {
            workers: Some(WorkersConfig::Speeds(speeds)),
            redundancy: Some(RedundancyConfig::new(2)),
            ..bench_sim_cfg(ModelKind::ForkJoinSingleQueue, l, k, jobs, seed)
        };
        let name = "sim/fj/l50/k400/scenario";
        let r = bencher.bench(name, || {
            sim::run(&cfg, RunOptions::default()).unwrap().sojourn_summary.count()
        });
        let phases = profile_sim_row(&cfg, false, &mut profiled)?;
        rows.push(
            BenchRow::new(name, "recursion", "fj+scenario", l, k, jobs, r).with_phases(phases),
        );

        let cfg = bench_sim_cfg(ModelKind::ForkJoinSingleQueue, l, k, jobs, seed);
        let name = "sim/fj/l50/k400/streaming";
        let r = bencher.bench(name, || {
            sim::run(&cfg, RunOptions { streaming: true, ..Default::default() })
                .unwrap()
                .sojourn_summary
                .count()
        });
        let phases = profile_sim_row(&cfg, true, &mut profiled)?;
        rows.push(
            BenchRow::new(name, "recursion", "fj+streaming", l, k, jobs, r).with_phases(phases),
        );

        // Dispatch-policy variant: the --policy flag set selects the
        // discipline; without flags the row defaults to SITA with a
        // boundary at the mean task size (both size classes stay
        // populated on the exp:{k/l} service law), so the policy layer's
        // cost is tracked next to the plain fj row on every run.
        let policy = match policy_from_args(args)? {
            Some(p) => p,
            None => PolicyConfig {
                kind: PolicyKind::Sita,
                sita_boundaries: vec![l as f64 / k as f64],
                ..PolicyConfig::default()
            },
        };
        let name = format!("sim/fj/l50/k400/policy-{}", policy.kind);
        let cfg = SimulationConfig {
            policy: Some(policy),
            ..bench_sim_cfg(ModelKind::ForkJoinSingleQueue, l, k, jobs, seed)
        };
        let r = bencher.bench(&name, || {
            sim::run(&cfg, RunOptions::default()).unwrap().sojourn_summary.count()
        });
        let phases = profile_sim_row(&cfg, false, &mut profiled)?;
        rows.push(
            BenchRow::new(&name, "recursion", "fj+policy", l, k, jobs, r).with_phases(phases),
        );
    }

    // Event-calendar engine, both disciplines (cross-validation path).
    for &(name, disc, tag, l, k, jobs) in &[
        ("calendar/sm/l50/k400", Discipline::SplitMerge, "sm", 50usize, 400usize, 200usize),
        ("calendar/fj/l50/k400", Discipline::SingleQueueForkJoin, "fj", 50, 400, 200),
    ] {
        let mut cal = Calendar::new(disc, l, vec![k as u32]);
        let oh = OverheadModel::none();
        let mu = k as f64 / l as f64;
        let r = bencher.bench(name, || {
            let mut w = Workload::new(
                Exponential::new(0.5).into(),
                Exponential::new(mu).into(),
                seed,
            );
            let mut tr = TraceLog::disabled();
            cal.run(jobs, &mut w, &oh, &mut tr).len()
        });
        let phases = profile_calendar_row(disc, l, k, jobs, mu, seed, &mut profiled);
        rows.push(BenchRow::new(name, "calendar", tag, l, k, jobs, r).with_phases(phases));
    }

    // Headline: the 500k-job single-queue fork-join run through the
    // calendar engine — the acceptance workload for the O(events·log l)
    // overhaul (the pre-rewrite engine was O(jobs²) here).
    {
        let (l, k) = (10usize, 20usize);
        let jobs = if fast { 20_000 } else { 500_000 };
        let name = "calendar/fj/l10/k20/headline";
        let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, l, vec![k as u32]);
        let oh = OverheadModel::none();
        let mu = k as f64 / l as f64;
        let r = bencher.bench(name, || {
            let mut w = Workload::new(
                Exponential::new(0.5).into(),
                Exponential::new(mu).into(),
                seed,
            );
            let mut tr = TraceLog::disabled();
            cal.run(jobs, &mut w, &oh, &mut tr).len()
        });
        let phases = profile_calendar_row(
            Discipline::SingleQueueForkJoin,
            l,
            k,
            jobs,
            mu,
            seed,
            &mut profiled,
        );
        rows.push(BenchRow::new(name, "calendar", "fj", l, k, jobs, r).with_phases(phases));
    }

    // Multithreaded headline: the same workload split into replication
    // shards (per-shard seed/engine/workload, merged totals) across the
    // thread pool — the sharded-run execution model, measured end to
    // end. `--threads` overrides the worker count (default: machine
    // parallelism, clamped to the headline's useful range).
    {
        use crate::rng::spawn_seeds;
        let (l, k) = (10usize, 20usize);
        let jobs = if fast { 20_000 } else { 500_000 };
        let threads = match args.get_usize("threads", 0).map_err(e)? {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 8),
            n => n.max(1),
        };
        let name = "calendar/fj/l10/k20/headline-mt";
        let pool = ThreadPool::new(threads);
        let mu = k as f64 / l as f64;
        let (base, rem) = (jobs / threads, jobs % threads);
        let work: Vec<(usize, u64)> = spawn_seeds(seed, threads)
            .into_iter()
            .enumerate()
            .map(|(i, s)| (base + usize::from(i < rem), s))
            .collect();
        let r = bencher.bench(name, || {
            pool.map(work.clone(), move |(share, s)| {
                let mut cal = Calendar::new(Discipline::SingleQueueForkJoin, l, vec![k as u32]);
                let oh = OverheadModel::none();
                let mut w = Workload::new(
                    Exponential::new(0.5).into(),
                    Exponential::new(mu).into(),
                    s,
                );
                let mut tr = TraceLog::disabled();
                cal.run(share, &mut w, &oh, &mut tr).len()
            })
            .expect("bench shard panicked")
            .into_iter()
            .sum::<usize>()
        });
        rows.push(BenchRow::new(name, "calendar", "fj-mt", l, k, jobs, r));
    }

    bencher.finish();
    let json = bench_json(fast, seed, &rows);
    std::fs::write(&out_path, &json)?;
    println!("wrote {}", out_path.display());
    write_metrics_report(
        args,
        "bench",
        &profiled,
        profiled.counter(Counter::JobsCompleted),
        t_bench.elapsed().as_secs_f64(),
    )?;

    // Regression gate: compare the headline row against a committed
    // baseline (CI fails the job when it regresses by more than
    // --max-regression, default 2x — integer-factor slowdowns of the
    // calendar hot path, not noise).
    if let Some(baseline_path) = args.get("baseline") {
        let factor = args.get_f64("max-regression", 2.0).map_err(e)?;
        let baseline_json = std::fs::read_to_string(baseline_path)?;
        // The single-core headline row is mandatory; the multithreaded
        // row gates once the baseline has ratcheted to include it (so an
        // old baseline file still works).
        let gated: &[(&str, bool)] = &[
            ("calendar/fj/l10/k20/headline", true),
            ("calendar/fj/l10/k20/headline-mt", false),
        ];
        let mut failed = false;
        for &(row, required) in gated {
            let base = match extract_jobs_per_sec(&baseline_json, row) {
                Some(b) => b,
                None if required => {
                    bail!("{baseline_path}: no jobs_per_sec entry for {row:?}")
                }
                None => continue,
            };
            let Some(cur) = extract_jobs_per_sec(&json, row) else {
                bail!("BENCH.json: no jobs_per_sec entry for {row:?}");
            };
            println!(
                "bench gate: {row} {cur:.0} jobs/s vs baseline {base:.0} \
                 (floor {:.0} = baseline/{factor})",
                base / factor
            );
            if cur * factor < base {
                println!("bench gate: FAIL — {row} regressed by more than {factor}x");
                failed = true;
            }
        }
        if failed {
            return Ok(1);
        }
        println!("bench gate: OK");
    }
    Ok(0)
}

/// Pull `jobs_per_sec` for the named entry out of a BENCH.json document
/// (hand-rolled, no serde). Whitespace-insensitive and tolerant of key
/// order / pretty-printing, so a jq-reformatted baseline still gates:
/// the entry is the innermost `{...}` containing the name match.
fn extract_jobs_per_sec(json: &str, name: &str) -> Option<f64> {
    let compact: String = json.chars().filter(|c| !c.is_whitespace()).collect();
    let needle = format!("\"name\":\"{name}\"");
    let at = compact.find(&needle)?;
    let obj_start = compact[..at].rfind('{').map(|i| i + 1).unwrap_or(0);
    let obj_end = compact[at..].find('}').map(|i| at + i).unwrap_or(compact.len());
    let entry = &compact[obj_start..obj_end];
    let idx = entry.find("\"jobs_per_sec\":")?;
    let rest = &entry[idx + "\"jobs_per_sec\":".len()..];
    let token: String = rest
        .chars()
        .take_while(|c| c.is_ascii_digit() || matches!(c, '.' | 'e' | 'E' | '+' | '-'))
        .collect();
    token.parse().ok()
}

/// `tiny-tasks trace record|replay|summarize|convert` — the persistent
/// trace workflows (record from either engine, drive any model from a
/// file, inspect, transcode).
pub fn cmd_trace(args: &Args) -> Result<i32> {
    let Some(sub) = args.positional.first() else {
        bail!(
            "usage: tiny-tasks trace <record|replay|summarize|convert> [flags]\n\
             run 'tiny-tasks help' for the flag list"
        );
    };
    match sub.as_str() {
        "record" => trace_record(args),
        "replay" => trace_replay(args),
        "summarize" => trace_summarize(args),
        "convert" => trace_convert(args),
        other => bail!("unknown trace subcommand {other:?} (record|replay|summarize|convert)"),
    }
}

fn trace_format_flag(args: &Args) -> Result<Option<crate::trace::TraceFormat>> {
    match args.get("format") {
        Some(s) => Ok(Some(crate::trace::TraceFormat::parse(s).map_err(e)?)),
        None => Ok(None),
    }
}

/// `trace record`: run one experiment with job + task capture on and
/// persist the trace (`--source sim|emulator`).
fn trace_record(args: &Args) -> Result<i32> {
    let out = args.get_or("out", "trace.ndjson");
    let format = trace_format_flag(args)?;
    let want_metrics = args.get("metrics").is_some();
    let t0 = std::time::Instant::now();
    let mut run_metrics: Option<Metrics> = None;
    let trace = match args.get_or("source", "sim").as_str() {
        "sim" | "des" => {
            let l = args.get_usize("servers", 8).map_err(e)?;
            let k = args.get_usize("k", 4 * l).map_err(e)?;
            let lambda = args.get_f64("lambda", 0.5).map_err(e)?;
            let mu = args.get_f64("mu", k as f64 / l as f64).map_err(e)?;
            // Scenario runs record as schema v2 (meta speeds/replicas +
            // per-row winner flags), so replay and calibrate --from-trace
            // see the real cluster shape.
            let (workers, redundancy) = scenario_from_args(args)?;
            let cfg = SimulationConfig {
                model: ModelKind::parse(&args.get_or("model", "fj")).map_err(e)?,
                servers: l,
                tasks_per_job: k,
                arrival: crate::config::ArrivalConfig {
                    interarrival: args.get_or("interarrival", &format!("exp:{lambda}")),
                },
                service: crate::config::ServiceConfig {
                    execution: args.get_or("execution", &format!("exp:{mu}")),
                },
                jobs: args.get_usize("jobs", 2_000).map_err(e)?,
                warmup: args.get_usize("warmup", 200).map_err(e)?,
                seed: args.get_u64("seed", 1).map_err(e)?,
                overhead: overhead_from_args(args)?,
                workers,
                redundancy,
                // Fault-injected runs record as schema v3 (attempt
                // counters + failure causes on task rows).
                faults: faults_from_args(args)?,
                // Policy runs record as schema v4 (policy token in the
                // meta + routing classes on task rows).
                policy: policy_from_args(args)?,
            };
            let mut res = sim::run(
                &cfg,
                RunOptions {
                    record_jobs: true,
                    trace: true,
                    metrics: want_metrics,
                    progress: args.get_bool("progress"),
                    ..Default::default()
                },
            )
            .map_err(e)?;
            if want_metrics {
                run_metrics = Some(std::mem::take(&mut res.metrics));
            }
            crate::trace::Trace::from_sim(&res).map_err(e)?
        }
        "emulator" | "emu" | "sparklite" => {
            let cfg = emulator_cfg_from_args(args)?;
            let res = emulator::run(&cfg).map_err(e)?;
            if want_metrics {
                run_metrics = Some(res.listener.to_obs());
            }
            crate::trace::Trace::from_emulator(&res).map_err(e)?
        }
        other => bail!("unknown --source {other:?} (sim|emulator)"),
    };
    let io_t0 = std::time::Instant::now();
    trace.write_file(&out, format).map_err(e)?;
    let io_secs = io_t0.elapsed().as_secs_f64();
    println!(
        "recorded {} jobs / {} task rows ({} source) -> {out}",
        trace.jobs.len(),
        trace.tasks.len(),
        trace.meta.source
    );
    if let Some(mut m) = run_metrics {
        m.phase_add_secs(Phase::Io, io_secs);
        write_metrics_report(
            args,
            "trace-record",
            &m,
            trace.jobs.len() as u64,
            t0.elapsed().as_secs_f64(),
        )?;
    }
    Ok(0)
}

/// `trace replay`: drive a model with a recorded trace's arrivals and
/// task sizes; report replayed sojourns and the PP distance to the
/// recorded ones.
fn trace_replay(args: &Args) -> Result<i32> {
    let Some(path) = args.get("in") else {
        bail!("trace replay needs --in FILE");
    };
    let trace = crate::trace::Trace::read_file(path).map_err(e)?;
    let opts = crate::trace::ReplayOptions {
        model: match args.get("model") {
            Some(m) => Some(ModelKind::parse(m).map_err(e)?),
            None => None,
        },
        servers: match args.get("servers") {
            Some(_) => Some(args.get_usize("servers", 0).map_err(e)?),
            None => None,
        },
        overhead: overhead_from_args(args)?,
        in_order_departures: args.get_bool("in-order"),
        seed: args.get_u64("seed", 1).map_err(e)?,
    };
    let rep = crate::trace::replay(&trace, &opts).map_err(e)?;
    let recorded = trace.sojourns();
    let replayed = rep.sojourns();
    if replayed.is_empty() || recorded.is_empty() {
        bail!("{path}: no measured jobs to compare against");
    }
    println!(
        "replayed {} jobs ({} tasks each) through {} on l={}",
        rep.jobs.len(),
        rep.tasks_per_job,
        rep.model,
        rep.servers
    );
    let mut sorted = replayed.clone();
    sorted.sort_by(f64::total_cmp);
    println!(
        "mean sojourn     {:.4} s (recorded {:.4} s)",
        replayed.iter().sum::<f64>() / replayed.len() as f64,
        recorded.iter().sum::<f64>() / recorded.len() as f64
    );
    for q in [0.5, 0.9, 0.99] {
        println!(
            "sojourn p{:<6} {:.4} s",
            q * 100.0,
            crate::stats::quantile_of_sorted(&sorted, q)
        );
    }
    let d = crate::stats::pp_distance(
        &crate::stats::Ecdf::new(replayed),
        &crate::stats::Ecdf::new(recorded),
        256,
    );
    println!("PP distance vs recorded sojourns: {d:.4}");
    Ok(0)
}

/// `trace summarize`: header, row counts, and phase-timing summaries.
fn trace_summarize(args: &Args) -> Result<i32> {
    let Some(path) = args.get("in") else {
        bail!("trace summarize needs --in FILE");
    };
    let trace = crate::trace::Trace::read_file(path).map_err(e)?;
    let m = &trace.meta;
    println!("schema           v{} ({} source)", m.schema, m.source);
    println!("model            {} (l={}, k={})", m.model, m.servers, m.tasks_per_job);
    println!("workload         {} / {}", m.interarrival, m.execution);
    if m.speeds.is_some() || m.replicas > 1 {
        let speeds = m.speeds.clone().unwrap_or_else(|| vec![1.0; m.servers as usize]);
        let min = speeds.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = speeds.iter().cloned().fold(0.0f64, f64::max);
        let losers = trace.tasks.iter().filter(|t| !t.winner).count();
        println!(
            "scenario         speeds in [{min:.3}, {max:.3}] (Σ = {:.3}), replicas r = {} \
             (launch {}s, {losers} cancelled-replica rows)",
            speeds.iter().sum::<f64>(),
            m.replicas,
            m.launch_overhead
        );
    }
    println!(
        "rows             {} jobs ({} measured, warmup {}), {} tasks",
        trace.jobs.len(),
        trace.measured_jobs().count(),
        m.warmup,
        trace.tasks.len()
    );
    if m.schema >= crate::trace::SCHEMA_V3 {
        use crate::trace::cause;
        let count = |c: u8| trace.tasks.iter().filter(|t| t.cause == c).count();
        let max_attempt = trace.tasks.iter().map(|t| t.attempt).max().unwrap_or(1);
        println!(
            "faults           {} failed, {} crashed, {} speculative rows \
             (max attempt {max_attempt})",
            count(cause::FAILED),
            count(cause::CRASHED),
            count(cause::SPECULATION),
        );
    }
    println!("seed             {} (time_scale {})", m.seed, m.time_scale);
    let summarize = |label: &str, xs: Vec<f64>| {
        if xs.is_empty() {
            return;
        }
        let mut s = crate::stats::Summary::new();
        for &x in &xs {
            s.push(x);
        }
        println!("{label:<17}mean {:.6} s, min {:.6}, max {:.6}", s.mean(), s.min(), s.max());
    };
    summarize("schedule delay", trace.measured_jobs().map(|j| j.schedule_delay()).collect());
    summarize("task service", trace.task_services());
    summarize("task overhead", trace.task_overheads());
    summarize(
        "pre-departure",
        trace.measured_jobs().map(|j| j.pre_departure_overhead).collect(),
    );
    let mut sojourns = trace.sojourns();
    if !sojourns.is_empty() {
        sojourns.sort_by(f64::total_cmp);
        for q in [0.5, 0.9, 0.99] {
            println!(
                "sojourn p{:<6} {:.4} s",
                q * 100.0,
                crate::stats::quantile_of_sorted(&sojourns, q)
            );
        }
    }
    Ok(0)
}

/// `trace convert`: transcode between the NDJSON and binary formats.
fn trace_convert(args: &Args) -> Result<i32> {
    let Some(input) = args.get("in") else {
        bail!("trace convert needs --in FILE");
    };
    let Some(out) = args.get("out") else {
        bail!("trace convert needs --out FILE (.bin/.tbin -> binary, else ndjson)");
    };
    let format = trace_format_flag(args)?;
    let t0 = std::time::Instant::now();
    let trace = crate::trace::Trace::read_file(input).map_err(e)?;
    trace.write_file(out, format).map_err(e)?;
    let io_secs = t0.elapsed().as_secs_f64();
    println!(
        "converted {input} -> {out} ({} jobs, {} tasks)",
        trace.jobs.len(),
        trace.tasks.len()
    );
    if args.get("metrics").is_some() {
        // Codec-only workload: the whole wall clock is I/O.
        let mut m = Metrics::enabled();
        m.phase_add_secs(Phase::Io, io_secs);
        write_metrics_report(args, "trace-convert", &m, trace.jobs.len() as u64, io_secs)?;
    }
    Ok(0)
}

/// `tiny-tasks selfcheck` — artifact vs native cross-validation.
pub fn cmd_selfcheck(_args: &Args) -> Result<i32> {
    let artifact = match BoundsEngine::artifact() {
        Ok(eng) => eng,
        Err(err) => {
            println!("artifacts unavailable ({err}); run `make artifacts`.");
            return Ok(1);
        }
    };
    let native = BoundsEngine::native();
    let queries: Vec<BoundQuery> = [(400usize, 50usize), (1000, 50), (64, 16), (1, 1)]
        .iter()
        .map(|&(k, l)| BoundQuery {
            k,
            l,
            lambda: 0.5,
            mu: k as f64 / l as f64,
            epsilon: 0.01,
            overhead: None,
        })
        .collect();
    let a = artifact.bounds(&queries)?;
    let n = native.bounds(&queries)?;
    let mut worst: f64 = 0.0;
    for (x, y) in a.iter().zip(&n) {
        for (va, vn) in [
            (x.split_merge, y.split_merge),
            (x.fork_join, y.fork_join),
            (x.ideal, y.ideal),
        ] {
            match (va, vn) {
                (Some(va), Some(vn)) => {
                    worst = worst.max((va - vn).abs() / vn.abs().max(1e-12))
                }
                (None, None) => {}
                _ => bail!("feasibility disagreement between engines"),
            }
        }
    }
    println!("artifact vs native: max rel deviation {worst:.2e} over {} queries", queries.len());
    if worst < 0.01 {
        println!("selfcheck OK");
        Ok(0)
    } else {
        println!("selfcheck FAILED (tolerance 1e-2)");
        Ok(1)
    }
}
