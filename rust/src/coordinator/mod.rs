//! Coordinator — Layer 3's top: experiment harness (parallel sweeps),
//! overhead calibration (Sec. 2.6 methodology), the per-figure
//! regeneration pipelines (DESIGN.md §4), the granularity advisor, and
//! CLI dispatch.

pub mod advisor;
pub mod calibrate;
pub mod commands;
pub mod figures;
pub mod report;
pub mod sweep;

use crate::cli::Args;
use anyhow::Result;

/// Dispatch a parsed command line; returns the process exit code.
pub fn dispatch(args: &Args) -> Result<i32> {
    match args.command.as_str() {
        "" | "help" => {
            println!("{}", crate::cli::USAGE);
            Ok(0)
        }
        "simulate" => commands::cmd_simulate(args),
        "profile" => commands::cmd_profile(args),
        "emulate" => commands::cmd_emulate(args),
        "bounds" => commands::cmd_bounds(args),
        "stability" => commands::cmd_stability(args),
        "figure" => commands::cmd_figure(args),
        "report" => {
            let dir = std::path::PathBuf::from(args.get_or("out", "reports"));
            let path = report::write(&dir)?;
            println!("wrote {}", path.display());
            Ok(0)
        }
        "bench" => commands::cmd_bench(args),
        "trace" => commands::cmd_trace(args),
        "calibrate" => commands::cmd_calibrate(args),
        "advisor" => commands::cmd_advisor(args),
        "approx" => commands::cmd_approx(args),
        "selfcheck" => commands::cmd_selfcheck(args),
        other => {
            eprintln!("unknown command {other:?}\n\n{}", crate::cli::USAGE);
            Ok(2)
        }
    }
}
