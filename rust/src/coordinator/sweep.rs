//! Parallel simulation sweeps: run many simulator configurations across
//! the thread pool, with per-point seeding derived from a master seed.

use crate::config::{
    ArrivalConfig, FaultsConfig, ModelKind, OverheadConfig, PolicyConfig, RedundancyConfig,
    ServiceConfig, SimulationConfig, WorkersConfig,
};
use crate::rng::spawn_seeds;
use crate::sim::{self, RunOptions, SimResult};
use crate::util::threadpool::ThreadPool;

/// One sweep point: a configuration plus the quantile(s) to extract.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Label for the output row (e.g. the k value).
    pub label: f64,
    /// The simulation to run.
    pub config: SimulationConfig,
}

/// Extracted result per point.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    /// Echoed label.
    pub label: f64,
    /// Requested sojourn quantile.
    pub sojourn_q: f64,
    /// Mean sojourn.
    pub sojourn_mean: f64,
    /// Mean total overhead per job.
    pub overhead_mean: f64,
    /// Mean cancelled-replica server time per job (redundancy cost;
    /// 0 outside redundancy scenarios).
    pub redundant_mean: f64,
    /// Mean server time lost to crashed/failed attempts per job
    /// (0 outside fault injection).
    pub lost_mean: f64,
    /// Mean task retries per job (0 outside fault injection).
    pub retry_mean: f64,
    /// Per-class mean sojourns (priority policies only; empty
    /// otherwise). Index = class.
    pub class_sojourn_mean: Vec<f64>,
    /// Jobs simulated per wall second (perf telemetry).
    pub jobs_per_sec: f64,
    /// The point's obs registry (disabled no-op unless
    /// [`SweepOptions::metrics`]); callers merge these across points in
    /// point order for a sweep-wide RUN_METRICS report.
    pub metrics: crate::obs::Metrics,
}

/// Sweep-wide options: the quantile to extract and the runner's memory
/// mode.
#[derive(Clone, Copy, Debug)]
pub struct SweepOptions {
    /// Sojourn quantile extracted per point.
    pub q: f64,
    /// O(1)-memory mode: each point estimates its quantile with the P²
    /// bank instead of storing every sojourn sample — million-job sweep
    /// points stop costing O(jobs) memory each.
    pub streaming: bool,
    /// Collect per-point obs metrics (counters + phase timers). Metrics
    /// consume no RNG, so sweep outputs are bitwise identical either way.
    pub metrics: bool,
}

/// One [`SweepPoint`] per k at constant mean job workload: Poisson
/// arrivals at `lambda`, tasks sized so `k · E[exec] = mean_workload`
/// (`exp:{k/mean_workload}`), warmup = jobs/10, seeds left to
/// [`run_sweep`]'s per-point reseeding. Shared by the approx
/// cross-validation surfaces (`tiny-tasks approx`, `figure
/// hetero-approx`) so the analytic and simulated curves stay comparable
/// point by point.
///
/// `mean_workload` and `lambda` arrive straight from CLI flags, so bad
/// values are `Err`s (surfaced as usage errors), not panics.
#[allow(clippy::too_many_arguments)]
pub fn constant_workload_points(
    model: ModelKind,
    servers: usize,
    lambda: f64,
    mean_workload: f64,
    jobs: usize,
    overhead: Option<OverheadConfig>,
    workers: Option<WorkersConfig>,
    redundancy: Option<RedundancyConfig>,
    faults: Option<FaultsConfig>,
    policy: Option<PolicyConfig>,
    ks: &[usize],
) -> Result<Vec<SweepPoint>, String> {
    if !(mean_workload > 0.0 && mean_workload.is_finite()) {
        return Err(format!(
            "mean workload must be positive and finite, got {mean_workload}"
        ));
    }
    if !(lambda > 0.0 && lambda.is_finite()) {
        return Err(format!("arrival rate must be positive and finite, got {lambda}"));
    }
    Ok(ks.iter()
        .map(|&k| SweepPoint {
            label: k as f64,
            config: SimulationConfig {
                model,
                servers,
                tasks_per_job: k,
                arrival: ArrivalConfig { interarrival: format!("exp:{lambda}") },
                service: ServiceConfig {
                    execution: format!("exp:{}", k as f64 / mean_workload),
                },
                jobs,
                warmup: jobs / 10,
                seed: 0, // reseeded per point by run_sweep
                overhead,
                workers: workers.clone(),
                redundancy,
                faults,
                policy: policy.clone(),
            },
        })
        .collect())
}

/// Run every point at quantile `q`, in parallel, reseeding each point
/// from `master_seed` so sweeps are reproducible regardless of pool size.
pub fn run_sweep(
    pool: &ThreadPool,
    points: Vec<SweepPoint>,
    q: f64,
    master_seed: u64,
) -> Result<Vec<SweepOutcome>, String> {
    run_sweep_with(
        pool,
        points,
        SweepOptions { q, streaming: false, metrics: false },
        master_seed,
    )
}

/// [`run_sweep`] with explicit [`SweepOptions`].
pub fn run_sweep_with(
    pool: &ThreadPool,
    points: Vec<SweepPoint>,
    opts: SweepOptions,
    master_seed: u64,
) -> Result<Vec<SweepOutcome>, String> {
    let seeds = spawn_seeds(master_seed, points.len());
    let tagged: Vec<(SweepPoint, u64)> = points.into_iter().zip(seeds).collect();
    let run_opts = RunOptions {
        streaming: opts.streaming,
        streaming_q: Some(opts.q),
        metrics: opts.metrics,
        ..Default::default()
    };
    let q = opts.q;
    let outcomes = pool.map(tagged, move |(point, seed)| {
        // The point is owned here — reseed it in place, no config clone.
        let SweepPoint { label, config: mut cfg } = point;
        cfg.seed = seed;
        let mut res: SimResult = sim::run(&cfg, run_opts)?;
        Ok::<SweepOutcome, String>(SweepOutcome {
            label,
            sojourn_q: res.sojourn_quantile(q),
            sojourn_mean: res.sojourn_summary.mean(),
            overhead_mean: res.overhead_summary.mean(),
            redundant_mean: res.redundant_summary.mean(),
            lost_mean: res.lost_summary.mean(),
            retry_mean: res.retry_summary.mean(),
            class_sojourn_mean: res.class_sojourn.iter().map(|s| s.mean()).collect(),
            jobs_per_sec: res.jobs_per_second(),
            metrics: res.metrics,
        })
    })?;
    outcomes.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;

    fn point(k: usize, jobs: usize) -> SweepPoint {
        SweepPoint {
            label: k as f64,
            config: SimulationConfig {
                model: ModelKind::ForkJoinSingleQueue,
                servers: 10,
                tasks_per_job: k,
                arrival: crate::config::ArrivalConfig { interarrival: "exp:0.5".into() },
                service: crate::config::ServiceConfig {
                    execution: format!("exp:{}", k as f64 / 10.0),
                },
                jobs,
                warmup: 100,
                seed: 0,
                overhead: None,
                workers: None,
                redundancy: None,
                faults: None,
                policy: None,
            },
        }
    }

    #[test]
    fn sweep_is_reproducible_across_pool_sizes() {
        let points: Vec<SweepPoint> = [10, 20, 40].iter().map(|&k| point(k, 2000)).collect();
        let pool1 = ThreadPool::new(1);
        let pool4 = ThreadPool::new(4);
        let a = run_sweep(&pool1, points.clone(), 0.99, 7).unwrap();
        let b = run_sweep(&pool4, points, 0.99, 7).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.sojourn_q, y.sojourn_q);
        }
    }

    /// Scenario configs flow through the sweep machinery: pool-size
    /// independence holds for heterogeneous + redundant points too, and
    /// the redundancy cost column is populated.
    #[test]
    fn scenario_sweep_reproducible_and_costed() {
        let mk = |k: usize| {
            let mut p = point(k, 1500);
            p.config.workers = Some(crate::config::WorkersConfig::Speeds(vec![
                1.5, 1.5, 1.5, 1.5, 1.5, 0.5, 0.5, 0.5, 0.5, 0.5,
            ]));
            p.config.redundancy =
                Some(crate::config::RedundancyConfig::new(2));
            p
        };
        let points: Vec<SweepPoint> = [10, 20].iter().map(|&k| mk(k)).collect();
        let pool1 = ThreadPool::new(1);
        let pool4 = ThreadPool::new(4);
        let a = run_sweep(&pool1, points.clone(), 0.9, 21).unwrap();
        let b = run_sweep(&pool4, points, 0.9, 21).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sojourn_q, y.sojourn_q);
            assert_eq!(x.redundant_mean, y.redundant_mean);
            assert!(x.redundant_mean > 0.0, "redundancy cost missing");
        }
    }

    /// Streaming sweeps reproduce the exact sweep's means bitwise (same
    /// sample stream) and its quantiles within P² tolerance, while
    /// storing no samples.
    #[test]
    fn streaming_sweep_matches_exact() {
        let points: Vec<SweepPoint> = [10, 20].iter().map(|&k| point(k, 12_000)).collect();
        let pool = ThreadPool::new(2);
        let exact = run_sweep(&pool, points.clone(), 0.99, 7).unwrap();
        let stream = run_sweep_with(
            &pool,
            points,
            SweepOptions { q: 0.99, streaming: true, metrics: false },
            7,
        )
        .unwrap();
        for (a, b) in exact.iter().zip(&stream) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.sojourn_mean, b.sojourn_mean, "mean must be bitwise equal");
            assert_eq!(a.overhead_mean, b.overhead_mean);
            assert!(
                (a.sojourn_q - b.sojourn_q).abs() / a.sojourn_q < 0.2,
                "k={}: exact {} vs P2 {}",
                a.label,
                a.sojourn_q,
                b.sojourn_q
            );
        }
    }

    /// Metrics collection consumes no RNG, so a metrics-on sweep matches
    /// the metrics-off sweep bitwise while the registries fill up.
    #[test]
    fn metrics_sweep_is_bitwise_identical() {
        let points: Vec<SweepPoint> = [10, 20].iter().map(|&k| point(k, 2000)).collect();
        let pool = ThreadPool::new(2);
        let off = run_sweep(&pool, points.clone(), 0.99, 7).unwrap();
        let on = run_sweep_with(
            &pool,
            points,
            SweepOptions { q: 0.99, streaming: false, metrics: true },
            7,
        )
        .unwrap();
        for (a, b) in off.iter().zip(&on) {
            assert_eq!(a.sojourn_q, b.sojourn_q);
            assert_eq!(a.sojourn_mean, b.sojourn_mean);
            assert!(!a.metrics.is_enabled());
            assert!(b.metrics.is_enabled());
            // Warmup jobs run through the model too: 2000 measured + 100.
            assert_eq!(b.metrics.counter(crate::obs::Counter::JobsCompleted), 2100);
        }
    }

    /// CLI-reachable bad inputs are errors, not panics (a user typing
    /// `--workload 0` used to assert).
    #[test]
    fn constant_workload_points_rejects_bad_inputs() {
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let r = constant_workload_points(
                ModelKind::ForkJoinSingleQueue,
                10,
                0.5,
                bad,
                1000,
                None,
                None,
                None,
                None,
                None,
                &[10, 20],
            );
            assert!(r.is_err(), "workload {bad} must be rejected");
        }
        let r = constant_workload_points(
            ModelKind::ForkJoinSingleQueue,
            10,
            0.0,
            10.0,
            1000,
            None,
            None,
            None,
            None,
            None,
            &[10],
        );
        assert!(r.is_err(), "lambda 0 must be rejected");
        let ok = constant_workload_points(
            ModelKind::ForkJoinSingleQueue,
            10,
            0.5,
            10.0,
            1000,
            None,
            None,
            None,
            None,
            None,
            &[10, 20],
        )
        .unwrap();
        assert_eq!(ok.len(), 2);
    }

    /// The paper's core effect, end to end through the sweep machinery:
    /// the FJ 0.99 sojourn quantile decreases with tinyfication.
    #[test]
    fn tinyfication_benefit_visible_in_simulation() {
        let pool = ThreadPool::with_default_size();
        let points: Vec<SweepPoint> =
            [10, 40, 160].iter().map(|&k| point(k, 12_000)).collect();
        let out = run_sweep(&pool, points, 0.99, 3).unwrap();
        assert!(
            out[2].sojourn_q < out[1].sojourn_q && out[1].sojourn_q < out[0].sojourn_q,
            "{:?}",
            out.iter().map(|o| o.sojourn_q).collect::<Vec<_>>()
        );
    }
}
