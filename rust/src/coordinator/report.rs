//! Report generator: collate the figure CSVs in `reports/` into a single
//! Markdown summary with headline statistics — the artifact a user reads
//! after `tiny-tasks figure all`.


use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// Parsed CSV: header + numeric rows (NaN for blanks).
pub struct Table {
    /// Column names.
    pub header: Vec<String>,
    /// Row-major numeric data.
    pub rows: Vec<Vec<f64>>,
}

/// Read a figure CSV back in.
pub fn read_table(path: &Path) -> Result<Table> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut lines = text.lines();
    let header: Vec<String> = lines
        .next()
        .context("empty csv")?
        .split(',')
        .map(str::to_string)
        .collect();
    let rows = lines
        .map(|l| {
            l.split(',')
                .map(|c| c.parse::<f64>().unwrap_or(f64::NAN))
                .collect()
        })
        .collect();
    Ok(Table { header, rows })
}

impl Table {
    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// All finite values of a column.
    pub fn finite(&self, col: usize) -> Vec<f64> {
        self.rows
            .iter()
            .map(|r| r[col])
            .filter(|v| v.is_finite())
            .collect()
    }

    /// Render as a Markdown table (up to `max_rows` rows).
    pub fn to_markdown(&self, max_rows: usize) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.header.iter().map(|_| "---:").collect::<Vec<_>>().join("|")
        );
        for row in self.rows.iter().take(max_rows) {
            let cells: Vec<String> = row
                .iter()
                .map(|v| {
                    if v.is_nan() {
                        "—".to_string()
                    } else if v.abs() >= 1000.0 || (*v != 0.0 && v.abs() < 0.01) {
                        format!("{v:.3e}")
                    } else {
                        format!("{v:.3}")
                    }
                })
                .collect();
            let _ = writeln!(out, "| {} |", cells.join(" | "));
        }
        if self.rows.len() > max_rows {
            let _ = writeln!(out, "| … ({} more rows) |", self.rows.len() - max_rows);
        }
        out
    }
}

/// Figures we know how to summarize: (id, csv files, one-line description).
const SECTIONS: &[(&str, &[&str], &str)] = &[
    ("Figs. 1–2", &["fig1_gantt.csv", "fig2_gantt.csv"], "executor activity traces (Gantt rows: job,task,server,start,end)"),
    ("Fig. 3", &["fig3_scaling.csv"], "sojourn quantile scaling vs servers, k = l"),
    ("Fig. 8(a)", &["fig8a_split_merge.csv"], "split-merge quantiles vs k: emulator / sim ±overhead / bound / approximation"),
    ("Fig. 8(b)", &["fig8b_fork_join.csv"], "fork-join quantiles vs k"),
    ("Fig. 9", &["fig9a_overhead_fraction.csv", "fig9b_job_overhead.csv"], "overhead fraction and per-job totals vs k"),
    ("Fig. 10", &["fig10_ppplot.csv"], "PP plots of sim vs emulator sojourn CDFs"),
    ("Fig. 11", &["fig11_stability.csv"], "stability regions vs k"),
    ("Fig. 12(a)", &["fig12a_stability.csv"], "direct refinement: stability vs l"),
    ("Fig. 12(b)", &["fig12b_bounds.csv"], "direct refinement: bounds vs l at three utilizations"),
    ("Fig. 13", &["fig13_bounds.csv"], "bounds vs k at ε = 1e-6"),
    ("Heterogeneous panel", &["hetero_panel.csv"], "sojourn quantiles vs k under worker-speed skew, with and without r = 2 first-finish-wins redundancy"),
];

/// Build `report.md` from whatever CSVs exist in `dir`.
pub fn generate(dir: &Path) -> Result<String> {
    let mut md = String::new();
    let _ = writeln!(md, "# tiny-tasks figure report\n");
    let _ = writeln!(
        md,
        "Generated from `{}`. Regenerate with `tiny-tasks figure all`.\n",
        dir.display()
    );
    let mut found = 0;
    for (name, files, desc) in SECTIONS {
        let present: Vec<&str> =
            files.iter().copied().filter(|f| dir.join(f).exists()).collect();
        if present.is_empty() {
            continue;
        }
        found += 1;
        let _ = writeln!(md, "## {name}\n\n{desc}\n");
        for f in present {
            let table = read_table(&dir.join(f))?;
            if *name == "Figs. 1–2" {
                // Gantt CSVs are huge; summarize instead of inlining.
                let _ = writeln!(md, "`{f}`: {} task executions.\n", table.rows.len());
                continue;
            }
            let _ = writeln!(md, "`{f}` ({} rows):\n", table.rows.len());
            let _ = writeln!(md, "{}", table.to_markdown(16));
        }
    }
    if found == 0 {
        let _ = writeln!(md, "_No figure CSVs found — run `tiny-tasks figure all` first._");
    }
    Ok(md)
}

/// Write the report and return its path.
pub fn write(dir: &Path) -> Result<std::path::PathBuf> {
    let md = generate(dir)?;
    let path = dir.join("report.md");
    std::fs::create_dir_all(dir)?;
    std::fs::write(&path, md)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csv::Csv;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tt-report-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_table() {
        let dir = tmp();
        let mut csv = Csv::new(vec!["k", "value"]);
        csv.push(&[100.0, 1.5]);
        csv.push(&[200.0, f64::NAN]);
        let p = dir.join("fig13_bounds.csv");
        csv.write_file(&p).unwrap();
        let t = read_table(&p).unwrap();
        assert_eq!(t.header, vec!["k", "value"]);
        assert_eq!(t.rows.len(), 2);
        assert!(t.rows[1][1].is_nan());
        assert_eq!(t.col("value"), Some(1));
        assert_eq!(t.finite(1), vec![1.5]);
        let md = t.to_markdown(10);
        assert!(md.contains("| k | value |"));
        assert!(md.contains('—'));
    }

    #[test]
    fn generate_handles_empty_dir() {
        let dir = tmp().join("empty");
        std::fs::create_dir_all(&dir).unwrap();
        let md = generate(&dir).unwrap();
        assert!(md.contains("No figure CSVs"));
    }

    #[test]
    fn generate_includes_present_sections() {
        let dir = tmp().join("partial");
        std::fs::create_dir_all(&dir).unwrap();
        let mut csv = Csv::new(vec!["k", "fork_join", "split_merge", "ideal"]);
        csv.push(&[50.0, 22.5, f64::NAN, 12.3]);
        csv.write_file(dir.join("fig13_bounds.csv")).unwrap();
        let md = generate(&dir).unwrap();
        assert!(md.contains("Fig. 13"));
        assert!(!md.contains("Fig. 11"));
        let path = write(&dir).unwrap();
        assert!(path.exists());
    }
}
