//! Theorem 1: for an S(m,n) server with iid increments, any θ > 0 with
//! `ρ_S(θ) ≤ ρ_A(−θ)` yields
//! `P[W > τ] ≤ e^{−θτ}` and `P[T > τ] ≤ e^{θ ρ_S(θ)} e^{−θτ}`.
//!
//! Setting the bound equal to the violation probability ε and solving for
//! τ gives quantile bounds
//! `τ_W(θ) = ln(1/ε)/θ` and `τ_T(θ) = ρ_S(θ) + ln(1/ε)/θ`,
//! which we minimize over the feasible θ range (coarse log-grid scan
//! followed by golden-section refinement).

use crate::util::math::golden_section_min;

/// Number of grid points in the coarse θ scan.
const GRID: usize = 256;

/// Generic θ-optimizer: minimizes `tau(θ)` over θ ∈ (0, theta_sup)
/// subject to `feasible(θ)`; returns `(θ*, τ*)` or `None` if no feasible
/// θ exists (the system is unstable for these parameters).
pub fn optimize_theta<T, F>(theta_sup: f64, mut tau: T, mut feasible: F) -> Option<(f64, f64)>
where
    T: FnMut(f64) -> f64,
    F: FnMut(f64) -> bool,
{
    assert!(theta_sup > 0.0);
    // Log-spaced grid in (0, theta_sup): the interesting θ often sits
    // orders of magnitude below the domain edge at high utilization.
    let lo = theta_sup * 1e-6;
    let ratio = (theta_sup * 0.999_999 / lo).powf(1.0 / (GRID - 1) as f64);
    let mut best: Option<(f64, f64)> = None;
    let mut theta = lo;
    let mut grid = Vec::with_capacity(GRID);
    for _ in 0..GRID {
        grid.push(theta);
        theta *= ratio;
    }
    let mut feasible_any = false;
    let mut best_idx = 0usize;
    for (i, &th) in grid.iter().enumerate() {
        if !feasible(th) {
            continue;
        }
        feasible_any = true;
        let t = tau(th);
        if t.is_finite() && best.map(|(_, bt)| t < bt).unwrap_or(true) {
            best = Some((th, t));
            best_idx = i;
        }
    }
    if !feasible_any {
        return None;
    }
    let (btheta, btau) = best?;
    // Golden-section refinement between the grid neighbours of the best
    // point, guarded by feasibility (infeasible θ gets +inf).
    let a = if best_idx > 0 { grid[best_idx - 1] } else { btheta * 0.5 };
    let b = if best_idx + 1 < grid.len() { grid[best_idx + 1] } else { btheta };
    let (rtheta, rtau) = golden_section_min(
        |th| if feasible(th) { tau(th) } else { f64::INFINITY },
        a,
        b,
        (b - a) * 1e-9,
        200,
    );
    if rtau < btau {
        Some((rtheta, rtau))
    } else {
        Some((btheta, btau))
    }
}

/// Sojourn-time ε-quantile bound for a max-plus server with envelope rate
/// `rho_s` and arrival rate `rho_a` (both as closures of θ):
/// minimize `τ(θ) = ρ_S(θ) + ln(1/ε)/θ` s.t. `ρ_S(θ) ≤ ρ_A(−θ)`.
pub fn sojourn_quantile<RS, RA>(
    theta_sup: f64,
    epsilon: f64,
    rho_s: RS,
    rho_a: RA,
) -> Option<f64>
where
    RS: Fn(f64) -> f64,
    RA: Fn(f64) -> f64,
{
    assert!(epsilon > 0.0 && epsilon < 1.0);
    let ln_inv_eps = -epsilon.ln();
    optimize_theta(
        theta_sup,
        |th| rho_s(th) + ln_inv_eps / th,
        |th| rho_s(th) <= rho_a(th),
    )
    .map(|(_, tau)| tau)
}

/// Waiting-time ε-quantile bound: minimize `ln(1/ε)/θ` over feasible θ —
/// i.e. `ln(1/ε) / θ_max_feasible`.
pub fn waiting_quantile<RS, RA>(
    theta_sup: f64,
    epsilon: f64,
    rho_s: RS,
    rho_a: RA,
) -> Option<f64>
where
    RS: Fn(f64) -> f64,
    RA: Fn(f64) -> f64,
{
    assert!(epsilon > 0.0 && epsilon < 1.0);
    let ln_inv_eps = -epsilon.ln();
    optimize_theta(
        theta_sup,
        |th| ln_inv_eps / th,
        |th| rho_s(th) <= rho_a(th),
    )
    .map(|(_, tau)| tau)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::envelope::{rho_arrival_exp, rho_service_exp};

    /// M/M/1: the MGF bound is exact in exponent — P[T > τ] ≤ e^{−(μ−λ)τ}
    /// with prefactor; optimal θ* = μ − λ... (θ-opt of ρ_S + ln(1/ε)/θ).
    /// Check against direct numeric minimization.
    #[test]
    fn mm1_bound_matches_direct_scan() {
        let (lambda, mu, eps) = (0.5, 1.0, 0.01);
        let got = sojourn_quantile(
            mu,
            eps,
            |th| rho_service_exp(mu, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        // Direct dense scan.
        let mut best = f64::INFINITY;
        for i in 1..200_000 {
            let th = i as f64 * (mu / 200_000.0);
            if rho_service_exp(mu, th) <= rho_arrival_exp(lambda, th) {
                let t = rho_service_exp(mu, th) - eps.ln() / th;
                best = best.min(t);
            }
        }
        assert!((got - best).abs() / best < 1e-4, "{got} vs {best}");
        // Known order of magnitude: exact M/M/1 0.99 quantile is
        // ln(100)/(μ−λ) ≈ 9.21; the Chernoff bound must dominate it.
        assert!(got >= 9.21 && got < 15.0, "{got}");
    }

    /// Unstable input (λ > μ) has no feasible θ.
    #[test]
    fn unstable_returns_none() {
        let got = sojourn_quantile(
            1.0,
            0.01,
            |th| rho_service_exp(1.0, th),
            |th| rho_arrival_exp(1.5, th),
        );
        assert!(got.is_none());
    }

    /// Waiting bound ≤ sojourn bound, both positive.
    #[test]
    fn waiting_below_sojourn() {
        let (lambda, mu, eps) = (0.3, 1.0, 1e-6);
        let s = sojourn_quantile(
            mu,
            eps,
            |th| rho_service_exp(mu, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        let w = waiting_quantile(
            mu,
            eps,
            |th| rho_service_exp(mu, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        assert!(w > 0.0 && s > w);
    }

    /// Bound is monotone in ε: smaller violation probability → larger τ.
    #[test]
    fn monotone_in_epsilon() {
        let (lambda, mu) = (0.5, 1.0);
        let f = |eps: f64| {
            sojourn_quantile(
                mu,
                eps,
                |th| rho_service_exp(mu, th),
                |th| rho_arrival_exp(lambda, th),
            )
            .unwrap()
        };
        assert!(f(1e-6) > f(1e-3));
        assert!(f(1e-3) > f(1e-1));
    }
}
