//! Closed-form stability regions (Sec. 4.2).

use crate::util::math::harmonic;

/// Tiny-tasks split-merge maximum stable utilization (Eq. 20):
/// `ρ* = 1 / (1 + (1/κ) Σ_{i=2}^{l} 1/i)` with κ = k/l.
pub fn sm_tiny_tasks(l: usize, k: usize) -> f64 {
    assert!(k >= l && l >= 1);
    let kappa = k as f64 / l as f64;
    1.0 / (1.0 + (harmonic(l as u64) - 1.0) / kappa)
}

/// Conventional (k = l) split-merge maximum stable utilization:
/// `ρ* = 1 / H_l` ([16, Eq. 21], recovered by Eq. 20 at κ = 1 only in the
/// exponential case — for Erlang big tasks use
/// [`crate::analysis::erlang::max_utilization_big_tasks`]).
pub fn sm_big_tasks_exponential(l: usize) -> f64 {
    1.0 / harmonic(l as u64)
}

/// Fork-join (any queueing discipline that is work-conserving) is stable
/// up to utilization 1 (Sec. 3.2.2).
pub fn fork_join() -> f64 {
    1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq20_special_cases() {
        // κ = 1 gives 1/H_l.
        for l in [2usize, 10, 50] {
            assert!((sm_tiny_tasks(l, l) - sm_big_tasks_exponential(l)).abs() < 1e-12);
        }
        // l = 1: always 1 (single server).
        assert_eq!(sm_tiny_tasks(1, 10), 1.0);
    }

    #[test]
    fn kappa_to_infinity_approaches_one() {
        let l = 50;
        let r10 = sm_tiny_tasks(l, 10 * l);
        let r100 = sm_tiny_tasks(l, 100 * l);
        let r1000 = sm_tiny_tasks(l, 1000 * l);
        assert!(r10 < r100 && r100 < r1000);
        assert!(r1000 > 0.995, "{r1000}");
    }

    /// The Fig.-12(a) effect: at κ = 20 the tiny-tasks region stays high
    /// while the big-tasks (κ = 1 exponential) region decays like 1/ln l.
    #[test]
    fn decay_rates() {
        let tiny_256 = sm_tiny_tasks(256, 20 * 256);
        let big_256 = sm_big_tasks_exponential(256);
        assert!(tiny_256 > 0.78, "{tiny_256}");
        assert!(big_256 < 0.17, "{big_256}");
    }

    /// The Fig. 8(a) setting: l = 50, λ = 0.5, E[L] = 50 s. κ = 1 is
    /// unstable (ρ = 0.5 > 1/H_50 ≈ 0.22); κ = 4 (k = 200) is stable.
    #[test]
    fn fig8a_stability_transitions() {
        let l = 50;
        let rho = 0.5; // λ·E[L]/l = 0.5·50/50
        assert!(rho > sm_tiny_tasks(l, l), "big tasks unstable");
        assert!(rho < sm_tiny_tasks(l, 200), "k=200 stable");
    }
}
