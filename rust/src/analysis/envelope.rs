//! (σ,ρ) envelope rates (Def. 2) for the iid processes used throughout
//! the paper. In the iid case σ = 0 and the envelopes are fully described
//! by their rates ρ(θ).

/// Arrival envelope rate for iid `Exp(lambda)` inter-arrival times
/// (Eq. 5): `ρ_A(−θ) = −(1/θ) ln(λ / (λ + θ))`, θ > 0.
#[inline]
pub fn rho_arrival_exp(lambda: f64, theta: f64) -> f64 {
    debug_assert!(lambda > 0.0 && theta > 0.0);
    -(lambda / (lambda + theta)).ln() / theta
}

/// Service envelope rate for iid `Exp(mu)` service times (Eq. 6):
/// `ρ_S(θ) = (1/θ) ln(μ / (μ − θ))`, valid for θ ∈ (0, μ).
/// Returns `f64::INFINITY` outside the domain.
#[inline]
pub fn rho_service_exp(mu: f64, theta: f64) -> f64 {
    debug_assert!(mu > 0.0 && theta > 0.0);
    if theta >= mu {
        return f64::INFINITY;
    }
    (mu / (mu - theta)).ln() / theta
}

/// Ideal-partition envelope rate (Eq. 10): jobs of k iid `Exp(mu)` tasks
/// split into l equal shares give `Erlang(k, l·mu)` service times with
/// `ρ_Q(θ) = (k/θ) ln(lμ / (lμ − θ))`, θ ∈ (0, lμ).
#[inline]
pub fn rho_ideal(k: usize, l: usize, mu: f64, theta: f64) -> f64 {
    debug_assert!(theta > 0.0);
    let lmu = l as f64 * mu;
    if theta >= lmu {
        return f64::INFINITY;
    }
    k as f64 * (lmu / (lmu - theta)).ln() / theta
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ρ_A(−θ) decreases from the mean inter-arrival time toward zero;
    /// ρ_S(θ) increases from the mean service time (Sec. 3.1 remark).
    #[test]
    fn limits_and_monotonicity() {
        let lambda = 0.5;
        let mu = 1.0;
        // θ → 0 limits approach the means.
        assert!((rho_arrival_exp(lambda, 1e-9) - 2.0).abs() < 1e-6);
        assert!((rho_service_exp(mu, 1e-9) - 1.0).abs() < 1e-6);
        let mut prev_a = f64::INFINITY;
        let mut prev_s = 0.0;
        for i in 1..100 {
            let theta = i as f64 * 0.009;
            let a = rho_arrival_exp(lambda, theta);
            let s = rho_service_exp(mu, theta);
            assert!(a < prev_a, "rho_A decreasing");
            assert!(s > prev_s, "rho_S increasing");
            prev_a = a;
            prev_s = s;
        }
    }

    #[test]
    fn service_domain_edge() {
        assert!(rho_service_exp(1.0, 1.0).is_infinite());
        assert!(rho_service_exp(1.0, 0.999) < f64::INFINITY);
    }

    /// Ideal with k = l = 1 equals the plain exponential envelope.
    #[test]
    fn ideal_reduces_to_exponential() {
        for theta in [0.1, 0.5, 0.9] {
            let a = rho_ideal(1, 1, 1.0, theta);
            let b = rho_service_exp(1.0, theta);
            assert!((a - b).abs() < 1e-12);
        }
    }

    /// MGF check: ρ_S(θ) = ln E[e^{θX}]/θ for X ~ Exp(mu), via Monte Carlo.
    #[test]
    fn matches_monte_carlo_mgf() {
        use crate::rng::{Pcg64, Rng};
        let mu = 2.0;
        let theta = 0.8;
        let mut rng = Pcg64::seed_from_u64(21);
        let n = 2_000_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let x = -rng.next_f64_open().ln() / mu;
            acc += (theta * x).exp();
        }
        let mc = (acc / n as f64).ln() / theta;
        let exact = rho_service_exp(mu, theta);
        assert!((mc - exact).abs() < 0.01, "{mc} vs {exact}");
    }
}
