//! Lemma 1: the tiny-tasks split-merge model is a max-plus server whose
//! iid-exponential service envelope decomposes as
//! `ρ_S(θ) = ρ_X(θ) + (k−l) ρ_Z(θ)` where X is the merge residual (max of
//! l residual exponentials) and Z the inter-start gap (min of l
//! exponentials, i.e. `Exp(l·mu)`); plus the Sec.-6 overhead-augmented
//! variants ρ_X°, ρ_Z° (Eqs. 26, 28, 31).

use crate::config::OverheadConfig;
use crate::util::math::harmonic;

/// `ρ_X(θ) = (1/θ) Σ_{i=1}^{l} ln(iμ / (iμ − θ))`, θ ∈ (0, μ) — the MGF
/// rate of `X = max_l Exp(mu)` via the order-statistics identity (Eq. 17).
pub fn rho_x(l: usize, mu: f64, theta: f64) -> f64 {
    debug_assert!(theta > 0.0);
    if theta >= mu {
        return f64::INFINITY;
    }
    let mut sum = 0.0;
    for i in 1..=l {
        let imu = i as f64 * mu;
        sum += (imu / (imu - theta)).ln();
    }
    sum / theta
}

/// `ρ_Z(θ) = (1/θ) ln(lμ / (lμ − θ))`, θ ∈ (0, lμ) — the MGF rate of
/// `Z = min_l Exp(mu) ~ Exp(lμ)`.
pub fn rho_z(l: usize, mu: f64, theta: f64) -> f64 {
    debug_assert!(theta > 0.0);
    let lmu = l as f64 * mu;
    if theta >= lmu {
        return f64::INFINITY;
    }
    (lmu / (lmu - theta)).ln() / theta
}

/// Lemma 1 service envelope rate `ρ_S(θ) = ρ_X(θ) + (k−l) ρ_Z(θ)`.
pub fn rho_s(l: usize, k: usize, mu: f64, theta: f64) -> f64 {
    debug_assert!(k >= l);
    rho_x(l, mu, theta) + (k - l) as f64 * rho_z(l, mu, theta)
}

/// Lemma 1 expected job service time
/// `E[Δ] = (1/μ)(k/l + Σ_{i=2}^{l} 1/i)`.
pub fn mean_service(l: usize, k: usize, mu: f64) -> f64 {
    debug_assert!(k >= l && l >= 1);
    (k as f64 / l as f64 + harmonic(l as u64) - 1.0) / mu
}

/// Overhead-augmented `ρ_X°(θ)` (fork-join form, Eq. 26): the mean task
/// overhead (Eq. 24) is added as a constant to X.
pub fn rho_x_overhead(l: usize, mu: f64, theta: f64, oh: &OverheadConfig) -> f64 {
    oh.mean_task_overhead() + rho_x(l, mu, theta)
}

/// Overhead-augmented `ρ_X°(θ)` for split-merge (Eq. 31): the blocking
/// pre-departure overhead `c_job^pd + k·c_task^pd` joins the constant.
pub fn rho_x_overhead_sm(
    l: usize,
    k: usize,
    mu: f64,
    theta: f64,
    oh: &OverheadConfig,
) -> f64 {
    oh.mean_task_overhead() + oh.pre_departure(k) + rho_x(l, mu, theta)
}

/// Overhead-augmented `ρ_Z°(θ)` (Eq. 28): each active task pays a `1/l`
/// share of the task overhead per scheduling epoch.
pub fn rho_z_overhead(l: usize, mu: f64, theta: f64, oh: &OverheadConfig) -> f64 {
    oh.mean_task_overhead() / l as f64 + rho_z(l, mu, theta)
}

/// Split-merge service envelope with overhead:
/// `ρ_S°(θ) = ρ_X°_sm(θ) + (k−l) ρ_Z°(θ)`.
pub fn rho_s_overhead_sm(
    l: usize,
    k: usize,
    mu: f64,
    theta: f64,
    oh: &OverheadConfig,
) -> f64 {
    rho_x_overhead_sm(l, k, mu, theta, oh) + (k - l) as f64 * rho_z_overhead(l, mu, theta, oh)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// k = l recovers the conventional split-merge envelope (Eq. 8).
    #[test]
    fn reduces_to_eq8_for_big_tasks() {
        let (l, mu, theta) = (50usize, 1.0, 0.4);
        let expect: f64 = (1..=l)
            .map(|i| {
                let imu = i as f64 * mu;
                (imu / (imu - theta)).ln()
            })
            .sum::<f64>()
            / theta;
        assert!((rho_s(l, l, mu, theta) - expect).abs() < 1e-12);
    }

    /// θ → 0 limit of ρ_S equals E[Δ] (the envelope rate starts at the
    /// mean, Sec. 3.1).
    #[test]
    fn theta_zero_limit_is_mean_service() {
        let (l, k, mu) = (10usize, 40usize, 2.0);
        let rho0 = rho_s(l, k, mu, 1e-9);
        let mean = mean_service(l, k, mu);
        assert!((rho0 - mean).abs() < 1e-5, "{rho0} vs {mean}");
    }

    /// ρ_X via Monte Carlo: E[e^{θ max_l Exp(mu)}].
    #[test]
    fn rho_x_matches_monte_carlo() {
        use crate::rng::{Pcg64, Rng};
        let (l, mu, theta) = (5usize, 1.0, 0.3);
        let mut rng = Pcg64::seed_from_u64(13);
        let n = 1_000_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let mut mx = 0.0f64;
            for _ in 0..l {
                mx = mx.max(-rng.next_f64_open().ln() / mu);
            }
            acc += (theta * mx).exp();
        }
        let mc = (acc / n as f64).ln() / theta;
        let exact = rho_x(l, mu, theta);
        assert!((mc - exact).abs() < 0.02, "{mc} vs {exact}");
    }

    /// Monotonicity in k: more tiny tasks → larger total service envelope
    /// (each extra task adds a ρ_Z term).
    #[test]
    fn monotone_in_k() {
        let (l, mu, theta) = (10usize, 1.0, 0.2);
        let mut prev = 0.0;
        for k in [10, 20, 40, 80] {
            let r = rho_s(l, k, mu, theta);
            assert!(r > prev);
            prev = r;
        }
    }

    /// Overhead variants exceed their clean counterparts and collapse to
    /// them when overhead is zero.
    #[test]
    fn overhead_variants_consistent() {
        let (l, k, mu, theta) = (10usize, 30usize, 3.0, 0.5);
        let oh = OverheadConfig::paper();
        let zero = OverheadConfig::zero();
        assert!(rho_x_overhead(l, mu, theta, &oh) > rho_x(l, mu, theta));
        assert!(rho_z_overhead(l, mu, theta, &oh) > rho_z(l, mu, theta));
        assert!(
            (rho_x_overhead(l, mu, theta, &zero) - rho_x(l, mu, theta)).abs() < 1e-15
        );
        assert!(
            (rho_s_overhead_sm(l, k, mu, theta, &zero) - rho_s(l, k, mu, theta)).abs()
                < 1e-12
        );
        // SM form includes the blocking pre-departure term.
        assert!(
            rho_x_overhead_sm(l, k, mu, theta, &oh) > rho_x_overhead(l, mu, theta, &oh)
        );
    }

    /// Mean service for l = 1: every task runs serially → E[Δ] = k/μ.
    #[test]
    fn single_server_mean() {
        assert!((mean_service(1, 7, 2.0) - 3.5).abs() < 1e-12);
    }
}
