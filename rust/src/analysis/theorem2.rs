//! Theorem 2: tiny-tasks single-queue fork-join bounds.
//!
//! For l servers, k ≥ l iid `Exp(mu)` tasks per job, and iid inter-arrival
//! times with envelope rate ρ_A(−θ), any θ ∈ (0, μ) with
//! `k·ρ_Z(θ) ≤ ρ_A(−θ)` gives
//!
//! * task waiting:  `P[W_i(n) ≥ τ] ≤ e^{θ(i−1)ρ_Z(θ)} e^{−θτ}`
//! * job sojourn:   `P[T(n) ≥ τ] ≤ e^{θ((k−1)ρ_Z(θ) + ρ_X(θ))} e^{−θτ}`
//!
//! with ρ_X, ρ_Z from Lemma 1. Solving for τ at violation ε and
//! minimizing over θ yields the quantile bounds below; the Sec.-6
//! overhead variants substitute ρ_X° and ρ_Z° and append the non-blocking
//! pre-departure overhead directly to the sojourn quantile (Eq. 29).

use super::lemma1::{rho_x, rho_x_overhead, rho_z, rho_z_overhead};
use super::theorem1::optimize_theta;
use crate::config::OverheadConfig;

/// Job sojourn ε-quantile bound (no overhead):
/// minimize `(k−1)ρ_Z(θ) + ρ_X(θ) + ln(1/ε)/θ` s.t. `kρ_Z(θ) ≤ ρ_A(−θ)`.
pub fn sojourn_quantile<RA>(
    l: usize,
    k: usize,
    mu: f64,
    epsilon: f64,
    mut rho_a: RA,
) -> Option<f64>
where
    RA: FnMut(f64) -> f64,
{
    assert!(k >= l && l >= 1);
    assert!(epsilon > 0.0 && epsilon < 1.0);
    let ln_inv_eps = -epsilon.ln();
    optimize_theta(
        mu,
        |th| (k - 1) as f64 * rho_z(l, mu, th) + rho_x(l, mu, th) + ln_inv_eps / th,
        |th| k as f64 * rho_z(l, mu, th) <= rho_a(th),
    )
    .map(|(_, tau)| tau)
}

/// Waiting ε-quantile bound for task `i` (1-based; `i = k` gives the
/// job's last task — the job-level waiting bound used in the figures):
/// minimize `(i−1)ρ_Z(θ) + ln(1/ε)/θ` s.t. `kρ_Z(θ) ≤ ρ_A(−θ)`.
pub fn waiting_quantile<RA>(
    l: usize,
    k: usize,
    task_i: usize,
    mu: f64,
    epsilon: f64,
    mut rho_a: RA,
) -> Option<f64>
where
    RA: FnMut(f64) -> f64,
{
    assert!((1..=k).contains(&task_i));
    let ln_inv_eps = -epsilon.ln();
    optimize_theta(
        mu,
        |th| (task_i - 1) as f64 * rho_z(l, mu, th) + ln_inv_eps / th,
        |th| k as f64 * rho_z(l, mu, th) <= rho_a(th),
    )
    .map(|(_, tau)| tau)
}

/// Sojourn ε-quantile **approximation with overhead** (Sec. 6.1):
/// substitute ρ_X° (Eq. 26) and ρ_Z° (Eq. 28) into Th. 2, then append the
/// non-blocking pre-departure overhead (Eq. 29):
/// `τ° = τ + c_job^pd + k·c_task^pd`.
pub fn sojourn_quantile_overhead<RA>(
    l: usize,
    k: usize,
    mu: f64,
    epsilon: f64,
    oh: &OverheadConfig,
    mut rho_a: RA,
) -> Option<f64>
where
    RA: FnMut(f64) -> f64,
{
    assert!(k >= l && l >= 1);
    let ln_inv_eps = -epsilon.ln();
    let tau = optimize_theta(
        mu,
        |th| {
            (k - 1) as f64 * rho_z_overhead(l, mu, th, oh)
                + rho_x_overhead(l, mu, th, oh)
                + ln_inv_eps / th
        },
        |th| k as f64 * rho_z_overhead(l, mu, th, oh) <= rho_a(th),
    )
    .map(|(_, tau)| tau)?;
    Some(tau + oh.pre_departure(k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::envelope::{rho_arrival_exp, rho_service_exp};
    use crate::analysis::theorem1;

    /// k = l = 1 recovers the single-server Theorem 1 bound for
    /// exponential jobs (the paper's stated special case).
    #[test]
    fn reduces_to_theorem1_single_server() {
        let (lambda, mu, eps) = (0.4, 1.0, 0.001);
        let th2 = sojourn_quantile(1, 1, mu, eps, |th| rho_arrival_exp(lambda, th)).unwrap();
        let th1 = theorem1::sojourn_quantile(
            mu,
            eps,
            |th| rho_service_exp(mu, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        assert!((th2 - th1).abs() / th1 < 1e-6, "{th2} vs {th1}");
    }

    /// The paper's headline effect (Fig. 13): with E[L] held constant
    /// (μ = k/l), the FJ bound *decreases* in k toward the ideal
    /// partition's bound.
    #[test]
    fn tinyfication_improves_bound_towards_ideal() {
        let l = 50usize;
        let lambda = 0.5;
        let eps = 1e-6;
        let tau_at = |k: usize| {
            let mu = k as f64 / l as f64;
            sojourn_quantile(l, k, mu, eps, |th| rho_arrival_exp(lambda, th)).unwrap()
        };
        let t50 = tau_at(50);
        let t100 = tau_at(100);
        let t600 = tau_at(600);
        let t3000 = tau_at(3000);
        assert!(t100 < t50, "{t100} !< {t50}");
        assert!(t600 < t100);
        assert!(t3000 < t600);
        // Ideal partition bound (Eq. 10 into Th. 1) as the k→∞ limit.
        let ideal = theorem1::sojourn_quantile(
            l as f64 * 3000.0 / l as f64,
            eps,
            |th| crate::analysis::envelope::rho_ideal(3000, l, 3000.0 / l as f64, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        assert!(t3000 > ideal, "bound stays above ideal");
        assert!((t3000 - ideal) / ideal < 0.35, "approaches ideal: {t3000} vs {ideal}");
    }

    /// Waiting bound grows with the task index i (later tasks wait
    /// longer) and the job-level (i = k) bound exceeds the first task's.
    #[test]
    fn waiting_monotone_in_task_index() {
        let (l, k, mu, lambda, eps) = (10usize, 40usize, 4.0, 0.5, 0.001);
        let w1 = waiting_quantile(l, k, 1, mu, eps, |th| rho_arrival_exp(lambda, th)).unwrap();
        let wk2 = waiting_quantile(l, k, k / 2, mu, eps, |th| rho_arrival_exp(lambda, th))
            .unwrap();
        let wk = waiting_quantile(l, k, k, mu, eps, |th| rho_arrival_exp(lambda, th)).unwrap();
        assert!(w1 < wk2 && wk2 < wk, "{w1} {wk2} {wk}");
    }

    /// Overhead approximation exceeds the clean bound and collapses to it
    /// (plus nothing) at zero overhead.
    #[test]
    fn overhead_consistency() {
        let (l, k, lambda, eps) = (50usize, 500usize, 0.5, 0.01);
        let mu = k as f64 / l as f64;
        let clean = sojourn_quantile(l, k, mu, eps, |th| rho_arrival_exp(lambda, th)).unwrap();
        let zero = sojourn_quantile_overhead(
            l,
            k,
            mu,
            eps,
            &crate::config::OverheadConfig::zero(),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        assert!((clean - zero).abs() / clean < 1e-9);
        let oh = sojourn_quantile_overhead(
            l,
            k,
            mu,
            eps,
            &crate::config::OverheadConfig::paper(),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        assert!(oh > clean);
    }

    /// Enough overhead makes the system infeasible (the Fig. 8 upturn).
    #[test]
    fn heavy_overhead_destabilizes() {
        let (l, lambda, eps) = (50usize, 0.5, 0.01);
        let k = 20_000usize; // extreme tinyfication
        let mu = k as f64 / l as f64;
        let got = sojourn_quantile_overhead(
            l,
            k,
            mu,
            eps,
            &crate::config::OverheadConfig::paper(),
            |th| rho_arrival_exp(lambda, th),
        );
        // At k = 20000 the mean task time is 2.5 ms but overhead is
        // 3.1 ms/task — utilization exceeds 1 and no θ is feasible.
        assert!(got.is_none());
    }
}
