//! Mean-value bounds derived from the MGF machinery.
//!
//! Theorem 1 gives `P[T > τ] ≤ e^{θρ_S(θ)} e^{−θτ}`. Integrating the
//! (capped) tail bound yields a mean-sojourn bound for any feasible θ:
//!
//!   E[T] = ∫₀^∞ P[T > τ] dτ ≤ τ₀ + e^{θρ_S(θ)} e^{−θτ₀} / θ
//!
//! minimized at `τ₀ = ρ_S(θ) (+ ln c / θ)` where the cap `min(1, ·)`
//! binds, giving the clean form `E[T] ≤ ρ_S(θ) + 1/θ`. Optimizing over θ
//! produces a mean bound companion to the quantile bounds — useful for
//! quick capacity arithmetic in the advisor.

use super::theorem1::optimize_theta;

/// Mean-sojourn bound `min_θ { ρ_S(θ) + 1/θ }` s.t. `ρ_S(θ) ≤ ρ_A(−θ)`.
pub fn mean_sojourn_bound<RS, RA>(theta_sup: f64, rho_s: RS, rho_a: RA) -> Option<f64>
where
    RS: Fn(f64) -> f64,
    RA: Fn(f64) -> f64,
{
    optimize_theta(
        theta_sup,
        |th| rho_s(th) + 1.0 / th,
        |th| rho_s(th) <= rho_a(th),
    )
    .map(|(_, v)| v)
}

/// Mean-waiting bound `min_θ { 1/θ }` over feasible θ.
pub fn mean_waiting_bound<RS, RA>(theta_sup: f64, rho_s: RS, rho_a: RA) -> Option<f64>
where
    RS: Fn(f64) -> f64,
    RA: Fn(f64) -> f64,
{
    optimize_theta(theta_sup, |th| 1.0 / th, |th| rho_s(th) <= rho_a(th))
        .map(|(_, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::envelope::{rho_arrival_exp, rho_service_exp};
    use crate::analysis::lemma1;

    /// M/M/1: exact E[T] = 1/(μ−λ); the bound must dominate it and stay
    /// within a small constant factor.
    #[test]
    fn mm1_mean_bound() {
        let (lambda, mu) = (0.5, 1.0);
        let exact = 1.0 / (mu - lambda);
        let bound = mean_sojourn_bound(
            mu,
            |th| rho_service_exp(mu, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        assert!(bound >= exact, "{bound} < exact {exact}");
        assert!(bound < 3.5 * exact, "{bound} vs {exact}");
    }

    /// The mean bound dominates the simulated mean for tiny-tasks SM.
    #[test]
    fn sm_mean_bound_dominates_simulation() {
        use crate::config::{ModelKind, SimulationConfig};
        let (l, k, lambda) = (10usize, 60usize, 0.4);
        let mu = k as f64 / l as f64;
        let bound = mean_sojourn_bound(
            mu,
            |th| lemma1::rho_s(l, k, mu, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        let cfg = SimulationConfig {
            model: ModelKind::SplitMerge,
            servers: l,
            tasks_per_job: k,
            arrival: crate::config::ArrivalConfig { interarrival: format!("exp:{lambda}") },
            service: crate::config::ServiceConfig { execution: format!("exp:{mu}") },
            jobs: 20_000,
            warmup: 2_000,
            seed: 5,
            overhead: None,
            workers: None,
            redundancy: None,
            faults: None,
            policy: None,
        };
        let res = crate::sim::run(&cfg, Default::default()).unwrap();
        let sim_mean = res.sojourn_summary.mean();
        assert!(sim_mean <= bound, "sim {sim_mean} > bound {bound}");
        assert!(bound < sim_mean * 5.0, "vacuous bound {bound} vs {sim_mean}");
    }

    /// Waiting ≤ sojourn; unstable → None.
    #[test]
    fn consistency() {
        let (lambda, mu) = (0.5, 1.0);
        let s = mean_sojourn_bound(
            mu,
            |th| rho_service_exp(mu, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        let w = mean_waiting_bound(
            mu,
            |th| rho_service_exp(mu, th),
            |th| rho_arrival_exp(lambda, th),
        )
        .unwrap();
        assert!(w < s);
        assert!(mean_sojourn_bound(
            1.0,
            |th| rho_service_exp(1.0, th),
            |th| rho_arrival_exp(2.0, th),
        )
        .is_none());
    }
}
