//! Stochastic network-calculus analysis (Secs. 3–6) in pure Rust.
//!
//! This module is the reference implementation of the paper's analytical
//! results; the AOT-compiled JAX/Pallas artifacts (see `python/compile/`)
//! evaluate the same math on the batched hot path and are cross-validated
//! against this module in `rust/tests/artifact_cross_validation.rs`.
//!
//! Contents:
//! * [`envelope`] — (σ,ρ) envelope rates for Exp arrivals/services
//!   (Eqs. 5–6) and the Erlang/ideal-partition rate (Eq. 10);
//! * [`lemma1`] — tiny-tasks split-merge service envelope
//!   ρ_S(θ) = ρ_X(θ) + (k−l) ρ_Z(θ) and E[Δ] (Lemma 1), plus the Sec.-6
//!   overhead-augmented variants (Eqs. 26, 28, 31);
//! * [`theorem1`] — the statistical waiting/sojourn bound machinery with
//!   θ-optimization (Theorem 1);
//! * [`theorem2`] — tiny-tasks single-queue fork-join bounds (Theorem 2);
//! * [`erlang`] — big-tasks split-merge via numeric integration of the
//!   Erlang-max CCDF/MGF (Eqs. 21–23, Sec. 4.3);
//! * [`stability`] — closed-form stability regions (Eqs. 20, 23);
//! * [`bounds`] — the high-level [`bounds::BoundParams`] →
//!   quantile-bound API used by the coordinator and figures.

pub mod bounds;
pub mod envelope;
pub mod erlang;
pub mod lemma1;
pub mod moments;
pub mod stability;
pub mod theorem1;
pub mod theorem2;

pub use bounds::{sojourn_bound, waiting_bound, BoundModel, BoundParams};
