//! High-level bound API: one entry point mapping (model, parameters) to
//! sojourn/waiting ε-quantile bounds — the pure-Rust reference engine.
//! The PJRT artifact path (`crate::runtime::bounds`) evaluates the same
//! quantities batched; the two are cross-validated in the test suite.

use super::envelope::{rho_arrival_exp, rho_ideal, rho_service_exp};
use super::theorem1;
use super::theorem2;
use super::{erlang, lemma1};
use crate::config::OverheadConfig;

/// Which analytic model to evaluate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BoundModel {
    /// Tiny-tasks split-merge (Lemma 1 + Th. 1).
    SplitMergeTiny,
    /// Big-tasks split-merge with `Erlang(kappa, mu)` tasks (Sec. 4.3).
    SplitMergeBigErlang {
        /// Erlang shape κ of each big task.
        kappa: u32,
    },
    /// Tiny-tasks single-queue fork-join (Th. 2).
    ForkJoinTiny,
    /// Classic per-server fork-join, k = l (Sec. 3.2.2, union bound).
    ForkJoinPerServer,
    /// Ideal partition (Eq. 10 + Th. 1).
    Ideal,
}

/// Parameters shared by every bound query.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// Number of servers l.
    pub l: usize,
    /// Tasks per job k (`≥ l` for the tiny-tasks models).
    pub k: usize,
    /// Poisson arrival rate λ (iid Exp inter-arrivals).
    pub lambda: f64,
    /// Task service rate μ (`Exp(mu)` tasks; for the big-tasks model, the
    /// rate of each Erlang stage).
    pub mu: f64,
    /// Violation probability ε of the quantile bound.
    pub epsilon: f64,
    /// Sec.-6 overhead approximation parameters (None = clean bound).
    pub overhead: Option<OverheadConfig>,
}

impl BoundParams {
    /// The Fig. 8/13 parameterization: l servers, λ = 0.5, E[L] = l s,
    /// μ = k/l so the expected workload is constant in k.
    pub fn paper_sweep(l: usize, k: usize, lambda: f64, epsilon: f64) -> Self {
        Self { l, k, lambda, mu: k as f64 / l as f64, epsilon, overhead: None }
    }

    /// Attach the overhead model.
    pub fn with_overhead(mut self, oh: OverheadConfig) -> Self {
        self.overhead = Some(oh);
        self
    }
}

/// Sojourn-time ε-quantile bound (or Sec.-6 approximation when overhead
/// is set). `None` means no feasible θ — the configuration is unstable
/// under the bound's stability condition.
pub fn sojourn_bound(model: BoundModel, p: &BoundParams) -> Option<f64> {
    validate(model, p);
    let rho_a = |th: f64| rho_arrival_exp(p.lambda, th);
    match (model, p.overhead) {
        (BoundModel::SplitMergeTiny, None) => theorem1::sojourn_quantile(
            p.mu,
            p.epsilon,
            |th| lemma1::rho_s(p.l, p.k, p.mu, th),
            rho_a,
        ),
        (BoundModel::SplitMergeTiny, Some(oh)) => theorem1::sojourn_quantile(
            p.mu,
            p.epsilon,
            |th| lemma1::rho_s_overhead_sm(p.l, p.k, p.mu, th, &oh),
            rho_a,
        ),
        (BoundModel::SplitMergeBigErlang { kappa }, _) => theorem1::sojourn_quantile(
            // θ capped at 0.9μ to keep the MGF quadrature truncation tight;
            // matches the AOT artifact's grid (a bound at suboptimal θ is
            // still a valid bound, just marginally looser).
            0.9 * p.mu,
            p.epsilon,
            |th| erlang::rho_s_big_tasks(p.l, kappa, p.mu, th),
            rho_a,
        ),
        (BoundModel::ForkJoinTiny, None) => {
            theorem2::sojourn_quantile(p.l, p.k, p.mu, p.epsilon, rho_a)
        }
        (BoundModel::ForkJoinTiny, Some(oh)) => {
            theorem2::sojourn_quantile_overhead(p.l, p.k, p.mu, p.epsilon, &oh, rho_a)
        }
        (BoundModel::ForkJoinPerServer, _) => {
            // Union bound over l per-server M/M/1 queues (Sec. 3.2.2):
            // P[T > τ] ≤ l e^{θρ_Q} e^{−θτ} → τ = ρ_Q + (ln l + ln 1/ε)/θ.
            let eff_eps = p.epsilon / p.l as f64;
            theorem1::sojourn_quantile(
                p.mu,
                eff_eps,
                |th| rho_service_exp(p.mu, th),
                rho_a,
            )
        }
        (BoundModel::Ideal, _) => theorem1::sojourn_quantile(
            p.l as f64 * p.mu,
            p.epsilon,
            |th| rho_ideal(p.k, p.l, p.mu, th),
            rho_a,
        ),
    }
}

/// Waiting-time ε-quantile bound.
pub fn waiting_bound(model: BoundModel, p: &BoundParams) -> Option<f64> {
    validate(model, p);
    let rho_a = |th: f64| rho_arrival_exp(p.lambda, th);
    match (model, p.overhead) {
        (BoundModel::SplitMergeTiny, None) => theorem1::waiting_quantile(
            p.mu,
            p.epsilon,
            |th| lemma1::rho_s(p.l, p.k, p.mu, th),
            rho_a,
        ),
        (BoundModel::SplitMergeTiny, Some(oh)) => theorem1::waiting_quantile(
            p.mu,
            p.epsilon,
            |th| lemma1::rho_s_overhead_sm(p.l, p.k, p.mu, th, &oh),
            rho_a,
        ),
        (BoundModel::SplitMergeBigErlang { kappa }, _) => theorem1::waiting_quantile(
            0.9 * p.mu,
            p.epsilon,
            |th| erlang::rho_s_big_tasks(p.l, kappa, p.mu, th),
            rho_a,
        ),
        (BoundModel::ForkJoinTiny, None) => {
            theorem2::waiting_quantile(p.l, p.k, p.k, p.mu, p.epsilon, rho_a)
        }
        (BoundModel::ForkJoinTiny, Some(oh)) => {
            // Waiting is unaffected by (non-blocking) pre-departure
            // overhead; only the ρ° substitution applies.
            let ln_inv_eps = -p.epsilon.ln();
            theorem1::optimize_theta(
                p.mu,
                |th| {
                    (p.k - 1) as f64 * lemma1::rho_z_overhead(p.l, p.mu, th, &oh)
                        + ln_inv_eps / th
                },
                |th| p.k as f64 * lemma1::rho_z_overhead(p.l, p.mu, th, &oh) <= rho_a(th),
            )
            .map(|(_, tau)| tau)
        }
        (BoundModel::ForkJoinPerServer, _) => {
            let eff_eps = p.epsilon / p.l as f64;
            theorem1::waiting_quantile(
                p.mu,
                eff_eps,
                |th| rho_service_exp(p.mu, th),
                rho_a,
            )
        }
        (BoundModel::Ideal, _) => theorem1::waiting_quantile(
            p.l as f64 * p.mu,
            p.epsilon,
            |th| rho_ideal(p.k, p.l, p.mu, th),
            rho_a,
        ),
    }
}

fn validate(model: BoundModel, p: &BoundParams) {
    assert!(p.l >= 1 && p.k >= 1, "l,k >= 1");
    assert!(p.lambda > 0.0 && p.mu > 0.0, "rates positive");
    assert!(p.epsilon > 0.0 && p.epsilon < 1.0, "epsilon in (0,1)");
    match model {
        BoundModel::SplitMergeTiny | BoundModel::ForkJoinTiny => {
            assert!(p.k >= p.l, "tiny tasks require k >= l")
        }
        BoundModel::ForkJoinPerServer | BoundModel::SplitMergeBigErlang { .. } => {
            assert!(p.k == p.l, "big-tasks models require k = l")
        }
        BoundModel::Ideal => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(l: usize, k: usize) -> BoundParams {
        BoundParams::paper_sweep(l, k, 0.5, 0.01)
    }

    /// Fig.-13 ordering at every k: ideal < fork-join < split-merge.
    /// (Split-merge needs κ ≳ 5 to even be stable at ρ = 0.5 — Fig. 8(a).)
    #[test]
    fn model_ordering() {
        for k in [400usize, 1600] {
            let fj = sojourn_bound(BoundModel::ForkJoinTiny, &p(50, k)).unwrap();
            let sm = sojourn_bound(BoundModel::SplitMergeTiny, &p(50, k)).unwrap();
            let id = sojourn_bound(BoundModel::Ideal, &p(50, k)).unwrap();
            assert!(id < fj, "k={k}: ideal {id} !< fj {fj}");
            assert!(fj < sm, "k={k}: fj {fj} !< sm {sm}");
        }
        // κ = 2 split-merge is unstable at these parameters.
        assert!(sojourn_bound(BoundModel::SplitMergeTiny, &p(50, 100)).is_none());
        assert!(sojourn_bound(BoundModel::ForkJoinTiny, &p(50, 100)).is_some());
    }

    /// Split-merge at κ = 1 with l = 50, λ = 0.5 is unstable (Fig. 8a).
    #[test]
    fn sm_big_tasks_unstable_at_fig8_params() {
        assert!(sojourn_bound(BoundModel::SplitMergeTiny, &p(50, 50)).is_none());
        assert!(sojourn_bound(BoundModel::SplitMergeTiny, &p(50, 200)).is_some());
    }

    /// Sojourn ≥ waiting for every model.
    #[test]
    fn sojourn_dominates_waiting() {
        let models = [
            (BoundModel::ForkJoinTiny, p(20, 100)),
            (BoundModel::SplitMergeTiny, p(20, 200)),
            (BoundModel::Ideal, p(20, 100)),
            (BoundModel::ForkJoinPerServer, {
                let mut q = p(20, 20);
                q.mu = 1.0;
                q.lambda = 0.2;
                q
            }),
        ];
        for (m, params) in models {
            let s = sojourn_bound(m, &params).unwrap();
            let w = waiting_bound(m, &params).unwrap();
            assert!(s >= w, "{m:?}: {s} < {w}");
        }
    }

    /// Simulation never exceeds the bound at the bound's ε (the bound is
    /// an upper bound on the true quantile).
    #[test]
    fn bound_dominates_simulation() {
        use crate::config::{ModelKind, SimulationConfig};
        let (l, k, lambda) = (10usize, 40usize, 0.5);
        let mu = k as f64 / l as f64;
        let eps = 0.01;
        for (bm, mk) in [
            (BoundModel::ForkJoinTiny, ModelKind::ForkJoinSingleQueue),
            (BoundModel::SplitMergeTiny, ModelKind::SplitMerge),
        ] {
            let params = BoundParams { l, k, lambda, mu, epsilon: eps, overhead: None };
            let bound = sojourn_bound(bm, &params).unwrap();
            let cfg = SimulationConfig {
                model: mk,
                servers: l,
                tasks_per_job: k,
                arrival: crate::config::ArrivalConfig {
                    interarrival: format!("exp:{lambda}"),
                },
                service: crate::config::ServiceConfig { execution: format!("exp:{mu}") },
                jobs: 30_000,
                warmup: 2_000,
                seed: 77,
                overhead: None,
                workers: None,
                redundancy: None,
                faults: None,
                policy: None,
            };
            let mut res = crate::sim::run(&cfg, Default::default()).unwrap();
            let sim_q = res.sojourn_quantile(1.0 - eps);
            assert!(
                sim_q <= bound,
                "{bm:?}: sim {sim_q} exceeds bound {bound}"
            );
            // And the bound is not vacuous (within ~6x of the simulated
            // quantile for these moderate parameters).
            assert!(bound < sim_q * 6.0, "{bm:?}: bound {bound} loose vs {sim_q}");
        }
    }

    /// Fig.-12(b) relationship: big-tasks bound exceeds the equivalent
    /// tiny-tasks bound (same workload distribution, κ = 20).
    #[test]
    fn direct_refinement_bound_ordering() {
        let kappa = 20u32;
        let mu = 20.0;
        for l in [5usize, 15] {
            let tiny = sojourn_bound(
                BoundModel::SplitMergeTiny,
                &BoundParams {
                    l,
                    k: kappa as usize * l,
                    lambda: 0.5,
                    mu,
                    epsilon: 1e-3,
                    overhead: None,
                },
            )
            .unwrap();
            let big = sojourn_bound(
                BoundModel::SplitMergeBigErlang { kappa },
                &BoundParams { l, k: l, lambda: 0.5, mu, epsilon: 1e-3, overhead: None },
            )
            .unwrap();
            assert!(tiny < big, "l={l}: tiny {tiny} !< big {big}");
        }
    }
}
