//! Big-tasks split-merge analysis (Secs. 4.2–4.3): jobs of k = l tasks
//! with `Erlang(kappa, mu)` service times — the direct-refinement
//! counterpart of the tiny-tasks model. Uses numeric integration of the
//! Erlang-max CCDF for `E[Δ]` (Eq. 21) and of the max-MGF for ρ_S(θ)
//! (Sec. 4.3).

use crate::dist::Erlang;
use crate::util::math::simpson;

/// `E[Δ] = E[max_{i∈[1,l]} Q_i]`, `Q_i ~ Erlang(kappa, mu)` — Eq. 21,
/// `∫_0^∞ 1 − F(x)^l dx` by Simpson quadrature with an adaptive upper
/// limit chosen from the Erlang tail.
pub fn mean_max_erlang(l: usize, kappa: u32, mu: f64) -> f64 {
    assert!(l >= 1 && kappa >= 1 && mu > 0.0);
    let erl = Erlang::new(kappa, mu);
    // Upper limit: mean + sd scaled by ln(l) margin, then extended until
    // the integrand is negligible.
    let mean = kappa as f64 / mu;
    let sd = (kappa as f64).sqrt() / mu;
    let mut hi = mean + sd * (6.0 + 2.0 * (l as f64).ln());
    while 1.0 - erl.cdf(hi).powi(l as i32) > 1e-13 {
        hi *= 1.5;
    }
    simpson(|x| 1.0 - erl.cdf(x).powi(l as i32), 0.0, hi, 4096)
}

/// MGF of the Erlang-max: `E[e^{θ max_l Erlang(kappa,mu)}]` (Sec. 4.3).
///
/// Substituting `x = e^{θy}` in the paper's CCDF integral gives
/// `E[e^{θS}] = 1 + θ ∫_0^∞ (1 − F(y)^l) e^{θy} dy`, convergent for
/// θ ∈ (0, μ). Returns `f64::INFINITY` outside the domain.
pub fn mgf_max_erlang(l: usize, kappa: u32, mu: f64, theta: f64) -> f64 {
    assert!(theta > 0.0);
    if theta >= mu {
        return f64::INFINITY;
    }
    let erl = Erlang::new(kappa, mu);
    // Integrand tail ~ l e^{-(mu-theta) y} y^{kappa-1}: pick the limit from
    // the exponential decay rate.
    let decay = mu - theta;
    let mean = kappa as f64 / mu;
    let mut hi = mean + (40.0 + 2.0 * (l as f64).ln() + 8.0 * kappa as f64) / decay;
    let integrand = |y: f64| (1.0 - erl.cdf(y).powi(l as i32)) * (theta * y).exp();
    while integrand(hi) > 1e-14 {
        hi *= 1.3;
    }
    1.0 + theta * simpson(integrand, 0.0, hi, 8192)
}

/// Envelope rate of the big-tasks split-merge service process:
/// `ρ_S(θ) = ln E[e^{θ max}] / θ`.
pub fn rho_s_big_tasks(l: usize, kappa: u32, mu: f64, theta: f64) -> f64 {
    let mgf = mgf_max_erlang(l, kappa, mu, theta);
    if !mgf.is_finite() {
        return f64::INFINITY;
    }
    mgf.ln() / theta
}

/// Big-tasks stability region (Eq. 23): `ρ* = κ / (μ · E[Δ])` with E[Δ]
/// from Eq. 21 (utilization ρ = λ E[Q] = λκ/μ).
pub fn max_utilization_big_tasks(l: usize, kappa: u32, mu: f64) -> f64 {
    kappa as f64 / (mu * mean_max_erlang(l, kappa, mu))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::harmonic;

    /// κ = 1 reduces to max of exponentials: E[Δ] = H_l / μ.
    #[test]
    fn kappa1_mean_is_harmonic() {
        for l in [1usize, 5, 20, 50] {
            let got = mean_max_erlang(l, 1, 2.0);
            let expect = harmonic(l as u64) / 2.0;
            assert!(
                (got - expect).abs() / expect < 1e-6,
                "l={l}: {got} vs {expect}"
            );
        }
    }

    /// κ = 1 MGF reduces to the product identity (Eq. 17):
    /// `E[e^{θ max_l Exp(mu)}] = Π_{i=1}^{l} iμ/(iμ−θ)`.
    #[test]
    fn kappa1_mgf_matches_product_identity() {
        let (l, mu, theta) = (10usize, 1.0, 0.35);
        let got = mgf_max_erlang(l, 1, mu, theta);
        let expect: f64 = (1..=l)
            .map(|i| {
                let imu = i as f64 * mu;
                imu / (imu - theta)
            })
            .product();
        assert!((got - expect).abs() / expect < 1e-6, "{got} vs {expect}");
    }

    /// l = 1: E[Δ] = κ/μ exactly; MGF = (μ/(μ−θ))^κ.
    #[test]
    fn single_server_closed_forms() {
        let (kappa, mu, theta) = (20u32, 20.0, 3.0);
        let mean = mean_max_erlang(1, kappa, mu);
        assert!((mean - 1.0).abs() < 1e-6, "{mean}");
        let mgf = mgf_max_erlang(1, kappa, mu, theta);
        let expect = (mu / (mu - theta)).powi(kappa as i32);
        assert!((mgf - expect).abs() / expect < 1e-6, "{mgf} vs {expect}");
    }

    /// Eq. 23 vs Monte-Carlo from the simulator's stability module:
    /// big-tasks stability for Erlang tasks.
    #[test]
    fn stability_matches_monte_carlo() {
        use crate::sim::stability::sm_max_utilization;
        use crate::sim::OverheadModel;
        let (l, kappa, mu) = (10usize, 20u32, 20.0);
        let analytic = max_utilization_big_tasks(l, kappa, mu);
        let erl = crate::dist::Erlang::new(kappa, mu);
        // Big tasks: k = l tasks with Erlang service.
        let mc = sm_max_utilization(l, l, &erl, &OverheadModel::none(), 20_000, 6);
        assert!(
            (analytic - mc).abs() / analytic < 0.02,
            "{analytic} vs {mc}"
        );
    }

    /// Direct refinement dominance: tiny tasks (Eq. 20) strictly beat big
    /// tasks (Eq. 23) for κ > 1 — the Fig. 12(a) relationship.
    #[test]
    fn tiny_beats_big() {
        let kappa = 20u32;
        let mu = 20.0;
        for l in [5usize, 20, 50] {
            let big = max_utilization_big_tasks(l, kappa, mu);
            let tiny =
                1.0 / (1.0 + (harmonic(l as u64) - 1.0) / kappa as f64); // Eq. 20
            assert!(tiny > big, "l={l}: tiny {tiny} !> big {big}");
        }
    }
}
