//! `--progress` heartbeat: a throttled stderr line with jobs done,
//! jobs/sec, ETA, and per-shard lag. Print-on-tick — no background
//! thread: the runner calls [`tick`] every few hundred measured jobs
//! and the line is emitted at most once a second. The state is a
//! process-global mutex because shards tick concurrently from the
//! thread pool; the lock is taken only on tick boundaries (every
//! [`TICK_JOBS`] jobs per shard), never per job, and never at all
//! unless `--progress` was requested. The heartbeat reads only
//! wall-clock time and shard completion counts — it consumes no RNG
//! draws and cannot affect simulation output.

use crate::util::logging::stderr_line;
use std::sync::Mutex;
use std::time::Instant;

/// Jobs between [`tick`] calls in the runner (per shard).
pub const TICK_JOBS: usize = 512;

struct ProgressState {
    total: u64,
    done: Vec<u64>,
    started: Instant,
    last_print: Option<Instant>,
}

static STATE: Mutex<Option<ProgressState>> = Mutex::new(None);

/// Begin a progress session for `total` measured jobs across `shards`
/// shards. Replaces any previous session.
pub fn start(total: u64, shards: usize) {
    let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
    *st = Some(ProgressState {
        total,
        done: vec![0; shards.max(1)],
        started: Instant::now(),
        last_print: None,
    });
}

fn render(st: &ProgressState) -> String {
    let done: u64 = st.done.iter().sum();
    let secs = st.started.elapsed().as_secs_f64().max(1e-9);
    let rate = done as f64 / secs;
    let eta = if rate > 0.0 && done < st.total {
        (st.total - done) as f64 / rate
    } else {
        0.0
    };
    let lag = match (st.done.iter().max(), st.done.iter().min()) {
        (Some(max), Some(min)) if st.done.len() > 1 => max - min,
        _ => 0,
    };
    format!(
        "jobs {done}/{} ({rate:.0} jobs/s, eta {eta:.0}s, shard lag {lag})",
        st.total
    )
}

/// Update shard `shard`'s completed-job count and emit the heartbeat if
/// at least a second has passed since the last line. No-op without an
/// active session.
pub fn tick(shard: usize, done: u64) {
    let mut guard = STATE.lock().unwrap_or_else(|e| e.into_inner());
    let Some(st) = guard.as_mut() else {
        return;
    };
    if shard < st.done.len() {
        st.done[shard] = done;
    }
    let due = match st.last_print {
        None => true,
        Some(t) => t.elapsed().as_secs_f64() >= 1.0,
    };
    if due {
        st.last_print = Some(Instant::now());
        let line = render(st);
        stderr_line("PROG ", "obs::progress", &line);
    }
}

/// Emit the final line and end the session. No-op without one.
pub fn finish() {
    let mut guard = STATE.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(st) = guard.take() {
        let line = render(&st);
        stderr_line("PROG ", "obs::progress", &format!("{line} — done"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_is_safe_and_lag_tracks_shards() {
        finish(); // no session: no-op
        tick(0, 10); // no session: no-op
        start(100, 4);
        tick(0, 30);
        tick(1, 10);
        tick(7, 5); // out-of-range shard ignored
        {
            let guard = STATE.lock().unwrap();
            let st = guard.as_ref().expect("session active");
            assert_eq!(st.done.iter().sum::<u64>(), 40);
            let line = render(st);
            assert!(line.contains("jobs 40/100"), "{line}");
            assert!(line.contains("shard lag 30"), "{line}");
        }
        finish();
        assert!(STATE.lock().unwrap().is_none());
    }
}
