//! `--progress` heartbeat: a throttled stderr line with jobs done,
//! jobs/sec, ETA, and per-shard lag. Print-on-tick — no background
//! thread: the runner calls [`tick`] every few hundred measured jobs
//! and the line is emitted at most once a second. The state is a
//! process-global mutex because shards tick concurrently from the
//! thread pool; the lock is taken only on tick boundaries (every
//! [`TICK_JOBS`] jobs per shard), never per job, and never at all
//! unless `--progress` was requested. The heartbeat reads only
//! wall-clock time and shard completion counts — it consumes no RNG
//! draws and cannot affect simulation output.

use crate::util::logging::stderr_line;
use std::sync::Mutex;
use std::time::Instant;

/// Jobs between [`tick`] calls in the runner (per shard).
pub const TICK_JOBS: usize = 512;

struct ProgressState {
    total: u64,
    done: Vec<u64>,
    started: Instant,
    last_print: Option<Instant>,
}

static STATE: Mutex<Option<ProgressState>> = Mutex::new(None);

/// Begin a progress session for `total` measured jobs across `shards`
/// shards. Replaces any previous session.
pub fn start(total: u64, shards: usize) {
    let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
    *st = Some(ProgressState {
        total,
        done: vec![0; shards.max(1)],
        started: Instant::now(),
        last_print: None,
    });
}

fn render(st: &ProgressState) -> String {
    let done: u64 = st.done.iter().sum();
    let secs = st.started.elapsed().as_secs_f64().max(1e-9);
    let rate = done as f64 / secs;
    let eta = if rate > 0.0 && done < st.total {
        (st.total - done) as f64 / rate
    } else {
        0.0
    };
    let lag = match (st.done.iter().max(), st.done.iter().min()) {
        (Some(max), Some(min)) if st.done.len() > 1 => max - min,
        _ => 0,
    };
    format!(
        "jobs {done}/{} ({rate:.0} jobs/s, eta {eta:.0}s, shard lag {lag})",
        st.total
    )
}

/// Update shard `shard`'s completed-job count and emit the heartbeat if
/// at least a second has passed since the last line. No-op without an
/// active session.
pub fn tick(shard: usize, done: u64) {
    let mut guard = STATE.lock().unwrap_or_else(|e| e.into_inner());
    let Some(st) = guard.as_mut() else {
        return;
    };
    if shard < st.done.len() {
        st.done[shard] = done;
    }
    let due = match st.last_print {
        None => true,
        Some(t) => t.elapsed().as_secs_f64() >= 1.0,
    };
    if due {
        st.last_print = Some(Instant::now());
        let line = render(st);
        stderr_line("PROG ", "obs::progress", &line);
    }
}

/// Final 100% line: actual completion count, total wall time, and the
/// run's *mean* rate — unlike [`render`]'s instantaneous view, this
/// cannot under-report by a stale throttled tick.
fn render_final(st: &ProgressState) -> String {
    let done: u64 = st.done.iter().sum();
    let secs = st.started.elapsed().as_secs_f64().max(1e-9);
    let rate = done as f64 / secs;
    format!(
        "jobs {done}/{} done in {secs:.2}s ({rate:.0} jobs/s mean)",
        st.total
    )
}

/// Emit the final 100% heartbeat (unthrottled — the last periodic tick
/// can lag by up to `TICK_JOBS` jobs / 1 s) and end the session. No-op
/// without one.
pub fn finish() {
    let mut guard = STATE.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(st) = guard.take() {
        stderr_line("PROG ", "obs::progress", &render_final(&st));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// A state whose clock started `secs_ago` seconds in the past, for
    /// deterministic-enough rate/ETA assertions without sleeping.
    fn aged_state(total: u64, done: Vec<u64>, secs_ago: u64) -> ProgressState {
        ProgressState {
            total,
            done,
            started: Instant::now() - Duration::from_secs(secs_ago),
            last_print: None,
        }
    }

    #[test]
    fn render_reports_rate_eta_and_lag() {
        // 40/100 jobs in ~2 s → 20 jobs/s, eta (100-40)/20 = 3 s.
        let st = aged_state(100, vec![30, 10], 2);
        let line = render(&st);
        assert!(line.starts_with("jobs 40/100 ("), "{line}");
        assert!(line.contains("20 jobs/s"), "{line}");
        assert!(line.contains("eta 3s"), "{line}");
        assert!(line.contains("shard lag 20"), "{line}");
    }

    #[test]
    fn render_handles_done_and_single_shard() {
        // Complete: eta 0, and a single shard reports zero lag.
        let st = aged_state(100, vec![100], 2);
        let line = render(&st);
        assert!(line.starts_with("jobs 100/100 ("), "{line}");
        assert!(line.contains("eta 0s"), "{line}");
        assert!(line.contains("shard lag 0"), "{line}");
        // Nothing done yet: rate 0 and eta degrades to 0, not inf/NaN.
        let idle = aged_state(100, vec![0, 0], 2);
        let line = render(&idle);
        assert!(line.contains("eta 0s"), "{line}");
    }

    #[test]
    fn final_line_reports_total_wall_and_mean_rate() {
        let st = aged_state(100, vec![60, 40], 2);
        let line = render_final(&st);
        assert!(line.starts_with("jobs 100/100 done in "), "{line}");
        assert!(line.ends_with("jobs/s mean)"), "{line}");
        // ~2 s wall → mean rate rounds to 50 jobs/s.
        assert!(line.contains("(50 jobs/s mean)"), "{line}");
    }

    #[test]
    fn lifecycle_is_safe_and_lag_tracks_shards() {
        finish(); // no session: no-op
        tick(0, 10); // no session: no-op
        start(100, 4);
        tick(0, 30);
        tick(1, 10);
        tick(7, 5); // out-of-range shard ignored
        {
            let guard = STATE.lock().unwrap();
            let st = guard.as_ref().expect("session active");
            assert_eq!(st.done.iter().sum::<u64>(), 40);
            let line = render(st);
            assert!(line.contains("jobs 40/100"), "{line}");
            assert!(line.contains("shard lag 30"), "{line}");
        }
        finish();
        assert!(STATE.lock().unwrap().is_none());
    }
}
