//! Observability — engine-wide metrics and profiling with zero
//! determinism cost.
//!
//! Two layers, split by cost model:
//!
//! * [`Tallies`] — raw, always-on `u64` event counts owned by the
//!   engines themselves (heap ops, dispatches, steals, retries, crashes,
//!   speculative launches, replica losers, sampler draws). A plain
//!   unconditional integer increment is cheaper than the branch that
//!   would gate it, so these run unconditionally and are harvested once
//!   per run.
//! * [`Metrics`] — the gated registry (counters, phase wall-times,
//!   fixed-bucket latency histograms, gauges). Every recording method is
//!   `#[inline]` and early-returns when the registry is disabled, so the
//!   disabled path compiles down to a predicted-not-taken branch on a
//!   local bool; phase clocks take **no** `Instant` reading when
//!   disabled ([`PhaseClock`] holds `None`).
//!
//! The hard invariant: nothing in this module consumes RNG draws or
//! feeds back into simulation state, so results are bitwise identical
//! with metrics on vs. off (test-enforced in `rust/tests/obs_metrics.rs`
//! the same way `TT_NO_FAST_EXP` and thread-count invariance are).
//! Registries are per-shard (each shard owns its own `Metrics`) and
//! merge deterministically in shard-index order alongside the Welford/P²
//! merges — there are no locks because there is no sharing.

pub mod progress;
pub mod report;

/// Counters tracked by the registry. Enum-indexed into a fixed array,
/// so recording is a bounds-check-free store and the report always
/// emits every key (CI asserts on their presence).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Calendar engine: events popped off the event heap.
    EventsProcessed,
    /// Server-heap / event-heap pushes.
    HeapPushes,
    /// Server-heap / event-heap pops.
    HeapPops,
    /// Logical tasks handed to a server (one per task, not per attempt).
    TasksDispatched,
    /// Jobs run to completion (warmup included — the engines cannot
    /// tell a warmup job from a measured one).
    JobsCompleted,
    /// Work-stealing: tasks run on a non-affinity server.
    Steals,
    /// Fault injection: failed attempts that re-entered the queue.
    Retries,
    /// Fault injection: worker crash events consumed.
    Crashes,
    /// Fault injection: speculative backup copies actually launched.
    SpeculativeLaunches,
    /// Redundancy: replica copies cancelled after losing the
    /// first-finish-wins race (having occupied a server).
    ReplicaLosers,
    /// Batched sampler calls (`Dist::draw_batch` via
    /// `Workload::next_executions`).
    BatchDraws,
    /// Interarrival draws.
    ArrivalDraws,
    /// Task execution-time draws (batched draws count per element).
    ExecutionDraws,
}

/// Number of [`Counter`] variants.
pub const COUNTER_COUNT: usize = 13;

impl Counter {
    /// Every counter, in report order.
    pub const ALL: [Counter; COUNTER_COUNT] = [
        Counter::EventsProcessed,
        Counter::HeapPushes,
        Counter::HeapPops,
        Counter::TasksDispatched,
        Counter::JobsCompleted,
        Counter::Steals,
        Counter::Retries,
        Counter::Crashes,
        Counter::SpeculativeLaunches,
        Counter::ReplicaLosers,
        Counter::BatchDraws,
        Counter::ArrivalDraws,
        Counter::ExecutionDraws,
    ];

    /// Stable snake-case key used in `RUN_METRICS.json`.
    pub fn key(self) -> &'static str {
        match self {
            Counter::EventsProcessed => "events_processed",
            Counter::HeapPushes => "heap_pushes",
            Counter::HeapPops => "heap_pops",
            Counter::TasksDispatched => "tasks_dispatched",
            Counter::JobsCompleted => "jobs_completed",
            Counter::Steals => "steals",
            Counter::Retries => "retries",
            Counter::Crashes => "crashes",
            Counter::SpeculativeLaunches => "speculative_launches",
            Counter::ReplicaLosers => "replica_losers",
            Counter::BatchDraws => "batch_draws",
            Counter::ArrivalDraws => "arrival_draws",
            Counter::ExecutionDraws => "execution_draws",
        }
    }
}

/// Wall-clock phases profiled around the engines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Config parsing, workload/model construction.
    Setup,
    /// Batched sample drawing (calendar stage pre-draws).
    Sampling,
    /// The main simulation / event loop.
    Dispatch,
    /// Cross-shard statistics merging.
    StatsMerge,
    /// File I/O (reports, traces, CSVs).
    Io,
}

/// Number of [`Phase`] variants.
pub const PHASE_COUNT: usize = 5;

impl Phase {
    /// Every phase, in report order.
    pub const ALL: [Phase; PHASE_COUNT] =
        [Phase::Setup, Phase::Sampling, Phase::Dispatch, Phase::StatsMerge, Phase::Io];

    /// Stable snake-case key used in `RUN_METRICS.json`.
    pub fn key(self) -> &'static str {
        match self {
            Phase::Setup => "setup",
            Phase::Sampling => "sampling",
            Phase::Dispatch => "dispatch",
            Phase::StatsMerge => "stats_merge",
            Phase::Io => "io",
        }
    }
}

/// Hierarchical event-loop spans profiled inside the calendar engine.
/// Each span has a static parent, so the set forms a fixed tree rooted
/// at [`Span::EventLoop`] — renderable as a text tree or as
/// collapsed-stack ("folded") lines for flamegraph tooling. Spans obey
/// the same two-layer contract as phases: clocks are only read when
/// profiling is on, no RNG is consumed, and results stay bitwise
/// identical either way.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Span {
    /// The whole calendar event loop (root).
    EventLoop,
    /// Popping the next event off the calendar heap.
    HeapPop,
    /// Arrival events (job admission + stage-0 enqueue).
    Arrival,
    /// Task-finish events (stage bookkeeping, barrier checks).
    Finish,
    /// Departure events (split-merge job completion records).
    Departure,
    /// Fault-axis events: crash, repair, retry re-queue, speculative
    /// launch.
    Fault,
    /// Work-stealing periodic scan ticks.
    StealTick,
    /// The dispatch pass after each event (FCFS fast path included).
    Dispatch,
    /// Stage pre-draw sampling under an arrival (stage 0).
    ArrivalSampling,
    /// Stage pre-draw sampling under a finish (barrier stages ≥ 1).
    FinishSampling,
    /// Completion-record/statistics updates under a finish.
    FinishStats,
    /// Policy-routed dispatch (SITA / priority / work stealing).
    PolicyDispatch,
}

/// Number of [`Span`] variants.
pub const SPAN_COUNT: usize = 12;

impl Span {
    /// Every span, parents before children (report order).
    pub const ALL: [Span; SPAN_COUNT] = [
        Span::EventLoop,
        Span::HeapPop,
        Span::Arrival,
        Span::Finish,
        Span::Departure,
        Span::Fault,
        Span::StealTick,
        Span::Dispatch,
        Span::ArrivalSampling,
        Span::FinishSampling,
        Span::FinishStats,
        Span::PolicyDispatch,
    ];

    /// Stable path key used in `RUN_METRICS.json` (`/`-separated along
    /// the parent chain, so sibling sub-spans stay distinct).
    pub fn key(self) -> &'static str {
        match self {
            Span::EventLoop => "event_loop",
            Span::HeapPop => "heap_pop",
            Span::Arrival => "arrival",
            Span::Finish => "finish",
            Span::Departure => "departure",
            Span::Fault => "fault",
            Span::StealTick => "steal_tick",
            Span::Dispatch => "dispatch",
            Span::ArrivalSampling => "arrival/sampling",
            Span::FinishSampling => "finish/sampling",
            Span::FinishStats => "finish/stats",
            Span::PolicyDispatch => "dispatch/policy",
        }
    }

    /// Display label (the last segment of [`Span::key`]).
    pub fn label(self) -> &'static str {
        match self.key().rsplit_once('/') {
            Some((_, leaf)) => leaf,
            None => self.key(),
        }
    }

    /// Static parent in the span tree (`None` for the root).
    pub fn parent(self) -> Option<Span> {
        match self {
            Span::EventLoop => None,
            Span::HeapPop
            | Span::Arrival
            | Span::Finish
            | Span::Departure
            | Span::Fault
            | Span::StealTick
            | Span::Dispatch => Some(Span::EventLoop),
            Span::ArrivalSampling => Some(Span::Arrival),
            Span::FinishSampling | Span::FinishStats => Some(Span::Finish),
            Span::PolicyDispatch => Some(Span::Dispatch),
        }
    }
}

/// Accumulated wall time and enter counts per [`Span`]. Owned by the
/// calendar engine (populated only under `--profile`-style flags) and
/// folded into the registry via [`Metrics::absorb_spans`]; merges are a
/// plain element-wise sum in shard-index order.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanSet {
    secs: [f64; SPAN_COUNT],
    counts: [u64; SPAN_COUNT],
}

impl Default for SpanSet {
    fn default() -> Self {
        SpanSet { secs: [0.0; SPAN_COUNT], counts: [0; SPAN_COUNT] }
    }
}

impl SpanSet {
    /// Add one timed entry of `span`.
    #[inline]
    pub fn add(&mut self, span: Span, secs: f64) {
        self.secs[span as usize] += secs;
        self.counts[span as usize] += 1;
    }

    /// Total seconds accumulated in `span` (children included).
    pub fn seconds(&self, span: Span) -> f64 {
        self.secs[span as usize]
    }

    /// Times `span` was entered.
    pub fn count(&self, span: Span) -> u64 {
        self.counts[span as usize]
    }

    /// No span was ever entered.
    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    /// Seconds spent in `span` itself, excluding its timed children
    /// (clamped at zero: child clocks nest inside the parent's, so
    /// timer noise can only push the difference slightly negative).
    pub fn self_seconds(&self, span: Span) -> f64 {
        let children: f64 = Span::ALL
            .iter()
            .filter(|c| c.parent() == Some(span))
            .map(|c| self.seconds(*c))
            .sum();
        (self.seconds(span) - children).max(0.0)
    }

    /// Element-wise sum merge (shard-index order in the runner).
    pub fn merge(&mut self, other: &SpanSet) {
        for (a, b) in self.secs.iter_mut().zip(&other.secs) {
            *a += *b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
    }

    /// Render the populated spans as an indented text tree with total,
    /// self, and enter-count columns. Empty string if nothing recorded.
    pub fn render_tree(&self) -> String {
        fn walk(set: &SpanSet, span: Span, depth: usize, out: &mut String) {
            if set.count(span) > 0 {
                let name = format!("{}{}", "  ".repeat(depth), span.label());
                out.push_str(&format!(
                    "{:<24} total {:>12.6}s  self {:>12.6}s  n {}\n",
                    name,
                    set.seconds(span),
                    set.self_seconds(span),
                    set.count(span)
                ));
            }
            for child in Span::ALL {
                if child.parent() == Some(span) {
                    walk(set, child, depth + 1, out);
                }
            }
        }
        let mut out = String::new();
        for root in Span::ALL.iter().filter(|s| s.parent().is_none()) {
            walk(self, *root, 0, &mut out);
        }
        out
    }

    /// Render collapsed-stack ("folded") lines — `a;b;leaf COUNT`, one
    /// per populated span, where COUNT is the span's **self** time in
    /// integer microseconds — consumable by inferno / flamegraph.pl.
    pub fn render_folded(&self) -> String {
        let mut out = String::new();
        for span in Span::ALL {
            if self.count(span) == 0 {
                continue;
            }
            let mut stack = vec![span.label()];
            let mut up = span.parent();
            while let Some(p) = up {
                stack.push(p.label());
                up = p.parent();
            }
            stack.reverse();
            let micros = (self.self_seconds(span) * 1e6).round() as u64;
            out.push_str(&format!("{} {}\n", stack.join(";"), micros));
        }
        out
    }
}

/// Raw always-on engine tallies (see module docs). Engines own one (or
/// expose per-component counts) and the runner folds them into the
/// registry at end of run via [`Metrics::absorb_tallies`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Tallies {
    /// Calendar events processed.
    pub events: u64,
    /// Heap pushes (server heap or event heap).
    pub heap_pushes: u64,
    /// Heap pops.
    pub heap_pops: u64,
    /// Logical tasks dispatched.
    pub dispatched: u64,
    /// Jobs completed (warmup included).
    pub jobs: u64,
    /// Work-stealing steals.
    pub steals: u64,
    /// Failed-attempt retries.
    pub retries: u64,
    /// Worker crashes consumed.
    pub crashes: u64,
    /// Speculative backups launched.
    pub spec_launches: u64,
    /// Cancelled first-finish-wins replicas.
    pub replica_losers: u64,
    /// Dispatches per policy class (index = class).
    pub class_dispatches: Vec<u64>,
}

impl Tallies {
    /// Count one dispatch of a task routed to `class`.
    #[inline]
    pub fn class_dispatch(&mut self, class: usize) {
        if class >= self.class_dispatches.len() {
            self.class_dispatches.resize(class + 1, 0);
        }
        self.class_dispatches[class] += 1;
    }

    /// Fold another tally set into this one.
    pub fn absorb(&mut self, other: &Tallies) {
        self.events += other.events;
        self.heap_pushes += other.heap_pushes;
        self.heap_pops += other.heap_pops;
        self.dispatched += other.dispatched;
        self.jobs += other.jobs;
        self.steals += other.steals;
        self.retries += other.retries;
        self.crashes += other.crashes;
        self.spec_launches += other.spec_launches;
        self.replica_losers += other.replica_losers;
        if other.class_dispatches.len() > self.class_dispatches.len() {
            self.class_dispatches.resize(other.class_dispatches.len(), 0);
        }
        for (a, b) in self.class_dispatches.iter_mut().zip(&other.class_dispatches) {
            *a += *b;
        }
    }
}

/// Fixed-bucket log-spaced latency histogram: bucket `i` covers
/// `[HIST_LO * 2^i, HIST_LO * 2^(i+1))` seconds; the first bucket also
/// absorbs everything below `HIST_LO`, the last everything above.
/// Fixed buckets make cross-shard merging a plain element-wise sum —
/// no interpolation, bitwise deterministic in merge order.
pub const HIST_BUCKETS: usize = 32;

/// Lower edge of the first histogram bucket (seconds).
pub const HIST_LO: f64 = 1e-4;

/// Fixed-bucket latency histogram (see [`HIST_BUCKETS`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FixedHistogram {
    counts: [u64; HIST_BUCKETS],
    /// Non-finite samples (NaN/±inf): counted here, never bucketed, so
    /// the underflow bucket only holds genuine sub-`HIST_LO` values.
    dropped: u64,
}

impl Default for FixedHistogram {
    fn default() -> Self {
        Self { counts: [0; HIST_BUCKETS], dropped: 0 }
    }
}

impl FixedHistogram {
    /// Record one sample (seconds). Non-finite samples land in the
    /// `dropped` tally instead of polluting the underflow bucket.
    #[inline]
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() {
            self.dropped += 1;
            return;
        }
        let idx = if x > HIST_LO {
            ((x / HIST_LO).log2() as usize).min(HIST_BUCKETS - 1)
        } else {
            0
        };
        self.counts[idx] += 1;
    }

    /// Bucket counts.
    pub fn counts(&self) -> &[u64; HIST_BUCKETS] {
        &self.counts
    }

    /// Total finite samples recorded (dropped samples excluded).
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Non-finite samples rejected by [`FixedHistogram::record`].
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Lower edge of bucket `i` in seconds (`0.0` for the underflow
    /// bucket's nominal edge).
    pub fn bucket_lo(i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            HIST_LO * (i as f64).exp2()
        }
    }

    /// Upper edge of bucket `i` in seconds. The open-ended last bucket
    /// reports one further octave — its interpolation ceiling.
    pub fn bucket_hi(i: usize) -> f64 {
        HIST_LO * ((i + 1) as f64).exp2()
    }

    /// Quantile `q` in `[0, 1]` via interpolation inside the covering
    /// log bucket: log-linear between the bucket edges (the natural
    /// scale for log-spaced buckets), linear from zero inside the
    /// underflow bucket (whose floor has no logarithm). `None` for an
    /// empty histogram or out-of-range `q`. Monotone in `q` and always
    /// within the covering bucket's `[lo, hi]` edges.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if !(0.0..=1.0).contains(&q) {
            return None;
        }
        let total = self.total();
        if total == 0 {
            return None;
        }
        let rank = (q * total as f64).ceil().clamp(1.0, total as f64) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= rank {
                let frac = (rank - cum) as f64 / c as f64;
                let lo = Self::bucket_lo(i);
                let hi = Self::bucket_hi(i);
                return Some(if i == 0 { hi * frac } else { lo * (hi / lo).powf(frac) });
            }
            cum += c;
        }
        None // unreachable: rank ≤ total
    }

    /// Element-wise sum merge.
    pub fn merge(&mut self, other: &FixedHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.dropped += other.dropped;
    }
}

/// A started (or inert) phase timer. Disabled registries hand out the
/// inert variant — no `Instant::now` call, no syscall, nothing to drop.
#[derive(Debug)]
pub struct PhaseClock(Option<std::time::Instant>);

impl PhaseClock {
    /// An inert clock (the disabled path).
    pub fn inert() -> Self {
        PhaseClock(None)
    }

    /// Seconds since the clock started, or `None` for an inert clock.
    pub fn elapsed_secs(&self) -> Option<f64> {
        self.0.map(|t| t.elapsed().as_secs_f64())
    }
}

/// The per-run (per-shard) metrics registry. Lock-free by construction:
/// each shard owns its registry exclusively and the sharded runner
/// merges them in shard-index order.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    enabled: bool,
    counters: [u64; COUNTER_COUNT],
    phases: [f64; PHASE_COUNT],
    /// Dispatches per policy class (index = class; empty without a
    /// policy).
    pub class_dispatches: Vec<u64>,
    /// Measured-job sojourn times.
    pub sojourn_hist: FixedHistogram,
    /// Measured-job waiting times.
    pub waiting_hist: FixedHistogram,
    /// Calendar event-loop span profile (empty unless the engine ran
    /// with profiling on).
    pub spans: SpanSet,
}

impl Metrics {
    /// An enabled registry.
    pub fn enabled() -> Self {
        Metrics { enabled: true, ..Metrics::default() }
    }

    /// A disabled registry: every recording method is a no-op and phase
    /// clocks never read the system clock.
    pub fn disabled() -> Self {
        Metrics::default()
    }

    /// Is this registry recording?
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Add `n` to a counter (no-op when disabled).
    #[inline]
    pub fn add(&mut self, c: Counter, n: u64) {
        if self.enabled {
            self.counters[c as usize] += n;
        }
    }

    /// Read a counter.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Fold an engine's raw tallies into the counters (no-op when
    /// disabled).
    pub fn absorb_tallies(&mut self, t: &Tallies) {
        if !self.enabled {
            return;
        }
        self.counters[Counter::EventsProcessed as usize] += t.events;
        self.counters[Counter::HeapPushes as usize] += t.heap_pushes;
        self.counters[Counter::HeapPops as usize] += t.heap_pops;
        self.counters[Counter::TasksDispatched as usize] += t.dispatched;
        self.counters[Counter::JobsCompleted as usize] += t.jobs;
        self.counters[Counter::Steals as usize] += t.steals;
        self.counters[Counter::Retries as usize] += t.retries;
        self.counters[Counter::Crashes as usize] += t.crashes;
        self.counters[Counter::SpeculativeLaunches as usize] += t.spec_launches;
        self.counters[Counter::ReplicaLosers as usize] += t.replica_losers;
        if t.class_dispatches.len() > self.class_dispatches.len() {
            self.class_dispatches.resize(t.class_dispatches.len(), 0);
        }
        for (a, b) in self.class_dispatches.iter_mut().zip(&t.class_dispatches) {
            *a += *b;
        }
    }

    /// Fold an engine's span set into the registry (no-op when
    /// disabled).
    pub fn absorb_spans(&mut self, s: &SpanSet) {
        if self.enabled {
            self.spans.merge(s);
        }
    }

    /// Record a measured job's sojourn time (no-op when disabled).
    #[inline]
    pub fn observe_sojourn(&mut self, x: f64) {
        if self.enabled {
            self.sojourn_hist.record(x);
        }
    }

    /// Record a measured job's waiting time (no-op when disabled).
    #[inline]
    pub fn observe_waiting(&mut self, x: f64) {
        if self.enabled {
            self.waiting_hist.record(x);
        }
    }

    /// Start a phase clock. Disabled registries return an inert clock —
    /// **no** `Instant::now` is taken on the no-op path.
    #[inline]
    pub fn phase_start(&self) -> PhaseClock {
        if self.enabled {
            PhaseClock(Some(std::time::Instant::now()))
        } else {
            PhaseClock::inert()
        }
    }

    /// Close a phase clock into `phase` (no-op for inert clocks).
    #[inline]
    pub fn phase_add(&mut self, phase: Phase, clock: PhaseClock) {
        if let Some(secs) = clock.elapsed_secs() {
            self.phases[phase as usize] += secs;
        }
    }

    /// Add raw seconds to a phase (no-op when disabled).
    pub fn phase_add_secs(&mut self, phase: Phase, secs: f64) {
        if self.enabled {
            self.phases[phase as usize] += secs;
        }
    }

    /// Seconds accumulated in `phase`.
    pub fn phase_seconds(&self, phase: Phase) -> f64 {
        self.phases[phase as usize]
    }

    /// Phase seconds in [`Phase::ALL`] order.
    pub fn phases_array(&self) -> [f64; PHASE_COUNT] {
        self.phases
    }

    /// Merge another registry (shard-index order in the sharded runner).
    /// Counters, phases and histograms sum; an enabled side wins.
    pub fn merge(&mut self, other: &Metrics) {
        self.enabled |= other.enabled;
        for (a, b) in self.counters.iter_mut().zip(&other.counters) {
            *a += *b;
        }
        for (a, b) in self.phases.iter_mut().zip(&other.phases) {
            *a += *b;
        }
        if other.class_dispatches.len() > self.class_dispatches.len() {
            self.class_dispatches.resize(other.class_dispatches.len(), 0);
        }
        for (a, b) in self.class_dispatches.iter_mut().zip(&other.class_dispatches) {
            *a += *b;
        }
        self.sojourn_hist.merge(&other.sojourn_hist);
        self.waiting_hist.merge(&other.waiting_hist);
        self.spans.merge(&other.spans);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_records_nothing() {
        let mut m = Metrics::disabled();
        m.add(Counter::Steals, 7);
        m.observe_sojourn(1.0);
        let c = m.phase_start();
        assert!(c.elapsed_secs().is_none());
        m.phase_add(Phase::Dispatch, c);
        m.phase_add_secs(Phase::Io, 3.0);
        assert_eq!(m.counter(Counter::Steals), 0);
        assert_eq!(m.sojourn_hist.total(), 0);
        assert_eq!(m.phase_seconds(Phase::Dispatch), 0.0);
        assert_eq!(m.phase_seconds(Phase::Io), 0.0);
    }

    #[test]
    fn tallies_fold_into_counters() {
        let mut t = Tallies { dispatched: 10, retries: 2, ..Tallies::default() };
        t.class_dispatch(1);
        t.class_dispatch(1);
        let mut m = Metrics::enabled();
        m.absorb_tallies(&t);
        assert_eq!(m.counter(Counter::TasksDispatched), 10);
        assert_eq!(m.counter(Counter::Retries), 2);
        assert_eq!(m.class_dispatches, vec![0, 2]);
    }

    #[test]
    fn merge_sums_and_enables() {
        let mut a = Metrics::disabled();
        let mut b = Metrics::enabled();
        b.add(Counter::HeapPushes, 3);
        b.observe_waiting(0.5);
        b.phase_add_secs(Phase::Setup, 1.5);
        a.merge(&b);
        assert!(a.is_enabled());
        assert_eq!(a.counter(Counter::HeapPushes), 3);
        assert_eq!(a.waiting_hist.total(), 1);
        assert_eq!(a.phase_seconds(Phase::Setup), 1.5);
    }

    #[test]
    fn histogram_buckets_and_merge() {
        let mut h = FixedHistogram::default();
        h.record(0.0); // underflow
        h.record(HIST_LO * 3.0); // bucket 1
        h.record(f64::INFINITY); // non-finite: dropped, not bucketed
        h.record(f64::NAN); // likewise
        assert_eq!(h.total(), 2);
        assert_eq!(h.dropped(), 2);
        assert_eq!(h.counts()[0], 1);
        assert_eq!(h.counts()[1], 1);
        assert_eq!(h.counts()[HIST_BUCKETS - 1], 0);
        let mut g = h.clone();
        g.merge(&h);
        assert_eq!(g.total(), 4);
        assert_eq!(g.dropped(), 4);
        assert!(FixedHistogram::bucket_lo(1) > 0.0);
        assert_eq!(FixedHistogram::bucket_hi(0), FixedHistogram::bucket_lo(1));
    }

    #[test]
    fn percentile_interpolates_within_bucket_edges() {
        let mut h = FixedHistogram::default();
        assert_eq!(h.percentile(0.5), None); // empty
        // 10 samples, all in bucket 3.
        for _ in 0..10 {
            h.record(HIST_LO * 10.0);
        }
        assert_eq!(h.percentile(-0.1), None);
        assert_eq!(h.percentile(1.1), None);
        let (lo, hi) = (FixedHistogram::bucket_lo(3), FixedHistogram::bucket_hi(3));
        for q in [0.01, 0.25, 0.5, 0.9, 1.0] {
            let p = h.percentile(q).unwrap();
            assert!(p >= lo && p <= hi, "q={q}: {p} outside [{lo}, {hi}]");
        }
        // q = 1 lands exactly on the bucket's upper edge.
        assert_eq!(h.percentile(1.0).unwrap(), hi);
    }

    #[test]
    fn percentile_is_monotone_in_q() {
        let mut h = FixedHistogram::default();
        // Spread over several buckets, underflow included.
        for x in [0.0, HIST_LO * 0.5, HIST_LO * 3.0, 0.01, 0.02, 0.1, 0.5, 2.0, 8.0] {
            h.record(x);
        }
        let mut prev = 0.0;
        for i in 0..=100 {
            let q = i as f64 / 100.0;
            let p = h.percentile(q).unwrap();
            assert!(p >= prev, "q={q}: {p} < {prev}");
            prev = p;
        }
    }

    #[test]
    fn span_tree_keys_and_rendering() {
        // Keys unique; every non-root span chains up to the root.
        let keys: std::collections::BTreeSet<_> = Span::ALL.iter().map(|s| s.key()).collect();
        assert_eq!(keys.len(), SPAN_COUNT);
        for s in Span::ALL {
            let mut cur = s;
            let mut hops = 0;
            while let Some(p) = cur.parent() {
                cur = p;
                hops += 1;
                assert!(hops <= SPAN_COUNT, "parent cycle at {}", s.key());
            }
            assert_eq!(cur, Span::EventLoop);
        }

        let mut set = SpanSet::default();
        assert!(set.is_empty());
        assert_eq!(set.render_tree(), "");
        set.add(Span::EventLoop, 10.0);
        set.add(Span::Arrival, 4.0);
        set.add(Span::ArrivalSampling, 1.0);
        set.add(Span::Dispatch, 2.0);
        // Self time excludes timed children.
        assert_eq!(set.self_seconds(Span::Arrival), 3.0);
        assert_eq!(set.self_seconds(Span::EventLoop), 4.0);
        let tree = set.render_tree();
        assert!(tree.contains("event_loop"), "{tree}");
        assert!(tree.contains("sampling"), "{tree}");
        let folded = set.render_folded();
        assert!(folded.contains("event_loop;arrival;sampling 1000000\n"), "{folded}");
        assert!(folded.contains("event_loop;arrival 3000000\n"), "{folded}");
        assert!(folded.contains("event_loop 4000000\n"), "{folded}");
        // Merge sums both time and enter counts.
        let mut other = SpanSet::default();
        other.add(Span::Arrival, 1.0);
        set.merge(&other);
        assert_eq!(set.seconds(Span::Arrival), 5.0);
        assert_eq!(set.count(Span::Arrival), 2);
    }

    #[test]
    fn counter_and_phase_keys_are_unique() {
        let keys: std::collections::BTreeSet<_> =
            Counter::ALL.iter().map(|c| c.key()).collect();
        assert_eq!(keys.len(), COUNTER_COUNT);
        let pkeys: std::collections::BTreeSet<_> =
            Phase::ALL.iter().map(|p| p.key()).collect();
        assert_eq!(pkeys.len(), PHASE_COUNT);
    }

    #[test]
    fn tallies_absorb_resizes_classes() {
        let mut a = Tallies::default();
        let mut b = Tallies::default();
        b.class_dispatch(2);
        a.absorb(&b);
        assert_eq!(a.class_dispatches, vec![0, 0, 1]);
    }
}
