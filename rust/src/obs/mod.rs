//! Observability — engine-wide metrics and profiling with zero
//! determinism cost.
//!
//! Two layers, split by cost model:
//!
//! * [`Tallies`] — raw, always-on `u64` event counts owned by the
//!   engines themselves (heap ops, dispatches, steals, retries, crashes,
//!   speculative launches, replica losers, sampler draws). A plain
//!   unconditional integer increment is cheaper than the branch that
//!   would gate it, so these run unconditionally and are harvested once
//!   per run.
//! * [`Metrics`] — the gated registry (counters, phase wall-times,
//!   fixed-bucket latency histograms, gauges). Every recording method is
//!   `#[inline]` and early-returns when the registry is disabled, so the
//!   disabled path compiles down to a predicted-not-taken branch on a
//!   local bool; phase clocks take **no** `Instant` reading when
//!   disabled ([`PhaseClock`] holds `None`).
//!
//! The hard invariant: nothing in this module consumes RNG draws or
//! feeds back into simulation state, so results are bitwise identical
//! with metrics on vs. off (test-enforced in `rust/tests/obs_metrics.rs`
//! the same way `TT_NO_FAST_EXP` and thread-count invariance are).
//! Registries are per-shard (each shard owns its own `Metrics`) and
//! merge deterministically in shard-index order alongside the Welford/P²
//! merges — there are no locks because there is no sharing.

pub mod progress;
pub mod report;

/// Counters tracked by the registry. Enum-indexed into a fixed array,
/// so recording is a bounds-check-free store and the report always
/// emits every key (CI asserts on their presence).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Calendar engine: events popped off the event heap.
    EventsProcessed,
    /// Server-heap / event-heap pushes.
    HeapPushes,
    /// Server-heap / event-heap pops.
    HeapPops,
    /// Logical tasks handed to a server (one per task, not per attempt).
    TasksDispatched,
    /// Jobs run to completion (warmup included — the engines cannot
    /// tell a warmup job from a measured one).
    JobsCompleted,
    /// Work-stealing: tasks run on a non-affinity server.
    Steals,
    /// Fault injection: failed attempts that re-entered the queue.
    Retries,
    /// Fault injection: worker crash events consumed.
    Crashes,
    /// Fault injection: speculative backup copies actually launched.
    SpeculativeLaunches,
    /// Redundancy: replica copies cancelled after losing the
    /// first-finish-wins race (having occupied a server).
    ReplicaLosers,
    /// Batched sampler calls (`Dist::draw_batch` via
    /// `Workload::next_executions`).
    BatchDraws,
    /// Interarrival draws.
    ArrivalDraws,
    /// Task execution-time draws (batched draws count per element).
    ExecutionDraws,
}

/// Number of [`Counter`] variants.
pub const COUNTER_COUNT: usize = 13;

impl Counter {
    /// Every counter, in report order.
    pub const ALL: [Counter; COUNTER_COUNT] = [
        Counter::EventsProcessed,
        Counter::HeapPushes,
        Counter::HeapPops,
        Counter::TasksDispatched,
        Counter::JobsCompleted,
        Counter::Steals,
        Counter::Retries,
        Counter::Crashes,
        Counter::SpeculativeLaunches,
        Counter::ReplicaLosers,
        Counter::BatchDraws,
        Counter::ArrivalDraws,
        Counter::ExecutionDraws,
    ];

    /// Stable snake-case key used in `RUN_METRICS.json`.
    pub fn key(self) -> &'static str {
        match self {
            Counter::EventsProcessed => "events_processed",
            Counter::HeapPushes => "heap_pushes",
            Counter::HeapPops => "heap_pops",
            Counter::TasksDispatched => "tasks_dispatched",
            Counter::JobsCompleted => "jobs_completed",
            Counter::Steals => "steals",
            Counter::Retries => "retries",
            Counter::Crashes => "crashes",
            Counter::SpeculativeLaunches => "speculative_launches",
            Counter::ReplicaLosers => "replica_losers",
            Counter::BatchDraws => "batch_draws",
            Counter::ArrivalDraws => "arrival_draws",
            Counter::ExecutionDraws => "execution_draws",
        }
    }
}

/// Wall-clock phases profiled around the engines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Config parsing, workload/model construction.
    Setup,
    /// Batched sample drawing (calendar stage pre-draws).
    Sampling,
    /// The main simulation / event loop.
    Dispatch,
    /// Cross-shard statistics merging.
    StatsMerge,
    /// File I/O (reports, traces, CSVs).
    Io,
}

/// Number of [`Phase`] variants.
pub const PHASE_COUNT: usize = 5;

impl Phase {
    /// Every phase, in report order.
    pub const ALL: [Phase; PHASE_COUNT] =
        [Phase::Setup, Phase::Sampling, Phase::Dispatch, Phase::StatsMerge, Phase::Io];

    /// Stable snake-case key used in `RUN_METRICS.json`.
    pub fn key(self) -> &'static str {
        match self {
            Phase::Setup => "setup",
            Phase::Sampling => "sampling",
            Phase::Dispatch => "dispatch",
            Phase::StatsMerge => "stats_merge",
            Phase::Io => "io",
        }
    }
}

/// Raw always-on engine tallies (see module docs). Engines own one (or
/// expose per-component counts) and the runner folds them into the
/// registry at end of run via [`Metrics::absorb_tallies`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Tallies {
    /// Calendar events processed.
    pub events: u64,
    /// Heap pushes (server heap or event heap).
    pub heap_pushes: u64,
    /// Heap pops.
    pub heap_pops: u64,
    /// Logical tasks dispatched.
    pub dispatched: u64,
    /// Jobs completed (warmup included).
    pub jobs: u64,
    /// Work-stealing steals.
    pub steals: u64,
    /// Failed-attempt retries.
    pub retries: u64,
    /// Worker crashes consumed.
    pub crashes: u64,
    /// Speculative backups launched.
    pub spec_launches: u64,
    /// Cancelled first-finish-wins replicas.
    pub replica_losers: u64,
    /// Dispatches per policy class (index = class).
    pub class_dispatches: Vec<u64>,
}

impl Tallies {
    /// Count one dispatch of a task routed to `class`.
    #[inline]
    pub fn class_dispatch(&mut self, class: usize) {
        if class >= self.class_dispatches.len() {
            self.class_dispatches.resize(class + 1, 0);
        }
        self.class_dispatches[class] += 1;
    }

    /// Fold another tally set into this one.
    pub fn absorb(&mut self, other: &Tallies) {
        self.events += other.events;
        self.heap_pushes += other.heap_pushes;
        self.heap_pops += other.heap_pops;
        self.dispatched += other.dispatched;
        self.jobs += other.jobs;
        self.steals += other.steals;
        self.retries += other.retries;
        self.crashes += other.crashes;
        self.spec_launches += other.spec_launches;
        self.replica_losers += other.replica_losers;
        if other.class_dispatches.len() > self.class_dispatches.len() {
            self.class_dispatches.resize(other.class_dispatches.len(), 0);
        }
        for (a, b) in self.class_dispatches.iter_mut().zip(&other.class_dispatches) {
            *a += *b;
        }
    }
}

/// Fixed-bucket log-spaced latency histogram: bucket `i` covers
/// `[HIST_LO * 2^i, HIST_LO * 2^(i+1))` seconds; the first bucket also
/// absorbs everything below `HIST_LO`, the last everything above.
/// Fixed buckets make cross-shard merging a plain element-wise sum —
/// no interpolation, bitwise deterministic in merge order.
pub const HIST_BUCKETS: usize = 32;

/// Lower edge of the first histogram bucket (seconds).
pub const HIST_LO: f64 = 1e-4;

/// Fixed-bucket latency histogram (see [`HIST_BUCKETS`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FixedHistogram {
    counts: [u64; HIST_BUCKETS],
}

impl Default for FixedHistogram {
    fn default() -> Self {
        Self { counts: [0; HIST_BUCKETS] }
    }
}

impl FixedHistogram {
    /// Record one sample (seconds).
    #[inline]
    pub fn record(&mut self, x: f64) {
        let idx = if x.is_finite() && x > HIST_LO {
            ((x / HIST_LO).log2() as usize).min(HIST_BUCKETS - 1)
        } else {
            0
        };
        self.counts[idx] += 1;
    }

    /// Bucket counts.
    pub fn counts(&self) -> &[u64; HIST_BUCKETS] {
        &self.counts
    }

    /// Total samples recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Lower edge of bucket `i` in seconds (`0.0` for the underflow
    /// bucket's nominal edge).
    pub fn bucket_lo(i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            HIST_LO * (i as f64).exp2()
        }
    }

    /// Element-wise sum merge.
    pub fn merge(&mut self, other: &FixedHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
    }
}

/// A started (or inert) phase timer. Disabled registries hand out the
/// inert variant — no `Instant::now` call, no syscall, nothing to drop.
#[derive(Debug)]
pub struct PhaseClock(Option<std::time::Instant>);

impl PhaseClock {
    /// An inert clock (the disabled path).
    pub fn inert() -> Self {
        PhaseClock(None)
    }

    /// Seconds since the clock started, or `None` for an inert clock.
    pub fn elapsed_secs(&self) -> Option<f64> {
        self.0.map(|t| t.elapsed().as_secs_f64())
    }
}

/// The per-run (per-shard) metrics registry. Lock-free by construction:
/// each shard owns its registry exclusively and the sharded runner
/// merges them in shard-index order.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    enabled: bool,
    counters: [u64; COUNTER_COUNT],
    phases: [f64; PHASE_COUNT],
    /// Dispatches per policy class (index = class; empty without a
    /// policy).
    pub class_dispatches: Vec<u64>,
    /// Measured-job sojourn times.
    pub sojourn_hist: FixedHistogram,
    /// Measured-job waiting times.
    pub waiting_hist: FixedHistogram,
}

impl Metrics {
    /// An enabled registry.
    pub fn enabled() -> Self {
        Metrics { enabled: true, ..Metrics::default() }
    }

    /// A disabled registry: every recording method is a no-op and phase
    /// clocks never read the system clock.
    pub fn disabled() -> Self {
        Metrics::default()
    }

    /// Is this registry recording?
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Add `n` to a counter (no-op when disabled).
    #[inline]
    pub fn add(&mut self, c: Counter, n: u64) {
        if self.enabled {
            self.counters[c as usize] += n;
        }
    }

    /// Read a counter.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Fold an engine's raw tallies into the counters (no-op when
    /// disabled).
    pub fn absorb_tallies(&mut self, t: &Tallies) {
        if !self.enabled {
            return;
        }
        self.counters[Counter::EventsProcessed as usize] += t.events;
        self.counters[Counter::HeapPushes as usize] += t.heap_pushes;
        self.counters[Counter::HeapPops as usize] += t.heap_pops;
        self.counters[Counter::TasksDispatched as usize] += t.dispatched;
        self.counters[Counter::JobsCompleted as usize] += t.jobs;
        self.counters[Counter::Steals as usize] += t.steals;
        self.counters[Counter::Retries as usize] += t.retries;
        self.counters[Counter::Crashes as usize] += t.crashes;
        self.counters[Counter::SpeculativeLaunches as usize] += t.spec_launches;
        self.counters[Counter::ReplicaLosers as usize] += t.replica_losers;
        if t.class_dispatches.len() > self.class_dispatches.len() {
            self.class_dispatches.resize(t.class_dispatches.len(), 0);
        }
        for (a, b) in self.class_dispatches.iter_mut().zip(&t.class_dispatches) {
            *a += *b;
        }
    }

    /// Record a measured job's sojourn time (no-op when disabled).
    #[inline]
    pub fn observe_sojourn(&mut self, x: f64) {
        if self.enabled {
            self.sojourn_hist.record(x);
        }
    }

    /// Record a measured job's waiting time (no-op when disabled).
    #[inline]
    pub fn observe_waiting(&mut self, x: f64) {
        if self.enabled {
            self.waiting_hist.record(x);
        }
    }

    /// Start a phase clock. Disabled registries return an inert clock —
    /// **no** `Instant::now` is taken on the no-op path.
    #[inline]
    pub fn phase_start(&self) -> PhaseClock {
        if self.enabled {
            PhaseClock(Some(std::time::Instant::now()))
        } else {
            PhaseClock::inert()
        }
    }

    /// Close a phase clock into `phase` (no-op for inert clocks).
    #[inline]
    pub fn phase_add(&mut self, phase: Phase, clock: PhaseClock) {
        if let Some(secs) = clock.elapsed_secs() {
            self.phases[phase as usize] += secs;
        }
    }

    /// Add raw seconds to a phase (no-op when disabled).
    pub fn phase_add_secs(&mut self, phase: Phase, secs: f64) {
        if self.enabled {
            self.phases[phase as usize] += secs;
        }
    }

    /// Seconds accumulated in `phase`.
    pub fn phase_seconds(&self, phase: Phase) -> f64 {
        self.phases[phase as usize]
    }

    /// Phase seconds in [`Phase::ALL`] order.
    pub fn phases_array(&self) -> [f64; PHASE_COUNT] {
        self.phases
    }

    /// Merge another registry (shard-index order in the sharded runner).
    /// Counters, phases and histograms sum; an enabled side wins.
    pub fn merge(&mut self, other: &Metrics) {
        self.enabled |= other.enabled;
        for (a, b) in self.counters.iter_mut().zip(&other.counters) {
            *a += *b;
        }
        for (a, b) in self.phases.iter_mut().zip(&other.phases) {
            *a += *b;
        }
        if other.class_dispatches.len() > self.class_dispatches.len() {
            self.class_dispatches.resize(other.class_dispatches.len(), 0);
        }
        for (a, b) in self.class_dispatches.iter_mut().zip(&other.class_dispatches) {
            *a += *b;
        }
        self.sojourn_hist.merge(&other.sojourn_hist);
        self.waiting_hist.merge(&other.waiting_hist);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_records_nothing() {
        let mut m = Metrics::disabled();
        m.add(Counter::Steals, 7);
        m.observe_sojourn(1.0);
        let c = m.phase_start();
        assert!(c.elapsed_secs().is_none());
        m.phase_add(Phase::Dispatch, c);
        m.phase_add_secs(Phase::Io, 3.0);
        assert_eq!(m.counter(Counter::Steals), 0);
        assert_eq!(m.sojourn_hist.total(), 0);
        assert_eq!(m.phase_seconds(Phase::Dispatch), 0.0);
        assert_eq!(m.phase_seconds(Phase::Io), 0.0);
    }

    #[test]
    fn tallies_fold_into_counters() {
        let mut t = Tallies { dispatched: 10, retries: 2, ..Tallies::default() };
        t.class_dispatch(1);
        t.class_dispatch(1);
        let mut m = Metrics::enabled();
        m.absorb_tallies(&t);
        assert_eq!(m.counter(Counter::TasksDispatched), 10);
        assert_eq!(m.counter(Counter::Retries), 2);
        assert_eq!(m.class_dispatches, vec![0, 2]);
    }

    #[test]
    fn merge_sums_and_enables() {
        let mut a = Metrics::disabled();
        let mut b = Metrics::enabled();
        b.add(Counter::HeapPushes, 3);
        b.observe_waiting(0.5);
        b.phase_add_secs(Phase::Setup, 1.5);
        a.merge(&b);
        assert!(a.is_enabled());
        assert_eq!(a.counter(Counter::HeapPushes), 3);
        assert_eq!(a.waiting_hist.total(), 1);
        assert_eq!(a.phase_seconds(Phase::Setup), 1.5);
    }

    #[test]
    fn histogram_buckets_and_merge() {
        let mut h = FixedHistogram::default();
        h.record(0.0); // underflow
        h.record(HIST_LO * 3.0); // bucket 1
        h.record(f64::INFINITY); // clamps to last
        assert_eq!(h.total(), 3);
        assert_eq!(h.counts()[0], 1);
        assert_eq!(h.counts()[1], 1);
        assert_eq!(h.counts()[HIST_BUCKETS - 1], 1);
        let mut g = h.clone();
        g.merge(&h);
        assert_eq!(g.total(), 6);
        assert!(FixedHistogram::bucket_lo(1) > 0.0);
    }

    #[test]
    fn counter_and_phase_keys_are_unique() {
        let keys: std::collections::BTreeSet<_> =
            Counter::ALL.iter().map(|c| c.key()).collect();
        assert_eq!(keys.len(), COUNTER_COUNT);
        let pkeys: std::collections::BTreeSet<_> =
            Phase::ALL.iter().map(|p| p.key()).collect();
        assert_eq!(pkeys.len(), PHASE_COUNT);
    }

    #[test]
    fn tallies_absorb_resizes_classes() {
        let mut a = Tallies::default();
        let mut b = Tallies::default();
        b.class_dispatch(2);
        a.absorb(&b);
        assert_eq!(a.class_dispatches, vec![0, 0, 1]);
    }
}
