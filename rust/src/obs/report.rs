//! `RUN_METRICS.json` — the schema-v2 run report shared by every
//! command surface (`simulate`, `approx` sweeps, `bench`, `trace`,
//! `emulate`, `profile`): counters, phase wall-times, throughput, a
//! peak-RSS estimate, and (new in v2) histogram percentiles, the
//! calendar span profile, dropped-sample tallies, and per-sweep-point
//! registries. Hand-rolled writer *and* parser (the offline registry
//! has no serde); the parser exists so reports can be
//! round-trip-tested, consumed by the CI smoke job, and diffed by
//! `profile --diff` (see [`diff_rows`] / [`check_gates`]).
//!
//! Compatibility contract (the BENCH v1→v2 precedent): every v2
//! addition is a **trailing** top-level key, so v1 readers that scan
//! for their keys keep working on v2 files, and this parser treats the
//! v2 keys as optional, so v1 files still parse (with empty maps).

use super::{Counter, FixedHistogram, Metrics, Phase, Span, HIST_BUCKETS};
use std::collections::BTreeMap;

/// Report schema version.
pub const SCHEMA_VERSION: u64 = 2;

/// Percentiles summarized in the report, as (quantile, key-suffix).
pub const PERCENTILES: [(f64, &str); 4] =
    [(0.5, "p50"), (0.9, "p90"), (0.99, "p99"), (0.999, "p999")];

/// Peak resident set size of this process in bytes, from
/// `/proc/self/status` (`VmHWM`). Returns 0 where the file or field is
/// unavailable (non-Linux) — the report field is an estimate, not a
/// guarantee.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

fn render_hist(h: &FixedHistogram) -> String {
    let counts: Vec<String> = h.counts().iter().map(|c| c.to_string()).collect();
    format!("[{}]", counts.join(", "))
}

/// One sweep point's summary row embedded in a sweep/bench report
/// (schema v2 `sweep_points`): the per-k registry slice that lets
/// downstream consumers read per-point cost without a separate
/// profiled run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepPointRecord {
    /// Sweep label (the swept k, or another axis value).
    pub label: f64,
    /// Measured jobs at this point.
    pub jobs: u64,
    /// Simulated jobs per wall second at this point.
    pub jobs_per_sec: f64,
    /// Calendar events processed (0 on the recursion engine).
    pub events: u64,
    /// Logical tasks dispatched.
    pub tasks_dispatched: u64,
    /// Seconds in the sampling phase.
    pub sampling_seconds: f64,
    /// Seconds in the dispatch phase.
    pub dispatch_seconds: f64,
}

impl SweepPointRecord {
    /// Build from one point's registry and throughput.
    pub fn from_metrics(label: f64, jobs: u64, jobs_per_sec: f64, m: &Metrics) -> Self {
        SweepPointRecord {
            label,
            jobs,
            jobs_per_sec,
            events: m.counter(Counter::EventsProcessed),
            tasks_dispatched: m.counter(Counter::TasksDispatched),
            sampling_seconds: m.phase_seconds(Phase::Sampling),
            dispatch_seconds: m.phase_seconds(Phase::Dispatch),
        }
    }
}

/// Serialize a registry into the schema-v2 report.
pub fn render(source: &str, m: &Metrics, jobs: u64, wall_seconds: f64) -> String {
    render_with_points(source, m, jobs, wall_seconds, &[])
}

/// Serialize a registry plus per-sweep-point rows. With an empty
/// `points` slice the `sweep_points` key is omitted entirely.
pub fn render_with_points(
    source: &str,
    m: &Metrics,
    jobs: u64,
    wall_seconds: f64,
    points: &[SweepPointRecord],
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema_version\": {SCHEMA_VERSION},\n"));
    s.push_str(&format!("  \"source\": \"{source}\",\n"));
    s.push_str("  \"counters\": {\n");
    for (i, c) in Counter::ALL.iter().enumerate() {
        let sep = if i + 1 < Counter::ALL.len() { "," } else { "" };
        s.push_str(&format!("    \"{}\": {}{sep}\n", c.key(), m.counter(*c)));
    }
    s.push_str("  },\n");
    let classes: Vec<String> = m.class_dispatches.iter().map(|c| c.to_string()).collect();
    s.push_str(&format!("  \"class_dispatches\": [{}],\n", classes.join(", ")));
    s.push_str("  \"phases\": {\n");
    for (i, p) in Phase::ALL.iter().enumerate() {
        let sep = if i + 1 < Phase::ALL.len() { "," } else { "" };
        s.push_str(&format!("    \"{}\": {}{sep}\n", p.key(), m.phase_seconds(*p)));
    }
    s.push_str("  },\n");
    let rate = if wall_seconds > 0.0 { jobs as f64 / wall_seconds } else { 0.0 };
    s.push_str("  \"throughput\": {\n");
    s.push_str(&format!("    \"jobs\": {jobs},\n"));
    s.push_str(&format!("    \"wall_seconds\": {wall_seconds},\n"));
    s.push_str(&format!("    \"jobs_per_sec\": {rate}\n"));
    s.push_str("  },\n");
    s.push_str(&format!("  \"peak_rss_bytes\": {},\n", peak_rss_bytes()));
    s.push_str("  \"histograms\": {\n");
    s.push_str(&format!(
        "    \"sojourn_seconds\": {},\n",
        render_hist(&m.sojourn_hist)
    ));
    s.push_str(&format!(
        "    \"waiting_seconds\": {}\n",
        render_hist(&m.waiting_hist)
    ));
    s.push_str("  },\n");
    // Schema-v2 additions: trailing keys only, so v1 readers that scan
    // for their own keys stay compatible.
    s.push_str("  \"percentiles\": {\n");
    for (hist, prefix) in [(&m.sojourn_hist, "sojourn"), (&m.waiting_hist, "waiting")] {
        for (i, (q, suffix)) in PERCENTILES.iter().enumerate() {
            let last = prefix == "waiting" && i + 1 == PERCENTILES.len();
            let sep = if last { "" } else { "," };
            let v = hist.percentile(*q).unwrap_or(0.0);
            s.push_str(&format!("    \"{prefix}_{suffix}\": {v}{sep}\n"));
        }
    }
    s.push_str("  },\n");
    s.push_str("  \"span_seconds\": {\n");
    for (i, sp) in Span::ALL.iter().enumerate() {
        let sep = if i + 1 < Span::ALL.len() { "," } else { "" };
        s.push_str(&format!("    \"{}\": {}{sep}\n", sp.key(), m.spans.seconds(*sp)));
    }
    s.push_str("  },\n");
    s.push_str("  \"span_counts\": {\n");
    for (i, sp) in Span::ALL.iter().enumerate() {
        let sep = if i + 1 < Span::ALL.len() { "," } else { "" };
        s.push_str(&format!("    \"{}\": {}{sep}\n", sp.key(), m.spans.count(*sp)));
    }
    s.push_str("  },\n");
    s.push_str("  \"dropped_samples\": {\n");
    s.push_str(&format!("    \"sojourn_seconds\": {},\n", m.sojourn_hist.dropped()));
    s.push_str(&format!("    \"waiting_seconds\": {}\n", m.waiting_hist.dropped()));
    if points.is_empty() {
        s.push_str("  }\n}\n");
        return s;
    }
    s.push_str("  },\n");
    s.push_str("  \"sweep_points\": [\n");
    for (i, p) in points.iter().enumerate() {
        let sep = if i + 1 < points.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"label\": {}, \"jobs\": {}, \"jobs_per_sec\": {}, \
             \"events\": {}, \"tasks_dispatched\": {}, \
             \"sampling_seconds\": {}, \"dispatch_seconds\": {}}}{sep}\n",
            p.label,
            p.jobs,
            p.jobs_per_sec,
            p.events,
            p.tasks_dispatched,
            p.sampling_seconds,
            p.dispatch_seconds
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write the report to `path`.
pub fn write_file(
    path: &str,
    source: &str,
    m: &Metrics,
    jobs: u64,
    wall_seconds: f64,
) -> Result<(), String> {
    std::fs::write(path, render(source, m, jobs, wall_seconds))
        .map_err(|e| format!("{path}: {e}"))
}

/// Write a report with per-sweep-point rows to `path`.
pub fn write_file_with_points(
    path: &str,
    source: &str,
    m: &Metrics,
    jobs: u64,
    wall_seconds: f64,
    points: &[SweepPointRecord],
) -> Result<(), String> {
    std::fs::write(path, render_with_points(source, m, jobs, wall_seconds, points))
        .map_err(|e| format!("{path}: {e}"))
}

/// A parsed `RUN_METRICS.json` document.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParsedReport {
    /// `schema_version`.
    pub schema_version: u64,
    /// Producing command (`simulate`, `sweep`, `bench`, ...).
    pub source: String,
    /// Counter key → value.
    pub counters: BTreeMap<String, u64>,
    /// Dispatches per policy class.
    pub class_dispatches: Vec<u64>,
    /// Phase key → wall seconds.
    pub phases: BTreeMap<String, f64>,
    /// Measured jobs.
    pub jobs: u64,
    /// Wall seconds.
    pub wall_seconds: f64,
    /// jobs / wall_seconds.
    pub jobs_per_sec: f64,
    /// Peak RSS estimate.
    pub peak_rss_bytes: u64,
    /// Sojourn histogram bucket counts (empty if absent).
    pub sojourn_hist: Vec<u64>,
    /// Waiting histogram bucket counts (empty if absent).
    pub waiting_hist: Vec<u64>,
    /// Percentile key → seconds (empty for schema-v1 files).
    pub percentiles: BTreeMap<String, f64>,
    /// Span path key → total seconds (empty for v1 files).
    pub span_seconds: BTreeMap<String, f64>,
    /// Span path key → enter count (empty for v1 files).
    pub span_counts: BTreeMap<String, u64>,
    /// Histogram name → non-finite samples dropped (empty for v1).
    pub dropped_samples: BTreeMap<String, u64>,
    /// Per-sweep-point rows (empty unless a sweep report).
    pub sweep_points: Vec<SweepPointRecord>,
}

/// Slice out the object body following `"key": {`, assuming no nested
/// braces inside (true for every object this schema emits).
fn object_body<'a>(compact: &'a str, key: &str) -> Result<&'a str, String> {
    let needle = format!("\"{key}\":{{");
    let at = compact
        .find(&needle)
        .ok_or_else(|| format!("RUN_METRICS.json: missing \"{key}\" object"))?;
    let start = at + needle.len();
    let end = compact[start..]
        .find('}')
        .ok_or_else(|| format!("RUN_METRICS.json: unterminated \"{key}\" object"))?;
    Ok(&compact[start..start + end])
}

/// Slice out the array body following `"key": [`.
fn array_body<'a>(compact: &'a str, key: &str) -> Result<&'a str, String> {
    let needle = format!("\"{key}\":[");
    let at = compact
        .find(&needle)
        .ok_or_else(|| format!("RUN_METRICS.json: missing \"{key}\" array"))?;
    let start = at + needle.len();
    let end = compact[start..]
        .find(']')
        .ok_or_else(|| format!("RUN_METRICS.json: unterminated \"{key}\" array"))?;
    Ok(&compact[start..start + end])
}

fn parse_u64_array(body: &str) -> Result<Vec<u64>, String> {
    if body.trim().is_empty() {
        return Ok(Vec::new());
    }
    body.split(',')
        .map(|t| t.trim().parse::<u64>().map_err(|e| format!("RUN_METRICS.json: {e}")))
        .collect()
}

/// `"k":v` pairs of a flat object body.
fn parse_pairs(body: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    for entry in body.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (k, v) = entry
            .split_once(':')
            .ok_or_else(|| format!("RUN_METRICS.json: bad entry {entry:?}"))?;
        out.push((k.trim().trim_matches('"').to_string(), v.trim().to_string()));
    }
    Ok(out)
}

fn scalar(compact: &str, key: &str) -> Result<String, String> {
    let needle = format!("\"{key}\":");
    let at = compact
        .find(&needle)
        .ok_or_else(|| format!("RUN_METRICS.json: missing \"{key}\""))?;
    let rest = &compact[at + needle.len()..];
    let end = rest
        .find(|c| matches!(c, ',' | '}' | ']'))
        .unwrap_or(rest.len());
    Ok(rest[..end].trim_matches('"').to_string())
}

/// Parse a schema-v1 report. Tolerant of whitespace/pretty-printing;
/// unknown top-level keys are ignored.
pub fn parse(text: &str) -> Result<ParsedReport, String> {
    // Keys and numeric values in this schema contain no whitespace, so a
    // whitespace strip yields a canonical compact form to scan.
    let compact: String = text.chars().filter(|c| !c.is_whitespace()).collect();
    let mut rep = ParsedReport {
        schema_version: scalar(&compact, "schema_version")?
            .parse()
            .map_err(|e| format!("RUN_METRICS.json: schema_version: {e}"))?,
        source: scalar(&compact, "source")?,
        ..ParsedReport::default()
    };
    for (k, v) in parse_pairs(object_body(&compact, "counters")?)? {
        rep.counters
            .insert(k, v.parse().map_err(|e| format!("RUN_METRICS.json: counters: {e}"))?);
    }
    for (k, v) in parse_pairs(object_body(&compact, "phases")?)? {
        rep.phases
            .insert(k, v.parse().map_err(|e| format!("RUN_METRICS.json: phases: {e}"))?);
    }
    rep.class_dispatches = parse_u64_array(array_body(&compact, "class_dispatches")?)?;
    let thr = object_body(&compact, "throughput")?;
    for (k, v) in parse_pairs(thr)? {
        match k.as_str() {
            "jobs" => rep.jobs = v.parse().map_err(|e| format!("jobs: {e}"))?,
            "wall_seconds" => {
                rep.wall_seconds = v.parse().map_err(|e| format!("wall_seconds: {e}"))?
            }
            "jobs_per_sec" => {
                rep.jobs_per_sec = v.parse().map_err(|e| format!("jobs_per_sec: {e}"))?
            }
            _ => {}
        }
    }
    rep.peak_rss_bytes = scalar(&compact, "peak_rss_bytes")?
        .parse()
        .map_err(|e| format!("peak_rss_bytes: {e}"))?;
    if let Ok(body) = array_body(&compact, "sojourn_seconds") {
        rep.sojourn_hist = parse_u64_array(body)?;
    }
    if let Ok(body) = array_body(&compact, "waiting_seconds") {
        rep.waiting_hist = parse_u64_array(body)?;
    }
    // Schema-v2 trailing keys — all optional, so v1 files still parse.
    if let Ok(body) = object_body(&compact, "percentiles") {
        for (k, v) in parse_pairs(body)? {
            rep.percentiles
                .insert(k, v.parse().map_err(|e| format!("percentiles: {e}"))?);
        }
    }
    if let Ok(body) = object_body(&compact, "span_seconds") {
        for (k, v) in parse_pairs(body)? {
            rep.span_seconds
                .insert(k, v.parse().map_err(|e| format!("span_seconds: {e}"))?);
        }
    }
    if let Ok(body) = object_body(&compact, "span_counts") {
        for (k, v) in parse_pairs(body)? {
            rep.span_counts
                .insert(k, v.parse().map_err(|e| format!("span_counts: {e}"))?);
        }
    }
    if let Ok(body) = object_body(&compact, "dropped_samples") {
        for (k, v) in parse_pairs(body)? {
            rep.dropped_samples
                .insert(k, v.parse().map_err(|e| format!("dropped_samples: {e}"))?);
        }
    }
    if let Ok(body) = array_body(&compact, "sweep_points") {
        let body = body.trim_start_matches('{').trim_end_matches('}');
        if !body.is_empty() {
            for obj in body.split("},{") {
                let mut p = SweepPointRecord::default();
                for (k, v) in parse_pairs(obj)? {
                    let fv = || -> Result<f64, String> {
                        v.parse().map_err(|e| format!("sweep_points.{k}: {e}"))
                    };
                    let uv = || -> Result<u64, String> {
                        v.parse().map_err(|e| format!("sweep_points.{k}: {e}"))
                    };
                    match k.as_str() {
                        "label" => p.label = fv()?,
                        "jobs" => p.jobs = uv()?,
                        "jobs_per_sec" => p.jobs_per_sec = fv()?,
                        "events" => p.events = uv()?,
                        "tasks_dispatched" => p.tasks_dispatched = uv()?,
                        "sampling_seconds" => p.sampling_seconds = fv()?,
                        "dispatch_seconds" => p.dispatch_seconds = fv()?,
                        _ => {}
                    }
                }
                rep.sweep_points.push(p);
            }
        }
    }
    Ok(rep)
}

/// One aligned row of a `profile --diff` comparison.
#[derive(Clone, Debug, PartialEq)]
pub struct DiffRow {
    /// Row name: a counter/phase/percentile/throughput key, or
    /// `span:<path>` (prefixed — the `dispatch` *span* is not the
    /// `dispatch` *phase*).
    pub name: String,
    /// Value in the baseline report.
    pub base: f64,
    /// Value in the new report.
    pub new: f64,
}

impl DiffRow {
    /// `new / base`, or `None` when the baseline value is zero.
    pub fn ratio(&self) -> Option<f64> {
        if self.base != 0.0 {
            Some(self.new / self.base)
        } else {
            None
        }
    }
}

fn union_keys<'a, V>(
    a: &'a BTreeMap<String, V>,
    b: &'a BTreeMap<String, V>,
) -> Vec<&'a String> {
    let mut keys: Vec<&String> = a.keys().chain(b.keys()).collect();
    keys.sort();
    keys.dedup();
    keys
}

/// Align two parsed reports into named rows over the union of their
/// counters, phases, percentiles, throughput figures, and spans. A key
/// missing on one side contributes 0 to that side.
pub fn diff_rows(base: &ParsedReport, new: &ParsedReport) -> Vec<DiffRow> {
    let mut rows = Vec::new();
    for k in union_keys(&base.counters, &new.counters) {
        rows.push(DiffRow {
            name: k.clone(),
            base: base.counters.get(k).copied().unwrap_or(0) as f64,
            new: new.counters.get(k).copied().unwrap_or(0) as f64,
        });
    }
    for k in union_keys(&base.phases, &new.phases) {
        rows.push(DiffRow {
            name: k.clone(),
            base: base.phases.get(k).copied().unwrap_or(0.0),
            new: new.phases.get(k).copied().unwrap_or(0.0),
        });
    }
    for k in union_keys(&base.percentiles, &new.percentiles) {
        rows.push(DiffRow {
            name: k.clone(),
            base: base.percentiles.get(k).copied().unwrap_or(0.0),
            new: new.percentiles.get(k).copied().unwrap_or(0.0),
        });
    }
    rows.push(DiffRow {
        name: "jobs_per_sec".into(),
        base: base.jobs_per_sec,
        new: new.jobs_per_sec,
    });
    rows.push(DiffRow {
        name: "wall_seconds".into(),
        base: base.wall_seconds,
        new: new.wall_seconds,
    });
    for k in union_keys(&base.span_seconds, &new.span_seconds) {
        rows.push(DiffRow {
            name: format!("span:{k}"),
            base: base.span_seconds.get(k).copied().unwrap_or(0.0),
            new: new.span_seconds.get(k).copied().unwrap_or(0.0),
        });
    }
    rows
}

/// Parse a `--gate` spec: `name:max_ratio[,name:max_ratio...]`. The
/// ratio is split off the **last** `:`, so row names containing colons
/// (`span:dispatch/policy`) gate naturally.
pub fn parse_gates(spec: &str) -> Result<Vec<(String, f64)>, String> {
    spec.split(',')
        .filter(|e| !e.trim().is_empty())
        .map(|entry| {
            let (name, ratio) = entry
                .rsplit_once(':')
                .ok_or_else(|| format!("bad gate {entry:?} (want name:max_ratio)"))?;
            let r: f64 =
                ratio.trim().parse().map_err(|e| format!("gate {entry:?}: {e}"))?;
            if !(r > 0.0) {
                return Err(format!("gate {entry:?}: max_ratio must be positive"));
            }
            Ok((name.trim().to_string(), r))
        })
        .collect()
}

/// Evaluate gates against diff rows: a gate fails when the named row's
/// `new` exceeds `max_ratio ×` its baseline (a zero baseline with a
/// nonzero new value is an infinite ratio and always fails). Returns
/// one human-readable line per failure; empty means all gates passed.
pub fn check_gates(rows: &[DiffRow], gates: &[(String, f64)]) -> Vec<String> {
    let mut failures = Vec::new();
    for (name, max_ratio) in gates {
        let Some(row) = rows.iter().find(|r| &r.name == name) else {
            failures.push(format!("gate {name}: no such row in either report"));
            continue;
        };
        match row.ratio() {
            Some(r) if r > *max_ratio => failures.push(format!(
                "gate {name}: {} vs baseline {} (ratio {:.4} > max {})",
                row.new, row.base, r, max_ratio
            )),
            None if row.new > 0.0 => failures.push(format!(
                "gate {name}: {} vs baseline 0 (ratio inf > max {})",
                row.new, max_ratio
            )),
            _ => {}
        }
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Tallies;

    #[test]
    fn render_parse_round_trip() {
        let mut m = Metrics::enabled();
        let mut t = Tallies {
            dispatched: 4000,
            jobs: 100,
            retries: 7,
            heap_pushes: 4100,
            heap_pops: 4100,
            ..Tallies::default()
        };
        t.class_dispatch(0);
        t.class_dispatch(1);
        m.absorb_tallies(&t);
        m.observe_sojourn(0.25);
        m.observe_waiting(0.125);
        m.phase_add_secs(Phase::Setup, 0.5);
        m.phase_add_secs(Phase::Dispatch, 2.0);
        let text = render("simulate", &m, 100, 2.5);
        let rep = parse(&text).unwrap();
        assert_eq!(rep.schema_version, SCHEMA_VERSION);
        assert_eq!(rep.source, "simulate");
        assert_eq!(rep.counters["tasks_dispatched"], 4000);
        assert_eq!(rep.counters["retries"], 7);
        assert_eq!(rep.counters["jobs_completed"], 100);
        // Every counter key is present, even at zero (CI asserts this).
        for c in Counter::ALL {
            assert!(rep.counters.contains_key(c.key()), "{}", c.key());
        }
        for p in Phase::ALL {
            assert!(rep.phases.contains_key(p.key()), "{}", p.key());
        }
        assert_eq!(rep.class_dispatches, vec![1, 1]);
        assert_eq!(rep.phases["setup"], 0.5);
        assert_eq!(rep.phases["dispatch"], 2.0);
        assert_eq!(rep.jobs, 100);
        assert_eq!(rep.wall_seconds, 2.5);
        assert_eq!(rep.jobs_per_sec, 40.0);
        assert_eq!(rep.sojourn_hist.len(), HIST_BUCKETS);
        assert_eq!(rep.sojourn_hist.iter().sum::<u64>(), 1);
        assert_eq!(rep.waiting_hist.iter().sum::<u64>(), 1);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{}").is_err());
        assert!(parse("not json").is_err());
    }

    /// Schema-v2 trailing sections round-trip: percentiles, spans,
    /// dropped-sample tallies, and sweep points.
    #[test]
    fn v2_sections_round_trip() {
        let mut m = Metrics::enabled();
        for _ in 0..100 {
            m.observe_sojourn(0.25);
        }
        m.observe_sojourn(f64::NAN);
        m.observe_waiting(0.125);
        m.spans.add(Span::EventLoop, 2.0);
        m.spans.add(Span::Dispatch, 0.5);
        m.spans.add(Span::PolicyDispatch, 0.25);
        let points = vec![
            SweepPointRecord {
                label: 2.0,
                jobs: 500,
                jobs_per_sec: 1000.0,
                events: 1500,
                tasks_dispatched: 1000,
                sampling_seconds: 0.125,
                dispatch_seconds: 0.25,
            },
            SweepPointRecord { label: 4.0, jobs: 500, ..SweepPointRecord::default() },
        ];
        let text = render_with_points("sweep", &m, 1000, 4.0, &points);
        let rep = parse(&text).unwrap();
        assert_eq!(rep.schema_version, 2);
        for prefix in ["sojourn", "waiting"] {
            for (_, suffix) in PERCENTILES {
                let key = format!("{prefix}_{suffix}");
                assert!(rep.percentiles.contains_key(&key), "{key}");
            }
        }
        let p50 = rep.percentiles["sojourn_p50"];
        assert_eq!(p50, m.sojourn_hist.percentile(0.5).unwrap());
        assert!(p50 > 0.0);
        for sp in Span::ALL {
            assert_eq!(rep.span_seconds[sp.key()], m.spans.seconds(sp), "{}", sp.key());
            assert_eq!(rep.span_counts[sp.key()], m.spans.count(sp), "{}", sp.key());
        }
        assert_eq!(rep.dropped_samples["sojourn_seconds"], 1);
        assert_eq!(rep.dropped_samples["waiting_seconds"], 0);
        assert_eq!(rep.sweep_points, points);
    }

    /// A v1-shaped document (no v2 keys) still parses, with the v2
    /// fields left empty — old reports stay consumable.
    #[test]
    fn v1_document_still_parses() {
        let v1 = r#"{
  "schema_version": 1,
  "source": "simulate",
  "counters": { "tasks_dispatched": 40, "jobs_completed": 10 },
  "class_dispatches": [],
  "phases": { "setup": 0.5, "dispatch": 2.0 },
  "throughput": { "jobs": 10, "wall_seconds": 2.5, "jobs_per_sec": 4.0 },
  "peak_rss_bytes": 0
}"#;
        let rep = parse(v1).unwrap();
        assert_eq!(rep.schema_version, 1);
        assert_eq!(rep.counters["tasks_dispatched"], 40);
        assert_eq!(rep.phases["dispatch"], 2.0);
        assert!(rep.percentiles.is_empty());
        assert!(rep.span_seconds.is_empty());
        assert!(rep.span_counts.is_empty());
        assert!(rep.dropped_samples.is_empty());
        assert!(rep.sweep_points.is_empty());
        assert!(rep.sojourn_hist.is_empty());
    }

    /// v2 keys trail every v1 key, so v1 readers that scan forward for
    /// their keys never see them first (the BENCH v1→v2 precedent).
    #[test]
    fn v2_keys_trail_v1_keys() {
        let m = Metrics::enabled();
        let text = render("simulate", &m, 10, 1.0);
        let last_v1 = text.find("\"histograms\"").unwrap();
        for key in ["\"percentiles\"", "\"span_seconds\"", "\"span_counts\"", "\"dropped_samples\""]
        {
            assert!(text.find(key).unwrap() > last_v1, "{key} before histograms");
        }
    }

    #[test]
    fn diff_rows_and_gates() {
        let mut base = Metrics::enabled();
        base.absorb_tallies(&Tallies { dispatched: 100, ..Tallies::default() });
        base.phase_add_secs(Phase::Dispatch, 1.0);
        base.spans.add(Span::EventLoop, 1.0);
        let a = parse(&render("profile", &base, 100, 1.0)).unwrap();
        // Degrade: 3x the dispatch phase, same counters.
        let mut worse = Metrics::enabled();
        worse.absorb_tallies(&Tallies { dispatched: 100, ..Tallies::default() });
        worse.phase_add_secs(Phase::Dispatch, 3.0);
        worse.spans.add(Span::EventLoop, 3.0);
        let b = parse(&render("profile", &worse, 100, 3.0)).unwrap();
        let rows = diff_rows(&a, &b);
        let dispatch = rows.iter().find(|r| r.name == "dispatch").unwrap();
        assert_eq!(dispatch.ratio(), Some(3.0));
        let span = rows.iter().find(|r| r.name == "span:event_loop").unwrap();
        assert_eq!(span.ratio(), Some(3.0));
        let counter = rows.iter().find(|r| r.name == "tasks_dispatched").unwrap();
        assert_eq!(counter.ratio(), Some(1.0));

        let gates = parse_gates("dispatch:1.5,tasks_dispatched:1.01").unwrap();
        let failures = check_gates(&rows, &gates);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("dispatch"), "{failures:?}");
        // Same-report diff passes the same gates.
        assert!(check_gates(&diff_rows(&a, &a), &gates).is_empty());
        // Span rows gate through the last-colon split.
        let g = parse_gates("span:event_loop:1.5").unwrap();
        assert_eq!(g[0].0, "span:event_loop");
        assert_eq!(check_gates(&rows, &g).len(), 1);
        // Unknown rows and malformed specs are errors, not silence.
        assert!(!check_gates(&rows, &[("nope".into(), 2.0)]).is_empty());
        assert!(parse_gates("dispatch").is_err());
        assert!(parse_gates("dispatch:-1").is_err());
    }

    #[test]
    fn peak_rss_probe_is_safe() {
        // On Linux this is positive; elsewhere it degrades to 0.
        let _ = peak_rss_bytes();
    }
}
