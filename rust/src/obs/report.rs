//! `RUN_METRICS.json` — the schema-v1 run report shared by every
//! command surface (`simulate`, `approx` sweeps, `bench`, `trace`,
//! `emulate`, `profile`): counters, phase wall-times, throughput, and a
//! peak-RSS estimate. Hand-rolled writer *and* parser (the offline
//! registry has no serde); the parser exists so reports can be
//! round-trip-tested and consumed by the CI smoke job.

use super::{Counter, FixedHistogram, Metrics, Phase, HIST_BUCKETS};
use std::collections::BTreeMap;

/// Report schema version.
pub const SCHEMA_VERSION: u64 = 1;

/// Peak resident set size of this process in bytes, from
/// `/proc/self/status` (`VmHWM`). Returns 0 where the file or field is
/// unavailable (non-Linux) — the report field is an estimate, not a
/// guarantee.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

fn render_hist(h: &FixedHistogram) -> String {
    let counts: Vec<String> = h.counts().iter().map(|c| c.to_string()).collect();
    format!("[{}]", counts.join(", "))
}

/// Serialize a registry into the schema-v1 report.
pub fn render(source: &str, m: &Metrics, jobs: u64, wall_seconds: f64) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema_version\": {SCHEMA_VERSION},\n"));
    s.push_str(&format!("  \"source\": \"{source}\",\n"));
    s.push_str("  \"counters\": {\n");
    for (i, c) in Counter::ALL.iter().enumerate() {
        let sep = if i + 1 < Counter::ALL.len() { "," } else { "" };
        s.push_str(&format!("    \"{}\": {}{sep}\n", c.key(), m.counter(*c)));
    }
    s.push_str("  },\n");
    let classes: Vec<String> = m.class_dispatches.iter().map(|c| c.to_string()).collect();
    s.push_str(&format!("  \"class_dispatches\": [{}],\n", classes.join(", ")));
    s.push_str("  \"phases\": {\n");
    for (i, p) in Phase::ALL.iter().enumerate() {
        let sep = if i + 1 < Phase::ALL.len() { "," } else { "" };
        s.push_str(&format!("    \"{}\": {}{sep}\n", p.key(), m.phase_seconds(*p)));
    }
    s.push_str("  },\n");
    let rate = if wall_seconds > 0.0 { jobs as f64 / wall_seconds } else { 0.0 };
    s.push_str("  \"throughput\": {\n");
    s.push_str(&format!("    \"jobs\": {jobs},\n"));
    s.push_str(&format!("    \"wall_seconds\": {wall_seconds},\n"));
    s.push_str(&format!("    \"jobs_per_sec\": {rate}\n"));
    s.push_str("  },\n");
    s.push_str(&format!("  \"peak_rss_bytes\": {},\n", peak_rss_bytes()));
    s.push_str("  \"histograms\": {\n");
    s.push_str(&format!(
        "    \"sojourn_seconds\": {},\n",
        render_hist(&m.sojourn_hist)
    ));
    s.push_str(&format!(
        "    \"waiting_seconds\": {}\n",
        render_hist(&m.waiting_hist)
    ));
    s.push_str("  }\n}\n");
    s
}

/// Write the report to `path`.
pub fn write_file(
    path: &str,
    source: &str,
    m: &Metrics,
    jobs: u64,
    wall_seconds: f64,
) -> Result<(), String> {
    std::fs::write(path, render(source, m, jobs, wall_seconds))
        .map_err(|e| format!("{path}: {e}"))
}

/// A parsed `RUN_METRICS.json` document.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParsedReport {
    /// `schema_version`.
    pub schema_version: u64,
    /// Producing command (`simulate`, `sweep`, `bench`, ...).
    pub source: String,
    /// Counter key → value.
    pub counters: BTreeMap<String, u64>,
    /// Dispatches per policy class.
    pub class_dispatches: Vec<u64>,
    /// Phase key → wall seconds.
    pub phases: BTreeMap<String, f64>,
    /// Measured jobs.
    pub jobs: u64,
    /// Wall seconds.
    pub wall_seconds: f64,
    /// jobs / wall_seconds.
    pub jobs_per_sec: f64,
    /// Peak RSS estimate.
    pub peak_rss_bytes: u64,
    /// Sojourn histogram bucket counts (empty if absent).
    pub sojourn_hist: Vec<u64>,
    /// Waiting histogram bucket counts (empty if absent).
    pub waiting_hist: Vec<u64>,
}

/// Slice out the object body following `"key": {`, assuming no nested
/// braces inside (true for every object this schema emits).
fn object_body<'a>(compact: &'a str, key: &str) -> Result<&'a str, String> {
    let needle = format!("\"{key}\":{{");
    let at = compact
        .find(&needle)
        .ok_or_else(|| format!("RUN_METRICS.json: missing \"{key}\" object"))?;
    let start = at + needle.len();
    let end = compact[start..]
        .find('}')
        .ok_or_else(|| format!("RUN_METRICS.json: unterminated \"{key}\" object"))?;
    Ok(&compact[start..start + end])
}

/// Slice out the array body following `"key": [`.
fn array_body<'a>(compact: &'a str, key: &str) -> Result<&'a str, String> {
    let needle = format!("\"{key}\":[");
    let at = compact
        .find(&needle)
        .ok_or_else(|| format!("RUN_METRICS.json: missing \"{key}\" array"))?;
    let start = at + needle.len();
    let end = compact[start..]
        .find(']')
        .ok_or_else(|| format!("RUN_METRICS.json: unterminated \"{key}\" array"))?;
    Ok(&compact[start..start + end])
}

fn parse_u64_array(body: &str) -> Result<Vec<u64>, String> {
    if body.trim().is_empty() {
        return Ok(Vec::new());
    }
    body.split(',')
        .map(|t| t.trim().parse::<u64>().map_err(|e| format!("RUN_METRICS.json: {e}")))
        .collect()
}

/// `"k":v` pairs of a flat object body.
fn parse_pairs(body: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    for entry in body.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (k, v) = entry
            .split_once(':')
            .ok_or_else(|| format!("RUN_METRICS.json: bad entry {entry:?}"))?;
        out.push((k.trim().trim_matches('"').to_string(), v.trim().to_string()));
    }
    Ok(out)
}

fn scalar(compact: &str, key: &str) -> Result<String, String> {
    let needle = format!("\"{key}\":");
    let at = compact
        .find(&needle)
        .ok_or_else(|| format!("RUN_METRICS.json: missing \"{key}\""))?;
    let rest = &compact[at + needle.len()..];
    let end = rest
        .find(|c| matches!(c, ',' | '}' | ']'))
        .unwrap_or(rest.len());
    Ok(rest[..end].trim_matches('"').to_string())
}

/// Parse a schema-v1 report. Tolerant of whitespace/pretty-printing;
/// unknown top-level keys are ignored.
pub fn parse(text: &str) -> Result<ParsedReport, String> {
    // Keys and numeric values in this schema contain no whitespace, so a
    // whitespace strip yields a canonical compact form to scan.
    let compact: String = text.chars().filter(|c| !c.is_whitespace()).collect();
    let mut rep = ParsedReport {
        schema_version: scalar(&compact, "schema_version")?
            .parse()
            .map_err(|e| format!("RUN_METRICS.json: schema_version: {e}"))?,
        source: scalar(&compact, "source")?,
        ..ParsedReport::default()
    };
    for (k, v) in parse_pairs(object_body(&compact, "counters")?)? {
        rep.counters
            .insert(k, v.parse().map_err(|e| format!("RUN_METRICS.json: counters: {e}"))?);
    }
    for (k, v) in parse_pairs(object_body(&compact, "phases")?)? {
        rep.phases
            .insert(k, v.parse().map_err(|e| format!("RUN_METRICS.json: phases: {e}"))?);
    }
    rep.class_dispatches = parse_u64_array(array_body(&compact, "class_dispatches")?)?;
    let thr = object_body(&compact, "throughput")?;
    for (k, v) in parse_pairs(thr)? {
        match k.as_str() {
            "jobs" => rep.jobs = v.parse().map_err(|e| format!("jobs: {e}"))?,
            "wall_seconds" => {
                rep.wall_seconds = v.parse().map_err(|e| format!("wall_seconds: {e}"))?
            }
            "jobs_per_sec" => {
                rep.jobs_per_sec = v.parse().map_err(|e| format!("jobs_per_sec: {e}"))?
            }
            _ => {}
        }
    }
    rep.peak_rss_bytes = scalar(&compact, "peak_rss_bytes")?
        .parse()
        .map_err(|e| format!("peak_rss_bytes: {e}"))?;
    if let Ok(body) = array_body(&compact, "sojourn_seconds") {
        rep.sojourn_hist = parse_u64_array(body)?;
    }
    if let Ok(body) = array_body(&compact, "waiting_seconds") {
        rep.waiting_hist = parse_u64_array(body)?;
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Tallies;

    #[test]
    fn render_parse_round_trip() {
        let mut m = Metrics::enabled();
        let mut t = Tallies {
            dispatched: 4000,
            jobs: 100,
            retries: 7,
            heap_pushes: 4100,
            heap_pops: 4100,
            ..Tallies::default()
        };
        t.class_dispatch(0);
        t.class_dispatch(1);
        m.absorb_tallies(&t);
        m.observe_sojourn(0.25);
        m.observe_waiting(0.125);
        m.phase_add_secs(Phase::Setup, 0.5);
        m.phase_add_secs(Phase::Dispatch, 2.0);
        let text = render("simulate", &m, 100, 2.5);
        let rep = parse(&text).unwrap();
        assert_eq!(rep.schema_version, SCHEMA_VERSION);
        assert_eq!(rep.source, "simulate");
        assert_eq!(rep.counters["tasks_dispatched"], 4000);
        assert_eq!(rep.counters["retries"], 7);
        assert_eq!(rep.counters["jobs_completed"], 100);
        // Every counter key is present, even at zero (CI asserts this).
        for c in Counter::ALL {
            assert!(rep.counters.contains_key(c.key()), "{}", c.key());
        }
        for p in Phase::ALL {
            assert!(rep.phases.contains_key(p.key()), "{}", p.key());
        }
        assert_eq!(rep.class_dispatches, vec![1, 1]);
        assert_eq!(rep.phases["setup"], 0.5);
        assert_eq!(rep.phases["dispatch"], 2.0);
        assert_eq!(rep.jobs, 100);
        assert_eq!(rep.wall_seconds, 2.5);
        assert_eq!(rep.jobs_per_sec, 40.0);
        assert_eq!(rep.sojourn_hist.len(), HIST_BUCKETS);
        assert_eq!(rep.sojourn_hist.iter().sum::<u64>(), 1);
        assert_eq!(rep.waiting_hist.iter().sum::<u64>(), 1);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{}").is_err());
        assert!(parse("not json").is_err());
    }

    #[test]
    fn peak_rss_probe_is_safe() {
        // On Linux this is positive; elsewhere it degrades to 0.
        let _ = peak_rss_bytes();
    }
}
