//! `tiny-tasks` CLI — the launcher for simulations, emulation, bound
//! evaluation, calibration, and figure regeneration.

use tiny_tasks::cli::Args;
use tiny_tasks::coordinator;

fn main() {
    tiny_tasks::util::logging::init();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", tiny_tasks::cli::USAGE);
            std::process::exit(2);
        }
    };
    match coordinator::dispatch(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
