//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the coordinator hot path.
//!
//! Layer boundaries (DESIGN.md §3): Python runs once at build time
//! (`make artifacts`); this module makes the Rust binary self-contained
//! afterwards. Interchange is HLO **text** — the image's xla_extension
//! 0.5.1 rejects jax ≥ 0.5 serialized protos (64-bit instruction ids),
//! while the text parser reassigns ids.

mod artifact;
mod engine;

pub use artifact::{Artifact, ArtifactSet, BATCH};
pub use engine::{BoundQuery, BoundRow, BoundsEngine, EngineKind, ErlangQuery, ErlangRow};

use anyhow::Result;
use std::cell::OnceCell;

std::thread_local! {
    // xla's PjRtClient is an Rc-based handle (not Send/Sync): the client —
    // and every executable compiled from it — lives on the thread that
    // created it. The coordinator therefore evaluates artifacts on its
    // main thread and parallelizes only the simulations (DESIGN.md §7).
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// Run `f` with this thread's PJRT CPU client (created on first use).
pub fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T>) -> Result<T> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let client = xla::PjRtClient::cpu()?;
            let _ = cell.set(client);
        }
        f(cell.get().expect("client initialized"))
    })
}

/// Default artifacts directory (`TT_ARTIFACTS` overrides; used by tests).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("TT_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
