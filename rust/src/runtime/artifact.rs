//! Loading and executing individual HLO-text artifacts.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Batch size baked into the artifacts by `python/compile/model.py`.
pub const BATCH: usize = 128;

/// One compiled artifact: a PJRT executable taking a single
/// `f64[BATCH, cols]` operand and returning a 1-tuple of
/// `f64[BATCH, outs]`.
pub struct Artifact {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    cols: usize,
    outs: usize,
}

impl Artifact {
    /// Load `<dir>/<name>.hlo.txt` and compile it on the shared client.
    pub fn load(dir: &Path, name: &str, cols: usize, outs: usize) -> Result<Self> {
        let path = dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = super::with_client(|client| Ok(client.compile(&comp)?))
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Self { name: name.to_string(), exe, cols, outs })
    }

    /// Artifact name (e.g. `"bounds"`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Evaluate one padded batch: `flat` must hold exactly
    /// `BATCH * cols` f64s (row-major). Returns `BATCH * outs` f64s.
    pub fn run_batch(&self, flat: &[f64]) -> Result<Vec<f64>> {
        if flat.len() != BATCH * self.cols {
            bail!(
                "artifact {}: expected {} values, got {}",
                self.name,
                BATCH * self.cols,
                flat.len()
            );
        }
        let input = xla::Literal::vec1(flat).reshape(&[BATCH as i64, self.cols as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        let out = result.to_vec::<f64>()?;
        if out.len() != BATCH * self.outs {
            bail!(
                "artifact {}: expected {} outputs, got {}",
                self.name,
                BATCH * self.outs,
                out.len()
            );
        }
        Ok(out)
    }

    /// Evaluate an arbitrary number of rows, padding the final batch by
    /// repeating `pad_row` (must be a benign, feasible configuration).
    pub fn run_rows(&self, rows: &[Vec<f64>], pad_row: &[f64]) -> Result<Vec<Vec<f64>>> {
        assert_eq!(pad_row.len(), self.cols, "pad row arity");
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(BATCH) {
            let mut flat = Vec::with_capacity(BATCH * self.cols);
            for row in chunk {
                assert_eq!(row.len(), self.cols, "row arity for {}", self.name);
                flat.extend_from_slice(row);
            }
            for _ in chunk.len()..BATCH {
                flat.extend_from_slice(pad_row);
            }
            let res = self.run_batch(&flat)?;
            for i in 0..chunk.len() {
                out.push(res[i * self.outs..(i + 1) * self.outs].to_vec());
            }
        }
        Ok(out)
    }
}

/// The full artifact set an experiment needs.
pub struct ArtifactSet {
    /// Tiny-tasks bound sweep (envelope kernel).
    pub bounds: Artifact,
    /// Big-tasks Erlang analysis.
    pub erlang_sm: Artifact,
    /// Closed-form stability sweep.
    pub stability: Artifact,
    /// Directory the artifacts were loaded from.
    pub dir: PathBuf,
}

impl ArtifactSet {
    /// Load all three artifacts from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        Ok(Self {
            bounds: Artifact::load(dir, "bounds", 7, 3)?,
            erlang_sm: Artifact::load(dir, "erlang_sm", 5, 3)?,
            stability: Artifact::load(dir, "stability", 2, 2)?,
            dir: dir.to_path_buf(),
        })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&super::default_artifacts_dir())
    }
}
