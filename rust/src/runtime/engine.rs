//! The bounds engine: one API, two backends.
//!
//! * `Artifact` — the production path: batched evaluation through the
//!   AOT-compiled JAX/Pallas HLO modules via PJRT.
//! * `Native` — the pure-Rust `analysis` module, used as fallback when
//!   artifacts are absent and as the cross-validation reference.

use super::artifact::ArtifactSet;
use crate::analysis::{self, BoundModel, BoundParams};
use crate::config::OverheadConfig;
use anyhow::Result;

/// Which backend a [`BoundsEngine`] is using.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT artifact via PJRT.
    Artifact,
    /// Pure-Rust analysis module.
    Native,
}

/// One bound query (the Fig. 8/12/13 sweep row).
#[derive(Clone, Copy, Debug)]
pub struct BoundQuery {
    /// Tasks per job.
    pub k: usize,
    /// Servers.
    pub l: usize,
    /// Arrival rate λ.
    pub lambda: f64,
    /// Task service rate μ.
    pub mu: f64,
    /// Violation probability ε.
    pub epsilon: f64,
    /// Overhead parameters (None = clean bound).
    pub overhead: Option<OverheadConfig>,
}

/// Result row: sojourn quantile bounds per model (None = infeasible).
#[derive(Clone, Copy, Debug, Default)]
pub struct BoundRow {
    /// Tiny-tasks split-merge (Lemma 1 → Th. 1).
    pub split_merge: Option<f64>,
    /// Tiny-tasks single-queue fork-join (Th. 2).
    pub fork_join: Option<f64>,
    /// Ideal partition (Eq. 10 → Th. 1).
    pub ideal: Option<f64>,
}

/// Big-tasks (Erlang) query for Fig. 12.
#[derive(Clone, Copy, Debug)]
pub struct ErlangQuery {
    /// Servers (= tasks per job).
    pub l: usize,
    /// Erlang shape κ of each big task.
    pub kappa: u32,
    /// Arrival rate λ.
    pub lambda: f64,
    /// Stage rate μ.
    pub mu: f64,
    /// Violation probability ε.
    pub epsilon: f64,
}

/// Big-tasks result row.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErlangRow {
    /// E[Δ] (Eq. 21).
    pub mean_service: f64,
    /// Max stable utilization (Eq. 23).
    pub max_utilization: f64,
    /// Sojourn ε-quantile bound (None = infeasible).
    pub sojourn: Option<f64>,
}

/// Bounds evaluation engine.
pub struct BoundsEngine {
    artifacts: Option<ArtifactSet>,
}

impl BoundsEngine {
    /// Artifact-backed engine (errors if artifacts are missing/corrupt).
    pub fn artifact() -> Result<Self> {
        Ok(Self { artifacts: Some(ArtifactSet::load_default()?) })
    }

    /// Pure-Rust engine.
    pub fn native() -> Self {
        Self { artifacts: None }
    }

    /// Artifact engine when available, otherwise native (logged).
    pub fn auto() -> Self {
        match Self::artifact() {
            Ok(e) => e,
            Err(err) => {
                eprintln!("note: falling back to native bounds engine ({err})");
                Self::native()
            }
        }
    }

    /// Which backend is active.
    pub fn kind(&self) -> EngineKind {
        if self.artifacts.is_some() {
            EngineKind::Artifact
        } else {
            EngineKind::Native
        }
    }

    /// Evaluate tiny-tasks bounds for a query sweep.
    pub fn bounds(&self, queries: &[BoundQuery]) -> Result<Vec<BoundRow>> {
        match &self.artifacts {
            Some(set) => {
                let rows: Vec<Vec<f64>> = queries
                    .iter()
                    .map(|q| {
                        let (eo, cpd) = match q.overhead {
                            Some(oh) => (oh.mean_task_overhead(), oh.pre_departure(q.k)),
                            None => (0.0, 0.0),
                        };
                        vec![
                            q.k as f64,
                            q.l as f64,
                            q.lambda,
                            q.mu,
                            eo,
                            cpd,
                            q.epsilon,
                        ]
                    })
                    .collect();
                // Benign pad row: M/M/1 at utilization 0.5.
                let pad = vec![1.0, 1.0, 0.5, 1.0, 0.0, 0.0, 0.01];
                let out = set.bounds.run_rows(&rows, &pad)?;
                Ok(out
                    .into_iter()
                    .map(|r| BoundRow {
                        split_merge: positive(r[0]),
                        fork_join: positive(r[1]),
                        ideal: positive(r[2]),
                    })
                    .collect())
            }
            None => Ok(queries.iter().map(|q| native_row(q)).collect()),
        }
    }

    /// Evaluate big-tasks Erlang analysis for a query sweep.
    pub fn erlang(&self, queries: &[ErlangQuery]) -> Result<Vec<ErlangRow>> {
        match &self.artifacts {
            Some(set) => {
                let rows: Vec<Vec<f64>> = queries
                    .iter()
                    .map(|q| {
                        vec![q.l as f64, q.kappa as f64, q.lambda, q.mu, q.epsilon]
                    })
                    .collect();
                let pad = vec![1.0, 1.0, 0.5, 1.0, 0.01];
                let out = set.erlang_sm.run_rows(&rows, &pad)?;
                Ok(out
                    .into_iter()
                    .map(|r| ErlangRow {
                        mean_service: r[0],
                        max_utilization: r[1],
                        sojourn: positive(r[2]),
                    })
                    .collect())
            }
            None => Ok(queries
                .iter()
                .map(|q| ErlangRow {
                    mean_service: analysis::erlang::mean_max_erlang(q.l, q.kappa, q.mu),
                    max_utilization: analysis::erlang::max_utilization_big_tasks(
                        q.l, q.kappa, q.mu,
                    ),
                    sojourn: analysis::sojourn_bound(
                        BoundModel::SplitMergeBigErlang { kappa: q.kappa },
                        &BoundParams {
                            l: q.l,
                            k: q.l,
                            lambda: q.lambda,
                            mu: q.mu,
                            epsilon: q.epsilon,
                            overhead: None,
                        },
                    ),
                })
                .collect()),
        }
    }

    /// Tiny-tasks split-merge stability (Eq. 20) for (k, l) pairs.
    pub fn stability(&self, pairs: &[(usize, usize)]) -> Result<Vec<f64>> {
        match &self.artifacts {
            Some(set) => {
                let rows: Vec<Vec<f64>> =
                    pairs.iter().map(|&(k, l)| vec![k as f64, l as f64]).collect();
                let pad = vec![1.0, 1.0];
                let out = set.stability.run_rows(&rows, &pad)?;
                Ok(out.into_iter().map(|r| r[0]).collect())
            }
            None => Ok(pairs
                .iter()
                .map(|&(k, l)| analysis::stability::sm_tiny_tasks(l, k))
                .collect()),
        }
    }
}

fn positive(x: f64) -> Option<f64> {
    if x >= 0.0 {
        Some(x)
    } else {
        None
    }
}

fn native_row(q: &BoundQuery) -> BoundRow {
    let p = BoundParams {
        l: q.l,
        k: q.k,
        lambda: q.lambda,
        mu: q.mu,
        epsilon: q.epsilon,
        overhead: q.overhead,
    };
    let clean = BoundParams { overhead: None, ..p };
    BoundRow {
        split_merge: analysis::sojourn_bound(BoundModel::SplitMergeTiny, &p),
        fork_join: analysis::sojourn_bound(BoundModel::ForkJoinTiny, &p),
        // Ideal ignores overhead by definition (reference curve).
        ideal: analysis::sojourn_bound(BoundModel::Ideal, &clean),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_matches_analysis() {
        let eng = BoundsEngine::native();
        assert_eq!(eng.kind(), EngineKind::Native);
        let q = BoundQuery {
            k: 400,
            l: 50,
            lambda: 0.5,
            mu: 8.0,
            epsilon: 0.01,
            overhead: None,
        };
        let rows = eng.bounds(&[q]).unwrap();
        let direct = analysis::sojourn_bound(
            BoundModel::ForkJoinTiny,
            &BoundParams {
                l: 50,
                k: 400,
                lambda: 0.5,
                mu: 8.0,
                epsilon: 0.01,
                overhead: None,
            },
        )
        .unwrap();
        assert!((rows[0].fork_join.unwrap() - direct).abs() < 1e-12);
    }

    #[test]
    fn native_stability() {
        let eng = BoundsEngine::native();
        let s = eng.stability(&[(50, 50), (500, 50)]).unwrap();
        assert!(s[0] < s[1]);
    }
}
