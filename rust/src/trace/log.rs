//! In-memory executor activity traces — the data behind Figs. 1 and 2
//! (Gantt-style diagrams of which executor ran which task when) and the
//! capture source for the persistent trace format in [`super::record`].

use crate::util::csv::Csv;

/// Why a task attempt's row did not simply succeed (schema v3). A
/// plain `u8` on the wire; the constants are the only defined values.
pub mod cause {
    /// Ordinary attempt (the only value in v1/v2 traces).
    pub const NONE: u8 = 0;
    /// The attempt failed at completion and was retried.
    pub const FAILED: u8 = 1;
    /// The worker crashed mid-attempt (fault injection).
    pub const CRASHED: u8 = 2;
    /// A speculative re-execution copy: on a loser row, the copy that
    /// was cancelled when its twin finished first; on a winner row, a
    /// backup copy whose result counted.
    pub const SPECULATION: u8 = 3;
    /// Largest defined cause value (validation bound).
    pub const MAX: u8 = SPECULATION;
}

/// One task execution on one server.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Job index.
    pub job: u32,
    /// Task index within the job.
    pub task: u32,
    /// Server (executor) id.
    pub server: u32,
    /// Service start time.
    pub start: f64,
    /// Service end time (includes task overhead).
    pub end: f64,
    /// Task-service overhead portion of `[start, end]` (wall duration on
    /// the worker; under a heterogeneous scenario this is the nominal
    /// overhead draw divided by the worker speed). The observed execution
    /// duration is `end − start − overhead`.
    pub overhead: f64,
    /// True for the replica whose result counted. Always true outside
    /// redundancy scenarios; under first-finish-wins dispatch the losing
    /// replicas record `false` (their rows measure cancelled work).
    pub winner: bool,
    /// Attempt number, 1-based (schema v3; always 1 without fault
    /// injection).
    pub attempt: u32,
    /// Failure cause tag (schema v3; see [`cause`]).
    pub cause: u8,
    /// Dispatch-policy class (schema v4): the SITA size interval or the
    /// priority class the task was routed by. Always 0 under FCFS and
    /// work stealing.
    pub class: u32,
}

/// Collected trace of task executions.
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    events: Vec<TraceEvent>,
    enabled: bool,
}

impl TraceLog {
    /// A recording trace log.
    pub fn enabled() -> Self {
        Self { events: Vec::new(), enabled: true }
    }

    /// A no-op trace log (hot paths skip recording).
    pub fn disabled() -> Self {
        Self { events: Vec::new(), enabled: false }
    }

    /// Whether events are being recorded.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event (no-op when disabled).
    #[inline]
    pub fn record(&mut self, ev: TraceEvent) {
        if self.enabled {
            self.events.push(ev);
        }
    }

    /// All recorded events.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Busy fraction per server over `[t0, t1]` — the idle-time statistic
    /// contrasted between Fig. 1 (coarse) and Fig. 2 (fine granularity).
    pub fn utilization(&self, servers: usize, t0: f64, t1: f64) -> Vec<f64> {
        assert!(t1 > t0);
        let mut busy = vec![0.0; servers];
        for ev in &self.events {
            let s = ev.start.max(t0);
            let e = ev.end.min(t1);
            if e > s {
                busy[ev.server as usize] += e - s;
            }
        }
        busy.iter().map(|b| b / (t1 - t0)).collect()
    }

    /// Export as CSV (`job,task,server,start,end,overhead`).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(vec!["job", "task", "server", "start", "end", "overhead"]);
        for ev in &self.events {
            csv.push(&[
                ev.job as f64,
                ev.task as f64,
                ev.server as f64,
                ev.start,
                ev.end,
                ev.overhead,
            ]);
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(job: u32, task: u32, server: u32, start: f64, end: f64) -> TraceEvent {
        TraceEvent {
            job,
            task,
            server,
            start,
            end,
            overhead: 0.0,
            winner: true,
            attempt: 1,
            cause: cause::NONE,
            class: 0,
        }
    }

    #[test]
    fn disabled_records_nothing() {
        let mut t = TraceLog::disabled();
        t.record(ev(0, 0, 0, 0.0, 1.0));
        assert!(t.events().is_empty());
    }

    #[test]
    fn utilization_window() {
        let mut t = TraceLog::enabled();
        t.record(ev(0, 0, 0, 0.0, 1.0));
        t.record(ev(0, 1, 1, 0.5, 2.0));
        let u = t.utilization(2, 0.0, 2.0);
        assert!((u[0] - 0.5).abs() < 1e-12);
        assert!((u[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn csv_has_all_rows() {
        let mut t = TraceLog::enabled();
        for i in 0..5 {
            t.record(ev(i, i, 0, 0.0, 1.0));
        }
        assert_eq!(t.to_csv().len(), 5);
    }
}
