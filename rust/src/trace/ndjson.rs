//! NDJSON trace codec — one flat JSON object per line, human-greppable
//! and trivially ingested by pandas/jq. The first line is the meta
//! header; `job` and `task` rows follow in canonical order.
//!
//! Round-trip exactness: floats are written with Rust's shortest
//! round-trip formatting and parsed back with `str::parse::<f64>`, which
//! recovers the identical bits; integers (`seed` may exceed 2⁵³) are
//! parsed as `u64` directly from the token text, never through `f64`.
//! The parser is hand-rolled (the offline registry has no serde) and
//! accepts exactly the flat shapes this writer emits (scalar values plus
//! flat numeric arrays for the v2 `speeds` field).
//!
//! Schema versioning: the writer emits the v1 line shapes byte-for-byte
//! when `meta.schema == 1` — pre-v2 files re-serialize identically — and
//! appends the scenario fields (`speeds`, `replicas` on the meta row;
//! `winner` on task rows) only for schema ≥ 2, the fault fields
//! (`attempt`, `cause` on task rows) only for schema ≥ 3, and the
//! policy fields (`policy` on the meta row; `class` on task rows) only
//! for schema 4, so v1, v2 *and* v3 files re-serialize byte-for-byte.

use super::record::{JobRow, TaskRow, Trace, TraceMeta, SCHEMA_V1, SCHEMA_V3, SCHEMA_V4};
use std::fmt::Write as _;

/// Serialize a trace to NDJSON text.
pub fn to_ndjson(trace: &Trace) -> String {
    let mut out = String::new();
    let m = &trace.meta;
    let v1 = m.schema == SCHEMA_V1;
    let v3 = m.schema >= SCHEMA_V3;
    let v4 = m.schema >= SCHEMA_V4;
    let _ = write!(
        out,
        "{{\"type\":\"meta\",\"schema\":{},\"source\":{},\"model\":{},\"servers\":{},\
         \"tasks_per_job\":{},\"warmup\":{},\"seed\":{},\"time_scale\":{},\
         \"interarrival\":{},\"execution\":{}",
        m.schema,
        quote(&m.source),
        quote(&m.model),
        m.servers,
        m.tasks_per_job,
        m.warmup,
        m.seed,
        fmt_f64(m.time_scale),
        quote(&m.interarrival),
        quote(&m.execution),
    );
    if !v1 {
        let _ = write!(out, ",\"replicas\":{}", m.replicas);
        let _ = write!(out, ",\"launch_overhead\":{}", fmt_f64(m.launch_overhead));
        if let Some(speeds) = &m.speeds {
            out.push_str(",\"speeds\":[");
            for (i, &s) in speeds.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&fmt_f64(s));
            }
            out.push(']');
        }
    }
    if v4 {
        let _ = write!(out, ",\"policy\":{}", quote(&m.policy));
    }
    out.push_str("}\n");
    for j in &trace.jobs {
        let _ = writeln!(
            out,
            "{{\"type\":\"job\",\"index\":{},\"tasks\":{},\"arrival\":{},\"departure\":{},\
             \"first_start\":{},\"workload\":{},\"task_overhead\":{},\
             \"pre_departure_overhead\":{},\"redundant_work\":{}}}",
            j.index,
            j.tasks,
            fmt_f64(j.arrival),
            fmt_f64(j.departure),
            fmt_f64(j.first_start),
            fmt_f64(j.workload),
            fmt_f64(j.task_overhead),
            fmt_f64(j.pre_departure_overhead),
            fmt_f64(j.redundant_work),
        );
    }
    for t in &trace.tasks {
        let _ = write!(
            out,
            "{{\"type\":\"task\",\"job\":{},\"task\":{},\"server\":{},\"start\":{},\
             \"end\":{},\"overhead\":{}",
            t.job,
            t.task,
            t.server,
            fmt_f64(t.start),
            fmt_f64(t.end),
            fmt_f64(t.overhead),
        );
        if !v1 {
            let _ = write!(out, ",\"winner\":{}", t.winner);
        }
        if v3 {
            let _ = write!(out, ",\"attempt\":{},\"cause\":{}", t.attempt, t.cause);
        }
        if v4 {
            let _ = write!(out, ",\"class\":{}", t.class);
        }
        out.push_str("}\n");
    }
    out
}

/// Parse a trace from NDJSON text.
pub fn from_ndjson(text: &str) -> Result<Trace, String> {
    let mut meta: Option<TraceMeta> = None;
    let mut jobs: Vec<JobRow> = Vec::new();
    let mut tasks: Vec<TaskRow> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let obj = parse_flat_object(line)
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let kind = obj.get_str("type")?;
        match kind.as_str() {
            "meta" => {
                if meta.is_some() {
                    return Err(format!("line {}: duplicate meta row", lineno + 1));
                }
                meta = Some(TraceMeta {
                    schema: obj.get_u64("schema")? as u32,
                    source: obj.get_str("source")?,
                    model: obj.get_str("model")?,
                    servers: obj.get_u64("servers")? as u32,
                    tasks_per_job: obj.get_u64("tasks_per_job")? as u32,
                    warmup: obj.get_u64("warmup")? as u32,
                    seed: obj.get_u64("seed")?,
                    time_scale: obj.get_f64("time_scale")?,
                    interarrival: obj.get_str("interarrival")?,
                    execution: obj.get_str("execution")?,
                    speeds: obj.get_f64_array_opt("speeds")?,
                    replicas: obj.get_u64_or("replicas", 1)? as u32,
                    launch_overhead: obj.get_f64_or("launch_overhead", 0.0)?,
                    policy: obj.get_str_or("policy", "")?,
                });
            }
            "job" => jobs.push(JobRow {
                index: obj.get_u64("index")? as u32,
                tasks: obj.get_u64("tasks")? as u32,
                arrival: obj.get_f64("arrival")?,
                departure: obj.get_f64("departure")?,
                first_start: obj.get_f64("first_start")?,
                workload: obj.get_f64("workload")?,
                task_overhead: obj.get_f64("task_overhead")?,
                pre_departure_overhead: obj.get_f64("pre_departure_overhead")?,
                redundant_work: obj.get_f64("redundant_work")?,
            }),
            "task" => tasks.push(TaskRow {
                job: obj.get_u64("job")? as u32,
                task: obj.get_u64("task")? as u32,
                server: obj.get_u64("server")? as u32,
                start: obj.get_f64("start")?,
                end: obj.get_f64("end")?,
                overhead: obj.get_f64("overhead")?,
                winner: obj.get_bool_or("winner", true)?,
                attempt: obj.get_u64_or("attempt", 1)? as u32,
                cause: obj.get_u64_or("cause", 0)? as u8,
                class: obj.get_u64_or("class", 0)? as u32,
            }),
            other => return Err(format!("line {}: unknown row type {other:?}", lineno + 1)),
        }
    }
    let meta = meta.ok_or("trace has no meta row")?;
    Ok(Trace { meta, jobs, tasks })
}

/// Shortest round-trip float formatting ("inf"/"NaN" parse back too).
fn fmt_f64(v: f64) -> String {
    v.to_string()
}

/// JSON string quoting (only `"` and `\` need escaping in our payloads).
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A parsed flat JSON object: raw number tokens, unescaped strings, and
/// flat arrays of raw number tokens.
struct FlatObject {
    fields: Vec<(String, FlatValue)>,
}

enum FlatValue {
    /// Unparsed numeric/boolean token text (exactness: parse as the
    /// target type).
    Raw(String),
    Str(String),
    /// Flat array of unparsed numeric tokens (the v2 `speeds` field).
    Arr(Vec<String>),
}

impl FlatObject {
    fn get(&self, key: &str) -> Result<&FlatValue, String> {
        self.fields
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
            .ok_or_else(|| format!("missing field {key:?}"))
    }

    fn get_opt(&self, key: &str) -> Option<&FlatValue> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    fn get_str(&self, key: &str) -> Result<String, String> {
        match self.get(key)? {
            FlatValue::Str(s) => Ok(s.clone()),
            _ => Err(format!("field {key:?} is not a string")),
        }
    }

    fn get_f64(&self, key: &str) -> Result<f64, String> {
        match self.get(key)? {
            FlatValue::Raw(t) => t
                .parse::<f64>()
                .map_err(|_| format!("field {key:?}: bad number {t:?}")),
            _ => Err(format!("field {key:?} is not a number")),
        }
    }

    fn get_u64(&self, key: &str) -> Result<u64, String> {
        match self.get(key)? {
            FlatValue::Raw(t) => t
                .parse::<u64>()
                .map_err(|_| format!("field {key:?}: bad integer {t:?}")),
            _ => Err(format!("field {key:?} is not a number")),
        }
    }

    /// Optional integer with a default (absent in v1 rows).
    fn get_u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get_opt(key) {
            None => Ok(default),
            Some(_) => self.get_u64(key),
        }
    }

    /// Optional float with a default (absent in v1 rows).
    fn get_f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get_opt(key) {
            None => Ok(default),
            Some(_) => self.get_f64(key),
        }
    }

    /// Optional string with a default (absent in pre-v4 meta rows).
    fn get_str_or(&self, key: &str, default: &str) -> Result<String, String> {
        match self.get_opt(key) {
            None => Ok(default.to_string()),
            Some(_) => self.get_str(key),
        }
    }

    /// Optional boolean with a default (absent in v1 rows).
    fn get_bool_or(&self, key: &str, default: bool) -> Result<bool, String> {
        match self.get_opt(key) {
            None => Ok(default),
            Some(FlatValue::Raw(t)) => match t.as_str() {
                "true" => Ok(true),
                "false" => Ok(false),
                other => Err(format!("field {key:?}: bad boolean {other:?}")),
            },
            Some(_) => Err(format!("field {key:?} is not a boolean")),
        }
    }

    /// Optional flat numeric array (absent in v1 meta rows).
    fn get_f64_array_opt(&self, key: &str) -> Result<Option<Vec<f64>>, String> {
        match self.get_opt(key) {
            None => Ok(None),
            Some(FlatValue::Arr(tokens)) => tokens
                .iter()
                .map(|t| {
                    t.parse::<f64>()
                        .map_err(|_| format!("field {key:?}: bad number {t:?}"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
            Some(_) => Err(format!("field {key:?} is not an array")),
        }
    }
}

/// Parse one `{"k":v,...}` line with string, numeric/boolean, or flat
/// numeric-array values (no nesting — exactly the shapes `to_ndjson`
/// writes).
fn parse_flat_object(line: &str) -> Result<FlatObject, String> {
    let bytes = line.as_bytes();
    let mut pos = 0usize;
    let skip_ws = |pos: &mut usize| {
        while *pos < bytes.len() && (bytes[*pos] as char).is_ascii_whitespace() {
            *pos += 1;
        }
    };
    let expect = |pos: &mut usize, c: u8| -> Result<(), String> {
        if *pos < bytes.len() && bytes[*pos] == c {
            *pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, *pos))
        }
    };
    let parse_string = |pos: &mut usize| -> Result<String, String> {
        expect(pos, b'"')?;
        let mut out = String::new();
        while *pos < bytes.len() {
            match bytes[*pos] {
                b'"' => {
                    *pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    *pos += 1;
                    let esc = *bytes.get(*pos).ok_or("dangling escape")?;
                    out.push(match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'n' => '\n',
                        b't' => '\t',
                        other => return Err(format!("unsupported escape \\{}", other as char)),
                    });
                    *pos += 1;
                }
                _ => {
                    // Multi-byte UTF-8 is copied through verbatim.
                    let s = &line[*pos..];
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    *pos += c.len_utf8();
                }
            }
        }
        Err("unterminated string".into())
    };
    let parse_raw = |pos: &mut usize| -> Result<String, String> {
        let start = *pos;
        while *pos < bytes.len() && !matches!(bytes[*pos], b',' | b'}' | b']') {
            *pos += 1;
        }
        let token = line[start..*pos].trim();
        if token.is_empty() {
            return Err(format!("empty value at byte {start}"));
        }
        Ok(token.to_string())
    };

    skip_ws(&mut pos);
    expect(&mut pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(&mut pos);
    if pos < bytes.len() && bytes[pos] == b'}' {
        return Ok(FlatObject { fields });
    }
    loop {
        skip_ws(&mut pos);
        let key = parse_string(&mut pos)?;
        skip_ws(&mut pos);
        expect(&mut pos, b':')?;
        skip_ws(&mut pos);
        let value = if pos < bytes.len() && bytes[pos] == b'"' {
            FlatValue::Str(parse_string(&mut pos)?)
        } else if pos < bytes.len() && bytes[pos] == b'[' {
            pos += 1;
            let mut tokens = Vec::new();
            skip_ws(&mut pos);
            if pos < bytes.len() && bytes[pos] == b']' {
                pos += 1;
            } else {
                loop {
                    skip_ws(&mut pos);
                    tokens.push(parse_raw(&mut pos)?);
                    skip_ws(&mut pos);
                    if pos < bytes.len() && bytes[pos] == b',' {
                        pos += 1;
                        continue;
                    }
                    expect(&mut pos, b']')?;
                    break;
                }
            }
            FlatValue::Arr(tokens)
        } else {
            FlatValue::Raw(parse_raw(&mut pos)?)
        };
        fields.push((key, value));
        skip_ws(&mut pos);
        if pos < bytes.len() && bytes[pos] == b',' {
            pos += 1;
            continue;
        }
        expect(&mut pos, b'}')?;
        break;
    }
    Ok(FlatObject { fields })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::record::{SCHEMA_V1, SCHEMA_V2, SCHEMA_V3, SCHEMA_V4};

    fn tiny_trace() -> Trace {
        Trace {
            meta: TraceMeta {
                schema: SCHEMA_V1,
                source: "sim".into(),
                model: "single-queue-fork-join".into(),
                servers: 2,
                tasks_per_job: 2,
                warmup: 0,
                seed: u64::MAX - 3, // exceeds 2^53: must not round-trip via f64
                time_scale: 1.0,
                interarrival: "exp:0.5".into(),
                execution: "exp:1.0".into(),
                speeds: None,
                replicas: 1,
                launch_overhead: 0.0,
                policy: String::new(),
            },
            jobs: vec![JobRow {
                index: 0,
                tasks: 2,
                arrival: 0.1 + 0.2, // deliberately non-representable
                departure: 2.0_f64.sqrt(),
                first_start: 0.30000000000000004,
                workload: 1e-300,
                task_overhead: 2.6e-3,
                pre_departure_overhead: 0.02,
                redundant_work: 0.0,
            }],
            tasks: vec![
                TaskRow {
                    job: 0,
                    task: 0,
                    server: 0,
                    start: 0.3,
                    end: 1.7,
                    overhead: 1e-3,
                    winner: true,
                    attempt: 1,
                    cause: 0,
                    class: 0,
                },
                TaskRow {
                    job: 0,
                    task: 1,
                    server: 1,
                    start: 0.3,
                    end: 1.4,
                    overhead: 0.0,
                    winner: true,
                    attempt: 1,
                    cause: 0,
                    class: 0,
                },
            ],
        }
    }

    fn tiny_trace_v2() -> Trace {
        let mut tr = tiny_trace();
        tr.meta.schema = SCHEMA_V2;
        tr.meta.speeds = Some(vec![1.5, 0.1 + 0.4]); // non-representable bits
        tr.meta.replicas = 2;
        tr.meta.launch_overhead = 0.1 + 0.02; // non-representable bits
        tr.tasks[1].winner = false;
        tr
    }

    fn tiny_trace_v3() -> Trace {
        let mut tr = tiny_trace();
        tr.meta.schema = SCHEMA_V3;
        tr.tasks[0].attempt = 3;
        tr.tasks[0].cause = crate::trace::cause::SPECULATION;
        tr.tasks[1].winner = false;
        tr.tasks[1].cause = crate::trace::cause::FAILED;
        tr
    }

    fn tiny_trace_v4() -> Trace {
        let mut tr = tiny_trace();
        tr.meta.schema = SCHEMA_V4;
        tr.meta.policy = "sita".into();
        tr.tasks[0].class = 1;
        tr
    }

    #[test]
    fn ndjson_round_trip_is_exact() {
        let tr = tiny_trace();
        let text = to_ndjson(&tr);
        let back = from_ndjson(&text).unwrap();
        assert_eq!(tr, back);
        assert_eq!(back.meta.seed, u64::MAX - 3);
        assert_eq!(
            tr.jobs[0].arrival.to_bits(),
            back.jobs[0].arrival.to_bits(),
            "float bits must survive the text round trip"
        );
        // Idempotent: re-serializing the parsed trace gives identical text.
        assert_eq!(text, to_ndjson(&back));
    }

    /// v1 lines carry no scenario keys (byte-compat with pre-v2 files);
    /// parsing fills the defaults.
    #[test]
    fn v1_wire_format_has_no_scenario_fields() {
        let text = to_ndjson(&tiny_trace());
        assert!(!text.contains("speeds"), "{text}");
        assert!(!text.contains("replicas"), "{text}");
        assert!(!text.contains("launch_overhead"), "{text}");
        assert!(!text.contains("winner"), "{text}");
        let back = from_ndjson(&text).unwrap();
        assert_eq!(back.meta.speeds, None);
        assert_eq!(back.meta.replicas, 1);
        assert_eq!(back.meta.launch_overhead, 0.0);
        assert!(back.tasks.iter().all(|t| t.winner));
    }

    #[test]
    fn v2_round_trip_is_exact() {
        let tr = tiny_trace_v2();
        let text = to_ndjson(&tr);
        assert!(text.contains("\"replicas\":2"), "{text}");
        assert!(text.contains("\"winner\":false"), "{text}");
        let back = from_ndjson(&text).unwrap();
        assert_eq!(tr, back);
        let a = tr.meta.speeds.as_ref().unwrap()[1];
        let b = back.meta.speeds.unwrap()[1];
        assert_eq!(a.to_bits(), b.to_bits(), "speed bits must survive");
        assert_eq!(text, to_ndjson(&tiny_trace_v2()));
    }

    /// v1/v2 task lines carry no fault keys (byte-compat with pre-v3
    /// files); parsing fills the defaults.
    #[test]
    fn pre_v3_wire_format_has_no_fault_fields() {
        for text in [to_ndjson(&tiny_trace()), to_ndjson(&tiny_trace_v2())] {
            assert!(!text.contains("attempt"), "{text}");
            assert!(!text.contains("cause"), "{text}");
            let back = from_ndjson(&text).unwrap();
            assert!(back.tasks.iter().all(|t| t.attempt == 1 && t.cause == 0));
        }
    }

    #[test]
    fn v3_round_trip_is_exact() {
        let tr = tiny_trace_v3();
        let text = to_ndjson(&tr);
        assert!(text.contains("\"attempt\":3"), "{text}");
        assert!(text.contains("\"cause\":1"), "{text}");
        let back = from_ndjson(&text).unwrap();
        assert_eq!(tr, back);
        assert_eq!(text, to_ndjson(&back));
    }

    /// v1–v3 lines carry no policy keys (byte-compat with pre-v4 files);
    /// parsing fills the defaults.
    #[test]
    fn pre_v4_wire_format_has_no_policy_fields() {
        for text in [
            to_ndjson(&tiny_trace()),
            to_ndjson(&tiny_trace_v2()),
            to_ndjson(&tiny_trace_v3()),
        ] {
            assert!(!text.contains("policy"), "{text}");
            assert!(!text.contains("class"), "{text}");
            let back = from_ndjson(&text).unwrap();
            assert!(back.meta.policy.is_empty());
            assert!(back.tasks.iter().all(|t| t.class == 0));
        }
    }

    #[test]
    fn v4_round_trip_is_exact() {
        let tr = tiny_trace_v4();
        let text = to_ndjson(&tr);
        assert!(text.contains("\"policy\":\"sita\""), "{text}");
        assert!(text.contains("\"class\":1"), "{text}");
        let back = from_ndjson(&text).unwrap();
        assert_eq!(tr, back);
        assert_eq!(text, to_ndjson(&back));
    }

    #[test]
    fn missing_meta_is_an_error() {
        assert!(from_ndjson("{\"type\":\"job\"}").is_err());
        assert!(from_ndjson("").is_err());
    }

    #[test]
    fn malformed_lines_are_errors_not_panics() {
        for bad in [
            "{",
            "{\"type\":}",
            "{\"type\":\"meta\"",
            "not json at all",
            "{\"type\":\"wat\"}",
            "{\"type\":\"meta\",\"speeds\":[}",
            "{\"type\":\"meta\",\"speeds\":[1.0,}",
        ] {
            assert!(from_ndjson(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn string_escapes_round_trip() {
        let mut tr = tiny_trace();
        tr.meta.execution = "custom \"spec\" with \\ and \n newline".into();
        let back = from_ndjson(&to_ndjson(&tr)).unwrap();
        assert_eq!(tr.meta.execution, back.meta.execution);
    }

    #[test]
    fn empty_speeds_array_parses() {
        let obj = parse_flat_object("{\"speeds\":[],\"x\":1}").unwrap();
        assert_eq!(obj.get_f64_array_opt("speeds").unwrap(), Some(vec![]));
        assert_eq!(obj.get_u64("x").unwrap(), 1);
    }
}
